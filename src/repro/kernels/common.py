"""Shared kernel utilities — one definition for the whole RME kernel suite.

The geometry contract every kernel honors
-----------------------------------------
A kernel's input is one table's row store (or one resident *chunk* of it): an
``(N, row_words)`` int32 buffer whose row stride is the **storage** schema —
the user columns back-to-back, followed by the two hidden MVCC timestamp
words ``__ts_begin`` / ``__ts_end`` (``repro.core.table``).  What a kernel
may touch is governed by word offsets into that stride:

* **Enabled words** — the projected column group of a
  :class:`~repro.core.schema.TableGeometry` (word-aligned widths/offsets,
  the configuration-port payload), plus any predicate / aggregate / group
  words a fused request names.  Only these are semantically read; the
  engine's bus-beat accounting charges exactly their Eq. (3) bursts (the
  union over all requests of a shared pass).
* **Hidden timestamp words** — addressed only via ``ts_word`` (>= 0 fuses
  the MVCC snapshot test ``begin <= ts < end`` into the row mask).  They are
  never part of a projected output, which is why cached packed blocks stay
  byte-valid across deletes/updates (the write path patches only these
  words) — and when a request enables them, they join the enabled-word union
  and are charged like any other burst.
* **Rows** are position-local: a kernel never assumes a global row index
  beyond padded-tail masking, so the same request runs unchanged over a
  whole table or any chunk of it, and per-chunk outputs concatenate (blocked)
  or add (accumulated) — the contract ``scan_multi_chunked`` builds on.

Every fused kernel also shares the conventions below: a default row-tile
height, zero-padding to a whole number of tiles, word-granule column slices,
4-byte column decoding (int32 passthrough / float32 bitcast), and the single
fused predicate (``gt`` / ``lt`` / ``none``).  These used to be copied per
kernel module (``rme_project`` / ``rme_filter`` / ``rme_aggregate``); they
live here once, and the heterogeneous one-pass kernel (``rme_scan_multi``)
composes them the same way the single-op kernels do.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.schema import TableGeometry

DEFAULT_BLOCK_ROWS = 256


def decode(x: jax.Array, dtype: str) -> jax.Array:
    """Reinterpret raw int32 storage words as the column's 4-byte dtype."""
    if dtype == "float32":
        return jax.lax.bitcast_convert_type(x, jnp.float32)
    if dtype == "int32":
        return x
    raise ValueError(f"4-byte numeric column required, got {dtype}")


def pred_mask(vals: jax.Array, op: str, k: jax.Array) -> jax.Array:
    """The fused predicate every offload kernel evaluates in-scan."""
    if op == "gt":
        return vals > k
    if op == "lt":
        return vals < k
    if op == "none":
        return jnp.ones(vals.shape, dtype=bool)
    raise ValueError(op)


def group_ids(raw: jax.Array, num_groups: int) -> jax.Array:
    """The one group-key lowering every group-by path shares.

    Raw int32 storage words map to ``[0, num_groups)`` by floored modulo —
    the sign follows the (positive) divisor, so negative keys land in-range
    instead of producing negative group ids, and int32 overflow keys wrap the
    same way on every path.  The fused Pallas kernel, the XLA fallback, the
    single-op ``groupby_sum`` kernel, the host-path planner fallback, the
    reference oracle, and the sharded ``dist_groupby`` all call this one
    definition, so sharded and fused group-bys agree bit-for-bit on every
    key, however hostile.
    """
    return jnp.remainder(raw, num_groups)


def pad_rows(words: jax.Array, block_rows: int) -> jax.Array:
    """Zero-pad the row dimension to a whole number of row tiles."""
    n = words.shape[0]
    pad = (-n) % block_rows
    if pad:
        words = jnp.pad(words, ((0, pad), (0, 0)))
    return words


def column_slices(geom: TableGeometry):
    """(src_word_offset, dst_word_offset, word_width) per enabled column."""
    return tuple(
        zip(geom.col_word_offsets, geom.out_word_offsets, geom.col_word_widths)
    )


def pred_k_bits(pred_k, pred_dtype: str) -> jax.Array:
    """The predicate constant as int32 bits (how kernels take it as operand)."""
    k_arr = jnp.asarray(
        pred_k, dtype=jnp.float32 if pred_dtype == "float32" else jnp.int32
    )
    return jax.lax.bitcast_convert_type(k_arr, jnp.int32)
