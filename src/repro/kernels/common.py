"""Shared kernel utilities — one definition for the whole RME kernel suite.

Every fused kernel walks the same row-store representation (int32 word
buffers, ``(N, row_words)``) with the same conventions: a default row-tile
height, zero-padding to a whole number of tiles, word-granule column slices
derived from a :class:`~repro.core.schema.TableGeometry`, 4-byte column
decoding (int32 passthrough / float32 bitcast), and the single fused
predicate (``gt`` / ``lt`` / ``none``).  These used to be copied per kernel
module (``rme_project`` / ``rme_filter`` / ``rme_aggregate``); they live here
once, and the heterogeneous one-pass kernel (``rme_scan_multi``) composes
them the same way the single-op kernels do.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.schema import TableGeometry

DEFAULT_BLOCK_ROWS = 256


def decode(x: jax.Array, dtype: str) -> jax.Array:
    """Reinterpret raw int32 storage words as the column's 4-byte dtype."""
    if dtype == "float32":
        return jax.lax.bitcast_convert_type(x, jnp.float32)
    if dtype == "int32":
        return x
    raise ValueError(f"4-byte numeric column required, got {dtype}")


def pred_mask(vals: jax.Array, op: str, k: jax.Array) -> jax.Array:
    """The fused predicate every offload kernel evaluates in-scan."""
    if op == "gt":
        return vals > k
    if op == "lt":
        return vals < k
    if op == "none":
        return jnp.ones(vals.shape, dtype=bool)
    raise ValueError(op)


def pad_rows(words: jax.Array, block_rows: int) -> jax.Array:
    """Zero-pad the row dimension to a whole number of row tiles."""
    n = words.shape[0]
    pad = (-n) % block_rows
    if pad:
        words = jnp.pad(words, ((0, pad), (0, 0)))
    return words


def column_slices(geom: TableGeometry):
    """(src_word_offset, dst_word_offset, word_width) per enabled column."""
    return tuple(
        zip(geom.col_word_offsets, geom.out_word_offsets, geom.col_word_widths)
    )


def pred_k_bits(pred_k, pred_dtype: str) -> jax.Array:
    """The predicate constant as int32 bits (how kernels take it as operand)."""
    k_arr = jnp.asarray(
        pred_k, dtype=jnp.float32 if pred_dtype == "float32" else jnp.int32
    )
    return jax.lax.bitcast_convert_type(k_arr, jnp.int32)
