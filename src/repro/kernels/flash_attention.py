"""Fused GQA flash attention — Pallas TPU kernel.

The §Roofline analysis shows every pure-XLA train/prefill cell is memory-
bound: the blockwise-attention logits (B·S·H·chunk f32) round-trip through
HBM once per KV chunk per layer.  This kernel keeps the (Bq × Bk) logit
tile, the running max/denominator and the output accumulator in VMEM —
attention's HBM traffic drops to the Q/K/V/O tensors themselves, moving the
cells toward the compute roofline (§Perf iteration 6 quantifies the delta).

Layout: q (BH, S, D), kv (B·KH, S, D); the BlockSpec index map shares one KV
tile across the G query heads of its group (``bh // G``) so GQA's bandwidth
advantage survives.  Grid = (BH, nq, nk), k-minor so the VMEM accumulator
scratch carries across the k dimension; masking covers causality, sliding
windows and tail padding.  MXU-aligned tiles (multiples of 128) by default.

Validated in interpret mode against the pure-jnp oracle over shape / dtype /
window / GQA sweeps (tests/test_flash_attention.py); the backward pass is
XLA's (rematerialized blockwise) — a fused bwd kernel is future work and is
accounted as such in EXPERIMENTS.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_K = 256
MASK_VALUE = -1e30


def _flash_kernel(
    spec: tuple,
    q_ref,  # (1, Bq, D)
    k_ref,  # (1, Bk, D)
    v_ref,  # (1, Bk, D)
    o_ref,  # (1, Bq, D)
    acc_ref,  # VMEM (Bq, D) f32
    m_ref,  # VMEM (Bq, 1) f32
    l_ref,  # VMEM (Bq, 1) f32
    *,
    scale: float,
    causal: bool,
    window: int,
    seq_len: int,
    block_q: int,
    block_k: int,
    n_k: int,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale  # (Bq, D)
    k = k_ref[0].astype(jnp.float32)  # (Bk, D)
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Bq, Bk)

    q_pos = iq * block_q + jax.lax.iota(jnp.int32, block_q)[:, None]
    k_pos = ik * block_k + jax.lax.iota(jnp.int32, block_k)[None, :]
    dist = q_pos - k_pos
    mask = (k_pos < seq_len) & (q_pos < seq_len)
    if causal:
        mask = mask & (dist >= 0) & (dist < window)
    else:
        mask = mask & (jnp.abs(dist) < window)
    logits = jnp.where(mask, logits, MASK_VALUE)

    m_prev = m_ref[...]  # (Bq, 1)
    m_new = jnp.maximum(m_prev, logits.max(axis=-1, keepdims=True))
    p = jnp.exp(logits - m_new)  # (Bq, Bk)
    alpha = jnp.exp(m_prev - m_new)  # (Bq, 1)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    m_ref[...] = m_new
    pv = jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (Bq, D)
    acc_ref[...] = acc_ref[...] * alpha + pv

    @pl.when(ik == n_k - 1)
    def _finalize():
        o_ref[0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, S, KH, D)
    v: jax.Array,  # (B, S, KH, D)
    causal: bool = True,
    window: int | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jax.Array:
    """Fused attention; semantics match ``layers.blockwise_attention``."""
    b, s, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    scale = d**-0.5
    win = window if window is not None else s

    block_q = min(block_q, s)
    block_k = min(block_k, s)
    pad_q = (-s) % block_q
    pad_k = (-s) % block_k
    s_q, s_k = s + pad_q, s + pad_k
    qh = jnp.moveaxis(q, 2, 1).reshape(b * h, s, d)  # (BH, S, D)
    kv_shape = (b * kh, s, d)
    kc = jnp.moveaxis(k, 2, 1).reshape(kv_shape)
    vc = jnp.moveaxis(v, 2, 1).reshape(kv_shape)
    if pad_q:
        qh = jnp.pad(qh, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kc = jnp.pad(kc, ((0, 0), (0, pad_k), (0, 0)))
        vc = jnp.pad(vc, ((0, 0), (0, pad_k), (0, 0)))
    n_q = s_q // block_q
    n_k = s_k // block_k

    kernel = functools.partial(
        _flash_kernel,
        (),
        scale=scale, causal=causal, window=win, seq_len=s,
        block_q=block_q, block_k=block_k, n_k=n_k,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * h, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, iq, ik: (bh, iq, 0)),
            # one KV tile feeds all G query heads of its group (GQA-aware)
            pl.BlockSpec((1, block_k, d), lambda bh, iq, ik, g=g: (bh // g, ik, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, iq, ik, g=g: (bh // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s_q, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kc, vc)
    out = out[:, :s].reshape(b, h, s, d)
    return jnp.moveaxis(out, 1, 2)  # (B, S, H, D)


def attention_hbm_bytes(
    b: int, s: int, h: int, kh: int, d: int, chunk: int, dtype_bytes: int = 2
) -> dict:
    """Modeled per-layer attention HBM traffic: fused kernel vs pure XLA.

    XLA blockwise: Q/K/V/O + the f32 logits and weight tiles spilled per
    chunk step (2 tiles of B·S·H·chunk f32 per chunk, written + read).
    Fused kernel: Q/K/V/O only (logits live in VMEM).
    """
    qkvo = (2 * b * s * h * d + 2 * b * s * kh * d) * dtype_bytes
    n_chunks = max(s // chunk, 1)
    logits_spill = 2 * 2 * b * s * h * chunk * 4 * n_chunks
    return {
        "xla_blockwise": qkvo + logits_spill,
        "fused": qkvo,
        "savings": logits_spill,
    }
