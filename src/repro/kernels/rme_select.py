"""Near-memory selection WITH compaction — the paper's §8 selection offload.

``filter_project`` (rme_filter.py) preserves row positions and ships a
validity mask: simple, but failing rows still occupy bus width.  This kernel
goes the final step the paper sketches for the hardware: rows that fail the
predicate are *compacted out* inside the engine, so the bytes shipped to the
consumer scale with selectivity, not cardinality.

TPU adaptation of a data-dependent output size (XLA needs static shapes):
each block emits a dense prefix of its selected rows plus a per-block count
— the same contract a DMA engine with a fill-level register provides.  The
host-side wrapper optionally concatenates the prefixes into one dense
relation (cheap: one gather over block offsets).

Compaction inside the kernel is expressed as a *sort by (!keep)* — a stable
sort moves selected rows to the front of the block while preserving order,
mapping onto the TPU's vectorized sort rather than serial control flow.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.schema import TableGeometry

from .common import DEFAULT_BLOCK_ROWS
from .common import decode as _decode
from .common import pred_mask as _pred


def _select_kernel(spec, x_ref, k_ref, ts_ref, o_ref, c_ref):
    slices, pred_word, pred_dtype, pred_op, ts_word, n_rows = spec
    i = pl.program_id(0)
    block_rows = x_ref.shape[0]

    k = _decode(k_ref[0, 0], pred_dtype)
    keep = _pred(_decode(x_ref[:, pred_word], pred_dtype), pred_op, k)
    ridx = i * block_rows + jax.lax.iota(jnp.int32, block_rows)
    keep = keep & (ridx < n_rows)
    if ts_word >= 0:
        ts = ts_ref[0, 0]
        keep = keep & (x_ref[:, ts_word] <= ts) & (ts < x_ref[:, ts_word + 1])

    parts = [x_ref[:, src : src + w] for src, _, w in slices]
    packed = jnp.concatenate(parts, axis=1)  # (B, out_w)
    # stable compaction: selected rows first, original order preserved
    order = jnp.argsort(jnp.logical_not(keep), stable=True)
    compacted = jnp.take(packed, order, axis=0)
    count = jnp.sum(keep.astype(jnp.int32))
    valid = jax.lax.iota(jnp.int32, block_rows) < count
    o_ref[...] = jnp.where(valid[:, None], compacted, 0)
    c_ref[0, 0] = count


@functools.partial(
    jax.jit,
    static_argnames=(
        "geom", "pred_word", "pred_dtype", "pred_op", "ts_word", "block_rows",
        "interpret",
    ),
)
def select_compact(
    words: jax.Array,
    geom: TableGeometry,
    pred_word: int,
    pred_dtype: str = "int32",
    pred_op: str = "gt",
    pred_k=0,
    ts: int = 0,
    ts_word: int = -1,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Returns ``(blocks (n_blocks, block_rows, out_w), counts (n_blocks,))``.

    ``blocks[b, :counts[b]]`` are the packed projections of the selected
    rows of block ``b`` in original order; the tail is zero-filled.
    """
    n, row_words = words.shape
    pad = (-n) % block_rows
    if pad:
        words = jnp.concatenate(
            [words, jnp.zeros((pad, row_words), jnp.int32)], axis=0
        )
    n_pad = words.shape[0]
    grid = n_pad // block_rows
    out_w = geom.out_words_per_row
    slices = tuple(
        zip(geom.col_word_offsets, geom.out_word_offsets, geom.col_word_widths)
    )
    k_arr = jnp.asarray(
        pred_k, dtype=jnp.float32 if pred_dtype == "float32" else jnp.int32
    )
    k_bits = jax.lax.bitcast_convert_type(k_arr, jnp.int32).reshape(1, 1)
    ts_arr = jnp.asarray(ts, dtype=jnp.int32).reshape(1, 1)
    spec = (slices, pred_word, pred_dtype, pred_op, ts_word, n)

    blocks, counts = pl.pallas_call(
        functools.partial(_select_kernel, spec),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block_rows, row_words), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, out_w), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, out_w), jnp.int32),
            jax.ShapeDtypeStruct((grid, 1), jnp.int32),
        ],
        interpret=interpret,
    )(words, k_bits, ts_arr)
    return blocks.reshape(grid, block_rows, out_w), counts[:, 0]


def densify(blocks: jax.Array, counts: jax.Array, total: int) -> jax.Array:
    """Concatenate block prefixes into one dense (total, out_w) relation.

    ``total`` is a static bound (≥ counts.sum()); surplus rows are zero.
    One gather over global positions — the host-side Reorganization Buffer
    read-out.
    """
    grid, block_rows, out_w = blocks.shape
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    # global destination of each (block, slot); invalid slots -> `total`
    slot = jnp.arange(block_rows, dtype=jnp.int32)
    dest = starts[:, None] + slot[None, :]
    valid = slot[None, :] < counts[:, None]
    dest = jnp.where(valid, dest, total)
    flat = blocks.reshape(grid * block_rows, out_w)
    out = jnp.zeros((total + 1, out_w), jnp.int32).at[dest.reshape(-1)].set(
        flat, mode="drop"
    )
    return out[:total]


def select_compact_ref(
    words: jax.Array, geom: TableGeometry, pred_word: int,
    pred_dtype: str = "int32", pred_op: str = "gt", pred_k=0,
) -> jax.Array:
    """Oracle: numpy-style dense selection of packed projections."""
    import numpy as np

    from . import ref as R

    packed = np.asarray(R.project_ref(words[:, : geom.row_words], geom))
    vals = np.asarray(_decode(words[:, pred_word], pred_dtype))
    mask = np.asarray(_pred(jnp.asarray(vals), pred_op, pred_k))
    return packed[mask]
