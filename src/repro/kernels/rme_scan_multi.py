"""Heterogeneous one-pass scan — filters, aggregates, group-bys, and
projections fused into the shared multi-view row-store pass.

``rme_project_multi`` made "scan once, answer everything" true for
*projections*: one Fetch-Unit stream per table per batch, every view's packed
block emitted from it.  But a mixed query tick is not all projections — the
paper's §8 extension argument (selection, aggregation, group-by offload) puts
every relational operator on that same stream, and the single-op kernels
(``rme_aggregate``, ``rme_filter``, ``groupby_sum``) each launch their own
full sweep of the row store.  N op kinds ⇒ N passes, which defeats the
amortization the whole design is built on.

This module closes that gap.  A **scan request** describes what one consumer
wants from the stream:

* :class:`ProjectRequest`   — a packed column-group block (what
  ``rme_project`` emits),
* :class:`FilterRequest`    — the packed block with predicate-failing rows
  zeroed plus a validity bitmap (``rme_filter``'s contract),
* :class:`AggregateRequest` — a partial ``[sum, count]`` scalar pair
  (``rme_aggregate``'s contract),
* :class:`GroupByRequest`   — partial per-group ``[sum, count]`` vectors
  (``groupby_sum``'s contract, one-hot MXU contraction).

:func:`scan_multi` lowers any mix of requests to **one** Pallas grid pass:
each row tile is streamed through VMEM once and every request's output is
emitted from that single visit — blocked outputs for projections/filters,
accumulated outputs for aggregates/group-bys.  MVCC snapshot tests and
padded-row masking are fused per request exactly as in the single-op kernels.
``scan_multi_xla`` is the fused-gather fallback for non-TPU lowering: one
gather of the union of every request's enabled words, then per-request
compute out of that shared array.

Byte accounting follows the same union discipline: :func:`union_geometry`
builds the one accounting geometry covering all requests' enabled words
(including predicate and hidden MVCC timestamp words), so the engine charges
the fused pass's bus beats exactly once (Eq. (3) bursts over the union).

Only the MLP formulation applies (whole-row tiles through the double-buffered
pipeline); as with ``rme_project_multi``, the BSL/PCK revisions route their
batched work through this kernel too, and ``revision="xla"`` dispatches the
fallback.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.schema import WORD, TableGeometry, geometry_from_intervals

from .common import (
    DEFAULT_BLOCK_ROWS,
    column_slices,
    decode,
    group_ids,
    pad_rows,
    pred_k_bits,
    pred_mask,
)


# ------------------------------------------------------------ scan requests
@dataclasses.dataclass(frozen=True)
class ProjectRequest:
    """A packed column-group block: ``(N, out_words)`` int32."""

    geom: TableGeometry


@dataclasses.dataclass(frozen=True)
class FilterRequest:
    """Packed block with failing rows zeroed + bool validity mask."""

    geom: TableGeometry
    pred_word: int
    pred_dtype: str = "int32"
    pred_op: str = "gt"
    pred_k: int | float = 0
    ts_word: int = -1  # >= 0 fuses the MVCC snapshot test
    ts: int = 0


@dataclasses.dataclass(frozen=True)
class AggregateRequest:
    """``[sum, count]`` float32 pair over the predicate-passing rows."""

    agg_word: int
    agg_dtype: str = "int32"
    pred_word: int = 0
    pred_dtype: str = "int32"
    pred_op: str = "none"
    pred_k: int | float = 0
    ts_word: int = -1
    ts: int = 0


@dataclasses.dataclass(frozen=True)
class GroupByRequest:
    """Per-group ``(sums[G], counts[G])`` over a static group domain."""

    group_word: int
    agg_word: int
    num_groups: int
    agg_dtype: str = "int32"
    pred_word: int = 0
    pred_dtype: str = "int32"
    pred_op: str = "none"
    pred_k: int | float = 0
    ts_word: int = -1
    ts: int = 0


ScanRequest = ProjectRequest | FilterRequest | AggregateRequest | GroupByRequest


def _strip_dynamic(req: ScanRequest) -> ScanRequest:
    """Normalize everything the kernel doesn't consume out of the static spec
    so it never retraces for it: the traced operands (predicate constant,
    snapshot time) and the geometry's ``row_count`` — output shapes follow
    the *words* operand, so a growing table (the HTAP ingest pattern: every
    tick appends a few rows) reuses one trace per chunk shape instead of
    recompiling every request every tick."""
    if isinstance(req, (ProjectRequest, FilterRequest)):
        req = dataclasses.replace(
            req, geom=dataclasses.replace(req.geom, row_count=0)
        )
    if isinstance(req, ProjectRequest):
        return req
    return dataclasses.replace(req, pred_k=0, ts=0)


def request_intervals(req: ScanRequest) -> list[tuple[int, int]]:
    """Byte intervals of the row-store words this request enables.

    This is the request's footprint on the Fetch-Unit stream: projected
    columns, the predicate word, the aggregate/group words, and the two
    hidden MVCC timestamp words when a snapshot test is fused.  The engine
    merges these across a batch into the one union accounting geometry.
    """
    spans: list[tuple[int, int]] = []
    if isinstance(req, (ProjectRequest, FilterRequest)):
        spans.extend(zip(req.geom.abs_offsets, req.geom.col_widths))
    if isinstance(req, AggregateRequest):
        spans.append((req.agg_word * WORD, WORD))
    if isinstance(req, GroupByRequest):
        spans.append((req.group_word * WORD, WORD))
        spans.append((req.agg_word * WORD, WORD))
    if not isinstance(req, ProjectRequest):
        if req.pred_op != "none":
            spans.append((req.pred_word * WORD, WORD))
        if req.ts_word >= 0:
            spans.append((req.ts_word * WORD, 2 * WORD))
    return spans


def union_geometry(
    requests: Sequence[ScanRequest], row_bytes: int, row_count: int
) -> TableGeometry:
    """The one accounting geometry covering every request's enabled words.

    Overlapping/adjacent intervals collapse into single burst chains via the
    shared charging rule (:func:`repro.core.schema.geometry_from_intervals`)
    — the fused pass's bus beats are charged once for the whole batch.
    """
    intervals = [
        (o, w) for req in requests for o, w in request_intervals(req)
    ]
    if not intervals:
        raise ValueError("union_geometry needs at least one enabled word")
    return geometry_from_intervals(intervals, row_bytes=row_bytes,
                                   row_count=row_count)


def scan_vmem_footprint_bytes(
    requests: Sequence[ScanRequest], row_words: int,
    block_rows: int = DEFAULT_BLOCK_ROWS,
) -> int:
    """Modeled VMEM working set of one fused grid step (2 MB SPM budget).

    The row tile and every blocked output are double-buffered (Pallas
    pipeline); accumulator outputs (aggregates, group-by partials) are tiny
    and resident for the whole pass.
    """
    total = 2 * block_rows * row_words * 4  # double-buffered row tile
    for req in requests:
        if isinstance(req, ProjectRequest):
            total += 2 * block_rows * req.geom.out_words_per_row * 4
        elif isinstance(req, FilterRequest):
            total += 2 * block_rows * (req.geom.out_words_per_row + 1) * 4
        elif isinstance(req, AggregateRequest):
            total += 2 * 4
        else:
            total += req.num_groups * 2 * 4
    return total


# ------------------------------------------------------------ Pallas kernel
def _fused_mask(req, i, block_rows, n_rows, x_ref, k_ref, ts_ref, r):
    """The per-request row mask: predicate & padded-tail & MVCC snapshot."""
    k = decode(k_ref[r, 0], req.pred_dtype)
    mask = pred_mask(decode(x_ref[:, req.pred_word], req.pred_dtype),
                     req.pred_op, k)
    ridx = i * block_rows + jax.lax.iota(jnp.int32, block_rows)
    mask = mask & (ridx < n_rows)
    if req.ts_word >= 0:
        ts = ts_ref[r, 0]
        mask = mask & (x_ref[:, req.ts_word] <= ts) & (ts < x_ref[:, req.ts_word + 1])
    return mask


def _scan_multi_kernel(requests, n_rows, x_ref, k_ref, ts_ref, *o_refs):
    i = pl.program_id(0)
    block_rows = x_ref.shape[0]
    oi = 0
    for r, req in enumerate(requests):
        if isinstance(req, ProjectRequest):
            parts = [x_ref[:, s : s + w] for s, _, w in column_slices(req.geom)]
            o_refs[oi][...] = jnp.concatenate(parts, axis=1)
            oi += 1
            continue
        mask = _fused_mask(req, i, block_rows, n_rows, x_ref, k_ref, ts_ref, r)
        if isinstance(req, FilterRequest):
            parts = [x_ref[:, s : s + w] for s, _, w in column_slices(req.geom)]
            packed = jnp.concatenate(parts, axis=1)
            o_refs[oi][...] = jnp.where(mask[:, None], packed, 0)
            o_refs[oi + 1][...] = mask[:, None].astype(jnp.int32)
            oi += 2
            continue
        o_ref = o_refs[oi]
        oi += 1

        @pl.when(i == 0)
        def _init(o_ref=o_ref):
            o_ref[...] = jnp.zeros_like(o_ref)

        vals = decode(x_ref[:, req.agg_word], req.agg_dtype).astype(jnp.float32)
        fm = mask.astype(jnp.float32)
        if isinstance(req, AggregateRequest):
            o_ref[0, 0] += jnp.sum(vals * fm)
            o_ref[0, 1] += jnp.sum(fm)
        else:  # GroupByRequest: one-hot × matmul MXU contraction
            g = group_ids(x_ref[:, req.group_word], req.num_groups)
            onehot = (
                g[:, None] == jax.lax.iota(jnp.int32, req.num_groups)[None, :]
            ).astype(jnp.float32)  # (B, G)
            contrib = jnp.stack([vals * fm, fm], axis=1)  # (B, 2)
            o_ref[...] += jax.lax.dot_general(
                onehot, contrib, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )  # (G, 2)


def _check_requests(row_words: int, requests: Sequence[ScanRequest]) -> None:
    if not requests:
        raise ValueError("scan_multi needs at least one request")
    for req in requests:
        if isinstance(req, (ProjectRequest, FilterRequest)):
            if row_words < req.geom.row_words:
                raise ValueError(
                    f"storage rows {row_words}w < geometry rows {req.geom.row_words}w"
                )


@functools.partial(
    jax.jit, static_argnames=("requests", "block_rows", "interpret")
)
def _scan_multi(
    words: jax.Array,
    k_bits: jax.Array,  # (R, 1) int32: per-request predicate constant bits
    ts_arr: jax.Array,  # (R, 1) int32: per-request snapshot times
    requests: tuple[ScanRequest, ...],
    block_rows: int,
    interpret: bool,
):
    n, row_words = words.shape
    x = pad_rows(words, block_rows)
    n_pad = x.shape[0]
    n_req = len(requests)

    out_specs: list[pl.BlockSpec] = []
    out_shape: list[jax.ShapeDtypeStruct] = []
    for req in requests:
        if isinstance(req, (ProjectRequest, FilterRequest)):
            w = req.geom.out_words_per_row
            out_specs.append(pl.BlockSpec((block_rows, w), lambda i: (i, 0)))
            out_shape.append(jax.ShapeDtypeStruct((n_pad, w), jnp.int32))
            if isinstance(req, FilterRequest):
                out_specs.append(pl.BlockSpec((block_rows, 1), lambda i: (i, 0)))
                out_shape.append(jax.ShapeDtypeStruct((n_pad, 1), jnp.int32))
        elif isinstance(req, AggregateRequest):
            out_specs.append(pl.BlockSpec((1, 2), lambda i: (0, 0)))
            out_shape.append(jax.ShapeDtypeStruct((1, 2), jnp.float32))
        else:
            out_specs.append(
                pl.BlockSpec((req.num_groups, 2), lambda i: (0, 0))
            )
            out_shape.append(
                jax.ShapeDtypeStruct((req.num_groups, 2), jnp.float32)
            )

    return pl.pallas_call(
        functools.partial(_scan_multi_kernel, requests, n),
        grid=(n_pad // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, row_words), lambda i: (i, 0)),
            pl.BlockSpec((n_req, 1), lambda i: (0, 0)),
            pl.BlockSpec((n_req, 1), lambda i: (0, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(x, k_bits, ts_arr)


def _unflatten(requests, flat, n):
    """Regroup the pallas outputs into each request's natural result shape."""
    results, fi = [], 0
    for req in requests:
        if isinstance(req, ProjectRequest):
            results.append(flat[fi][:n])
            fi += 1
        elif isinstance(req, FilterRequest):
            results.append((flat[fi][:n], flat[fi + 1][:n, 0].astype(bool)))
            fi += 2
        elif isinstance(req, AggregateRequest):
            results.append(flat[fi][0])
            fi += 1
        else:
            results.append((flat[fi][:, 0], flat[fi][:, 1]))
            fi += 1
    return results


def scan_multi(
    words: jax.Array,
    requests: Sequence[ScanRequest],
    revision: str = "mlp",
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> list:
    """One row-store pass serving a heterogeneous request batch.

    Returns one result per request, in order, each matching its single-op
    kernel's contract: ``(N, out_words)`` packed blocks for projections,
    ``(packed, bool mask)`` pairs for filters, float32 ``[sum, count]`` for
    aggregates, and ``(sums[G], counts[G])`` for group-bys.  The predicate
    constants and snapshot times are traced operands — distinct values do not
    retrace the kernel.
    """
    if revision == "xla":
        return scan_multi_xla(words, tuple(requests))
    n, row_words = words.shape
    _check_requests(row_words, requests)
    k_bits, ts_arr = _dynamic_operands(requests)
    flat = _scan_multi(
        words, k_bits, ts_arr, tuple(_strip_dynamic(r) for r in requests),
        block_rows, interpret,
    )
    return _unflatten(requests, flat, n)


def combine_chunk_outputs(req: ScanRequest, parts: Sequence) -> object:
    """Merge one request's per-chunk outputs into its whole-table result.

    The delta-chunked row store (``repro.core.engine.DeviceRowStore``) keeps
    a table as a base chunk plus appended tail chunks; a fused pass streams
    each chunk independently and this is the combine rule — the reason it is
    *possible* is that every request kind is either row-local (blocked
    outputs: rows of chunk k land at their global offsets, so concatenation
    reassembles the table order) or an associative reduction (aggregate /
    group-by partials add, exactly how the single-chunk kernel already
    combines its row tiles).  MVCC snapshot tests are per-row, so chunk
    boundaries never change visibility.
    """
    if isinstance(req, ProjectRequest):
        return jnp.concatenate(list(parts), axis=0)
    if isinstance(req, FilterRequest):
        return (jnp.concatenate([p[0] for p in parts], axis=0),
                jnp.concatenate([p[1] for p in parts], axis=0))
    if isinstance(req, AggregateRequest):
        total = parts[0]
        for p in parts[1:]:
            total = total + p
        return total
    sums, counts = parts[0]
    for s, c in parts[1:]:
        sums, counts = sums + s, counts + c
    return sums, counts


def scan_multi_chunked(
    chunks: Sequence[jax.Array],
    requests: Sequence[ScanRequest],
    revision: str = "mlp",
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> list:
    """One fused pass per resident chunk, combined into per-request results.

    ``chunks`` are consecutive row ranges of one table's row store (base +
    appended tails); each is streamed through :func:`scan_multi` once and the
    per-chunk outputs merge via :func:`combine_chunk_outputs`.  A single
    chunk degenerates to exactly ``scan_multi`` — the common (write-free)
    case pays nothing for the chunked formulation.
    """
    if len(chunks) == 1:
        return scan_multi(chunks[0], requests, revision=revision,
                          block_rows=block_rows, interpret=interpret)
    per_chunk = [
        scan_multi(chunk, requests, revision=revision,
                   block_rows=block_rows, interpret=interpret)
        for chunk in chunks
    ]
    return [
        combine_chunk_outputs(req, [outs[r] for outs in per_chunk])
        for r, req in enumerate(requests)
    ]


def reduced_result_bytes(req: ScanRequest) -> int | None:
    """Bytes of one request's *reduced* partial, or ``None`` for blocked kinds.

    This is the unit of the sharded backend's interconnect accounting: when
    per-shard fused passes combine via :func:`combine_chunk_outputs`, an
    aggregate ships its float32 ``[sum, count]`` pair (8 bytes) and a
    group-by its ``(G, 2)`` partial — never anything proportional to the
    shard's row count.  Blocked outputs (projections, filters) return
    ``None``: they stay shard-resident until finalize and are charged to
    ``bytes_to_cpu`` like any packed view, not to the collective.
    """
    if isinstance(req, AggregateRequest):
        return 2 * 4
    if isinstance(req, GroupByRequest):
        return req.num_groups * 2 * 4
    return None


def scan_shard(
    chunks: Sequence[jax.Array],
    requests: Sequence[ScanRequest],
    revision: str = "mlp",
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> list[list]:
    """Shard-local entry point: one fused pass over each resident chunk of
    one shard (bank), per-chunk outputs left **uncombined**.

    The sharded engine needs the per-chunk granularity — blocked outputs are
    reassembled into global row order from each chunk's ownership segments,
    and reduced partials combine shard-locally before anything crosses the
    interconnect — so unlike :func:`scan_multi_chunked` this returns
    ``[chunk][request]`` raw outputs.  Every pass is an ordinary
    single-device :func:`scan_multi` on the shard's own device: requests are
    row-position-local, so no SPMD lowering is required and the Pallas
    revisions work per shard exactly as they do per chunk.
    """
    return [
        scan_multi(chunk, requests, revision=revision,
                   block_rows=block_rows, interpret=interpret)
        for chunk in chunks
    ]


def _dynamic_operands(requests: Sequence[ScanRequest]) -> tuple[jax.Array, jax.Array]:
    """Per-request (k_bits, ts) operand columns — traced, never static."""
    k_bits = jnp.stack(
        [pred_k_bits(getattr(r, "pred_k", 0), getattr(r, "pred_dtype", "int32"))
         for r in requests]
    ).reshape(len(requests), 1)
    ts_arr = jnp.asarray(
        [getattr(r, "ts", 0) for r in requests], dtype=jnp.int32
    ).reshape(len(requests), 1)
    return k_bits, ts_arr


# ------------------------------------------------------------- XLA fallback
def scan_multi_xla(words: jax.Array, requests: tuple[ScanRequest, ...]) -> list:
    """Fused-gather fallback: gather the union of enabled words once, then
    compute every request's output from that single shared pass.  Like the
    Pallas path, predicate constants and snapshot times travel as traced
    operands — distinct values never retrace."""
    _check_requests(words.shape[1], requests)
    k_bits, ts_arr = _dynamic_operands(requests)
    return _scan_multi_xla(
        words, k_bits, ts_arr, tuple(_strip_dynamic(r) for r in requests)
    )


@functools.partial(jax.jit, static_argnames=("requests",))
def _scan_multi_xla(
    words: jax.Array,
    k_bits: jax.Array,
    ts_arr: jax.Array,
    requests: tuple[ScanRequest, ...],
) -> list:
    union: list[int] = []
    seen: set[int] = set()
    for req in requests:
        for off, w in request_intervals(req):
            for word in range(off // WORD, (off + w) // WORD):
                if word not in seen:
                    seen.add(word)
                    union.append(word)
    union.sort()
    pos = {word: i for i, word in enumerate(union)}
    shared = jnp.take(words, jnp.asarray(union, dtype=jnp.int32), axis=1)

    def col(word: int) -> jax.Array:
        return shared[:, pos[word]]

    def mask_of(req, r: int) -> jax.Array:
        if req.pred_op != "none":
            k = decode(k_bits[r, 0], req.pred_dtype)
            m = pred_mask(decode(col(req.pred_word), req.pred_dtype),
                          req.pred_op, k)
        else:
            m = jnp.ones(shared.shape[:1], dtype=bool)
        if req.ts_word >= 0:
            ts = ts_arr[r, 0]
            m = m & (col(req.ts_word) <= ts) & (ts < col(req.ts_word + 1))
        return m

    def packed_of(geom: TableGeometry) -> jax.Array:
        idx = []
        for off, w in zip(geom.col_word_offsets, geom.col_word_widths):
            idx.extend(pos[word] for word in range(off, off + w))
        return jnp.take(shared, jnp.asarray(idx, dtype=jnp.int32), axis=1)

    results = []
    for r, req in enumerate(requests):
        if isinstance(req, ProjectRequest):
            results.append(packed_of(req.geom))
            continue
        if isinstance(req, FilterRequest):
            mask = mask_of(req, r)
            results.append((jnp.where(mask[:, None], packed_of(req.geom), 0), mask))
            continue
        mask = mask_of(req, r)
        vals = decode(col(req.agg_word), req.agg_dtype).astype(jnp.float32)
        fm = mask.astype(jnp.float32)
        if isinstance(req, AggregateRequest):
            results.append(jnp.stack([jnp.sum(vals * fm), jnp.sum(fm)]))
        else:
            g = group_ids(col(req.group_word), req.num_groups)
            sums = jax.ops.segment_sum(vals * fm, g, num_segments=req.num_groups)
            counts = jax.ops.segment_sum(fm, g, num_segments=req.num_groups)
            results.append((sums, counts))
    return results
