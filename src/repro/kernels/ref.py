"""Pure-jnp oracles for every RME kernel (the correctness ground truth).

All tables are row-major int32 word buffers of shape ``(N, row_words)``; the
geometry (static) gives enabled-column word offsets/widths.  Every Pallas kernel
in this package must match these functions bit-exactly (projection) or to float
tolerance (aggregation) across the test sweeps.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.schema import TableGeometry

from .common import group_ids


def gather_indices(geom: TableGeometry) -> np.ndarray:
    """Word indices within a row for the packed projection, in packed order."""
    idx = []
    for off, w in zip(geom.col_word_offsets, geom.col_word_widths):
        idx.extend(range(off, off + w))
    return np.asarray(idx, dtype=np.int32)


def project_ref(words: jax.Array, geom: TableGeometry) -> jax.Array:
    """Packed projection: (N, row_words) -> (N, out_words)."""
    return jnp.take(words, jnp.asarray(gather_indices(geom)), axis=1)


def _decode(col_words: jax.Array, dtype: str) -> jax.Array:
    if dtype == "float32":
        return jax.lax.bitcast_convert_type(col_words, jnp.float32)
    if dtype == "int32":
        return col_words
    raise ValueError(f"aggregation supports 4-byte numeric columns, got {dtype}")


def _predicate(vals: jax.Array, op: str, k) -> jax.Array:
    if op == "gt":
        return vals > k
    if op == "lt":
        return vals < k
    if op == "none":
        return jnp.ones(vals.shape, dtype=bool)
    raise ValueError(op)


def mvcc_mask_ref(words: jax.Array, ts_begin_word: int, ts: int) -> jax.Array:
    """Snapshot-isolation validity from the two hidden timestamp words."""
    begin = words[:, ts_begin_word]
    end = words[:, ts_begin_word + 1]
    return (begin <= ts) & (ts < end)


def aggregate_ref(
    words: jax.Array,
    agg_word: int,
    agg_dtype: str,
    pred_word: int,
    pred_dtype: str,
    pred_op: str,
    pred_k,
    valid: jax.Array | None = None,
) -> jax.Array:
    """SELECT SUM(a) FROM t WHERE pred(b)  — Q0 (pred_op='none') and Q3."""
    vals = _decode(words[:, agg_word], agg_dtype).astype(jnp.float32)
    mask = _predicate(_decode(words[:, pred_word], pred_dtype), pred_op, pred_k)
    if valid is not None:
        mask = mask & valid
    return jnp.sum(jnp.where(mask, vals, 0.0))


def filter_project_ref(
    words: jax.Array,
    geom: TableGeometry,
    pred_word: int,
    pred_dtype: str,
    pred_op: str,
    pred_k,
    valid: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Selection pushdown: packed projection with failing rows zeroed + mask.

    Static-shape TPU adaptation of 'only selected rows are shipped': rows that
    fail the predicate are never written to the reorganized output (zeros), and
    the mask lets the consumer run predicated compute.
    """
    packed = project_ref(words, geom)
    mask = _predicate(_decode(words[:, pred_word], pred_dtype), pred_op, pred_k)
    if valid is not None:
        mask = mask & valid
    return jnp.where(mask[:, None], packed, 0), mask


def hash_join_ref(
    s_key: jax.Array,
    s_val: jax.Array,
    r_key: jax.Array,
    r_val: jax.Array,
    s_valid: jax.Array | None = None,
    r_valid: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Equi-join oracle: one slot per probe row + validity mask (Q5 contract).

    The build side is duplicate-free on ``r_key`` (primary key, paper §6).
    ``s_valid``/``r_valid`` are MVCC visibility masks — an invisible probe row
    emits zeros and ``matched=False``; an invisible build row never matches.
    Pure jnp sort-probe: the ground truth both the host sort-probe route and
    the device hash-partition probe must reproduce bit-exactly.
    """
    order = jnp.argsort(r_key)
    rk, rv = r_key[order], r_val[order]
    rvalid = (jnp.ones(rk.shape, dtype=bool) if r_valid is None
              else r_valid[order])
    pos = jnp.clip(jnp.searchsorted(rk, s_key), 0, rk.shape[0] - 1)
    matched = (rk[pos] == s_key) & rvalid[pos]
    svalid = (jnp.ones(s_key.shape, dtype=bool) if s_valid is None
              else s_valid)
    matched = matched & svalid
    return (
        jnp.where(svalid, s_val, 0),
        jnp.where(matched, rv[pos], 0),
        matched,
    )


def groupby_sum_ref(
    words: jax.Array,
    group_word: int,
    agg_word: int,
    agg_dtype: str,
    num_groups: int,
    pred_word: int | None = None,
    pred_dtype: str = "int32",
    pred_op: str = "none",
    pred_k=0,
    valid: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """SELECT SUM(a), COUNT(*) FROM t WHERE pred GROUP BY g — Q4 core.

    Group keys are int32 taken modulo ``num_groups`` (static group domain).
    Returns (sums[G], counts[G]).
    """
    g = group_ids(words[:, group_word], num_groups)
    vals = _decode(words[:, agg_word], agg_dtype).astype(jnp.float32)
    mask = jnp.ones(g.shape, dtype=bool)
    if pred_word is not None:
        mask = _predicate(_decode(words[:, pred_word], pred_dtype), pred_op, pred_k)
    if valid is not None:
        mask = mask & valid
    vals = jnp.where(mask, vals, 0.0)
    cnt = mask.astype(jnp.float32)
    sums = jax.ops.segment_sum(vals, g, num_segments=num_groups)
    counts = jax.ops.segment_sum(cnt, g, num_segments=num_groups)
    return sums, counts
