"""Public jit'd entry points for the RME kernel suite.

One import surface for the engine and the benchmarks; every function has a
bit-exact (or float-tolerant) oracle in ``ref.py`` and an interpret-mode sweep
in ``tests/test_kernels_*.py``.  ``revision`` selects the paper's hardware
revision; ``"xla"`` is the pure-XLA production path used when the program is
lowered for targets where the Pallas TPU kernels don't apply (CPU, dry-run).
"""

from __future__ import annotations

import jax

from repro.core.schema import TableGeometry

from .rme_aggregate import aggregate, groupby_sum
from .rme_filter import filter_project
from .rme_join import (
    JoinPartitions,
    broadcast_partitions,
    build_partitions,
    hash_join,
    hash_join_xla,
    probe_vmem_footprint_bytes,
)
from .rme_project import (
    DEFAULT_BLOCK_ROWS,
    project,
    project_xla,
    vmem_footprint_bytes,
)
from .rme_project_multi import project_multi, project_multi_xla
from .rme_scan_multi import (
    AggregateRequest,
    FilterRequest,
    GroupByRequest,
    ProjectRequest,
    combine_chunk_outputs,
    reduced_result_bytes,
    request_intervals,
    scan_multi,
    scan_multi_chunked,
    scan_multi_xla,
    scan_shard,
    scan_vmem_footprint_bytes,
    union_geometry,
)

REVISIONS = ("bsl", "pck", "mlp", "xla")


def project_any(
    words: jax.Array,
    geom: TableGeometry,
    revision: str = "mlp",
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> jax.Array:
    """Dispatch projection across revisions, including the XLA path."""
    if revision == "xla":
        return project_xla(words, geom)
    return project(words, geom, revision=revision, block_rows=block_rows,
                   interpret=interpret)


__all__ = [
    "REVISIONS",
    "DEFAULT_BLOCK_ROWS",
    "AggregateRequest",
    "FilterRequest",
    "GroupByRequest",
    "JoinPartitions",
    "ProjectRequest",
    "aggregate",
    "broadcast_partitions",
    "build_partitions",
    "combine_chunk_outputs",
    "filter_project",
    "groupby_sum",
    "hash_join",
    "hash_join_xla",
    "probe_vmem_footprint_bytes",
    "project",
    "project_any",
    "project_multi",
    "project_multi_xla",
    "project_xla",
    "reduced_result_bytes",
    "request_intervals",
    "scan_multi",
    "scan_multi_chunked",
    "scan_multi_xla",
    "scan_shard",
    "scan_vmem_footprint_bytes",
    "union_geometry",
    "vmem_footprint_bytes",
]
