"""Fused near-memory selection + aggregation kernels (paper Q0/Q3 offload).

The paper's RME prototype offloads projection and "lays the groundwork for
pushing more functionality, i.e., selection, aggregation, group by" (§1, §8).
We implement that next step: the Pallas grid step reads a row tile, extracts
only the predicate and aggregate words, applies the predicate, and accumulates a
partial sum — nothing but a scalar ever leaves the engine.  This is the
beyond-paper extension of the reproduction (recorded in EXPERIMENTS.md §Perf).

MVCC snapshots ride along: when the storage rows carry the two hidden timestamp
words, the kernels take the snapshot time as a scalar operand and fuse the
row-validity test into the predicate, exactly as paper §4 describes the RME
generating only the rows valid at query time.  Padded rows are invalid by
construction (ts_begin = TS_INF).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import DEFAULT_BLOCK_ROWS, group_ids
from .common import decode as _decode
from .common import pred_mask as _pred


def _agg_kernel(
    spec: tuple,
    x_ref,  # (block_rows, row_words) int32 row tile
    k_ref,  # (1, 1) predicate constant (bits of int32/float32)
    ts_ref,  # (1, 1) snapshot time (int32); ignored unless ts_word >= 0
    o_ref,  # (1, 2) float32: [sum, count]
):
    agg_word, agg_dtype, pred_word, pred_dtype, pred_op, ts_word, n_rows = spec
    i = pl.program_id(0)
    block_rows = x_ref.shape[0]

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    vals = _decode(x_ref[:, agg_word], agg_dtype).astype(jnp.float32)
    k = _decode(k_ref[0, 0], pred_dtype)
    mask = _pred(_decode(x_ref[:, pred_word], pred_dtype), pred_op, k)
    # padded tail rows (beyond the true row count) never contribute
    ridx = i * block_rows + jax.lax.iota(jnp.int32, block_rows)
    mask = mask & (ridx < n_rows)
    if ts_word >= 0:
        ts = ts_ref[0, 0]
        begin = x_ref[:, ts_word]
        end = x_ref[:, ts_word + 1]
        mask = mask & (begin <= ts) & (ts < end)
    fm = mask.astype(jnp.float32)
    o_ref[0, 0] += jnp.sum(vals * fm)
    o_ref[0, 1] += jnp.sum(fm)


@functools.partial(
    jax.jit,
    static_argnames=(
        "agg_word",
        "agg_dtype",
        "pred_word",
        "pred_dtype",
        "pred_op",
        "ts_word",
        "block_rows",
        "interpret",
    ),
)
def aggregate(
    words: jax.Array,
    agg_word: int,
    agg_dtype: str = "int32",
    pred_word: int = 0,
    pred_dtype: str = "int32",
    pred_op: str = "none",
    pred_k=0,
    ts: int = 0,
    ts_word: int = -1,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> jax.Array:
    """``SELECT SUM(a), COUNT(*) FROM t WHERE pred(b)`` fused in the engine.

    Returns float32 ``[sum, count]``.  ``ts_word >= 0`` enables the fused MVCC
    snapshot test against storage words ``ts_word`` / ``ts_word + 1``.
    """
    n, row_words = words.shape
    pad = (-n) % block_rows
    if pad:
        words = jnp.concatenate(
            [words, jnp.zeros((pad, row_words), dtype=jnp.int32)], axis=0
        )
    n_pad = words.shape[0]

    k_arr = jnp.asarray(pred_k, dtype=jnp.float32 if pred_dtype == "float32" else jnp.int32)
    k_bits = jax.lax.bitcast_convert_type(k_arr, jnp.int32).reshape(1, 1)
    ts_arr = jnp.asarray(ts, dtype=jnp.int32).reshape(1, 1)
    spec = (agg_word, agg_dtype, pred_word, pred_dtype, pred_op, ts_word, n)

    out = pl.pallas_call(
        functools.partial(_agg_kernel, spec),
        grid=(n_pad // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, row_words), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 2), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 2), jnp.float32),
        interpret=interpret,
    )(words, k_bits, ts_arr)
    return out[0]


def _groupby_kernel(
    spec: tuple,
    x_ref,  # (block_rows, row_words)
    k_ref,  # (1, 1)
    ts_ref,  # (1, 1)
    o_ref,  # (num_groups, 2) float32: [:, 0]=sum, [:, 1]=count
):
    (group_word, agg_word, agg_dtype, pred_word, pred_dtype, pred_op, ts_word,
     num_groups, n_rows) = spec
    i = pl.program_id(0)
    block_rows = x_ref.shape[0]

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    g = group_ids(x_ref[:, group_word], num_groups)  # (B,)
    vals = _decode(x_ref[:, agg_word], agg_dtype).astype(jnp.float32)
    k = _decode(k_ref[0, 0], pred_dtype)
    mask = _pred(_decode(x_ref[:, pred_word], pred_dtype), pred_op, k)
    ridx = i * block_rows + jax.lax.iota(jnp.int32, block_rows)
    mask = mask & (ridx < n_rows)
    if ts_word >= 0:
        ts = ts_ref[0, 0]
        mask = mask & (x_ref[:, ts_word] <= ts) & (ts < x_ref[:, ts_word + 1])
    fm = mask.astype(jnp.float32)
    # One-hot × matmul: group-by as an MXU contraction (TPU-native group-by).
    onehot = (g[:, None] == jax.lax.iota(jnp.int32, num_groups)[None, :]).astype(
        jnp.float32
    )  # (B, G)
    contrib = jnp.stack([vals * fm, fm], axis=1)  # (B, 2)
    o_ref[...] += jax.lax.dot_general(
        onehot, contrib, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (G, 2)


@functools.partial(
    jax.jit,
    static_argnames=(
        "group_word",
        "agg_word",
        "agg_dtype",
        "num_groups",
        "pred_word",
        "pred_dtype",
        "pred_op",
        "ts_word",
        "block_rows",
        "interpret",
    ),
)
def groupby_sum(
    words: jax.Array,
    group_word: int,
    agg_word: int,
    num_groups: int,
    agg_dtype: str = "int32",
    pred_word: int = 0,
    pred_dtype: str = "int32",
    pred_op: str = "none",
    pred_k=0,
    ts: int = 0,
    ts_word: int = -1,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """``SELECT SUM(a), COUNT(*) ... GROUP BY g`` via one-hot MXU contraction.

    Returns ``(sums[G], counts[G])``.  The group key domain is ``g mod G``
    (static G — the hardware analogue of a fixed number of accumulators).
    """
    n, row_words = words.shape
    pad = (-n) % block_rows
    if pad:
        words = jnp.concatenate(
            [words, jnp.zeros((pad, row_words), dtype=jnp.int32)], axis=0
        )
    n_pad = words.shape[0]

    k_arr = jnp.asarray(pred_k, dtype=jnp.float32 if pred_dtype == "float32" else jnp.int32)
    k_bits = jax.lax.bitcast_convert_type(k_arr, jnp.int32).reshape(1, 1)
    ts_arr = jnp.asarray(ts, dtype=jnp.int32).reshape(1, 1)
    spec = (
        group_word, agg_word, agg_dtype, pred_word, pred_dtype, pred_op, ts_word,
        num_groups, n,
    )
    out = pl.pallas_call(
        functools.partial(_groupby_kernel, spec),
        grid=(n_pad // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, row_words), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((num_groups, 2), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_groups, 2), jnp.float32),
        interpret=interpret,
    )(words, k_bits, ts_arr)
    return out[:, 0], out[:, 1]
