"""Scan-sharing multi-view projection — one row-store pass, many packed outputs.

The paper's RME serves several ephemeral views from one Fetch-Unit stream: the
Requestor walks the row store once and each enabled column chunk is routed to
its view's slice of the Reorganization Buffer.  Per-view kernels lose exactly
that amortization — a batch of Q0–Q5 views over one table re-reads the base
data once per view.  This module restores it in software: the Pallas grid
streams each row tile through VMEM **once** and emits every registered column
group's packed block from that single pass.

Only the MLP formulation applies here (whole-row tiles through the
double-buffered pipeline, all views packed per grid step); the BSL/PCK
micro-architecture studies are per-view by construction, so the engine routes
their batched materializations through this kernel too.  ``project_multi_xla``
is the fused-gather fallback used when lowering for non-TPU targets: a single
gather of the *union* of enabled words, then per-view slicing out of that one
pass.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.schema import TableGeometry

from .common import DEFAULT_BLOCK_ROWS
from .common import column_slices as _column_slices
from .common import pad_rows as _pad_rows


def _mlp_multi_kernel(view_slices, x_ref, *o_refs):
    # one VMEM row tile feeds every view's packed output block
    for slices, o_ref in zip(view_slices, o_refs):
        parts = [x_ref[:, src : src + w] for src, _, w in slices]
        o_ref[...] = jnp.concatenate(parts, axis=1)


def _check_geoms(row_words: int, geoms: Sequence[TableGeometry]) -> None:
    if not geoms:
        raise ValueError("project_multi needs at least one geometry")
    for g in geoms:
        if row_words < g.row_words:
            raise ValueError(
                f"storage rows {row_words}w < geometry rows {g.row_words}w"
            )


@functools.partial(
    jax.jit, static_argnames=("geoms", "revision", "block_rows", "interpret")
)
def project_multi(
    words: jax.Array,
    geoms: tuple[TableGeometry, ...],
    revision: str = "mlp",
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> tuple[jax.Array, ...]:
    """Shared-scan projection ``(N, row_words) -> [(N, out_words_v), ...]``.

    All geometries must describe views over the same row layout; the row store
    is streamed exactly once regardless of how many views are materialized.
    ``revision="xla"`` dispatches the fused-gather fallback; every Pallas
    revision shares the MLP streaming formulation (see module docstring).
    """
    if revision == "xla":
        return project_multi_xla(words, geoms)
    n, row_words = words.shape
    _check_geoms(row_words, geoms)
    x = _pad_rows(words, block_rows)
    n_pad = x.shape[0]
    grid_rows = n_pad // block_rows

    outs = pl.pallas_call(
        functools.partial(
            _mlp_multi_kernel, tuple(_column_slices(g) for g in geoms)
        ),
        grid=(grid_rows,),
        in_specs=[pl.BlockSpec((block_rows, row_words), lambda i: (i, 0))],
        out_specs=tuple(
            pl.BlockSpec((block_rows, g.out_words_per_row), lambda i: (i, 0))
            for g in geoms
        ),
        out_shape=tuple(
            jax.ShapeDtypeStruct((n_pad, g.out_words_per_row), jnp.int32)
            for g in geoms
        ),
        interpret=interpret,
    )(x)
    return tuple(o[:n] for o in outs)


@functools.partial(jax.jit, static_argnames=("geoms",))
def project_multi_xla(
    words: jax.Array, geoms: tuple[TableGeometry, ...]
) -> tuple[jax.Array, ...]:
    """Fused-gather fallback: gather the union of enabled words once, slice per view."""
    _check_geoms(words.shape[1], geoms)
    union: list[int] = []
    seen: set[int] = set()
    for g in geoms:
        for off, w in zip(g.col_word_offsets, g.col_word_widths):
            for word in range(off, off + w):
                if word not in seen:
                    seen.add(word)
                    union.append(word)
    union.sort()
    pos = {word: i for i, word in enumerate(union)}
    shared = jnp.take(words, jnp.asarray(union, dtype=jnp.int32), axis=1)
    outs = []
    for g in geoms:
        idx = []
        for off, w in zip(g.col_word_offsets, g.col_word_widths):
            idx.extend(pos[word] for word in range(off, off + w))
        outs.append(jnp.take(shared, jnp.asarray(idx, dtype=jnp.int32), axis=1))
    return tuple(outs)
