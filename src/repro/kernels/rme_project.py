"""The RME projection engine as Pallas TPU kernels — BSL / PCK / MLP revisions.

Paper §5.2 evaluates three hardware revisions of the engine; we reproduce each
as a structurally faithful Pallas variant (see DESIGN.md §2 for the mapping):

* ``BSL`` — baseline: one Fetch-Unit transaction at a time, each extracted
  column chunk written straight to the Reorganization Buffer.  Pallas grid is
  ``(row_blocks, Q)``: one enabled column copied per grid step, stored directly
  into its slice of the output block (many small stores; the output block is
  revisited Q times).
* ``PCK`` — packer register: column chunks accumulate in a register until a
  full cache line is assembled, then a single BRAM write.  Pallas: a VMEM
  scratch accumulator collects all Q column slices; the packed block is written
  to the output once, on the last column step.
* ``MLP`` — memory-level parallelism (16 outstanding transactions).  Pallas:
  whole-row tiles stream through the automatically double-buffered pipeline
  (outstanding DMAs), and all Q columns are sliced and packed in one vectorized
  step.  This is the TPU-native formulation and the production default, exactly
  as MLP is the paper's production revision.

Tables are int32 word buffers ``(N, row_words)``; geometry is static (the
configuration port is written once per query, paper Table 1), so each distinct
geometry traces its own kernel — matching "the RME is runtime-configurable and
hence usable for multiple queries" at the cost of one trace per geometry.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.schema import TableGeometry

from .common import DEFAULT_BLOCK_ROWS, column_slices as _column_slices
from .common import pad_rows as _pad_rows

__all__ = [
    "DEFAULT_BLOCK_ROWS", "project", "project_xla", "vmem_footprint_bytes",
]


# --------------------------------------------------------------------- MLP
def _mlp_kernel(slices, x_ref, o_ref):
    parts = [x_ref[:, src : src + w] for src, _, w in slices]
    o_ref[...] = jnp.concatenate(parts, axis=1)


# --------------------------------------------------------------------- PCK
def _pck_kernel(slices, q, x_ref, o_ref, acc_ref):
    j = pl.program_id(1)
    for jj, (src, dst, w) in enumerate(slices):
        @pl.when(j == jj)
        def _copy(src=src, dst=dst, w=w):
            # the packer register accumulates one column chunk per transaction
            acc_ref[:, dst : dst + w] = x_ref[:, src : src + w]

    @pl.when(j == q - 1)
    def _flush():
        # single write of the fully packed line to the reorganization buffer
        o_ref[...] = acc_ref[...]


# --------------------------------------------------------------------- BSL
def _bsl_kernel(slices, x_ref, o_ref):
    j = pl.program_id(1)
    for jj, (src, dst, w) in enumerate(slices):
        @pl.when(j == jj)
        def _copy(src=src, dst=dst, w=w):
            # no packer: every extracted chunk is its own buffer write
            o_ref[:, dst : dst + w] = x_ref[:, src : src + w]


@functools.partial(
    jax.jit, static_argnames=("geom", "revision", "block_rows", "interpret")
)
def project(
    words: jax.Array,
    geom: TableGeometry,
    revision: str = "mlp",
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> jax.Array:
    """Packed projection ``(N, row_words) -> (N, out_words)`` via the RME.

    ``interpret=True`` executes the kernel body on CPU (validation); on a real
    TPU deployment this flag is dropped and the same BlockSpecs drive HBM→VMEM
    DMA.  ``words.shape[1]`` may exceed ``geom.row_words`` (hidden MVCC words
    ride along in storage but are never shipped unless enabled).
    """
    n, row_words = words.shape
    if row_words < geom.row_words:
        raise ValueError(f"storage rows {row_words}w < geometry rows {geom.row_words}w")
    out_w = geom.out_words_per_row
    slices = _column_slices(geom)
    x = _pad_rows(words, block_rows)
    n_pad = x.shape[0]
    grid_rows = n_pad // block_rows

    in_spec_row = pl.BlockSpec((block_rows, row_words), lambda i, *_: (i, 0))
    out_shape = jax.ShapeDtypeStruct((n_pad, out_w), jnp.int32)

    if revision == "mlp":
        out = pl.pallas_call(
            functools.partial(_mlp_kernel, slices),
            grid=(grid_rows,),
            in_specs=[pl.BlockSpec((block_rows, row_words), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((block_rows, out_w), lambda i: (i, 0)),
            out_shape=out_shape,
            interpret=interpret,
        )(x)
    elif revision == "pck":
        out = pl.pallas_call(
            functools.partial(_pck_kernel, slices, geom.q),
            grid=(grid_rows, geom.q),
            in_specs=[in_spec_row],
            out_specs=pl.BlockSpec((block_rows, out_w), lambda i, j: (i, 0)),
            out_shape=out_shape,
            scratch_shapes=[pltpu.VMEM((block_rows, out_w), jnp.int32)],
            interpret=interpret,
        )(x)
    elif revision == "bsl":
        out = pl.pallas_call(
            functools.partial(_bsl_kernel, slices),
            grid=(grid_rows, geom.q),
            in_specs=[in_spec_row],
            out_specs=pl.BlockSpec((block_rows, out_w), lambda i, j: (i, 0)),
            out_shape=out_shape,
            interpret=interpret,
        )(x)
    else:
        raise ValueError(f"unknown RME revision {revision!r}")
    return out[:n]


@functools.partial(jax.jit, static_argnames=("geom",))
def project_xla(words: jax.Array, geom: TableGeometry) -> jax.Array:
    """Production XLA path (fused gather); semantically identical to the kernels.

    Used where the program is lowered for CPU/dry-run (Pallas TPU kernels are
    swapped in on real hardware by `repro.core.engine` revision selection).
    """
    idx = []
    for off, w in zip(geom.col_word_offsets, geom.col_word_widths):
        idx.extend(range(off, off + w))
    return jnp.take(words, jnp.asarray(idx, dtype=jnp.int32), axis=1)


def vmem_footprint_bytes(
    geom: TableGeometry, block_rows: int = DEFAULT_BLOCK_ROWS, revision: str = "mlp"
) -> int:
    """Modeled VMEM working set of one grid step (the 'data SPM' budget).

    MLP double-buffers the row tile (Pallas pipeline) and holds the packed
    output block; PCK adds the packer scratch; BSL holds a row tile + output.
    """
    row_tile = block_rows * geom.row_words * 4
    out_tile = block_rows * geom.out_words_per_row * 4
    if revision == "mlp":
        return 2 * row_tile + 2 * out_tile  # double-buffered in and out
    if revision == "pck":
        return row_tile + 2 * out_tile
    return row_tile + out_tile
