"""Pallas TPU kernels for the Relational Memory Engine + LM hot spots.

``rme_project``     — the paper's core contribution (BSL/PCK/MLP revisions)
``rme_filter``      — fused selection + projection pushdown
``rme_aggregate``   — fused selection + aggregation and one-hot MXU group-by
``flash_attention`` — fused GQA attention (the LM cells' memory-term fix)
``ops``             — jit'd public wrappers;  ``ref`` — pure-jnp oracles

Submodules are imported explicitly (``from repro.kernels import ops``) to
keep the package import acyclic with ``repro.core``.
"""
