"""Device-resident hash equi-join — the last §8 offload escape hatch closed.

The paper's closing claim is that Relational Memory "can be easily extended
to support offloading of a number of operations to hardware, e.g., selection,
group by, aggregation, and joins".  Selection, aggregation, and group-by ride
the heterogeneous one-pass scan (``rme_scan_multi``); joins, until now, were
slimmed to {key, payload} on device and then sort-probed on the CPU.  This
module moves the probe itself next to the data:

* :func:`build_partitions` hash-partitions the build side's
  ``{key, payload, __ts_begin, __ts_end}`` columns into **static device
  buckets** — a ``(P, C)`` array per column, ``P`` buckets of capacity ``C``
  (the observed maximum occupancy, so nothing ever overflows).  Built once
  per build-table version and cached exactly like the q5 sorted index
  (:mod:`repro.core.planner`).
* :func:`hash_join` probes in one Pallas grid pass that streams the probe
  rows — straight out of the :class:`~repro.core.engine.DeviceRowStore`
  chunks, or out of a packed block the shared scan already produced — and
  emits the same static-shape contract as the host route: one slot per probe
  row (``s_proj``, ``r_proj``) plus a ``matched`` validity mask.

TPU adaptation: buckets are selected with a one-hot MXU contraction (the
``groupby_sum`` idiom), not a gather.  Because float32 matmuls are only exact
to 2^24, every int32 bucket column travels as two exact 16-bit halves through
the contraction and is recombined bitwise afterwards — bit-exact selection on
the MXU, no dynamic indexing in the kernel.

The bucket hash is **Fibonacci multiplicative hashing**: ``bucket = (key *
2654435761) >>> (32 - log2 P)`` (the top bits of the wrapped product, same
modular arithmetic in numpy, Pallas, and XLA).  Taking high bits matters: a
plain ``key mod P`` degenerates to one bucket for stride-aligned keys (every
multiple of P lands in bucket 0), blowing the dense ``(P, C)`` arrays up to
``P × n`` words, while the multiplicative mix spreads any stride pattern
uniformly — capacity only degenerates if the build side violates its
documented primary-key (duplicate-free) contract.  Empty bucket slots are
filled with ``1`` in bucket 0 and ``0`` elsewhere: ``hash(0) = 0`` and
``hash(1) = 2654435761 >>> (32 - log2 P) >= 1``, so a fill value can never
hash to its own bucket, and since a probe key only ever compares against its
own bucket's slots, fills can never false-match.

MVCC fuses on both sides: the probe pass tests the probe rows' hidden
timestamp words in-scan (``ts_word >= 0``), and the bucket ``begin``/``end``
columns let the same snapshot test run against the *build* rows — one cached
partition set serves any snapshot time, because ``ts`` is a traced operand.

``hash_join_xla`` is the fused-gather fallback (plain ``jnp.take`` bucket
lookup) used for the ``xla`` revision and as the per-query escape when the
Pallas probe fails to lower — non-TPU targets keep working, mirroring
``scan_multi_xla``.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .common import DEFAULT_BLOCK_ROWS, pad_rows

# target average bucket occupancy: P is the smallest power of two with
# n_rows / P <= TARGET_BUCKET_LOAD (capacity C is then the observed maximum)
TARGET_BUCKET_LOAD = 16

# Fibonacci hashing constant (2654435761 = floor(2^32 / golden ratio)); the
# int32 spelling is its two's-complement bit pattern — jnp int32 multiplies
# wrap, giving the same modular product as the numpy uint32 build-side math
MIX_UINT32 = np.uint32(2654435761)
MIX_INT32 = np.int32(np.uint32(2654435761).astype(np.int64) - (1 << 32))


class JoinPartitions(NamedTuple):
    """The build side as static device buckets: four ``(P, C)`` int32 arrays.

    A NamedTuple of arrays on purpose — the planner's join build cache
    accounts entry bytes by iterating the entry, exactly as it does for the
    sorted-index tuples it already holds.  Empty ``keys`` slots hold a fill
    that provably hashes to a *different* bucket (see :func:`bucket_fills`),
    so they can never false-match; their ``begin=1, end=0`` timestamps are
    never visible at any snapshot either.
    """

    keys: jax.Array  # (P, C) raw int32 key words
    vals: jax.Array  # (P, C) raw int32 payload words
    begin: jax.Array  # (P, C) __ts_begin of each build row
    end: jax.Array  # (P, C) __ts_end of each build row

    @property
    def num_buckets(self) -> int:
        return self.keys.shape[0]

    @property
    def capacity(self) -> int:
        return self.keys.shape[1]

    @property
    def nbytes(self) -> int:
        return sum(a.size * a.dtype.itemsize for a in self)


def num_buckets_for(n_rows: int) -> int:
    """Smallest power-of-two bucket count with average load <= the target
    (never below 2, so the hash has at least one output bit)."""
    p = 2
    while p * TARGET_BUCKET_LOAD < n_rows:
        p <<= 1
    return p


def bucket_of_np(key: np.ndarray, p: int) -> np.ndarray:
    """Fibonacci bucket hash, numpy spelling: top ``log2 p`` bits of the
    wrapped ``key * 2654435761`` product.  Must stay bit-identical to the
    in-kernel spelling (:func:`_bucket_of`)."""
    mixed = np.asarray(key, dtype=np.int32).view(np.uint32) * MIX_UINT32
    return (mixed >> np.uint32(32 - (p.bit_length() - 1))).astype(np.int64)


def _bucket_of(key, p: int):
    """Fibonacci bucket hash, traced (jnp) spelling — int32 wrap-around
    multiply + logical shift, bit-identical to :func:`bucket_of_np`."""
    mixed = key * jnp.int32(MIX_INT32)
    return jax.lax.shift_right_logical(mixed, 32 - (p.bit_length() - 1))


def bucket_fills(p: int) -> np.ndarray:
    """Per-bucket empty-slot key fills that provably never false-match:
    ``hash(0) = 0`` (safe everywhere but bucket 0) and ``hash(1) =
    2654435761 >>> (32 - log2 p) >= 1`` for any ``p >= 2`` (safe in bucket
    0).  A probe key equal to a fill hashes to the fill's own bucket, which
    is never the bucket holding it."""
    fills = np.zeros(p, dtype=np.int32)
    fills[0] = 1
    return fills


def estimated_partition_bytes(n_rows: int) -> int:
    """Planner-side estimate of a build table's partition-array bytes (four
    ``(P, C)`` int32 arrays at the target load) — the build-upload term of
    the join route cost model, available before anything is built."""
    p = num_buckets_for(n_rows)
    c = max(1, -(-n_rows // p))
    return 4 * p * c * 4


def build_partitions(
    key: np.ndarray,
    val: np.ndarray,
    ts_begin: np.ndarray | None = None,
    ts_end: np.ndarray | None = None,
) -> JoinPartitions:
    """Hash-partition the build side's raw column words into device buckets.

    Host-side preprocessing (numpy), run once per build-table version; the
    returned arrays are the device-resident state every subsequent probe
    reuses.  The Fibonacci hash spreads any stride-aligned key pattern
    uniformly, so capacity stays near the target load for every
    duplicate-free key set; genuinely repeated keys (a violation of the
    build side's primary-key contract, or MVCC version pairs from updates)
    degrade capacity, never correctness.
    """
    key = np.asarray(key, dtype=np.int32)
    val = np.asarray(val, dtype=np.int32)
    n = key.shape[0]
    p = num_buckets_for(n)
    g = bucket_of_np(key, p)
    counts = np.bincount(g, minlength=p)
    cap = max(int(counts.max()) if n else 1, 1)
    # slot index of each row within its bucket (stable order within buckets)
    order = np.argsort(g, kind="stable")
    starts = np.cumsum(counts) - counts
    slot = np.arange(n, dtype=np.int64) - np.repeat(starts, counts)
    gb, sb = g[order], slot

    def scatter(fill: np.ndarray, values: np.ndarray) -> jax.Array:
        arr = np.broadcast_to(fill[:, None], (p, cap)).copy()
        arr[gb, sb] = values[order]
        return jnp.asarray(arr)

    return JoinPartitions(
        keys=scatter(bucket_fills(p), key),  # fills provably never match
        vals=scatter(np.zeros(p, np.int32), val),
        begin=scatter(np.ones(p, np.int32),
                      np.zeros(n, np.int32) if ts_begin is None
                      else np.asarray(ts_begin, dtype=np.int32)),
        end=scatter(np.zeros(p, np.int32),
                    np.zeros(n, np.int32) if ts_end is None
                    else np.asarray(ts_end, dtype=np.int32)),
    )


# ------------------------------------------------------------ Pallas probe
def _split16(words: jax.Array) -> tuple[jax.Array, jax.Array]:
    """int32 -> two float32 halves, each exactly representable (< 2^16)."""
    hi = jax.lax.shift_right_logical(words, 16).astype(jnp.float32)
    lo = (words & 0xFFFF).astype(jnp.float32)
    return hi, lo


def _merge16(hi: jax.Array, lo: jax.Array) -> jax.Array:
    """Recombine the exact halves into the original int32 bit pattern."""
    return (hi.astype(jnp.int32) << 16) | lo.astype(jnp.int32)


def _onehot_select(onehot: jax.Array, bucket_words: jax.Array) -> jax.Array:
    """Bit-exact per-row bucket selection on the MXU: ``(B, P) @ (P, C)``
    contractions over the two 16-bit halves, recombined bitwise."""
    hi, lo = _split16(bucket_words)
    dims = (((1,), (0,)), ((), ()))
    sel_hi = jax.lax.dot_general(onehot, hi, dims,
                                 preferred_element_type=jnp.float32)
    sel_lo = jax.lax.dot_general(onehot, lo, dims,
                                 preferred_element_type=jnp.float32)
    return _merge16(sel_hi, sel_lo)


def _probe_kernel(key_word, val_word, ts_word, build_ts, n_rows,
                  x_ref, bk_ref, bv_ref, bb_ref, be_ref, ts_ref,
                  s_ref, r_ref, m_ref):
    i = pl.program_id(0)
    block_rows = x_ref.shape[0]
    p = bk_ref.shape[0]
    s_key = x_ref[:, key_word]
    g = _bucket_of(s_key, p)
    onehot = (
        g[:, None] == jax.lax.iota(jnp.int32, p)[None, :]
    ).astype(jnp.float32)  # (B, P)
    match = _onehot_select(onehot, bk_ref[...]) == s_key[:, None]  # (B, C)
    ts = ts_ref[0, 0]
    if build_ts:
        match = match & (_onehot_select(onehot, bb_ref[...]) <= ts)
        match = match & (ts < _onehot_select(onehot, be_ref[...]))
    ridx = i * block_rows + jax.lax.iota(jnp.int32, block_rows)
    valid = ridx < n_rows
    if ts_word >= 0:
        valid = valid & (x_ref[:, ts_word] <= ts) & (ts < x_ref[:, ts_word + 1])
    matched = jnp.any(match, axis=1) & valid
    r_val = jnp.sum(
        jnp.where(match, _onehot_select(onehot, bv_ref[...]), 0), axis=1
    )  # primary-key build side: at most one slot matches
    s_ref[...] = jnp.where(valid, x_ref[:, val_word], 0)[:, None]
    r_ref[...] = jnp.where(matched, r_val, 0)[:, None]
    m_ref[...] = matched[:, None].astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("key_word", "val_word", "ts_word", "build_ts",
                     "block_rows", "interpret"),
)
def _hash_join(
    words: jax.Array,
    bk: jax.Array,
    bv: jax.Array,
    bb: jax.Array,
    be: jax.Array,
    ts_arr: jax.Array,  # (1, 1) int32 traced snapshot time
    key_word: int,
    val_word: int,
    ts_word: int,
    build_ts: bool,
    block_rows: int,
    interpret: bool,
):
    n, row_words = words.shape
    x = pad_rows(words, block_rows)
    n_pad = x.shape[0]
    p, c = bk.shape
    full = pl.BlockSpec((p, c), lambda i: (0, 0))
    col = pl.BlockSpec((block_rows, 1), lambda i: (i, 0))
    out_shape = jax.ShapeDtypeStruct((n_pad, 1), jnp.int32)
    return pl.pallas_call(
        functools.partial(_probe_kernel, key_word, val_word, ts_word,
                          build_ts, n),
        grid=(n_pad // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, row_words), lambda i: (i, 0)),
            full, full, full, full,
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[col, col, col],
        out_shape=[out_shape, out_shape, out_shape],
        interpret=interpret,
    )(x, bk, bv, bb, be, ts_arr)


def hash_join(
    words: jax.Array,
    partitions: JoinPartitions,
    key_word: int,
    val_word: int,
    ts_word: int = -1,
    ts: int = 0,
    build_ts: bool = False,
    revision: str = "mlp",
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Probe ``words`` (a row-store chunk or a packed block) against cached
    build partitions; returns ``(s_proj, r_proj, matched)`` with one slot per
    probe row.

    ``key_word``/``val_word`` address the probe key and payload within the
    row stride — schema offsets when streaming the device row store, packed
    offsets when probing a shared-scan output.  ``ts_word >= 0`` fuses the
    probe-side MVCC test from the hidden timestamp words; ``build_ts`` fuses
    the same test against the build rows' bucketed timestamps.  ``ts`` is a
    traced operand: distinct snapshot times never retrace.  Rows are
    position-local, so per-chunk outputs concatenate (the
    ``scan_multi_chunked`` contract).
    """
    if revision == "xla":
        return hash_join_xla(words, partitions, key_word, val_word,
                             ts_word=ts_word, ts=ts, build_ts=build_ts)
    ts_arr = jnp.asarray([[ts]], dtype=jnp.int32)
    n = words.shape[0]
    s, r, m = _hash_join(
        words, *partitions, ts_arr, key_word=key_word, val_word=val_word,
        ts_word=ts_word, build_ts=build_ts, block_rows=block_rows,
        interpret=interpret,
    )
    return s[:n, 0], r[:n, 0], m[:n, 0].astype(bool)


@functools.partial(
    jax.jit,
    static_argnames=("key_word", "val_word", "ts_word", "build_ts"),
)
def _hash_join_xla(words, bk, bv, bb, be, ts_arr, key_word, val_word,
                   ts_word, build_ts):
    p = bk.shape[0]
    s_key = words[:, key_word]
    g = _bucket_of(s_key, p)
    match = jnp.take(bk, g, axis=0) == s_key[:, None]  # (N, C)
    ts = ts_arr[0, 0]
    if build_ts:
        match = match & (jnp.take(bb, g, axis=0) <= ts)
        match = match & (ts < jnp.take(be, g, axis=0))
    valid = jnp.ones(s_key.shape, dtype=bool)
    if ts_word >= 0:
        valid = (words[:, ts_word] <= ts) & (ts < words[:, ts_word + 1])
    matched = jnp.any(match, axis=1) & valid
    r_val = jnp.sum(jnp.where(match, jnp.take(bv, g, axis=0), 0), axis=1)
    return (
        jnp.where(valid, words[:, val_word], 0),
        jnp.where(matched, r_val, 0),
        matched,
    )


def hash_join_xla(
    words: jax.Array,
    partitions: JoinPartitions,
    key_word: int,
    val_word: int,
    ts_word: int = -1,
    ts: int = 0,
    build_ts: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused-gather probe fallback: one ``jnp.take`` bucket lookup per
    partition column, then the same match/visibility math as the Pallas pass.
    Lowers anywhere; the ``xla`` revision and per-query lowering-failure
    fallback both dispatch here."""
    ts_arr = jnp.asarray([[ts]], dtype=jnp.int32)
    return _hash_join_xla(words, *partitions, ts_arr, key_word=key_word,
                          val_word=val_word, ts_word=ts_word,
                          build_ts=build_ts)


def probe_vmem_footprint_bytes(
    partitions: JoinPartitions, row_words: int,
    block_rows: int = DEFAULT_BLOCK_ROWS,
) -> int:
    """Modeled VMEM working set of one probe grid step: the double-buffered
    row tile and output columns, plus the bucket arrays resident for the
    whole pass."""
    return (2 * block_rows * (row_words + 3) * 4) + partitions.nbytes


def broadcast_partitions(
    partitions: JoinPartitions, devices,
) -> list[JoinPartitions]:
    """Shard-local entry point: replicate the (small) build-side partition
    set onto every shard's device — the join's only collective.

    ``devices`` is one entry per shard; ``None`` means a logical shard on the
    current device (the replica is the original, no transfer).  The sharded
    engine charges ``(shards - 1) * partitions.nbytes`` of interconnect
    traffic for this broadcast — build partitions are O(build rows), never
    O(probe rows), which is what keeps collective bytes proportional to the
    smaller relation."""
    return [
        partitions if d is None else jax.device_put(partitions, d)
        for d in devices
    ]
