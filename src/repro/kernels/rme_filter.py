"""Fused selection + projection kernel (Q2-style pushdown).

``SELECT A1 FROM t WHERE A3 > k``: the engine ships only the projected column
group, with rows failing the predicate zeroed and a validity bitmap alongside.
Static-shape TPU adaptation of the paper's future-work selection offload: the
row *positions* are preserved (no compaction — XLA needs static shapes), so the
consumer runs predicated compute on the packed view.  The data-movement win is
identical to the paper's: non-projected columns never leave the engine.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.schema import TableGeometry

from .common import DEFAULT_BLOCK_ROWS
from .common import decode as _decode
from .common import pred_mask as _pred


def _filter_kernel(spec, x_ref, k_ref, ts_ref, o_ref, m_ref):
    slices, pred_word, pred_dtype, pred_op, ts_word, n_rows = spec
    i = pl.program_id(0)
    block_rows = x_ref.shape[0]

    k = _decode(k_ref[0, 0], pred_dtype)
    mask = _pred(_decode(x_ref[:, pred_word], pred_dtype), pred_op, k)
    ridx = i * block_rows + jax.lax.iota(jnp.int32, block_rows)
    mask = mask & (ridx < n_rows)
    if ts_word >= 0:
        ts = ts_ref[0, 0]
        mask = mask & (x_ref[:, ts_word] <= ts) & (ts < x_ref[:, ts_word + 1])

    parts = [x_ref[:, src : src + w] for src, _, w in slices]
    packed = jnp.concatenate(parts, axis=1)
    o_ref[...] = jnp.where(mask[:, None], packed, 0)
    m_ref[...] = mask[:, None].astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=(
        "geom", "pred_word", "pred_dtype", "pred_op", "ts_word", "block_rows",
        "interpret",
    ),
)
def filter_project(
    words: jax.Array,
    geom: TableGeometry,
    pred_word: int,
    pred_dtype: str = "int32",
    pred_op: str = "gt",
    pred_k=0,
    ts: int = 0,
    ts_word: int = -1,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Returns ``(packed (N, out_words) int32, mask (N,) bool)``."""
    n, row_words = words.shape
    pad = (-n) % block_rows
    if pad:
        words = jnp.concatenate(
            [words, jnp.zeros((pad, row_words), dtype=jnp.int32)], axis=0
        )
    n_pad = words.shape[0]
    out_w = geom.out_words_per_row
    slices = tuple(
        zip(geom.col_word_offsets, geom.out_word_offsets, geom.col_word_widths)
    )
    k_arr = jnp.asarray(
        pred_k, dtype=jnp.float32 if pred_dtype == "float32" else jnp.int32
    )
    k_bits = jax.lax.bitcast_convert_type(k_arr, jnp.int32).reshape(1, 1)
    ts_arr = jnp.asarray(ts, dtype=jnp.int32).reshape(1, 1)
    spec = (slices, pred_word, pred_dtype, pred_op, ts_word, n)

    packed, mask = pl.pallas_call(
        functools.partial(_filter_kernel, spec),
        grid=(n_pad // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, row_words), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, out_w), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, out_w), jnp.int32),
            jax.ShapeDtypeStruct((n_pad, 1), jnp.int32),
        ],
        interpret=interpret,
    )(words, k_bits, ts_arr)
    return packed[:n], mask[:n, 0].astype(bool)
