"""seamless-m4t-medium — encoder-decoder, multimodal.  [arXiv:2308.11596; hf]

Backbone only: the speech frontend is a stub — ``input_specs`` provides
precomputed frame embeddings (B, S/8, D) for the encoder (8× conv
subsampling), while the decoder consumes text tokens.  Decode shapes
exercise the decoder with the fixed encoder context.
"""

from .base import ArchConfig

FULL = ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,  # decoder layers
    n_enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=256206,
    enc_subsample=8,
    rope_theta=1e4,
    mlp_kind="gelu",  # vanilla transformer FFN
    source="arXiv:2308.11596",
)

SMOKE = ArchConfig(
    name="seamless-smoke",
    family="audio",
    n_layers=2,
    n_enc_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab=512,
    enc_subsample=8,
    rope_theta=1e4,
    mlp_kind="gelu",
    attn_chunk=64,
    loss_chunk=64,
)
