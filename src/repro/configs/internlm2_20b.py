"""internlm2-20b — dense GQA transformer.  [arXiv:2403.17297; hf]"""

from .base import ArchConfig

FULL = ArchConfig(
    name="internlm2-20b",
    family="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=92544,
    grad_accum=8,
    scan_unroll=2,
    rope_theta=1e6,
    mlp_kind="swiglu",
    source="arXiv:2403.17297",
)

SMOKE = ArchConfig(
    name="internlm2-20b-smoke",
    family="dense",
    n_layers=3,
    d_model=96,
    n_heads=6,
    n_kv_heads=3,
    head_dim=16,
    d_ff=192,
    vocab=512,
    rope_theta=1e4,
    attn_chunk=64,
    loss_chunk=64,
)
