"""Config schema + shape registry for the assigned architecture matrix."""

from __future__ import annotations

import dataclasses
import importlib
from typing import Iterator

# ----------------------------------------------------------------- configs
@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One architecture, expressed as a layer pattern over block kinds.

    ``block_pattern`` is the repeat unit (e.g. 5 local + 1 global for
    gemma3); layers = pattern repeated ``n_layers // len(pattern)`` times,
    plus a prefix tail for the remainder.  Kinds: ``attn`` (global causal),
    ``local`` (sliding window), ``moe`` (global attn + MoE FFN), ``ssd``
    (Mamba-2 mixer, no FFN), ``rglru`` (RG-LRU mixer + FFN).
    """

    name: str
    family: str  # dense | moe | vlm | ssm | audio | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    block_pattern: tuple[str, ...] = ("attn",)
    window: int = 1024  # sliding window for "local" kinds
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    mrope: bool = False  # M-RoPE (qwen2-vl): positions are (B, 3, S)
    mlp_kind: str = "swiglu"  # swiglu | geglu | gelu (vanilla)
    embed_inputs: bool = True  # False: batch provides precomputed embeddings
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba2)
    ssm_state: int = 128
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    # hybrid (recurrentgemma)
    lru_width: int = 0  # 0 -> d_model
    # enc-dec (seamless)
    n_enc_layers: int = 0  # >0 selects the encoder-decoder family
    enc_subsample: int = 8  # frontend stub: frames = seq // subsample
    # numerics / scale
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"  # master params+moments; bf16 for MoE giants
    grad_accum: int = 1  # microbatches per step (activation-memory control)
    scan_unroll: int = 1  # units per scan step (residual-checkpoint control)
    attn_chunk: int = 1024
    loss_chunk: int = 2048
    vocab_pad_to: int = 128
    sub_quadratic: bool = False  # eligible for long_500k (DESIGN.md skip rules)
    notes: str = ""
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return ((self.vocab + p - 1) // p) * p

    @property
    def n_units(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def tail_pattern(self) -> tuple[str, ...]:
        return self.block_pattern[: self.n_layers % len(self.block_pattern)]

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    def param_count(self) -> int:
        """Exact parameter count N for the 6·N·D model-FLOPs estimate."""
        d, hd = self.d_model, self.resolved_head_dim
        h, k = self.n_heads, self.n_kv_heads
        attn = d * hd * (h + 2 * k) + h * hd * d
        if self.qkv_bias:
            attn += hd * (h + 2 * k)
        if self.qk_norm:
            attn += 2 * hd
        mlp = d * self.d_ff * (3 if self.mlp_kind in ("swiglu", "geglu") else 2)
        moe = d * self.n_experts + self.n_experts * d * self.d_ff * 3
        di = self.ssm_expand * d
        ssm_h = di // self.ssm_head_dim
        ssd = (
            d * (2 * di + 2 * self.ssm_state + ssm_h)
            + 4 * (di + 2 * self.ssm_state)
            + 3 * ssm_h + di + di * d
        )
        lw = self.lru_width or d
        rglru = d * 2 * lw + 4 * lw + 2 * lw * lw + 2 * lw + lw + lw * d
        per_kind = {
            "attn": attn + mlp + 2 * d,
            "local": attn + mlp + 2 * d,
            "moe": attn + moe + 2 * d,
            "ssd": ssd + d,
            "rglru": attn * 0 + rglru + mlp + 2 * d,
        }
        total = 0
        kinds = list(self.block_pattern) * self.n_units + list(self.tail_pattern)
        for kind in kinds:
            total += per_kind[kind]
        if self.is_encdec:  # encoder self-attn + FFN, decoder adds cross-attn
            total += self.n_enc_layers * (attn + mlp + 2 * d)
            total += self.n_layers * (attn + d)  # cross-attention + norm
        total += self.padded_vocab * d  # embedding
        total += self.padded_vocab * d  # untied lm head
        total += d  # final norm
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.n_experts == 0:
            return self.param_count()
        d = self.d_model
        expert = d * self.d_ff * 3
        inactive = (self.n_experts - self.top_k) * expert
        n_moe_layers = sum(
            1 for kind in (list(self.block_pattern) * self.n_units
                           + list(self.tail_pattern)) if kind == "moe"
        )
        return self.param_count() - n_moe_layers * inactive


# ------------------------------------------------------------------ shapes
@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

ARCH_NAMES = (
    "qwen1.5-110b",
    "qwen3-8b",
    "internlm2-20b",
    "gemma3-27b",
    "llama4-maverick-400b-a17b",
    "qwen3-moe-235b-a22b",
    "qwen2-vl-72b",
    "mamba2-1.3b",
    "seamless-m4t-medium",
    "recurrentgemma-9b",
)

_MODULES = {
    "qwen1.5-110b": "qwen1_5_110b",
    "qwen3-8b": "qwen3_8b",
    "internlm2-20b": "internlm2_20b",
    "gemma3-27b": "gemma3_27b",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "mamba2-1.3b": "mamba2_1_3b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "recurrentgemma-9b": "recurrentgemma_9b",
}


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown architecture {name!r}; want one of {ARCH_NAMES}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> ArchConfig:
    return _module(name).FULL


def get_smoke_config(name: str) -> ArchConfig:
    return _module(name).SMOKE


def cell_status(arch: str, shape: str) -> str:
    """'run' or a 'SKIP: reason' marker per the assignment's skip rules."""
    cfg = get_config(arch)
    sh = SHAPES[shape]
    if sh.name == "long_500k" and not cfg.sub_quadratic:
        return ("SKIP: pure full-attention config — 500k-token KV has no "
                "sub-quadratic mechanism (DESIGN.md §Shape-cell skips)")
    return "run"


def iter_cells() -> Iterator[tuple[str, str, str]]:
    for arch in ARCH_NAMES:
        for shape in SHAPES:
            yield arch, shape, cell_status(arch, shape)
