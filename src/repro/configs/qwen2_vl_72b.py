"""qwen2-vl-72b — VLM backbone with M-RoPE.  [arXiv:2409.12191; hf]

Backbone only, per the assignment: the vision frontend is a stub —
``input_specs`` provides precomputed patch embeddings (B, S, D) plus the
3-component M-RoPE position ids (B, 3, S).
"""

from .base import ArchConfig

FULL = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab=152064,
    qkv_bias=True,  # qwen2 family keeps QKV bias
    mrope=True,
    embed_inputs=False,  # frontend stub: embeddings arrive precomputed
    grad_accum=16,
    scan_unroll=2,
    rope_theta=1e6,
    mlp_kind="swiglu",
    source="arXiv:2409.12191",
)

SMOKE = ArchConfig(
    name="qwen2-vl-smoke",
    family="vlm",
    n_layers=3,
    d_model=96,
    n_heads=6,
    n_kv_heads=2,
    head_dim=16,
    d_ff=192,
    vocab=512,
    qkv_bias=True,
    mrope=True,
    embed_inputs=False,
    rope_theta=1e4,
    attn_chunk=64,
    loss_chunk=64,
)
