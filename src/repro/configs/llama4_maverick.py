"""llama4-maverick-400b-a17b — MoE, 128 routed experts, top-1.

[hf:meta-llama/Llama-4-Scout-17B-16E family; unverified tier]
Per the assignment row every layer is a routed-MoE layer with expert FFN
width d_ff=8192 and top-1 routing (the shared-expert/interleaved-dense
variations of the released checkpoints are out of the assigned geometry —
recorded in DESIGN.md §Arch-applicability notes).
"""

from .base import ArchConfig

FULL = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,  # per-expert hidden width
    vocab=202048,
    block_pattern=("attn", "moe"),  # interleaved dense:MoE 1:1 -> ~400B total
    n_experts=128,
    top_k=1,
    grad_accum=8,  # §Perf iter 2
    scan_unroll=2,
    param_dtype="bfloat16",  # f32 AdamW state cannot fit 395B on 256 chips
    rope_theta=5e5,
    mlp_kind="swiglu",
    source="hf:meta-llama/Llama-4-Scout-17B-16E (family); unverified",
)

SMOKE = ArchConfig(
    name="llama4-maverick-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab=512,
    block_pattern=("moe",),
    n_experts=8,
    top_k=1,
    rope_theta=1e4,
    attn_chunk=64,
    loss_chunk=64,
)
