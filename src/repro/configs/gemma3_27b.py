"""gemma3-27b — dense GQA with 5:1 local:global attention, 128k context.

[hf:google/gemma-3-1b-pt family; unverified tier]  The 5-local:1-global
pattern makes only ~1/6 of layers hold full-length KV, so the config is
``sub_quadratic``-eligible for long_500k: global-layer KV is sequence-sharded
(decode-SP) while local layers keep a 1024-slot ring buffer.
"""

from .base import ArchConfig

FULL = ArchConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,  # gemma3 uses an explicit head_dim (not d_model/heads)
    d_ff=21504,
    vocab=262144,
    block_pattern=("local", "local", "local", "local", "local", "attn"),
    window=1024,
    grad_accum=8,
    qk_norm=True,  # gemma3 applies RMS-norm to q and k
    rope_theta=1e6,
    mlp_kind="geglu",
    sub_quadratic=True,
    source="hf:google/gemma-3-1b-pt (family); unverified",
    notes="62 = 10×(5L+1G) + 2L tail; local window 1024",
)

SMOKE = ArchConfig(
    name="gemma3-27b-smoke",
    family="dense",
    n_layers=8,  # 1 full unit + 2-layer tail exercises the tail path
    d_model=96,
    n_heads=6,
    n_kv_heads=2,
    head_dim=16,
    d_ff=192,
    vocab=512,
    block_pattern=("local", "local", "local", "local", "local", "attn"),
    window=32,
    qk_norm=True,
    rope_theta=1e4,
    mlp_kind="geglu",
    sub_quadratic=True,
    attn_chunk=64,
    loss_chunk=64,
)
