"""qwen3-moe-235b-a22b — 128-expert top-8 MoE.  [hf:Qwen/Qwen3-30B-A3B; hf]"""

from .base import ArchConfig

FULL = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,  # qwen3 family uses explicit head_dim=128 (64·128 > d_model)
    d_ff=1536,  # per-expert hidden (moe_intermediate_size)
    vocab=151936,
    block_pattern=("moe",),
    n_experts=128,
    top_k=8,
    qk_norm=True,  # qwen3 family signature
    grad_accum=4,  # §Perf iter 2: 16 re-gathered expert weights 4× too often
    scan_unroll=2,  # halves residual checkpoints (94 -> 47 scan steps)
    param_dtype="bfloat16",  # f32 AdamW state cannot fit 235B on 256 chips
    rope_theta=1e6,
    mlp_kind="swiglu",
    source="hf:Qwen/Qwen3-30B-A3B (family)",
)

SMOKE = ArchConfig(
    name="qwen3-moe-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=32,
    vocab=512,
    block_pattern=("moe",),
    n_experts=8,
    top_k=2,
    qk_norm=True,
    rope_theta=1e4,
    attn_chunk=64,
    loss_chunk=64,
)
