"""qwen3-8b — dense GQA transformer with QK-norm.  [hf:Qwen/Qwen3-8B; hf]"""

from .base import ArchConfig

FULL = ArchConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab=151936,
    qk_norm=True,  # the Qwen3 signature
    grad_accum=4,
    scan_unroll=2,
    rope_theta=1e6,
    mlp_kind="swiglu",
    source="hf:Qwen/Qwen3-8B",
)

SMOKE = ArchConfig(
    name="qwen3-8b-smoke",
    family="dense",
    n_layers=3,
    d_model=96,
    n_heads=6,
    n_kv_heads=2,
    head_dim=16,
    d_ff=192,
    vocab=512,
    qk_norm=True,
    rope_theta=1e4,
    attn_chunk=64,
    loss_chunk=64,
)
