"""Architecture configs: one module per assigned architecture + the paper's own.

``get_config(name)`` returns the full-size config; ``get_smoke_config(name)``
returns the reduced same-family config used by the CPU smoke tests.  The
``SHAPES`` registry defines the four assigned input-shape cells and the
per-family skip rules (DESIGN.md §Shape-cell skips).
"""

from .base import (  # noqa: F401
    ArchConfig,
    ShapeSpec,
    SHAPES,
    ARCH_NAMES,
    get_config,
    get_smoke_config,
    cell_status,
    iter_cells,
)
