"""qwen1.5-110b — dense GQA transformer with QKV bias.

[hf:Qwen/Qwen1.5-0.5B family scaled per assignment; hf-verified tier]
"""

from .base import ArchConfig

FULL = ArchConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=49152,
    vocab=152064,
    qkv_bias=True,  # the Qwen1.5 signature
    grad_accum=16,
    scan_unroll=2,  # §Perf iter 2: 80 -> 40 residual checkpoints (unroll=4 refuted: +10% memory term, no peak win)
    rope_theta=1e6,
    mlp_kind="swiglu",
    source="hf:Qwen/Qwen1.5-0.5B (family); assignment row",
)

SMOKE = ArchConfig(
    name="qwen1.5-110b-smoke",
    family="dense",
    n_layers=4,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    head_dim=16,
    d_ff=256,
    vocab=512,
    qkv_bias=True,
    rope_theta=1e4,
    mlp_kind="swiglu",
    attn_chunk=64,
    loss_chunk=64,
)
