"""mamba2-1.3b — attention-free SSD (state-space duality).  [arXiv:2405.21060]

O(1)-state decode makes every decode shape (incl. long_500k) runnable.
n_heads/n_kv_heads are unused by the SSD mixer (kept for schema uniformity).
"""

from .base import ArchConfig

FULL = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=0,  # attention-free: SSD blocks only, no FFN
    vocab=50280,
    block_pattern=("ssd",),
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    grad_accum=4,  # §Perf: SSD chunk tensors scale with microbatch; 19->~10 GiB
    sub_quadratic=True,
    source="arXiv:2405.21060; unverified",
)

SMOKE = ArchConfig(
    name="mamba2-smoke",
    family="ssm",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=512,
    block_pattern=("ssd",),
    ssm_state=16,
    ssm_head_dim=16,
    ssm_expand=2,
    ssm_chunk=32,
    sub_quadratic=True,
    attn_chunk=64,
    loss_chunk=64,
)
