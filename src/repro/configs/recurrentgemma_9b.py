"""recurrentgemma-9b — RG-LRU + local attention hybrid, 1 attn : 2 recurrent.

[arXiv:2402.19427; unverified]  Constant-size RG-LRU state + 2048-window
local attention make it sub-quadratic: long_500k runs with O(window) memory.
38 layers = 12×(R,R,L) + 2-layer (R,R) tail.
"""

from .base import ArchConfig

FULL = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,  # MQA, per the assignment row
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    block_pattern=("rglru", "rglru", "local"),
    window=2048,
    lru_width=4096,
    grad_accum=4,
    rope_theta=1e4,
    mlp_kind="geglu",
    sub_quadratic=True,
    source="arXiv:2402.19427; unverified",
)

SMOKE = ArchConfig(
    name="recurrentgemma-smoke",
    family="hybrid",
    n_layers=5,  # 1 unit + (rglru, rglru) tail
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab=512,
    block_pattern=("rglru", "rglru", "local"),
    window=32,
    lru_width=64,
    rope_theta=1e4,
    mlp_kind="geglu",
    sub_quadratic=True,
    attn_chunk=64,
    loss_chunk=64,
)
