"""Training launcher: mesh + rules + sharded state + trainer loop.

On real hardware this is the per-host entrypoint (jax.distributed handles
multi-host init); on this container it runs the same code path over however
many devices the process sees — which is exactly what the integration tests
exercise with forced host-device counts.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \
      --steps 100 --batch 16 --seq 128 --ckpt-dir /tmp/run1

A preempted/killed run restarted with the same flags resumes from the last
checkpoint (elastic: the mesh may differ between runs).
"""

import argparse

import jax
import jax.numpy as jnp
from repro.compat import set_mesh

from repro.configs import get_config, get_smoke_config
from repro.data import RecordStore, TrainPipeline, synthetic_corpus
from repro.distributed.partitioning import axis_rules, rules_for_mesh
from repro.launch import specs as S
from repro.launch.mesh import host_device_mesh
from repro.models import build_model
from repro.train import AdamWConfig, make_train_step
from repro.train.step import init_train_state
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--samples", type=int, default=512)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not cfg.embed_inputs or cfg.is_encdec:
        raise SystemExit("this CLI drives token-input decoder archs; see "
                         "examples/ for VLM/enc-dec batches")
    model = build_model(cfg)
    mesh = host_device_mesh(model_axis=args.model_axis)
    rules = rules_for_mesh(mesh)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    print(f"mesh {mesh_shape}, arch {cfg.name}")

    store = RecordStore(seq_len=args.seq)
    tok, lab = synthetic_corpus(args.samples, args.seq, cfg.vocab, seed=1)
    store.ingest(tok, lab)
    pipe = TrainPipeline(store, batch_size=args.batch, seed=0)

    with axis_rules(rules, mesh_shape), set_mesh(mesh):
        state = init_train_state(model, jax.random.PRNGKey(0))
        state_sh = S.train_state_shardings(
            mesh, jax.eval_shape(lambda: state)
        )
        state = jax.device_put(state, state_sh)
        opt = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                          decay_steps=args.steps)
        step_fn = jax.jit(
            make_train_step(model, opt, grad_accum=cfg.grad_accum),
            in_shardings=(state_sh, None), out_shardings=(state_sh, None),
            donate_argnums=(0,),
        )

        def batches():
            for b in pipe.batches():
                yield {k: jnp.asarray(v) for k, v in b.items()}

        trainer = Trainer(
            step_fn, state, batches(),
            TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                          ckpt_every=args.ckpt_every, log_every=10),
            state_shardings=state_sh,
        )
        if trainer.try_restore():
            print(f"resumed from step {trainer.step}")
            trainer.batches = iter(
                {k: jnp.asarray(v) for k, v in b.items()}
                for b in pipe.batches(start_step=trainer.step)
            )
        history = trainer.run()
    for row in history:
        print(" ".join(f"{k}={v:.4g}" for k, v in row.items()))
    print(f"done at step {trainer.step}; stragglers: {trainer.straggler_steps}")


if __name__ == "__main__":
    main()
