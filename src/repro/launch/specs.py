"""ShapeDtypeStruct stand-ins + partition specs for every dry-run cell.

``input_specs`` returns weak-type-correct, shardable ShapeDtypeStructs for
every model input of a given (arch × shape) cell — the same pattern the
kernels' dry-run uses: nothing is ever allocated.  ``*_shardings`` translate
the logical annotations into NamedShardings for jit's in/out_shardings.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, ArchConfig, ShapeSpec
from repro.distributed.partitioning import logical_spec, params_partition_specs
from repro.train.optimizer import opt_state_specs

SDS = jax.ShapeDtypeStruct


def input_specs(arch: str, shape: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a dry-run cell.

    Weak-type-correct and shardable; nothing is allocated.  For train cells
    this is the training batch; for prefill, the request batch; for decode,
    {tokens, pos} (the KV cache spec comes from ``cache_shapes``).
    """
    from repro.configs import SHAPES, get_config

    cfg = get_config(arch)
    sh = SHAPES[shape]
    if sh.kind == "train":
        return train_batch_shapes(cfg, sh)
    if sh.kind == "prefill":
        return prefill_batch_shapes(cfg, sh)
    return {
        "tokens": decode_token_shapes(cfg, sh),
        "pos": SDS((), jnp.int32),
    }


# ------------------------------------------------------------------ inputs
def train_batch_shapes(cfg: ArchConfig, sh: ShapeSpec) -> dict:
    b, s = sh.global_batch, sh.seq_len
    batch: dict[str, Any] = {"labels": SDS((b, s), jnp.int32)}
    if cfg.is_encdec:
        batch["enc_embeds"] = SDS(
            (b, s // cfg.enc_subsample, cfg.d_model), jnp.bfloat16
        )
        batch["tokens"] = SDS((b, s), jnp.int32)
    elif cfg.embed_inputs:
        batch["tokens"] = SDS((b, s), jnp.int32)
    else:
        batch["embeds"] = SDS((b, s, cfg.d_model), jnp.bfloat16)
        if cfg.mrope:
            batch["positions"] = SDS((b, 3, s), jnp.int32)
    return batch


def prefill_batch_shapes(cfg: ArchConfig, sh: ShapeSpec) -> dict:
    batch = train_batch_shapes(cfg, sh)
    batch.pop("labels")
    return batch


def decode_token_shapes(cfg: ArchConfig, sh: ShapeSpec) -> Any:
    b = sh.global_batch
    if cfg.embed_inputs or cfg.is_encdec:
        return SDS((b, 1), jnp.int32)
    return SDS((b, 1, cfg.d_model), jnp.bfloat16)


def batch_shardings(mesh: Mesh, batch_shapes) -> Any:
    def one(x):
        spec = logical_spec("batch", *([None] * (len(x.shape) - 1)), shape=x.shape)
        return NamedSharding(mesh, spec)

    return jax.tree.map(one, batch_shapes)


# ------------------------------------------------------------------ params
def param_shapes(model, dtype: str | None = None) -> Any:
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    if dtype is None:
        return shapes
    dt = jnp.dtype(dtype)
    return jax.tree.map(
        lambda l: SDS(l.shape, dt if jnp.issubdtype(l.dtype, jnp.floating) else l.dtype),
        shapes,
    )


def param_shardings(mesh: Mesh, shapes) -> Any:
    specs = params_partition_specs(shapes)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def train_state_shapes(model, cfg: ArchConfig) -> dict:
    p = param_shapes(model, cfg.param_dtype)
    return {
        "params": p,
        "opt": {
            "mu": p,
            "nu": p,
            "step": SDS((), jnp.int32),
        },
    }


def train_state_shardings(mesh: Mesh, state_shapes) -> dict:
    pspecs = params_partition_specs(state_shapes["params"])
    ospecs = opt_state_specs(state_shapes["params"])
    as_shard = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree, is_leaf=lambda x: isinstance(x, P)
    )
    return {"params": as_shard(pspecs), "opt": as_shard(ospecs)}


# ------------------------------------------------------------------- cache
def cache_shapes(model, cfg: ArchConfig, sh: ShapeSpec) -> Any:
    return jax.eval_shape(
        functools.partial(model.init_cache, sh.global_batch, sh.seq_len)
    )


_CACHE_AXES = {
    # decode KV caches are sequence-sharded (decode-SP): ring writes stay
    # shard-local and the partial-softmax combine replaces cache gathers
    "k": ("batch", None, "kv_seq", None),
    "v": ("batch", None, "kv_seq", None),
    "cross_k": ("batch", "kv_heads", "kv_seq", None),
    "cross_v": ("batch", "kv_heads", "kv_seq", None),
    "ssm": ("batch", "heads", None, None),
    "conv": ("batch", None, "mlp"),
    "h": ("batch", "mlp"),
}


def cache_partition_specs(cache_shapes_tree) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes_tree)
    specs = []
    for kp, leaf in flat:
        path = tuple(
            p.key if hasattr(p, "key") else str(getattr(p, "idx", p)) for p in kp
        )
        name = path[-1]
        axes = _CACHE_AXES.get(name)
        stacked = path and path[0] == "units"
        shape = tuple(leaf.shape)
        if axes is None:
            specs.append(P(*([None] * len(shape))))
            continue
        inner_shape = shape[1:] if stacked else shape
        spec = logical_spec(*axes, shape=inner_shape)
        if stacked:
            spec = P(None, *spec)
        specs.append(spec)
    return jax.tree_util.tree_unflatten(treedef, specs)


def cache_shardings(mesh: Mesh, cache_shapes_tree) -> Any:
    specs = cache_partition_specs(cache_shapes_tree)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
