"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets its
fake-device XLA flag before the first jax call, and tests/benches keep their
1-device view.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5 exposes explicit axis types; older releases imply Auto
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def _mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    """Arbitrary mesh with Auto axis types (tests, examples, benches)."""
    return _mesh(shape, axes)


def host_device_mesh(model_axis: int = 1) -> Mesh:
    """Mesh over whatever devices this process actually has (CPU tests)."""
    n = len(jax.devices())
    return make_mesh((n // model_axis, model_axis), ("data", "model"))
