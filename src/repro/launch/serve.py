"""Serving launcher: continuous batching with optional int8 weights.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
      --requests 8 --slots 4 --max-new 16 [--int8]
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import build_model
from repro.models.layers import quantize_for_serving
from repro.serve import ServeSession
from repro.serve.engine import Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--int8", action="store_true",
                    help="int8-quantize matmul weights (the decode-cell path)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not cfg.embed_inputs or cfg.is_encdec:
        raise SystemExit("token-input decoder archs only")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.int8:
        params = quantize_for_serving(params)
    sess = ServeSession(model, params, batch_slots=args.slots,
                        max_len=args.max_len)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 8 + i % 8).astype(np.int32),
                    max_new=args.max_new) for i in range(args.requests)]
    for r in reqs:
        sess.submit(r)
    t0 = time.perf_counter()
    sess.run_to_completion()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in reqs)
    print(f"{cfg.name}: {toks} tokens / {dt:.2f}s = {toks/dt:.0f} tok/s "
          f"({'int8' if args.int8 else 'bf16/f32'} weights)")
    for r in reqs[:4]:
        print(f"  req {r.rid}: {r.out}")


if __name__ == "__main__":
    main()
