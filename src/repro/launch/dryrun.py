import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count on first init); 512 placeholder host devices back both production
meshes.  For each cell this driver:

  1. builds the model and ShapeDtypeStruct inputs (no allocation),
  2. jits the right step (train_step / prefill / serve decode_step) with
     explicit in/out shardings from the logical rules,
  3. ``.lower().compile()`` — a sharding mismatch, compile-time OOM, or
     unsupported collective here is a bug in the framework,
  4. records memory_analysis, cost_analysis and the HLO collective bytes
     (trip-count-weighted) into a JSON cell report for §Dry-run / §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh both
  python -m repro.launch.dryrun --all --out results/dryrun
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from repro.compat import set_mesh

from repro.configs import SHAPES, ARCH_NAMES, cell_status, get_config
from repro.distributed.partitioning import axis_rules, rules_for_mesh
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.roofline.analysis import analyze_compiled
from repro.train import AdamWConfig, make_train_step


def model_flops_estimate(cfg, sh) -> float:
    """6·N·D model FLOPs (dense) / 6·N_active·D (MoE); decode: D=batch·1."""
    n = cfg.active_param_count()
    if sh.kind == "train":
        return 6.0 * n * sh.tokens
    if sh.kind == "prefill":
        return 2.0 * n * sh.tokens
    return 2.0 * n * sh.global_batch  # decode: one token per sequence


def lower_cell(arch: str, shape: str, multi_pod: bool):
    cfg = get_config(arch)
    sh = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for_mesh(mesh)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    model = build_model(cfg)

    with axis_rules(rules, mesh_shape), set_mesh(mesh):
        if sh.kind == "train":
            state_shapes = S.train_state_shapes(model, cfg)
            state_shardings = S.train_state_shardings(mesh, state_shapes)
            batch_shapes = S.train_batch_shapes(cfg, sh)
            batch_shardings = S.batch_shardings(mesh, batch_shapes)
            # a microbatch must still divide the batch shards, or its batch
            # dim silently de-shards (replicates!) on the wider mesh — cap
            # grad-accum so each microbatch keeps ≥1 sample per batch shard
            batch_shards = 1
            for name in ("pod", "data"):
                batch_shards *= mesh_shape.get(name, 1)
            grad_accum = max(
                min(cfg.grad_accum, sh.global_batch // batch_shards), 1
            )
            step = make_train_step(
                model, AdamWConfig(), grad_accum=grad_accum
            )
            metrics_shardings = None  # infer: replicated scalars
            jitted = jax.jit(
                step,
                in_shardings=(state_shardings, batch_shardings),
                out_shardings=(state_shardings, metrics_shardings),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state_shapes, batch_shapes)
        elif sh.kind == "prefill":
            params_shapes = S.param_shapes(model, "bfloat16")  # serving dtype
            params_shardings = S.param_shardings(mesh, params_shapes)
            batch_shapes = S.prefill_batch_shapes(cfg, sh)
            batch_shardings = S.batch_shardings(mesh, batch_shapes)
            cache_sh = S.cache_shardings(
                mesh, jax.eval_shape(
                    lambda: model.init_cache(sh.global_batch, sh.seq_len)
                )
            )

            def prefill(params, batch):
                return model.prefill(params, batch, sh.seq_len)

            jitted = jax.jit(
                prefill,
                in_shardings=(params_shardings, batch_shardings),
                out_shardings=(None, cache_sh),
            )
            lowered = jitted.lower(params_shapes, batch_shapes)
        else:  # decode
            # §Perf iteration 5: decode weights are int8-quantized and
            # TP-only sharded — no weight all-gathers in the decode step
            from repro.models.layers import quantize_for_serving

            params_shapes = jax.eval_shape(
                quantize_for_serving, S.param_shapes(model, None)
            )
            params_shardings = S.param_shardings(mesh, params_shapes)
            cache_shapes = jax.eval_shape(
                lambda: model.init_cache(sh.global_batch, sh.seq_len)
            )
            cache_sh = S.cache_shardings(mesh, cache_shapes)
            tok_shapes = S.decode_token_shapes(cfg, sh)
            tok_shardings = S.batch_shardings(mesh, tok_shapes)

            def serve_step(params, cache, tokens, pos):
                return model.decode_step(params, cache, tokens, pos)

            jitted = jax.jit(
                serve_step,
                in_shardings=(
                    params_shardings, cache_sh, tok_shardings, S.replicated(mesh)
                ),
                out_shardings=(None, cache_sh),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(
                params_shapes, cache_shapes, tok_shapes,
                jax.ShapeDtypeStruct((), jnp.int32),
            )
        compiled = lowered.compile()
    return compiled, mesh, cfg, sh


def run_cell(arch: str, shape: str, multi_pod: bool, verbose: bool = True) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    status = cell_status(arch, shape)
    if status != "run":
        return {
            "arch": arch, "shape": shape, "mesh": mesh_name, "status": status,
        }
    t0 = time.time()
    compiled, mesh, cfg, sh = lower_cell(arch, shape, multi_pod)
    dt = time.time() - t0
    result = analyze_compiled(
        compiled, arch=arch, shape=shape, mesh_name=mesh_name,
        n_devices=mesh.devices.size,
        model_flops=model_flops_estimate(cfg, sh),
    )
    mem = compiled.memory_analysis()
    out = dataclasses.asdict(result)
    summary = result.summary()
    out["terms"] = {k: summary[k] for k in ("compute", "memory", "collective")}
    out["dominant"] = summary["dominant"]
    out["useful_flops_ratio"] = summary["useful_flops_ratio"]
    out["roofline_fraction"] = summary["roofline_fraction"]
    out["step_time_lower_bound_s"] = summary["step_time_lower_bound_s"]
    out["compile_seconds"] = dt
    if verbose:
        t = result.terms()
        print(
            f"[{mesh_name}] {arch} × {shape}: compile {dt:.1f}s  "
            f"compute {t['compute']*1e3:.2f}ms  memory {t['memory']*1e3:.2f}ms  "
            f"collective {t['collective']*1e3:.2f}ms  "
            f"dominant={max(t, key=t.get)}  "
            f"peak/device={out['memory']['peak_bytes']/2**30:.2f}GiB"
        )
        print("  memory_analysis:", str(mem).replace(chr(10), " ")[:300])
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--keep-going", action="store_true")
    args = ap.parse_args()

    archs = ARCH_NAMES if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for multi_pod in meshes:
                mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
                fname = os.path.join(
                    args.out, f"{arch}__{shape}__{mesh_name}.json"
                )
                try:
                    out = run_cell(arch, shape, multi_pod)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    failures.append((arch, shape, mesh_name, str(e)))
                    if not args.keep_going:
                        raise
                    continue
                with open(fname, "w") as f:
                    json.dump(out, f, indent=1, default=str)
    if failures:
        print(f"\n{len(failures)} FAILED CELLS:")
        for f4 in failures:
            print("  ", *f4[:3], "->", f4[3][:200])
        raise SystemExit(1)
    print("\nDRY-RUN COMPLETE: all requested cells lowered + compiled.")


if __name__ == "__main__":
    main()
