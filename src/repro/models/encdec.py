"""Encoder-decoder family (seamless-m4t): speech-frontend stub + text decoder.

The encoder consumes precomputed frame embeddings (B, S_enc, D) — the conv
subsampling frontend is a stub per the assignment — through bidirectional
self-attention layers.  The decoder is a causal LM whose layers add
cross-attention over the encoder output; cross-KV is computed once at
prefill and reused by every decode step.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.distributed.partitioning import lsc

from . import layers as L
from .lm import DecoderLM


def _init_cross_attention(key, spec: L.AttnSpec) -> dict:
    # same projection structure as self-attention, no rope at apply time
    return L.init_attention(key, spec)


def _cross_kv(params: dict, spec: L.AttnSpec, enc_out: jax.Array):
    """Project encoder output to (B, K, S_enc, Dh) cross K/V (no rope)."""
    b, s, _ = enc_out.shape
    dt = enc_out.dtype
    k = (enc_out @ L.cast(params["wk"], dt)).reshape(b, s, spec.n_kv_heads, spec.head_dim)
    v = (enc_out @ L.cast(params["wv"], dt)).reshape(b, s, spec.n_kv_heads, spec.head_dim)
    return k.swapaxes(1, 2), v.swapaxes(1, 2)


def _cross_attend(params: dict, spec: L.AttnSpec, x: jax.Array, ck, cv):
    """q from decoder states x (B,S,D); kv (B,K,S_enc,Dh) precomputed."""
    b, s, _ = x.shape
    dt = x.dtype
    q = (x @ L.cast(params["wq"], dt)).reshape(b, s, spec.n_heads, spec.head_dim)
    q = lsc(q, "batch", None, "heads", None)
    kh = spec.n_kv_heads
    g = spec.n_heads // kh
    qh = (q * spec.scale).reshape(b, s, kh, g, spec.head_dim).astype(jnp.float32)
    logits = jnp.einsum("bskgd,bkcd->bskgc", qh, ck.astype(jnp.float32))
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bskgc,bkcd->bskgd", w, cv.astype(jnp.float32))
    out = out.reshape(b, s, spec.n_heads * spec.head_dim).astype(dt)
    return lsc(out @ L.cast(params["wo"], dt), "batch", None, None)


class EncDecLM:
    """Same public interface as DecoderLM; batch adds ``enc_embeds``."""

    def __init__(self, cfg: ArchConfig):
        if not cfg.is_encdec:
            raise ValueError("EncDecLM needs n_enc_layers > 0")
        self.cfg = cfg
        self.compute_dtype = jnp.dtype(cfg.compute_dtype)
        hd = cfg.resolved_head_dim
        base = dict(
            d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=hd, rope_theta=cfg.rope_theta,
        )
        self.enc_spec = L.AttnSpec(**base, causal=False)
        self.dec_spec = L.AttnSpec(**base)
        self.cross_spec = L.AttnSpec(**base)
        # decoder-side LM machinery (embedding, head, chunked loss) is reused
        self._dec = DecoderLM(cfg)

    # ------------------------------------------------------------------ init
    def _init_enc_layer(self, key) -> dict:
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {
            "ln1": L.init_rms_norm(cfg.d_model),
            "mixer": L.init_attention(k1, self.enc_spec),
            "ln2": L.init_rms_norm(cfg.d_model),
            "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_kind),
        }

    def _init_dec_layer(self, key) -> dict:
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "ln1": L.init_rms_norm(cfg.d_model),
            "mixer": L.init_attention(k1, self.dec_spec),
            "ln_x": L.init_rms_norm(cfg.d_model),
            "cross": _init_cross_attention(k2, self.cross_spec),
            "ln2": L.init_rms_norm(cfg.d_model),
            "mlp": L.init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.mlp_kind),
        }

    def init(self, key) -> dict:
        cfg = self.cfg
        k_emb, k_enc, k_dec, k_head = jax.random.split(key, 4)
        v, d = cfg.padded_vocab, cfg.d_model
        return {
            "token_embedding": L.normal(k_emb, (v, d), 1.0),
            "enc_units": jax.vmap(self._init_enc_layer)(
                jax.random.split(k_enc, cfg.n_enc_layers)
            ),
            "units": jax.vmap(self._init_dec_layer)(
                jax.random.split(k_dec, cfg.n_layers)
            ),
            "enc_norm": L.init_rms_norm(d),
            "final_norm": L.init_rms_norm(d),
            "lm_head": L.normal(k_head, (d, v), d**-0.5),
        }

    # --------------------------------------------------------------- encoder
    def encode(self, params: dict, enc_embeds: jax.Array) -> jax.Array:
        cfg = self.cfg
        h = lsc(enc_embeds.astype(self.compute_dtype), "batch", None, None)
        b, s, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))

        def layer_fn(h, p):
            hn = L.rms_norm(h, p["ln1"]["scale"])
            h = h + L.attention_train(
                p["mixer"], self.enc_spec, hn, positions, chunk=cfg.attn_chunk
            )
            hn = L.rms_norm(h, p["ln2"]["scale"])
            h = h + L.mlp(p["mlp"], hn, cfg.mlp_kind)
            return lsc(h, "batch", None, None), None

        h, _ = lax.scan(jax.checkpoint(layer_fn), h, params["enc_units"])
        return L.rms_norm(h, params["enc_norm"]["scale"])

    # --------------------------------------------------------------- decoder
    def _dec_layer_train(self, p, h, positions, enc_out):
        cfg = self.cfg
        hn = L.rms_norm(h, p["ln1"]["scale"])
        h = h + L.attention_train(
            p["mixer"], self.dec_spec, hn, positions, chunk=cfg.attn_chunk
        )
        hn = L.rms_norm(h, p["ln_x"]["scale"])
        ck, cv = _cross_kv(p["cross"], self.cross_spec, enc_out)
        h = h + _cross_attend(p["cross"], self.cross_spec, hn, ck, cv)
        hn = L.rms_norm(h, p["ln2"]["scale"])
        h = h + L.mlp(p["mlp"], hn, cfg.mlp_kind)
        return lsc(h, "batch", None, None)

    def loss(self, params: dict, batch: dict) -> tuple[jax.Array, dict]:
        enc_out = self.encode(params, batch["enc_embeds"])
        x = jnp.take(
            params["token_embedding"].astype(self.compute_dtype),
            batch["tokens"], axis=0,
        )
        x = lsc(x, "batch", None, None)
        b, s = batch["tokens"].shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))

        def layer_fn(h, p):
            return self._dec_layer_train(p, h, positions, enc_out), None

        h, _ = lax.scan(jax.checkpoint(layer_fn), x, params["units"])
        h = L.rms_norm(h, params["final_norm"]["scale"])
        nll = self._dec._chunked_xent(params, h, batch["labels"])
        return nll, {"nll": nll, "aux": jnp.zeros((), jnp.float32)}

    # --------------------------------------------------------------- serving
    def init_cache(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        one = {
            "self": L.init_attention_cache(
                self.dec_spec, batch, max_len, self.compute_dtype
            ),
            "cross_k": jnp.zeros(
                (batch, self.cross_spec.n_kv_heads,
                 max(max_len // cfg.enc_subsample, 1), self.cross_spec.head_dim),
                self.compute_dtype,
            ),
            "cross_v": jnp.zeros(
                (batch, self.cross_spec.n_kv_heads,
                 max(max_len // cfg.enc_subsample, 1), self.cross_spec.head_dim),
                self.compute_dtype,
            ),
        }
        return {
            "units": jax.tree.map(
                lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), one
            )
        }

    def prefill(self, params: dict, batch: dict, max_len: int) -> tuple:
        """Encode + run decoder prompt; emits self-KV and cross-KV caches."""
        cfg = self.cfg
        enc_out = self.encode(params, batch["enc_embeds"])
        x = jnp.take(
            params["token_embedding"].astype(self.compute_dtype),
            batch["tokens"], axis=0,
        )
        b, s = batch["tokens"].shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))

        def layer_fn(h, p):
            hn = L.rms_norm(h, p["ln1"]["scale"])
            mix, self_cache = L.attention_prefill(
                p["mixer"], self.dec_spec, hn, positions, max_len,
                chunk=cfg.attn_chunk,
            )
            h = h + mix
            hn = L.rms_norm(h, p["ln_x"]["scale"])
            ck, cv = _cross_kv(p["cross"], self.cross_spec, enc_out)
            h = h + _cross_attend(p["cross"], self.cross_spec, hn, ck, cv)
            hn = L.rms_norm(h, p["ln2"]["scale"])
            h = h + L.mlp(p["mlp"], hn, cfg.mlp_kind)
            cache = {"self": self_cache, "cross_k": ck, "cross_v": cv}
            return lsc(h, "batch", None, None), cache

        h, caches = lax.scan(layer_fn, lsc(x, "batch", None, None), params["units"])
        h = L.rms_norm(h, params["final_norm"]["scale"])
        return self._dec._logits(params, h), {"units": caches}

    def decode_step(
        self, params: dict, cache: dict, tokens: jax.Array, pos: jax.Array
    ) -> tuple:
        cfg = self.cfg
        x = jnp.take(
            params["token_embedding"].astype(self.compute_dtype), tokens, axis=0
        )
        x = lsc(x, "batch", None, None)

        def layer_fn(h, inp):
            p, c = inp
            hn = L.rms_norm(h, p["ln1"]["scale"])
            mix, self_cache = L.attention_decode(
                p["mixer"], self.dec_spec, hn, c["self"], pos
            )
            h = h + mix
            hn = L.rms_norm(h, p["ln_x"]["scale"])
            h = h + _cross_attend(
                p["cross"], self.cross_spec, hn, c["cross_k"], c["cross_v"]
            )
            hn = L.rms_norm(h, p["ln2"]["scale"])
            h = h + L.mlp(p["mlp"], hn, cfg.mlp_kind)
            new_c = {"self": self_cache, "cross_k": c["cross_k"],
                     "cross_v": c["cross_v"]}
            return lsc(h, "batch", None, None), new_c

        h, caches = lax.scan(layer_fn, x, (params["units"], cache["units"]))
        h = L.rms_norm(h, params["final_norm"]["scale"])
        return self._dec._logits(params, h), {"units": caches}
