"""Model zoo: the ten assigned architectures in pure JAX.

Every architecture is expressed as a *layer pattern* over a small set of
mixer/channel-mix blocks (``layers.py``), stacked with ``lax.scan`` over
repeat units so the lowered HLO stays small enough to compile 512-way SPMD
programs quickly.  ``lm.py`` is the decoder-only family (dense GQA, MoE,
SSM, hybrid, VLM backbone); ``encdec.py`` covers seamless-m4t.
"""

from .registry import build_model, MODEL_FAMILIES  # noqa: F401
