"""Decoder-only LM covering dense / MoE / VLM / SSM / hybrid families.

The architecture is a *layer pattern* (configs.base.ArchConfig): a repeat
unit of block kinds scanned ``n_units`` times plus an unrolled tail.  One
``lax.scan`` over stacked unit parameters keeps the lowered HLO small — a
94-layer MoE at 512-way SPMD compiles in seconds instead of minutes — and
``jax.checkpoint`` around the unit body gives layer-granular rematerialization.

Interface (shared with the enc-dec family):
  init(key) -> params                           f32 master parameters
  loss(params, batch) -> (loss, metrics)        train forward (bf16 compute)
  prefill(params, batch, max_len) -> (logits, cache)
  decode_step(params, cache, tokens, pos) -> (logits, cache)
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.distributed.partitioning import lsc

from . import layers as L

ATTN_KINDS = ("attn", "local", "moe")


class DecoderLM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.compute_dtype = jnp.dtype(cfg.compute_dtype)
        hd = cfg.resolved_head_dim
        base = dict(
            d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=hd, qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm,
            rope_theta=cfg.rope_theta, mrope=cfg.mrope,
        )
        self.attn_specs = {
            "attn": L.AttnSpec(**base),
            "local": L.AttnSpec(**base, window=cfg.window),
            "moe": L.AttnSpec(**base),
        }
        self.moe_spec = L.MoESpec(
            d_model=cfg.d_model, d_ff=cfg.d_ff, n_experts=cfg.n_experts,
            top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
        ) if cfg.n_experts else None
        self.ssd_spec = L.SSDSpec(
            d_model=cfg.d_model, d_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim,
            expand=cfg.ssm_expand, conv_width=4, chunk=cfg.ssm_chunk,
        )
        self.rglru_spec = L.RGLRUSpec(
            d_model=cfg.d_model, lru_width=cfg.lru_width or cfg.d_model
        )

    # ------------------------------------------------------------------ init
    def _init_layer(self, key, kind: str) -> dict:
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        p: dict[str, Any] = {"ln1": L.init_rms_norm(cfg.d_model)}
        if kind in ATTN_KINDS:
            p["mixer"] = L.init_attention(k1, self.attn_specs[kind])
        elif kind == "ssd":
            p["mixer"] = L.init_ssd(k1, self.ssd_spec)
        elif kind == "rglru":
            p["mixer"] = L.init_rglru(k1, self.rglru_spec)
        else:
            raise ValueError(f"unknown block kind {kind!r}")
        if kind == "moe":
            p["ln2"] = L.init_rms_norm(cfg.d_model)
            p["moe"] = L.init_moe(k2, self.moe_spec)
        elif kind != "ssd":
            p["ln2"] = L.init_rms_norm(cfg.d_model)
            p["mlp"] = L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_kind)
        return p

    def _init_unit(self, key) -> dict:
        ks = jax.random.split(key, len(self.cfg.block_pattern))
        return {
            f"b{i}": self._init_layer(ks[i], kind)
            for i, kind in enumerate(self.cfg.block_pattern)
        }

    def init(self, key) -> dict:
        cfg = self.cfg
        k_emb, k_units, k_tail, k_head = jax.random.split(key, 4)
        params: dict[str, Any] = {}
        v, d = cfg.padded_vocab, cfg.d_model
        if cfg.embed_inputs:
            params["token_embedding"] = L.normal(k_emb, (v, d), 1.0)
        params["units"] = jax.vmap(self._init_unit)(
            jax.random.split(k_units, cfg.n_units)
        )
        params["tail"] = {
            f"b{i}": self._init_layer(k, kind)
            for (i, kind), k in zip(
                enumerate(cfg.tail_pattern),
                jax.random.split(k_tail, max(len(cfg.tail_pattern), 1)),
            )
        }
        params["final_norm"] = L.init_rms_norm(d)
        params["lm_head"] = L.normal(k_head, (d, v), d**-0.5)
        return params

    # --------------------------------------------------------------- forward
    def _apply_layer(self, kind: str, p: dict, h: jax.Array, positions) -> tuple:
        """Pre-norm residual block. Returns (h, aux_loss)."""
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        hn = L.rms_norm(h, p["ln1"]["scale"])
        if kind in ATTN_KINDS:
            mix = L.attention_train(
                p["mixer"], self.attn_specs[kind], hn, positions,
                chunk=cfg.attn_chunk,
            )
        elif kind == "ssd":
            mix = L.ssd_block(p["mixer"], self.ssd_spec, hn)
        else:
            mix = L.rglru_block(p["mixer"], self.rglru_spec, hn)
        h = h + mix
        if kind == "moe":
            hn = L.rms_norm(h, p["ln2"]["scale"])
            h = h + L.moe_block(p["moe"], self.moe_spec, hn)
            aux = L.moe_aux_loss(p["moe"], self.moe_spec, hn)
        elif kind != "ssd":
            hn = L.rms_norm(h, p["ln2"]["scale"])
            h = h + L.mlp(p["mlp"], hn, cfg.mlp_kind)
        return lsc(h, "batch", None, None), aux

    def _stack(self, params: dict, h: jax.Array, positions) -> tuple:
        """Scan the repeat units, then the unrolled tail. Returns (h, aux).

        Perf notes (§Perf iterations 1-2):
        * unit parameters are cast to the compute dtype BEFORE the scan, so
          the per-unit FSDP all-gathers inside the loop move bf16, not f32 —
          half the wire and no whole-buffer converts in the loop body;
        * ``scan_unroll`` units run per scan step: the residual-stream
          checkpoint count drops by that factor (same recompute total),
          trading a little in-step liveness for activation memory.
        """
        cfg = self.cfg
        pattern = cfg.block_pattern
        dt = self.compute_dtype

        def cast_f(p):
            return p.astype(dt) if jnp.issubdtype(p.dtype, jnp.floating) else p

        def unit_fn(h, up):
            aux = jnp.zeros((), jnp.float32)
            for i, kind in enumerate(pattern):
                h, a = self._apply_layer(kind, up[f"b{i}"], h, positions)
                aux = aux + a
            return h, aux

        u = max(getattr(cfg, "scan_unroll", 1), 1)
        if cfg.n_units:
            units = jax.tree.map(cast_f, params["units"])
            if cfg.n_units % u == 0 and u > 1:
                units = jax.tree.map(
                    lambda a: a.reshape((cfg.n_units // u, u) + a.shape[1:]),
                    units,
                )

                def chunk_fn(h, chunk):
                    aux = jnp.zeros((), jnp.float32)
                    for j in range(u):
                        up = jax.tree.map(lambda a, j=j: a[j], chunk)
                        h, a = unit_fn(h, up)
                        aux = aux + a
                    return h, aux

                h, auxs = lax.scan(jax.checkpoint(chunk_fn), h, units)
            else:
                h, auxs = lax.scan(jax.checkpoint(unit_fn), h, units)
            aux = auxs.sum()
        else:
            aux = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(cfg.tail_pattern):
            h, a = self._apply_layer(kind, params["tail"][f"b{i}"], h, positions)
            aux = aux + a
        return h, aux

    def _embed(self, params: dict, batch: dict) -> tuple[jax.Array, Any]:
        cfg = self.cfg
        if cfg.embed_inputs:
            x = jnp.take(
                params["token_embedding"].astype(self.compute_dtype),
                batch["tokens"], axis=0,
            )
            b, s = batch["tokens"].shape
        else:
            x = batch["embeds"].astype(self.compute_dtype)
            b, s = x.shape[:2]
        if cfg.mrope:
            positions = batch.get("positions")
            if positions is None:
                p1 = jnp.broadcast_to(jnp.arange(s), (b, s))
                positions = jnp.broadcast_to(p1[:, None, :], (b, 3, s))
        else:
            positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        return lsc(x, "batch", None, None), positions

    def _chunked_xent(
        self, params: dict, h: jax.Array, labels: jax.Array
    ) -> jax.Array:
        """Cross entropy scanned over sequence chunks.

        Never materializes the full (B, S, V) logits — per step only
        (B, chunk, V) exists, vocab-sharded.  This is what makes the 262k-
        vocab archs fit at seq 4096 × batch 256.
        """
        cfg = self.cfg
        b, s, d = h.shape
        c = min(cfg.loss_chunk, s)
        assert s % c == 0, (s, c)
        n = s // c
        w = params["lm_head"].astype(self.compute_dtype)

        def step(tot, inp):
            hc, lc = inp  # (B,c,D), (B,c)
            logits = jnp.einsum(
                "bcd,dv->bcv", hc, w, preferred_element_type=jnp.float32
            )
            logits = lsc(logits, "batch", None, "vocab")
            lse = jax.nn.logsumexp(logits, axis=-1)  # (B,c)
            gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
            return tot + jnp.sum(lse - gold), None

        hc = jnp.moveaxis(h.reshape(b, n, c, d), 1, 0)
        lc = jnp.moveaxis(labels.reshape(b, n, c), 1, 0)
        total, _ = lax.scan(step, jnp.zeros((), jnp.float32), (hc, lc))
        return total / (b * s)

    def loss(self, params: dict, batch: dict) -> tuple[jax.Array, dict]:
        x, positions = self._embed(params, batch)
        h, aux = self._stack(params, x, positions)
        h = L.rms_norm(h, params["final_norm"]["scale"])
        nll = self._chunked_xent(params, h, batch["labels"])
        loss = nll + 1e-2 * aux
        return loss, {"nll": nll, "aux": aux}

    # --------------------------------------------------------------- serving
    def _layer_cache(self, kind: str, batch: int, max_len: int) -> dict:
        if kind in ATTN_KINDS:
            return L.init_attention_cache(
                self.attn_specs[kind], batch, max_len, self.compute_dtype
            )
        if kind == "ssd":
            return L.init_ssd_state(self.ssd_spec, batch)
        return L.init_rglru_state(self.rglru_spec, batch)

    def init_cache(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        one_unit = {
            f"b{i}": self._layer_cache(kind, batch, max_len)
            for i, kind in enumerate(cfg.block_pattern)
        }
        units = jax.tree.map(
            lambda a: jnp.zeros((cfg.n_units,) + a.shape, a.dtype), one_unit
        )
        tail = {
            f"b{i}": self._layer_cache(kind, batch, max_len)
            for i, kind in enumerate(cfg.tail_pattern)
        }
        return {"units": units, "tail": tail}

    def _prefill_layer(self, kind, p, h, positions, max_len):
        cfg = self.cfg
        hn = L.rms_norm(h, p["ln1"]["scale"])
        if kind in ATTN_KINDS:
            spec = self.attn_specs[kind]
            cache_len = min(max_len, spec.window) if spec.window else max_len
            mix, cache = L.attention_prefill(
                p["mixer"], spec, hn, positions, cache_len, chunk=cfg.attn_chunk
            )
        elif kind == "ssd":
            mix, cache = L.ssd_block(p["mixer"], self.ssd_spec, hn, return_state=True)
        else:
            mix, cache = L.rglru_block(
                p["mixer"], self.rglru_spec, hn, return_state=True
            )
        h = h + mix
        if kind == "moe":
            hn = L.rms_norm(h, p["ln2"]["scale"])
            h = h + L.moe_block(p["moe"], self.moe_spec, hn)
        elif kind != "ssd":
            hn = L.rms_norm(h, p["ln2"]["scale"])
            h = h + L.mlp(p["mlp"], hn, cfg.mlp_kind)
        return lsc(h, "batch", None, None), cache

    def _decode_layer(self, kind, p, h, cache, pos):
        cfg = self.cfg
        hn = L.rms_norm(h, p["ln1"]["scale"])
        if kind in ATTN_KINDS:
            mix, cache = L.attention_decode(
                p["mixer"], self.attn_specs[kind], hn, cache, pos
            )
        elif kind == "ssd":
            mix, cache = L.ssd_decode(p["mixer"], self.ssd_spec, hn, cache)
        else:
            mix, cache = L.rglru_decode(p["mixer"], self.rglru_spec, hn, cache)
        h = h + mix
        if kind == "moe":
            hn = L.rms_norm(h, p["ln2"]["scale"])
            h = h + L.moe_block(p["moe"], self.moe_spec, hn)
        elif kind != "ssd":
            hn = L.rms_norm(h, p["ln2"]["scale"])
            h = h + L.mlp(p["mlp"], hn, cfg.mlp_kind)
        return lsc(h, "batch", None, None), cache

    def _logits(self, params: dict, h_last: jax.Array) -> jax.Array:
        """(B, 1, D) -> (B, V) vocab-sharded logits for the next token."""
        logits = jnp.einsum(
            "bd,dv->bv", h_last[:, -1],
            params["lm_head"].astype(self.compute_dtype),
            preferred_element_type=jnp.float32,
        )
        return lsc(logits, "batch", "vocab")

    def prefill(self, params: dict, batch: dict, max_len: int) -> tuple:
        cfg = self.cfg
        x, positions = self._embed(params, batch)
        pattern = cfg.block_pattern

        def unit_fn(h, up):
            caches = {}
            for i, kind in enumerate(pattern):
                h, c = self._prefill_layer(kind, up[f"b{i}"], h, positions, max_len)
                caches[f"b{i}"] = c
            return h, caches

        if cfg.n_units:
            h, unit_caches = lax.scan(unit_fn, x, params["units"])
        else:
            h, unit_caches = x, {}
        tail_caches = {}
        for i, kind in enumerate(cfg.tail_pattern):
            h, c = self._prefill_layer(
                kind, params["tail"][f"b{i}"], h, positions, max_len
            )
            tail_caches[f"b{i}"] = c
        h = L.rms_norm(h, params["final_norm"]["scale"])
        return self._logits(params, h), {"units": unit_caches, "tail": tail_caches}

    def decode_step(
        self, params: dict, cache: dict, tokens: jax.Array, pos: jax.Array
    ) -> tuple:
        """One decode step. tokens (B, 1) int32 (or embeds (B,1,D)), pos ()."""
        cfg = self.cfg
        if cfg.embed_inputs:
            x = jnp.take(
                params["token_embedding"].astype(self.compute_dtype), tokens, axis=0
            )
        else:
            x = tokens.astype(self.compute_dtype)
        x = lsc(x, "batch", None, None)
        pattern = cfg.block_pattern

        def unit_fn(h, inp):
            up, uc = inp
            new_c = {}
            for i, kind in enumerate(pattern):
                h, c = self._decode_layer(kind, up[f"b{i}"], h, uc[f"b{i}"], pos)
                new_c[f"b{i}"] = c
            return h, new_c

        if cfg.n_units:
            h, unit_caches = lax.scan(unit_fn, x, (params["units"], cache["units"]))
        else:
            h, unit_caches = x, cache["units"]
        tail_caches = {}
        for i, kind in enumerate(cfg.tail_pattern):
            h, c = self._decode_layer(
                kind, params["tail"][f"b{i}"], h, cache["tail"][f"b{i}"], pos
            )
            tail_caches[f"b{i}"] = c
        h = L.rms_norm(h, params["final_norm"]["scale"])
        return self._logits(params, h), {"units": unit_caches, "tail": tail_caches}
