"""Layer library for the ten assigned architectures — pure JAX.

Blocks: RMSNorm, RoPE/M-RoPE, GQA attention (blockwise-causal for train and
prefill, cached for decode, optional sliding window / qk-norm / QKV bias),
SwiGLU/GeGLU/vanilla FFN, sort-based expert-parallel MoE, Mamba-2 SSD
(chunked, MXU-friendly matmuls), RG-LRU (associative scan), causal depthwise
conv.  All arrays are annotated with logical axes (``lsc``) so the same code
lowers for every mesh in the dry-run matrix.

Dtype discipline: parameters are stored f32 (master copy), compute runs in
``cfg.compute_dtype`` (bf16 on TPU), and numerically sensitive reductions
(softmax, norms, SSM/LRU states, losses) stay f32.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax import lax

from repro.distributed.partitioning import (
    current_mesh_shape,
    current_rules,
    logical_spec,
    lsc,
)

Params = dict
F32 = jnp.float32

MASK_VALUE = -1e30


def normal(key, shape, scale, dtype=jnp.float32):
    return scale * jax.random.normal(key, shape, dtype)


def cast(x, dtype):
    """Cast a weight to the compute dtype; dequantizes int8 weight records.

    A quantized weight is the pytree leaf-pair ``{"q": int8 (in, out),
    "s": scale (1, out)}`` (per-output-channel absmax).  The dequant
    multiply fuses into the consuming matmul on TPU, so the HBM read is the
    int8 buffer — the serving path's §Perf iteration 5.
    """
    if isinstance(x, dict) and "q" in x:
        return x["q"].astype(dtype) * x["s"].astype(dtype)
    return x.astype(dtype) if x.dtype != dtype else x


def quantize_weight(w: jax.Array) -> dict:
    """Per-output-channel absmax int8 quantization of a 2D weight."""
    s = jnp.max(jnp.abs(w), axis=0, keepdims=True) / 127.0
    s = jnp.maximum(s, 1e-12)
    q = jnp.clip(jnp.round(w / s), -127, 127).astype(jnp.int8)
    return {"q": q, "s": s.astype(jnp.bfloat16)}


# RG-LRU gate matrices (w_a, w_x) stay bf16: they parameterize decay rates,
# where int8 grid error compounds over thousands of recurrence steps
_QUANT_NAMES = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "w_in",
                "w_out", "w_branch", "w_zx")


def quantize_for_serving(params: Params) -> Params:
    """int8-quantize the large 2D matmul weights for the decode path.

    Embeddings / lm_head / norms / small vectors stay bf16-castable.  The
    quantized tree is TP-only shardable (no FSDP axis needed): a 110B model
    holds 6.9 GB int8 per device at TP=16 — weight all-gathers disappear
    from the decode step.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for kp, leaf in flat:
        name = None
        for p in kp:
            if hasattr(p, "key"):
                name = p.key
        if (
            name in _QUANT_NAMES
            and hasattr(leaf, "ndim")
            and leaf.ndim >= 2
            and jnp.issubdtype(leaf.dtype, jnp.floating)
        ):
            if leaf.ndim == 2:
                out.append(quantize_weight(leaf))
            else:  # stacked unit weights (n_units, in, out): vmap the quant
                out.append(jax.vmap(quantize_weight)(leaf))
        elif (
            name not in ("a_log", "dt_bias", "lambda_", "d_skip")  # stay f32
            and hasattr(leaf, "dtype")
            and jnp.issubdtype(leaf.dtype, jnp.floating)
        ):
            out.append(leaf.astype(jnp.bfloat16))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


# ------------------------------------------------------------------ norms
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(F32))
    return out.astype(dtype)


def init_rms_norm(d: int) -> Params:
    return {"scale": jnp.zeros((d,), F32)}  # stored as (1 + scale), gemma-style


# ------------------------------------------------------------------- RoPE
def mrope_sections(head_dim: int) -> tuple[int, int, int]:
    """Frequency-index split for M-RoPE (temporal, height, width).

    Matches Qwen2-VL's published 16/24/24 split at head_dim=128 and scales
    proportionally elsewhere: s0 = hd/8, s1 = s2 = (hd/2 - s0)/2.
    """
    half = head_dim // 2
    s0 = head_dim // 8
    s1 = (half - s0) // 2
    return (s0, s1, half - s0 - s1)


def rope_cos_sin(
    positions: jax.Array, head_dim: int, theta: float, mrope: bool = False
) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables: positions (B,S) → (B,S,half); (B,3,S) for M-RoPE."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(half, dtype=F32) / half)  # (half,)
    if not mrope:
        ang = positions.astype(F32)[..., None] * freqs  # (B,S,half)
    else:
        if positions.ndim != 3:
            raise ValueError("M-RoPE wants positions (B, 3, S)")
        ang3 = positions.astype(F32)[..., None] * freqs  # (B,3,S,half)
        sec = mrope_sections(head_dim)
        comp = jnp.concatenate(
            [jnp.full((n,), i, jnp.int32) for i, n in enumerate(sec)]
        )  # (half,) -> which of t/h/w drives each frequency
        onehot = jax.nn.one_hot(comp, 3, dtype=F32)  # (half, 3)
        ang = jnp.einsum("bcsf,fc->bsf", ang3, onehot)  # pick component per freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (B,S,H,Dh) rotated with (B,S,half) tables (llama-style half split)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[:, :, None, :].astype(F32)
    s = sin[:, :, None, :].astype(F32)
    x1f, x2f = x1.astype(F32), x2.astype(F32)
    out = jnp.concatenate([x1f * c - x2f * s, x1f * s + x2f * c], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- attention
@dataclasses.dataclass(frozen=True)
class AttnSpec:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    mrope: bool = False
    window: int | None = None  # None = full causal
    causal: bool = True  # False: bidirectional (encoder self-attention)
    softmax_scale: float | None = None

    @property
    def scale(self) -> float:
        return self.softmax_scale or self.head_dim**-0.5


def init_attention(key, spec: AttnSpec) -> Params:
    d, h, k, hd = spec.d_model, spec.n_heads, spec.n_kv_heads, spec.head_dim
    ks = jax.random.split(key, 4)
    s = d**-0.5
    p = {
        "wq": normal(ks[0], (d, h * hd), s),
        "wk": normal(ks[1], (d, k * hd), s),
        "wv": normal(ks[2], (d, k * hd), s),
        "wo": normal(ks[3], (h * hd, d), (h * hd) ** -0.5),
    }
    if spec.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), F32)
        p["bk"] = jnp.zeros((k * hd,), F32)
        p["bv"] = jnp.zeros((k * hd,), F32)
    if spec.qk_norm:
        p["q_norm"] = init_rms_norm(hd)
        p["k_norm"] = init_rms_norm(hd)
    return p


def _qkv(params: Params, spec: AttnSpec, x: jax.Array, cos, sin):
    """Project + rope; returns q (B,S,H,Dh), k/v (B,S,K,Dh)."""
    b, s, _ = x.shape
    dt = x.dtype
    q = x @ cast(params["wq"], dt)
    k = x @ cast(params["wk"], dt)
    v = x @ cast(params["wv"], dt)
    if spec.qkv_bias:
        q = q + cast(params["bq"], dt)
        k = k + cast(params["bk"], dt)
        v = v + cast(params["bv"], dt)
    q = q.reshape(b, s, spec.n_heads, spec.head_dim)
    k = k.reshape(b, s, spec.n_kv_heads, spec.head_dim)
    v = v.reshape(b, s, spec.n_kv_heads, spec.head_dim)
    q = lsc(q, "batch", None, "heads", None)
    k = lsc(k, "batch", None, "kv_heads", None)
    v = lsc(v, "batch", None, "kv_heads", None)
    if spec.qk_norm:
        q = rms_norm(q, params["q_norm"]["scale"])
        k = rms_norm(k, params["k_norm"]["scale"])
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def blockwise_attention(
    q: jax.Array,  # (B, S, H, Dh)
    k: jax.Array,  # (B, S, K, Dh)
    v: jax.Array,
    spec: AttnSpec,
    chunk: int = 1024,
) -> jax.Array:
    """Flash-style causal attention: online-softmax scan over KV chunks.

    Peak memory is O(S * chunk) logits instead of O(S^2); the paper-side
    analogue is the RME never shipping more than a reorg-buffer's worth of
    data at a time.  The ``window`` in ``spec`` applies a sliding-window mask
    (gemma3 local layers, recurrentgemma local attention).
    """
    b, s, h, hd = q.shape
    kh = k.shape[2]
    g = h // kh  # GQA group size
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:  # pad KV to a chunk multiple; padded keys are masked out below
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s_kv = s + pad
    n_kv = s_kv // chunk
    window = spec.window or s_kv

    # keep operands in compute dtype (bf16): collectives and HBM traffic at
    # half width; the MXU accumulates in f32 via preferred_element_type
    qh = (q * spec.scale).reshape(b, s, kh, g, hd)
    q_pos = jnp.arange(s)

    def step(carry, inputs):
        acc, m, l = carry
        kc, vc, kv_start = inputs  # (B, chunk, K, Dh) ×2, scalar
        k_pos = kv_start + jnp.arange(chunk)
        logits = jnp.einsum(
            "bqkgd,bckd->bqkgc", qh, kc, preferred_element_type=F32
        )
        dist = q_pos[:, None] - k_pos[None, :]
        if spec.causal:
            mask = (dist >= 0) & (dist < window)  # (S, chunk)
        else:
            mask = jnp.abs(dist) < window  # bidirectional (encoder)
        mask = mask & (k_pos < s)[None, :]  # drop chunk padding
        logits = jnp.where(mask[None, :, None, None, :], logits, MASK_VALUE)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum(
            "bqkgc,bckd->bqkgd", p.astype(q.dtype), vc,
            preferred_element_type=F32,
        )
        acc_new = acc * alpha[..., None] + pv
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, s, kh, g, hd), F32)
    m0 = jnp.full((b, s, kh, g), -jnp.inf, F32)
    l0 = jnp.zeros((b, s, kh, g), F32)
    kc = k.reshape(b, n_kv, chunk, kh, hd).swapaxes(0, 1)
    vc = v.reshape(b, n_kv, chunk, kh, hd).swapaxes(0, 1)
    del k, v
    starts = jnp.arange(n_kv) * chunk
    # checkpoint the chunk step: its backward recomputes the (S × chunk)
    # probability tile instead of the scan stashing one per chunk — the
    # flash-attention recompute schedule, expressed at the XLA level
    # (§Perf iteration 7; crucial where heads can't shard, e.g. 40 heads
    # on a 16-way model axis)
    (acc, m, l), _ = lax.scan(
        jax.checkpoint(step), (acc0, m0, l0), (kc, vc, starts)
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, s, h, hd).astype(q.dtype)


def _attend(q, k, v, spec: AttnSpec, chunk: int) -> jax.Array:
    """Attention dispatch: fused Pallas kernel on TPU, XLA blockwise else.

    The kernel keeps logits in VMEM (§Perf iteration 6); the XLA path is the
    oracle-checked fallback used on CPU (tests, dry-run lowering).
    """
    if jax.default_backend() == "tpu":  # pragma: no cover - TPU runtime only
        from repro.kernels.flash_attention import flash_attention

        return flash_attention(
            q, k, v, causal=spec.causal, window=spec.window, interpret=False
        )
    return blockwise_attention(q, k, v, spec, chunk=chunk)


def attention_train(
    params: Params, spec: AttnSpec, x: jax.Array, positions: jax.Array,
    chunk: int = 1024,
) -> jax.Array:
    cos, sin = rope_cos_sin(positions, spec.head_dim, spec.rope_theta, spec.mrope)
    q, k, v = _qkv(params, spec, x, cos, sin)
    out = _attend(q, k, v, spec, chunk=chunk)
    out = lsc(out, "batch", None, "heads", None)
    b, s = x.shape[:2]
    out = out.reshape(b, s, spec.n_heads * spec.head_dim)
    return lsc(out @ cast(params["wo"], x.dtype), "batch", None, None)


def attention_prefill(
    params: Params, spec: AttnSpec, x: jax.Array, positions: jax.Array,
    cache_len: int, chunk: int = 1024,
) -> tuple[jax.Array, dict]:
    """Like train, but also emits the KV cache laid out for decode.

    Cache layout: (B, K, cache_len, Dh) with the *sequence* dim annotated
    ``kv_seq`` — sharded over the model axis at serve time (decode-SP), the
    cluster analogue of the RME assembling a line from parallel banks.
    """
    cos, sin = rope_cos_sin(positions, spec.head_dim, spec.rope_theta, spec.mrope)
    q, k, v = _qkv(params, spec, x, cos, sin)
    out = _attend(q, k, v, spec, chunk=chunk)
    b, s = x.shape[:2]
    y = out.reshape(b, s, spec.n_heads * spec.head_dim) @ cast(params["wo"], x.dtype)
    pad = cache_len - (s if spec.window is None else min(s, spec.window))
    ck = k if spec.window is None else k[:, -min(s, spec.window):]
    cv = v if spec.window is None else v[:, -min(s, spec.window):]
    ck = jnp.pad(ck, ((0, 0), (0, max(pad, 0)), (0, 0), (0, 0)))
    cv = jnp.pad(cv, ((0, 0), (0, max(pad, 0)), (0, 0), (0, 0)))
    cache = {
        "k": lsc(ck.swapaxes(1, 2), "batch", None, "kv_seq", None),
        "v": lsc(cv.swapaxes(1, 2), "batch", None, "kv_seq", None),
    }
    return lsc(y, "batch", None, None), cache


def _decode_sp_axes(cache_shape: tuple[int, ...]):
    """Physical axes carrying the decode cache's sequence dim, or None."""
    spec = logical_spec("batch", None, "kv_seq", None, shape=cache_shape)
    entries = list(spec) + [None] * (4 - len(spec))
    seq_axes = entries[2]
    if seq_axes is None:
        return None, None
    seq_axes = seq_axes if isinstance(seq_axes, tuple) else (seq_axes,)
    batch_axes = entries[0]
    if batch_axes is not None and not isinstance(batch_axes, tuple):
        batch_axes = (batch_axes,)
    return seq_axes, batch_axes


def _attention_decode_sp(
    spec: AttnSpec, q, k, v, cache: dict, pos, seq_axes, batch_axes
) -> tuple[jax.Array, dict]:
    """Sequence-parallel cached attention (decode-SP, shard_map).

    The KV cache's sequence dim is sharded over ``seq_axes`` (the model
    axis): each shard owns a contiguous chunk of ring-buffer slots, writes
    the new token *locally* iff it owns the slot, computes partial attention
    over its chunk, and the shards combine with a 3-term online-softmax psum
    — the cluster analogue of the RME assembling one cache line from
    parallel DRAM banks.  No all-gather of the cache, ever.
    """
    b = q.shape[0]  # q: (B, 1, H, Dh)
    kh = spec.n_kv_heads
    g = spec.n_heads // kh
    hd = spec.head_dim
    n_seq = 1
    for a in seq_axes:
        n_seq *= current_mesh_shape().get(a, 1)
    s_cache = cache["k"].shape[2]
    chunk = s_cache // n_seq
    bspec = batch_axes if batch_axes is None else (
        batch_axes if len(batch_axes) > 1 else batch_axes[0]
    )
    sspec = seq_axes if len(seq_axes) > 1 else seq_axes[0]
    cache_spec = jax.sharding.PartitionSpec(bspec, None, sspec, None)
    rep_spec = jax.sharding.PartitionSpec(bspec, None, None, None)

    def local(qh, kn, vn, ck, cv, pos):
        # qh (B,K,G,D) f32-scaled; kn/vn (B,K,1,D); ck/cv (B,K,chunk,D)
        idx = lax.axis_index(seq_axes)
        slot = pos % s_cache
        local_slot = slot - idx * chunk
        ok = (local_slot >= 0) & (local_slot < chunk)
        ls = jnp.clip(local_slot, 0, chunk - 1)
        cur_k = lax.dynamic_slice(ck, (0, 0, ls, 0), kn.shape)
        cur_v = lax.dynamic_slice(cv, (0, 0, ls, 0), vn.shape)
        ck = lax.dynamic_update_slice(ck, jnp.where(ok, kn, cur_k), (0, 0, ls, 0))
        cv = lax.dynamic_update_slice(cv, jnp.where(ok, vn, cur_v), (0, 0, ls, 0))
        k_pos = idx * chunk + jnp.arange(chunk)
        valid = k_pos <= pos
        logits = jnp.einsum("bkgd,bksd->bkgs", qh, ck.astype(F32))
        logits = jnp.where(valid[None, None, None, :], logits, MASK_VALUE)
        m = logits.max(axis=-1)  # (B,K,G)
        mg = lax.pmax(m, seq_axes)
        p = jnp.exp(logits - mg[..., None])
        l_part = p.sum(axis=-1)
        acc = jnp.einsum("bkgs,bksd->bkgd", p, cv.astype(F32))
        l_tot = lax.psum(l_part, seq_axes)
        acc_tot = lax.psum(acc, seq_axes)
        out = acc_tot / jnp.maximum(l_tot[..., None], 1e-30)
        return out, ck, cv

    out, ck, cv = shard_map(
        local,
        in_specs=(rep_spec, rep_spec, rep_spec, cache_spec, cache_spec,
                  jax.sharding.PartitionSpec()),
        out_specs=(rep_spec, cache_spec, cache_spec),
    )(
        (q * spec.scale).reshape(b, kh, g, hd).astype(F32),
        k.swapaxes(1, 2), v.swapaxes(1, 2), cache["k"], cache["v"], pos,
    )
    return out, {"k": ck, "v": cv}


def attention_decode(
    params: Params, spec: AttnSpec, x: jax.Array, cache: dict, pos: jax.Array
) -> tuple[jax.Array, dict]:
    """One-token cached attention. x (B,1,D); cache k/v (B,K,S,Dh); pos ().

    For windowed layers the cache is a ring buffer of size ``window``; the
    write slot is ``pos % window`` and the mask keeps the last ``window``
    positions — constant memory for gemma3-local / recurrentgemma-local at
    524k context.  When the active sharding rules place the cache's sequence
    dim on a mesh axis, the sequence-parallel shard_map path is used (local
    ring writes + online-softmax combine); otherwise the single-device path.
    """
    b = x.shape[0]
    s_cache = cache["k"].shape[2]
    pos_b = jnp.broadcast_to(pos, (b, 1))
    cos, sin = rope_cos_sin(
        pos_b if not spec.mrope else jnp.broadcast_to(pos, (b, 3, 1)),
        spec.head_dim, spec.rope_theta, spec.mrope,
    )
    q, k, v = _qkv(params, spec, x, cos, sin)
    kh = spec.n_kv_heads
    g = spec.n_heads // kh

    seq_axes, batch_axes = _decode_sp_axes(cache["k"].shape)
    if seq_axes is not None:
        out, new_cache = _attention_decode_sp(
            spec, q, k, v, cache, pos, seq_axes, batch_axes
        )
    else:
        # windowed layers use the cache as a ring buffer; full caches never
        # wrap (pos < s_cache), so one modular slot covers both
        slot = pos % s_cache
        ck = lax.dynamic_update_slice(
            cache["k"], k.swapaxes(1, 2), (0, 0, slot, 0)
        )
        cv = lax.dynamic_update_slice(
            cache["v"], v.swapaxes(1, 2), (0, 0, slot, 0)
        )
        qh = (q * spec.scale).reshape(b, kh, g, spec.head_dim).astype(F32)
        logits = jnp.einsum("bkgd,bksd->bkgs", qh, ck.astype(F32))
        # a ring slot only holds one of the last s_cache positions, so slot
        # validity reduces to "has this slot been written yet"
        k_pos = jnp.arange(s_cache)
        valid = k_pos <= pos
        logits = jnp.where(valid[None, None, None, :], logits, MASK_VALUE)
        w = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bkgs,bksd->bkgd", w, cv.astype(F32))
        new_cache = {"k": ck, "v": cv}
    out = out.reshape(b, 1, spec.n_heads * spec.head_dim).astype(x.dtype)
    y = out @ cast(params["wo"], x.dtype)
    return lsc(y, "batch", None, None), new_cache


def init_attention_cache(
    spec: AttnSpec, batch: int, max_len: int, dtype=jnp.bfloat16
) -> dict:
    s = min(max_len, spec.window) if spec.window is not None else max_len
    shape = (batch, spec.n_kv_heads, s, spec.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# ------------------------------------------------------------------- FFNs
def init_mlp(key, d_model: int, d_ff: int, kind: str = "swiglu") -> Params:
    ks = jax.random.split(key, 3)
    s_in, s_out = d_model**-0.5, d_ff**-0.5
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": normal(ks[0], (d_model, d_ff), s_in),
            "w_up": normal(ks[1], (d_model, d_ff), s_in),
            "w_down": normal(ks[2], (d_ff, d_model), s_out),
        }
    return {  # vanilla transformer FFN (seamless encoder/decoder)
        "w_in": normal(ks[0], (d_model, d_ff), s_in),
        "w_down": normal(ks[1], (d_ff, d_model), s_out),
    }


def mlp(params: Params, x: jax.Array, kind: str = "swiglu") -> jax.Array:
    dt = x.dtype
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else functools.partial(
            jax.nn.gelu, approximate=True
        )
        h = act(x @ cast(params["w_gate"], dt)) * (x @ cast(params["w_up"], dt))
        h = lsc(h, "batch", None, "mlp")
        return lsc(h @ cast(params["w_down"], dt), "batch", None, None)
    h = jax.nn.gelu(x @ cast(params["w_in"], dt), approximate=True)
    h = lsc(h, "batch", None, "mlp")
    return lsc(h @ cast(params["w_down"], dt), "batch", None, None)


# -------------------------------------------------------------------- MoE
@dataclasses.dataclass(frozen=True)
class MoESpec:
    d_model: int
    d_ff: int  # per-expert hidden
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25


def init_moe(key, spec: MoESpec) -> Params:
    ks = jax.random.split(key, 4)
    e, d, f = spec.n_experts, spec.d_model, spec.d_ff
    return {
        "router": normal(ks[0], (d, e), d**-0.5),
        "expert_gate": normal(ks[1], (e, d, f), d**-0.5),
        "expert_up": normal(ks[2], (e, d, f), d**-0.5),
        "expert_down": normal(ks[3], (e, f, d), f**-0.5),
    }


def _moe_dispatch_compute(
    spec: MoESpec, xt: jax.Array, probs: jax.Array, wg, wu, wd,
    n_experts: int, expert_base: int, cap: int,
) -> jax.Array:
    """Capacity-bounded top-k dispatch + expert FFN + weighted combine.

    Handles a contiguous expert range [expert_base, expert_base+n_experts):
    tokens routed elsewhere are dropped here (another shard owns them).
    Everything is local compute: argsort, scatter, three matmuls, scatter-add.
    """
    t, d = xt.shape
    dt = xt.dtype
    k = spec.top_k
    gate, idx = lax.top_k(probs, k)  # (T, k) over the FULL expert domain
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    local = idx - expert_base
    mine = (local >= 0) & (local < n_experts)
    slot_expert = jnp.where(mine, local, n_experts).reshape(t * k)  # E -> drop
    slot_token = jnp.repeat(jnp.arange(t), k)
    slot_gate = gate.reshape(t * k)
    order = jnp.argsort(slot_expert, stable=True)
    se = slot_expert[order]
    st = slot_token[order]
    sg = slot_gate[order]
    seg_start = jnp.searchsorted(se, jnp.arange(n_experts))
    rank = jnp.arange(t * k) - seg_start[jnp.minimum(se, n_experts - 1)]
    keep = (rank < cap) & (se < n_experts)
    dest = jnp.where(keep, se * cap + rank, n_experts * cap)  # OOB -> dropped

    buf = jnp.zeros((n_experts * cap, d), dt).at[dest].set(
        xt[st], mode="drop", unique_indices=True
    ).reshape(n_experts, cap, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg))
    h = h * jnp.einsum("ecd,edf->ecf", buf, wu)
    out = jnp.einsum("ecf,efd->ecd", h, wd).reshape(n_experts * cap, d)

    gathered = jnp.where(
        keep[:, None], out.at[dest].get(mode="fill", fill_value=0), 0
    )
    return jnp.zeros((t, d), dt).at[st].add(gathered * sg[:, None].astype(dt))


def _moe_axes() -> tuple | None:
    """(expert_axes, fsdp_axes) when EP sharding rules are active."""
    rules = current_rules()
    if not rules:
        return None
    ea = rules.get("expert")
    if not ea:
        return None
    sizes = current_mesh_shape()
    n = 1
    for a in ea:
        n *= sizes.get(a, 1)
    if n <= 1:
        return None
    return tuple(ea), tuple(rules.get("fsdp") or ())


def moe_block(params: Params, spec: MoESpec, x: jax.Array) -> jax.Array:
    """Token-choice top-k MoE with sort-based, capacity-bounded dispatch.

    Distributed form (§Perf iteration 3): activations are replicated across
    the ``model`` (expert) axis, so dispatch needs NO collectives at all —
    each expert shard selects the tokens routed to ITS experts from its
    local copy (shard_map), runs the expert FFN on weights whose d_model dim
    is all-gathered across the FSDP axis (the only weight movement), and the
    per-shard partial outputs combine with one activation-sized psum.  This
    replaced a pjit scatter formulation whose dispatch buffers XLA could not
    partition (231 GiB/device peak on qwen3-moe → 84 MB local buffers).
    """
    b, s, d = x.shape
    dt = x.dtype
    t = b * s
    e, k = spec.n_experts, spec.top_k

    axes = _moe_axes()
    if axes is None:  # single-device / test path
        cap = max(int(math.ceil(spec.capacity_factor * k * t / e)), 4)
        xt = x.reshape(t, d)
        probs = jax.nn.softmax(
            (xt @ cast(params["router"], dt)).astype(F32), axis=-1
        )
        y = _moe_dispatch_compute(
            spec, xt, probs,
            cast(params["expert_gate"], dt), cast(params["expert_up"], dt),
            cast(params["expert_down"], dt), e, 0, cap,
        )
        return lsc(y.reshape(b, s, d), "batch", None, None)

    expert_axes, fsdp_axes = axes
    sizes = current_mesh_shape()
    n_shards = 1
    for a in expert_axes:
        n_shards *= sizes.get(a, 1)
    n_fsdp = 1
    for a in fsdp_axes:
        n_fsdp *= sizes.get(a, 1)
    e_local = e // n_shards
    f_ff = params["expert_down"].shape[-2]
    rules = current_rules()
    batch_axes = tuple(rules.get("batch") or ())
    bspec = (batch_axes if len(batch_axes) > 1 else batch_axes[0]) if batch_axes else None
    espec = expert_axes if len(expert_axes) > 1 else expert_axes[0]
    fspec = (fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]) if fsdp_axes else None
    P = jax.sharding.PartitionSpec

    # Mode decision (§Perf iteration 9): gathering weights moves ~3·E_l·D·F
    # bytes/shard; keeping weights stationary moves ~tokens·k·(D+F).  Train
    # steps (10^5-10^6 tokens) want the gather; decode (10^2 tokens) wants
    # stationary — the gather form costs 48 GB PER TOKEN STEP on llama4.
    stationary = fsdp_axes and (t * k < 3 * e_local * f_ff)

    def local_gather(xl, router, wg, wu, wd):
        bl, sl, _ = xl.shape
        tl = bl * sl
        cap = max(int(math.ceil(spec.capacity_factor * k * tl / e)), 4)
        xt = xl.reshape(tl, d)
        probs = jax.nn.softmax((xt @ cast(router, dt)).astype(F32), axis=-1)
        shard = lax.axis_index(expert_axes)
        base = shard * e_local
        # complete the weights' d_model dim across the FSDP axis (bf16)
        if fsdp_axes:
            wg = lax.all_gather(cast(wg, dt), fsdp_axes, axis=1, tiled=True)
            wu = lax.all_gather(cast(wu, dt), fsdp_axes, axis=1, tiled=True)
            wd = lax.all_gather(cast(wd, dt), fsdp_axes, axis=2, tiled=True)
        else:
            wg, wu, wd = cast(wg, dt), cast(wu, dt), cast(wd, dt)
        y = _moe_dispatch_compute(spec, xt, probs, wg, wu, wd,
                                  e_local, base, cap)
        # every shard produced the partial output of ITS experts
        y = lax.psum(y, expert_axes)
        return y.reshape(bl, sl, d)

    def local_stationary(xl, router, wg, wu, wd):
        """Decode-sized MoE: tokens travel, the (huge) weights never do.

        All tokens are gathered to every shard (KBs), each (expert, d-slice)
        shard contracts its local weight block, partial activations psum
        across the FSDP axis and expert outputs psum across the expert axis
        — total wire per layer ≈ tokens·(D+F) bytes instead of 3·E_l·D·F.
        """
        bl, sl, _ = xl.shape
        xg = lax.all_gather(xl, batch_axes, axis=0, tiled=True) if batch_axes else xl
        tg = xg.shape[0] * sl
        cap = max(int(math.ceil(spec.capacity_factor * k * tg / e)), 4)
        xt = xg.reshape(tg, d)
        probs = jax.nn.softmax((xt @ cast(router, dt)).astype(F32), axis=-1)
        shard = lax.axis_index(expert_axes)
        base = shard * e_local
        fshard = lax.axis_index(fsdp_axes)
        d_slice = d // n_fsdp
        gate, idx = lax.top_k(probs, k)
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
        local_e = idx - base
        mine = (local_e >= 0) & (local_e < e_local)
        slot_e = jnp.where(mine, local_e, e_local).reshape(tg * k)
        slot_t = jnp.repeat(jnp.arange(tg), k)
        slot_g = gate.reshape(tg * k)
        order = jnp.argsort(slot_e, stable=True)
        se, st, sg = slot_e[order], slot_t[order], slot_g[order]
        seg = jnp.searchsorted(se, jnp.arange(e_local))
        rank = jnp.arange(tg * k) - seg[jnp.minimum(se, e_local - 1)]
        keep = (rank < cap) & (se < e_local)
        dest = jnp.where(keep, se * cap + rank, e_local * cap)
        # dispatch only my d-slice of each token
        xt_slice = lax.dynamic_slice(xt, (0, fshard * d_slice), (tg, d_slice))
        buf = jnp.zeros((e_local * cap, d_slice), dt).at[dest].set(
            xt_slice[st], mode="drop", unique_indices=True
        ).reshape(e_local, cap, d_slice)
        # partial hidden from my d-slice; complete across the FSDP axis
        h = jnp.einsum("ecd,edf->ecf", buf, cast(wg, dt))
        hu = jnp.einsum("ecd,edf->ecf", buf, cast(wu, dt))
        h = lax.psum(jnp.stack([h, hu]), fsdp_axes)
        h = jax.nn.silu(h[0]) * h[1]
        out = jnp.einsum("ecf,efd->ecd", h, cast(wd, dt))  # (E_l, cap, d_slice)
        out = out.reshape(e_local * cap, d_slice)
        gathered = jnp.where(
            keep[:, None], out.at[dest].get(mode="fill", fill_value=0), 0
        )
        y = jnp.zeros((tg, d_slice), dt).at[st].add(
            gathered * sg[:, None].astype(dt)
        )
        y = lax.psum(y, expert_axes)  # combine expert shards
        # reassemble full D, then take my batch rows back
        y = lax.all_gather(y, fsdp_axes, axis=1, tiled=True)  # (tg, D)
        tl = bl * sl
        bshard = lax.axis_index(batch_axes) if batch_axes else 0
        y = lax.dynamic_slice(y, (bshard * tl, 0), (tl, d))
        return y.reshape(bl, sl, d)

    y = shard_map(
        local_stationary if stationary else local_gather,
        in_specs=(
            P(bspec, None, None),  # x: batch-sharded, replicated over model
            P(),  # router (small, replicated)
            P(espec, fspec, None),  # (E, D, F)
            P(espec, fspec, None),
            P(espec, None, fspec),  # (E, F, D)
        ),
        out_specs=P(bspec, None, None),
    )(x, params["router"], params["expert_gate"], params["expert_up"],
      params["expert_down"])
    return lsc(y, "batch", None, None)


def moe_aux_loss(params: Params, spec: MoESpec, x: jax.Array) -> jax.Array:
    """Switch-style load-balancing loss (mean over tokens)."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    logits = (xt @ cast(params["router"], x.dtype)).astype(F32)
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, spec.n_experts, dtype=F32), axis=0)
    imp = jnp.mean(probs, axis=0)
    return spec.n_experts * jnp.sum(frac * imp)


# --------------------------------------------------------- depthwise conv
def causal_conv1d(
    x: jax.Array, kernel: jax.Array, state: jax.Array | None = None
) -> tuple[jax.Array, jax.Array]:
    """Causal depthwise conv. x (B,S,C), kernel (W,C). Returns (y, new_state).

    Implemented as W shifted adds (W is 4): cheap, fusion-friendly, no conv
    primitive.  ``state`` is the last W-1 inputs for streaming decode.
    """
    w = kernel.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], w - 1, x.shape[2]), x.dtype)
    ext = jnp.concatenate([state, x], axis=1)  # (B, S+W-1, C)
    y = sum(
        ext[:, i : i + x.shape[1]] * cast(kernel[i], x.dtype)[None, None, :]
        for i in range(w)
    )
    return y, ext[:, -(w - 1):]


# ---------------------------------------------------------------- Mamba-2
@dataclasses.dataclass(frozen=True)
class SSDSpec:
    d_model: int
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256
    n_groups: int = 1

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def init_ssd(key, spec: SSDSpec) -> Params:
    d, di, n, h = spec.d_model, spec.d_inner, spec.d_state, spec.n_heads
    g = spec.n_groups
    ks = jax.random.split(key, 5)
    conv_ch = di + 2 * g * n
    return {
        # fused input projection: [z, x, B, C, dt]
        "w_zx": normal(ks[0], (d, 2 * di + 2 * g * n + h), d**-0.5),
        "conv_kernel": normal(ks[1], (spec.conv_width, conv_ch), conv_ch**-0.5),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(F32)),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((h,), 1e-2, F32))),  # softplus^-1
        "d_skip": jnp.ones((h,), F32),
        "norm": init_rms_norm(di),
        "w_out": normal(ks[4], (di, d), di**-0.5),
    }


def _ssd_split(params, spec: SSDSpec, x):
    """Input projection + causal conv; returns z, xh, Bm, Cm, dt."""
    b, s, _ = x.shape
    di, n, h, g = spec.d_inner, spec.d_state, spec.n_heads, spec.n_groups
    dt_ = x.dtype
    zxbcdt = x @ cast(params["w_zx"], dt_)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : di + di + 2 * g * n]
    dt = zxbcdt[..., -h:]
    return z, xbc, dt


def _ssd_post(params, spec, y, z):
    y = rms_norm(y * jax.nn.silu(z.astype(F32)).astype(y.dtype),
                 params["norm"]["scale"])
    return lsc(y @ cast(params["w_out"], y.dtype), "batch", None, None)


def ssd_block(
    params: Params, spec: SSDSpec, x: jax.Array, return_state: bool = False
):
    """Mamba-2 SSD, chunked "state-space duality" form (matmuls on the MXU).

    Within a chunk the recurrence is an attention-like masked contraction;
    across chunks a tiny sequential scan carries the (H, P, N) state.  This is
    the TPU-native adaptation: the GPU implementation leans on fused Triton
    scans, the SSD matmul form maps straight onto the MXU.
    """
    b, s, _ = x.shape
    di, n, h, p = spec.d_inner, spec.d_state, spec.n_heads, spec.head_dim
    if spec.n_groups != 1:
        raise NotImplementedError("SSD is implemented for n_groups=1 (mamba2 default)")
    q = min(spec.chunk, s)
    pad = (-s) % q
    s_real = s
    if pad:  # pad to a chunk multiple; padded steps are frozen via dt=0 below
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    nc = s // q

    z, xbc, dt = _ssd_split(params, spec, x)
    xbc_pre = jax.nn.silu(xbc)
    xbc, conv_state = causal_conv1d(xbc_pre, params["conv_kernel"])
    if pad and return_state:  # conv state = last W-1 *valid* inputs
        w = params["conv_kernel"].shape[0]
        ext = jnp.concatenate(
            [jnp.zeros((b, w - 1, xbc_pre.shape[2]), xbc_pre.dtype),
             xbc_pre[:, :s_real]], axis=1,
        )
        conv_state = ext[:, -(w - 1):]
    xh = xbc[..., :di]
    bm = xbc[..., di : di + n]  # (B,S,N), single group
    cm = xbc[..., di + n :]  # (B,S,N)

    dt = jax.nn.softplus(dt.astype(F32) + params["dt_bias"])  # (B,S,H)
    if pad:  # dt=0 on padding: decay=1 and zero input — state passes through
        valid = (jnp.arange(s) < s_real).astype(F32)
        dt = dt * valid[None, :, None]
    a = -jnp.exp(params["a_log"])  # (H,)
    log_decay = dt * a  # (B,S,H) = log a_t  (negative)

    xh = xh.reshape(b, s, h, p)
    xdt = xh.astype(F32) * dt[..., None]  # dt-weighted input

    # chunk views
    xc = xdt.reshape(b, nc, q, h, p)
    bc = bm.reshape(b, nc, q, n).astype(F32)
    cc = cm.reshape(b, nc, q, n).astype(F32)
    ld = log_decay.reshape(b, nc, q, h)
    cum = jnp.cumsum(ld, axis=2)  # (B,nc,Q,H) inclusive cumulative log decay
    total = cum[:, :, -1]  # (B,nc,H)

    # ---- intra-chunk: M[q,k,h] = (C_q . B_k) * exp(cum_q - cum_k) * causal
    gl = jnp.einsum("bcqn,bckn->bcqk", cc, bc)  # (b,nc,Q,K)
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (b,nc,Q,K,H)
    causal = jnp.tril(jnp.ones((q, q), bool))
    m = jnp.where(
        causal[None, None, :, :, None], jnp.exp(decay) * gl[..., None], 0.0
    )
    m = lsc(m, "batch", None, None, None, "heads")
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", m, xc)

    # ---- chunk states: S_c = sum_k B_k ⊗ x_k * exp(total - cum_k)
    w = jnp.exp(total[:, :, None, :] - cum)  # (b,nc,Q,H)
    states = jnp.einsum("bcqn,bcqhp,bcqh->bchpn", bc, xc, w)
    states = lsc(states, "batch", None, "heads", None, None)

    # ---- inter-chunk scan (nc steps, tiny state)
    def scan_fn(h_prev, inp):
        st, tot = inp  # (b,h,p,n), (b,h)
        h_new = h_prev * jnp.exp(tot)[:, :, None, None] + st
        return h_new, h_prev

    h0 = jnp.zeros((b, h, p, n), F32)
    h_final, h_prevs = lax.scan(
        scan_fn, h0, (states.swapaxes(0, 1), total.swapaxes(0, 1))
    )  # h_prevs: (nc, b, h, p, n) = state entering each chunk
    h_prevs = h_prevs.swapaxes(0, 1)  # (b, nc, h, p, n)

    # ---- inter-chunk contribution: Y_inter[q] = (C_q . h_prev) * exp(cum_q)
    y_inter = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", cc, h_prevs, jnp.exp(cum))

    y = (y_intra + y_inter).reshape(b, s, h, p)
    y = y + xh.astype(F32) * params["d_skip"][None, None, :, None]
    y = y.reshape(b, s, di).astype(x.dtype)
    if pad:
        y, z = y[:, :s_real], z[:, :s_real]
    out = _ssd_post(params, spec, y, z)
    if return_state:
        return out, {"conv": conv_state, "ssm": h_final}
    return out


def init_ssd_state(spec: SSDSpec, batch: int, dtype=jnp.float32) -> dict:
    g = spec.n_groups
    return {
        "conv": jnp.zeros(
            (batch, spec.conv_width - 1, spec.d_inner + 2 * g * spec.d_state),
            jnp.bfloat16,
        ),
        "ssm": jnp.zeros((batch, spec.n_heads, spec.head_dim, spec.d_state), dtype),
    }


def ssd_decode(
    params: Params, spec: SSDSpec, x: jax.Array, state: dict
) -> tuple[jax.Array, dict]:
    """Single-token SSD step: h = a*h + B ⊗ (dt*x);  y = C.h + D*x."""
    b = x.shape[0]
    di, n, h, p = spec.d_inner, spec.d_state, spec.n_heads, spec.head_dim
    z, xbc, dt = _ssd_split(params, spec, x)
    xbc, conv_state = causal_conv1d(
        jax.nn.silu(xbc), params["conv_kernel"], state["conv"]
    )
    xh = xbc[:, 0, :di].reshape(b, h, p).astype(F32)
    bm = xbc[:, 0, di : di + n].astype(F32)  # (B,N), single group
    cm = xbc[:, 0, di + n :].astype(F32)  # (B,N)
    dt = jax.nn.softplus(dt[:, 0].astype(F32) + params["dt_bias"])  # (B,H)
    a = jnp.exp(dt * -jnp.exp(params["a_log"]))  # (B,H)
    xdt = xh * dt[..., None]  # (B,H,P)
    h_new = state["ssm"] * a[..., None, None] + xdt[..., None] * bm[:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", h_new, cm)
    y = y + xh * params["d_skip"][None, :, None]
    y = y.reshape(b, 1, di).astype(x.dtype)
    out = _ssd_post(params, spec, y, z)
    return out, {"conv": conv_state, "ssm": h_new}


# ----------------------------------------------------------------- RG-LRU
@dataclasses.dataclass(frozen=True)
class RGLRUSpec:
    d_model: int
    lru_width: int
    conv_width: int = 4
    c: float = 8.0  # the paper's fixed temperature


def init_rglru(key, spec: RGLRUSpec) -> Params:
    d, w = spec.d_model, spec.lru_width
    ks = jax.random.split(key, 6)
    # Λ init so that a = sigmoid(Λ)^c lands in [0.9, 0.999] (griffin init)
    u = jax.random.uniform(ks[0], (w,), F32, 0.9**2, 0.999**2)
    lam = jnp.log(u ** (1.0 / spec.c) / (1 - u ** (1.0 / spec.c)))
    return {
        "w_branch": normal(ks[1], (d, 2 * w), d**-0.5),  # [gate branch, rec branch]
        "conv_kernel": normal(ks[2], (spec.conv_width, w), w**-0.5),
        "w_a": normal(ks[3], (w, w), w**-0.5),  # recurrence gate
        "b_a": jnp.zeros((w,), F32),
        "w_x": normal(ks[4], (w, w), w**-0.5),  # input gate
        "b_x": jnp.zeros((w,), F32),
        "lambda_": lam,
        "w_out": normal(ks[5], (w, d), w**-0.5),
    }


def _rglru_gates(params, spec, xr):
    """Per-step gate math shared by scan and decode. xr (…, W) f32."""
    r = jax.nn.sigmoid(xr @ cast(params["w_a"], F32) + cast(params["b_a"], F32))
    i = jax.nn.sigmoid(xr @ cast(params["w_x"], F32) + cast(params["b_x"], F32))
    log_a = -spec.c * r * jax.nn.softplus(params["lambda_"])  # (…, W)
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, mult * i * xr


def rglru_block(
    params: Params, spec: RGLRUSpec, x: jax.Array, return_state: bool = False
):
    """Griffin recurrent block: conv → RG-LRU (associative scan) → gate-mix."""
    b, s, d = x.shape
    dt = x.dtype
    branches = x @ cast(params["w_branch"], dt)
    gate = jax.nn.gelu(branches[..., : spec.lru_width], approximate=True)
    xr, conv_state = causal_conv1d(
        branches[..., spec.lru_width :], params["conv_kernel"]
    )
    xr = lsc(xr, "batch", None, "mlp").astype(F32)

    a, bterm = _rglru_gates(params, spec, xr)  # (B,S,W) each

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, h = lax.associative_scan(combine, (a, bterm), axis=1)
    y = (h.astype(dt) * gate)
    y = lsc(y, "batch", None, "mlp")
    out = lsc(y @ cast(params["w_out"], dt), "batch", None, None)
    if return_state:
        return out, {"conv": conv_state, "h": h[:, -1]}
    return out


def init_rglru_state(spec: RGLRUSpec, batch: int) -> dict:
    return {
        "conv": jnp.zeros((batch, spec.conv_width - 1, spec.lru_width), jnp.bfloat16),
        "h": jnp.zeros((batch, spec.lru_width), F32),
    }


def rglru_decode(
    params: Params, spec: RGLRUSpec, x: jax.Array, state: dict
) -> tuple[jax.Array, dict]:
    b, _, d = x.shape
    dt = x.dtype
    branches = x @ cast(params["w_branch"], dt)
    gate = jax.nn.gelu(branches[..., : spec.lru_width], approximate=True)
    xr, conv_state = causal_conv1d(
        branches[..., spec.lru_width :], params["conv_kernel"], state["conv"]
    )
    xr = xr[:, 0].astype(F32)
    a, bterm = _rglru_gates(params, spec, xr)
    h = a * state["h"] + bterm
    y = (h[:, None, :].astype(dt) * gate)
    return (
        lsc(y @ cast(params["w_out"], dt), "batch", None, None),
        {"conv": conv_state, "h": h},
    )
