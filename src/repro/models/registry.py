"""Model registry: config -> model object (shared init/loss/prefill/decode)."""

from __future__ import annotations

from repro.configs.base import ArchConfig

from .encdec import EncDecLM
from .lm import DecoderLM

MODEL_FAMILIES = ("dense", "moe", "vlm", "ssm", "audio", "hybrid")


def build_model(cfg: ArchConfig):
    if cfg.is_encdec:
        return EncDecLM(cfg)
    return DecoderLM(cfg)
