"""Serving substrate: prefill/decode step factories + batched sessions."""

from .engine import ServeSession, make_decode_step, make_prefill

__all__ = ["ServeSession", "make_decode_step", "make_prefill"]
