"""Serving substrate: LM prefill/decode sessions + the relational QueryServer."""

from .engine import ServeSession, make_decode_step, make_prefill
from .query_server import QueryServer, QueryTicket, ServerStats

__all__ = [
    "QueryServer",
    "QueryTicket",
    "ServeSession",
    "ServerStats",
    "make_decode_step",
    "make_prefill",
]
