"""Serving substrate: LM prefill/decode sessions + the relational QueryServer."""

from .engine import ServeSession, make_decode_step, make_prefill
from .query_server import (
    DeadlineExceeded,
    LaneStats,
    LatencyReservoir,
    QueryServer,
    QueryTicket,
    ServerOverloaded,
    ServerStats,
    StreamingTicket,
)

__all__ = [
    "DeadlineExceeded",
    "LaneStats",
    "LatencyReservoir",
    "QueryServer",
    "QueryTicket",
    "ServeSession",
    "ServerOverloaded",
    "ServerStats",
    "StreamingTicket",
    "make_decode_step",
    "make_prefill",
]
