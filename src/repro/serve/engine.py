"""Batched serving: continuous decode over a fixed-capacity request batch.

``serve_step`` (what the decode-shape dry-runs lower) is one cached decode
step over the whole batch: (params, cache, tokens, pos) -> (logits, cache).
``ServeSession`` wraps it with a small scheduler: requests join free slots,
finished slots free on EOS/length, every slot shares the same jitted step —
the standard continuous-batching shape for TPU serving (static shapes; slot
liveness is a mask, not a dynamic batch).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


def make_prefill(model, max_len: int) -> Callable:
    def prefill(params, batch):
        return model.prefill(params, batch, max_len)

    return prefill


def make_decode_step(model) -> Callable:
    def decode_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)

    return decode_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeSession:
    """Greedy continuous-batching session over one model + cache capacity.

    The implementation is deliberately synchronous (one decode step per
    ``tick``): scheduling policy, slot reuse, and EOS handling are the parts
    a cluster serving stack needs correct; async plumbing is orthogonal.
    """

    def __init__(self, model, params, batch_slots: int, max_len: int,
                 eos_id: int = -1):
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.prefill_fn = jax.jit(make_prefill(model, max_len))
        self.decode_fn = jax.jit(make_decode_step(model))
        self.cache = model.init_cache(batch_slots, max_len)
        self.live: dict[int, Request] = {}  # slot -> request
        self.pos = 0
        self.queue: list[Request] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        """Admit queued requests into free slots (same-length prompt batch)."""
        free = [s for s in range(self.slots) if s not in self.live]
        admit = self.queue[: len(free)]
        if not admit:
            return
        del self.queue[: len(admit)]
        s_len = max(len(r.prompt) for r in admit)
        toks = np.zeros((self.slots, s_len), np.int32)
        for slot, r in zip(free, admit):
            toks[slot, -len(r.prompt):] = r.prompt
            self.live[slot] = r
        logits, cache = self.prefill_fn(self.params, {"tokens": jnp.asarray(toks)})
        self.cache = cache
        self.pos = s_len
        nxt = np.asarray(jnp.argmax(logits, -1))
        for slot, r in zip(free, admit):
            r.out.append(int(nxt[slot]))

    def tick(self) -> bool:
        """One decode step for every live slot; returns False when idle."""
        if not self.live and self.queue:
            self._admit()
        if not self.live:
            return False
        toks = np.zeros((self.slots, 1), np.int32)
        for slot, r in self.live.items():
            toks[slot, 0] = r.out[-1] if r.out else 0
        logits, self.cache = self.decode_fn(
            self.params, self.cache, jnp.asarray(toks),
            jnp.asarray(self.pos, jnp.int32),
        )
        self.pos += 1
        nxt = np.asarray(jnp.argmax(logits, -1))
        for slot in list(self.live):
            r = self.live[slot]
            tok = int(nxt[slot])
            r.out.append(tok)
            if tok == self.eos_id or len(r.out) >= r.max_new or (
                self.pos >= self.max_len - 1
            ):
                r.done = True
                del self.live[slot]
        return True

    def run_to_completion(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            if not self.tick() and not self.queue:
                break
