"""Concurrent query serving: many clients, one engine, shared scans per tick.

The paper's closing argument (§8) is that native column access "can vastly
simplify the software logic" of an analytics engine.  This module is the
multi-tenant half of that story: a :class:`QueryServer` owns one
:class:`~repro.core.engine.RelationalMemoryEngine` and admits *logical plans*
(:mod:`repro.core.plan`) from any number of concurrent clients.  Requests are
not executed as they arrive — they queue, and each serving **tick** drains a
batch, compiles every plan (:func:`repro.core.planner.compile_plan`), and
coalesces all of the batch's scan ops into **one** ``execute_many`` call:
same-table work from different clients — projections, fused filters, fused
aggregates, and group-bys alike — rides a single shared Fetch-Unit stream
(the heterogeneous one-pass kernel ``rme_scan_multi``), so a mixed-kind
same-table tick performs exactly one row-store pass instead of one per op
kind.  Nothing in the tick syncs with the host until finalize.

Threading model: ``submit`` is thread-safe and non-blocking (clients get a
:class:`QueryTicket` and block on ``result()`` at their leisure); all engine
work happens on whichever single thread calls ``run_tick`` — either the
caller's (deterministic, what the tests drive) or the background serving
thread started by ``start()``/the ``serving()`` context manager.  JAX traces
and device buffers are therefore never touched from two threads at once.

Accounting: the server reports engine-level :class:`~repro.core.engine.
EngineStats` plus its own :class:`ServerStats` — queue depth, shared-scan
ratio (cold table-groups served by a genuine multi-view scan), and
``bytes_saved``: the row-store bytes a per-query cold execution of the same
traffic would have moved minus what the shared scans actually moved
(union-geometry pricing, the same Eq.(3) bus-beat model the planner costs
with).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any, Mapping

import numpy as np

from repro.core.engine import RelationalMemoryEngine
from repro.core.plan import PlanBuilder, PlanNode
from repro.core.planner import PhysicalQuery, compile_plan
from repro.core.requests import ProjectOp


class QueryTicket:
    """A client's handle on one admitted query; resolved at end of its tick."""

    __slots__ = ("client", "submitted_at", "latency_s", "route",
                 "_event", "_result", "_error")

    def __init__(self, client: str):
        self.client = client
        self.submitted_at = time.perf_counter()
        self.latency_s: float | None = None
        self.route: str | None = None
        self._event = threading.Event()
        self._result: Any = None
        self._error: BaseException | None = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None) -> Any:
        """Block until served; re-raises compile/execution errors."""
        if not self._event.wait(timeout):
            raise TimeoutError(f"query for client {self.client!r} not served")
        if self._error is not None:
            raise self._error
        return self._result

    def _resolve(self, result: Any = None, error: BaseException | None = None,
                 route: str | None = None) -> None:
        self.latency_s = time.perf_counter() - self.submitted_at
        self.route = route
        self._result, self._error = result, error
        self._event.set()


@dataclasses.dataclass
class ServerStats:
    """Serving-layer counters (the engine's own PMU counts the bytes)."""

    submitted: int = 0
    served: int = 0
    failed: int = 0
    ticks: int = 0
    max_queue_depth: int = 0
    table_groups: int = 0  # cold same-table view groups across all ticks
    table_groups_shared: int = 0  # of those, served by a multi-view shared scan
    bytes_saved: int = 0  # row-store bytes avoided vs per-query cold execution
    latency_sum_s: float = 0.0
    latency_max_s: float = 0.0

    @property
    def shared_scan_ratio(self) -> float:
        """Fraction of cold table-groups that coalesced into a shared scan."""
        return self.table_groups_shared / max(self.table_groups, 1)

    @property
    def mean_latency_s(self) -> float:
        return self.latency_sum_s / max(self.served, 1)


@dataclasses.dataclass
class _Admitted:
    ticket: QueryTicket
    node: PlanNode
    path: str
    colstore: Mapping[str, np.ndarray] | None
    right_colstore: Mapping[str, np.ndarray] | None


class QueryServer:
    """Admission queue + tick executor over one relational memory engine."""

    def __init__(
        self,
        engine: RelationalMemoryEngine | None = None,
        max_batch: int = 64,
    ):
        self.engine = engine if engine is not None else RelationalMemoryEngine()
        self.max_batch = max_batch
        self.stats = ServerStats()
        self._lock = threading.Lock()
        self._queue: deque[_Admitted] = deque()
        # per-client running (count, sum_s, max_s) — scalars, not a sample
        # list: a long-running server must not grow per served query
        self._client_latency: dict[str, list[float]] = {}
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()

    # ------------------------------------------------------------ admission
    def submit(
        self,
        query: PlanNode | PlanBuilder,
        client: str = "anon",
        path: str = "rme",
        colstore: Mapping[str, np.ndarray] | None = None,
        right_colstore: Mapping[str, np.ndarray] | None = None,
    ) -> QueryTicket:
        """Admit a logical plan; returns immediately with a ticket."""
        node = query.build() if isinstance(query, PlanBuilder) else query
        ticket = QueryTicket(client)
        with self._lock:
            self._queue.append(
                _Admitted(ticket, node, path, colstore, right_colstore)
            )
            self.stats.submitted += 1
            self.stats.max_queue_depth = max(
                self.stats.max_queue_depth, len(self._queue)
            )
        return ticket

    @property
    def queue_depth(self) -> int:
        with self._lock:
            return len(self._queue)

    # ------------------------------------------------------------ execution
    def _account_cold_groups(self, ops) -> None:
        """Shared-scan ratio + bytes-saved credit for this tick's op batch.

        Cold ops (projections not served by the reorg cache, plus every
        filter/aggregate/group-by) are grouped per table, the way
        ``execute_many`` will coalesce them; a group of ≥2 distinct lowered
        requests becomes one shared scan whose cost is the union geometry
        over all enabled words, while a per-query execution would have paid
        every request's own pass.
        """
        by_table: dict[int, tuple[Any, dict]] = {}
        for op in ops:
            if isinstance(op, ProjectOp):
                key = self.engine.view_key(op.table, op.view.geometry)
                if self.engine.cache.peek(key, op.table.version) is not None:
                    continue  # hot: free either way
            entry = by_table.setdefault(op.table.uid, (op.table, {}))
            entry[1].setdefault(op.lower())
        for table, reqs in by_table.values():
            self.stats.table_groups += 1
            if len(reqs) >= 2:
                self.stats.table_groups_shared += 1
                independent = sum(
                    self.engine.scan_bytes(table, (r,)) for r in reqs
                )
                union = self.engine.scan_bytes(table, tuple(reqs))
                self.stats.bytes_saved += independent - union

    def run_tick(self) -> int:
        """Serve one batch: drain ≤ ``max_batch`` requests, coalesce, execute.

        Returns the number of requests processed (served + failed).  All
        device work of the batch is enqueued before any query's finalize
        blocks, and every kind of same-table op fuses into the shared pass,
        so one tick costs at most one scan per distinct table.
        """
        with self._lock:
            n = min(self.max_batch, len(self._queue))
            batch = [self._queue.popleft() for _ in range(n)]
        if not batch:
            return 0
        self.stats.ticks += 1

        compiled: list[PhysicalQuery | None] = []
        for req in batch:
            try:
                compiled.append(compile_plan(
                    self.engine, req.node, path=req.path,
                    colstore=req.colstore, right_colstore=req.right_colstore,
                ))
            except Exception as e:  # compile errors belong to the client
                compiled.append(None)
                self.stats.failed += 1
                req.ticket._resolve(error=e)

        # one engine batch for every scan op in the tick: cross-client
        # same-table work — projections, filters, aggregates, group-bys —
        # coalesces into one heterogeneous shared scan (the engine counts it)
        ops, spans = [], []
        for pq in compiled:
            if pq is None:
                spans.append((0, 0))
                continue
            spans.append((len(ops), len(pq.ops)))
            ops.extend(pq.ops)
        self._account_cold_groups(ops)
        try:
            packed = self.engine.execute_many(ops) if ops else []
        except Exception:
            # the shared step failed (one op's lowering error, OOM on the
            # union geometry, ...).  One bad client must not poison the
            # tick: fall back to executing each query individually, so every
            # healthy ticket still resolves with its result and only the
            # offender carries the error.  (PMU counters may over-charge the
            # aborted shared attempt — accounting noise, not a result bug.)
            for req, pq in zip(batch, compiled):
                if pq is None:
                    continue
                try:
                    result = pq.run()
                except Exception as e:
                    self.stats.failed += 1
                    req.ticket._resolve(error=e)
                    continue
                req.ticket._resolve(result=result, route=pq.route)
                self.stats.served += 1
                self._record_latency(req.ticket)
            return len(batch)

        tokens: list[Any] = []
        for i, (req, pq) in enumerate(zip(batch, compiled)):
            if pq is None:
                tokens.append(None)
                continue
            off, k = spans[i]
            try:
                tokens.append(pq.launch(packed[off : off + k]))
            except Exception as e:
                tokens.append(None)
                compiled[i] = None
                self.stats.failed += 1
                req.ticket._resolve(error=e)

        for req, pq, token in zip(batch, compiled, tokens):
            if pq is None:
                continue
            try:
                result = pq.finalize(token)
            except Exception as e:
                self.stats.failed += 1
                req.ticket._resolve(error=e)
                continue
            req.ticket._resolve(result=result, route=pq.route)
            self.stats.served += 1
            self._record_latency(req.ticket)
        return len(batch)

    def _record_latency(self, ticket: QueryTicket) -> None:
        lat = ticket.latency_s
        self.stats.latency_sum_s += lat
        self.stats.latency_max_s = max(self.stats.latency_max_s, lat)
        with self._lock:  # client_latencies() iterates under the lock
            ent = self._client_latency.setdefault(ticket.client, [0, 0.0, 0.0])
            ent[0] += 1
            ent[1] += lat
            ent[2] = max(ent[2], lat)

    def drain(self) -> int:
        """Run ticks until the admission queue is empty; returns total processed."""
        total = 0
        while True:
            n = self.run_tick()
            if n == 0:
                return total
            total += n

    # ------------------------------------------------------ background loop
    def start(self, idle_wait_s: float = 0.001) -> None:
        """Serve ticks on a background thread until :meth:`stop`."""
        if self._thread is not None:
            raise RuntimeError("server already started")
        self._stop.clear()

        def loop() -> None:
            while not self._stop.is_set():
                if self.run_tick() == 0:
                    self._stop.wait(idle_wait_s)

        self._thread = threading.Thread(target=loop, name="query-server", daemon=True)
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None

    def __enter__(self) -> "QueryServer":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------ reporting
    def client_latencies(self) -> dict[str, dict[str, float]]:
        """Per-client latency summary: count / mean / max seconds."""
        with self._lock:
            return {
                client: {
                    "count": count,
                    "mean_s": total / count,
                    "max_s": max_s,
                }
                for client, (count, total, max_s) in self._client_latency.items()
            }

    def snapshot(self) -> dict[str, Any]:
        """One flat dict of serving + engine counters (for logs/benchmarks)."""
        e = self.engine.stats
        return {
            "queue_depth": self.queue_depth,
            "submitted": self.stats.submitted,
            "served": self.stats.served,
            "failed": self.stats.failed,
            "ticks": self.stats.ticks,
            "max_queue_depth": self.stats.max_queue_depth,
            "shared_scan_ratio": self.stats.shared_scan_ratio,
            "bytes_saved": self.stats.bytes_saved,
            "mean_latency_s": self.stats.mean_latency_s,
            "max_latency_s": self.stats.latency_max_s,
            "engine_shared_scans": e.shared_scans,
            "engine_hot_hits": e.hot_hits,
            "engine_cold_misses": e.cold_misses,
            "engine_bytes_from_dram": e.bytes_from_dram,
            "engine_bytes_uploaded": e.bytes_uploaded,
            "engine_uploads": e.uploads,
        }
