"""Concurrent query serving: many clients, one engine, shared scans per tick —
with live HTAP writes, pipelined ticks, priority lanes, and streaming results.

The paper's closing argument (§8) is that native column access "can vastly
simplify the software logic" of an analytics engine.  This module is the
multi-tenant half of that story: a :class:`QueryServer` owns one
:class:`~repro.core.engine.RelationalMemoryEngine` — or, built with
``mesh=``/``num_shards=``, a mesh-sharded
:class:`~repro.core.distributed.ShardedEngine` whose ticks run one fused
pass per shard — and admits *logical plans* (:mod:`repro.core.plan`) from
any number of concurrent clients.  Requests are not executed as they arrive
— they queue, and each serving **tick** drains a batch, compiles every plan
(:func:`repro.core.planner.compile_plan`), and coalesces the tick's scan ops
into **one** ``execute_many`` call: same-table work from different clients —
projections, fused filters, fused aggregates, and group-bys alike,
regardless of lane — rides a single shared Fetch-Unit stream (the
heterogeneous one-pass kernel ``rme_scan_multi``), so a mixed-kind
same-table tick performs exactly one row-store pass instead of one per op
kind.  Nothing in the tick syncs with the host until finalize.

The pipelined tick (double buffering)
-------------------------------------
A tick splits into :meth:`QueryServer.begin_tick` — drain, apply writes,
serve the express lane, compile the bulk lane, and *enqueue* its device pass
(:meth:`~repro.core.engine.RelationalMemoryEngine.execute_many_async` +
per-query ``launch``, no host syncs) — and :meth:`QueryServer.finish_tick`,
the only blocking half, which finalizes the bulk results and resolves their
tickets.  ``drain()`` and the background loop interleave them double-
buffered: tick N+1's admission drain, write application, and ``compile_plan``
run while tick N's device pass is still in flight (``begin_tick(N+1)`` →
``finish_tick(N)``), so compile and device time overlap instead of adding.
This is safe because a launched pass holds immutable device arrays — tick
N+1's writes patch the *host* row store and upload fresh delta chunks; they
cannot retroactively change work already enqueued — and because each read
was compiled against its own tick's post-write snapshot.  Serial semantics
are a flag away (``pipeline=False``) and ``run_tick()`` is still
begin+finish in one call.

Priority lanes, deadlines, backpressure
---------------------------------------
Tickets ride one of two **lanes**.  The *express* lane is for point work —
writes, fused aggregates, small group-bys (estimated result ≤
``express_result_bytes``) — drained ahead of any bulk backlog and served to
completion inside ``begin_tick``: its scalar-sized results are finalized
immediately, while the tick's bulk results (and their O(rows) host
transfers) stay in flight until ``finish_tick``.  An express ticket
therefore never waits behind a queued 50k-row packed projection — though
co-tick scans of the same table still fuse into one shared pass, lanes and
all.  The *bulk* lane carries everything else through the pipelined pass
above.  Lanes are
auto-classified from the plan shape; ``submit(..., lane=...)`` overrides.
Per-ticket ``deadline_s`` bounds queue wait + service: an expired ticket
fails with :class:`DeadlineExceeded` (a ``TimeoutError``) at drain or
finalize time instead of hanging, and is counted per lane.  Admission is
bounded by ``max_queue``: beyond it the server **sheds**
(:class:`ServerOverloaded` at submit) or **degrades** (admits demoted to the
bulk lane, deadline stripped) per the ``overload`` policy — and hard-sheds
at twice the bound so memory stays bounded either way.

Streaming results
-----------------
``submit(..., stream=True)`` (projection-shaped rme plans) returns a
:class:`StreamingTicket` whose result arrives **incrementally**: the engine
streams the packed projection one resident row-store chunk at a time
(:meth:`~repro.core.engine.RelationalMemoryEngine.stream_project`;
``stream_chunk_rows`` re-slices large base chunks), the serving loop pushes
each chunk into the ticket as its scan lands, and ``chunks()`` yields them
while the pass is still running.  ``result()`` still returns the full block
— byte-identical to the blocking route.

The write path (HTAP)
---------------------
Clients also submit **write tickets** — :meth:`QueryServer.submit_insert` /
``submit_update`` / ``submit_delete`` — which always ride the express lane.
A tick applies its writes *first*, in admission order, then serves every
read of the tick from the resulting state: one consistent post-write
snapshot per tick, so readers never block on writers and writers never wait
for readers (MVCC gives pinned readers their own view regardless).  Once a
server has admitted any write (or always, with ``snapshot_reads=True``), the
snapshot is explicit — each read is compiled with ``snapshot_ts`` set to its
table's post-write clock, fusing the MVCC visibility test in-scan (see
:func:`repro.core.planner.compile_plan`; note this changes project-shaped
results to the ``(packed, mask)`` filter contract).  Because the engine's
row store is delta-chunked, a tick's writes cost O(delta) host→device bytes:
appended rows ship as tail chunks, deletes and updates ship only patched
timestamp words, and hot views survive appends via incremental tail scans
instead of cold rebuilds.

Fault tolerance (``docs/reliability.md``)
-----------------------------------------
The tick executor degrades gracefully instead of failing wholesale.  A
transient fault (:class:`repro.core.faults.TransientFault` — an injected
or real spurious failure of an upload, scan, or stream) retries the
affected ticket up to ``max_retries`` times on its individual fallback
path; a ticket that *keeps* failing resolves typed and its plan signature
enters **poison quarantine** — re-submissions of the same shape fail
immediately with :class:`PoisonedPlanError` for ``poison_cooldown_ticks``
ticks instead of burning retry budget, and the rest of the tick is never
poisoned (extending PR 3's per-query fallback).  Repeated Pallas lowering
failures flip the (table, request-shape) route to the XLA fallback via the
engine's circuit breaker (cooldown + half-open probes —
``breaker_*`` in :meth:`snapshot`).  Built with ``wal=`` (a
:class:`repro.core.wal.WriteAheadLog`), every applied write appends a
checksummed record *before* the host store mutates, so
:meth:`repro.core.table.RelationalTable.recover` replays a byte-identical
table after a crash at any record boundary.

Threading model: ``submit*`` is thread-safe and non-blocking (clients get a
:class:`QueryTicket` and block on ``result()`` — or iterate ``chunks()`` —
at their leisure); all engine *and table* work happens on whichever single
thread calls ``begin_tick``/``finish_tick``/``run_tick`` — either the
caller's (deterministic, what the tests drive) or the background serving
thread started by ``start()``/the ``serving()`` context manager.  JAX
traces, device buffers, and the host row stores are therefore never touched
from two threads at once.

Accounting: the server reports engine-level :class:`~repro.core.engine.
EngineStats` plus its own :class:`ServerStats` — queue depth, shared-scan
ratio, ``bytes_saved``, write counters, and per-lane :class:`LaneStats`:
served/failed/deadline-miss counts, result bytes, and bounded
:class:`LatencyReservoir` samples of total latency, queue wait, and service
time, from which ``snapshot()`` exports p50/p95/p99 per lane.  See
``docs/metrics.md`` for every counter's charging rule and
``docs/serving.md`` for operating the loop under load.
"""

from __future__ import annotations

import dataclasses
import math
import random
import threading
import time
from collections import deque
from typing import Any, Iterator, Mapping

import jax.numpy as jnp
import numpy as np

from repro.core import faults
from repro.core.engine import RelationalMemoryEngine
from repro.core.plan import PlanBuilder, PlanNode, Scan, decompose
from repro.core.planner import (
    CompileOptions,
    PhysicalQuery,
    _device_join_expressible,
    compile_plan,
)
from repro.core.requests import ProjectOp
from repro.core.table import RelationalTable

LANES = ("express", "bulk")


class DeadlineExceeded(TimeoutError):
    """The ticket's ``deadline_s`` elapsed before the server could serve it.

    Raised *through the ticket* (``result()`` re-raises it): the serving loop
    resolves an expired ticket with this error at drain or finalize time, so
    a missed deadline is a prompt, typed failure — never a hang."""


class ServerOverloaded(RuntimeError):
    """Admission refused: the queue is at ``max_queue`` under the ``"shed"``
    policy (or at twice the bound under ``"degrade"`` — the hard limit that
    keeps a degrading server memory-bounded).  The message names the lane
    that shed and both lanes' queue depths; per-lane shed counts live in
    ``LaneStats.shed``."""


class PoisonedPlanError(RuntimeError):
    """The plan's signature is in poison quarantine: an identically-shaped
    query exhausted its transient-fault retries within the last
    ``poison_cooldown_ticks`` ticks, so the server fails this one
    immediately — typed, at compile time — instead of burning another
    tick's retry budget on a deterministically failing plan."""


class LatencyReservoir:
    """Bounded latency sample: exact percentiles up to ``cap`` samples, then
    uniform reservoir sampling (Vitter's Algorithm R) — every observation
    ever added has equal probability ``cap/count`` of being in the sample,
    so the percentile estimate stays unbiased while memory stays O(cap) for
    millions of tickets.  ``count``/``sum``/``max`` are exact regardless.
    The RNG is seeded, so a deterministic workload reports deterministic
    percentiles."""

    __slots__ = ("cap", "count", "sum", "max", "_samples", "_rng")

    def __init__(self, cap: int = 4096, seed: int = 0x5EED):
        if cap <= 0:
            raise ValueError("reservoir cap must be positive")
        self.cap = cap
        self.count = 0
        self.sum = 0.0
        self.max = 0.0
        self._samples: list[float] = []
        self._rng = random.Random(seed)

    def add(self, x: float) -> None:
        self.count += 1
        self.sum += x
        if x > self.max:
            self.max = x
        if len(self._samples) < self.cap:
            self._samples.append(x)
        else:
            j = self._rng.randrange(self.count)
            if j < self.cap:
                self._samples[j] = x

    @property
    def mean(self) -> float:
        return self.sum / max(self.count, 1)

    def percentile(self, q: float) -> float:
        """Nearest-rank percentile over the retained sample (exact while
        ``count <= cap``); 0.0 when empty."""
        if not self._samples:
            return 0.0
        s = sorted(self._samples)
        rank = max(1, math.ceil(q / 100.0 * len(s)))
        return s[min(rank, len(s)) - 1]


def _reservoir() -> LatencyReservoir:
    return LatencyReservoir()


class QueryTicket:
    """A client's handle on one admitted request; resolved at end of its tick.

    Read tickets resolve to their query result; write tickets resolve to the
    new physical row indices (insert/update) or ``None`` (delete).  A ticket
    whose ``deadline_s`` expires resolves with :class:`DeadlineExceeded`.
    """

    __slots__ = ("client", "lane", "deadline_s", "submitted_at", "admitted_at",
                 "queue_wait_s", "latency_s", "route",
                 "_event", "_result", "_error")

    def __init__(self, client: str, lane: str = "bulk",
                 deadline_s: float | None = None):
        self.client = client
        self.lane = lane
        self.deadline_s = deadline_s
        self.submitted_at = time.perf_counter()
        self.admitted_at: float | None = None  # set when a tick drains it
        self.queue_wait_s: float | None = None
        self.latency_s: float | None = None
        self.route: str | None = None
        self._event = threading.Event()
        self._result: Any = None
        self._error: BaseException | None = None

    def done(self) -> bool:
        return self._event.is_set()

    def expired(self, now: float | None = None) -> bool:
        if self.deadline_s is None:
            return False
        now = time.perf_counter() if now is None else now
        return now > self.submitted_at + self.deadline_s

    def result(self, timeout: float | None = None) -> Any:
        """Block until served; re-raises compile/execution/deadline errors."""
        if not self._event.wait(timeout):
            raise TimeoutError(f"query for client {self.client!r} not served")
        if self._error is not None:
            raise self._error
        return self._result

    def _resolve(self, result: Any = None, error: BaseException | None = None,
                 route: str | None = None) -> None:
        self.latency_s = time.perf_counter() - self.submitted_at
        self.route = route
        self._result, self._error = result, error
        self._event.set()


class StreamingTicket(QueryTicket):
    """A ticket whose result arrives incrementally, one packed chunk per
    resident row-store chunk.

    ``chunks()`` yields each chunk as the serving loop pushes it — while the
    stream's remaining scans are still running — and ``result()`` blocks for
    the whole thing and returns the chunks' concatenation, byte-identical to
    the blocking (non-streamed) route.  Both re-raise the ticket's error.
    """

    __slots__ = ("_cond", "_chunks")

    def __init__(self, client: str, lane: str = "bulk",
                 deadline_s: float | None = None):
        super().__init__(client, lane, deadline_s)
        self._cond = threading.Condition()
        self._chunks: list[Any] = []

    def _push(self, chunk: Any) -> None:
        with self._cond:
            self._chunks.append(chunk)
            self._cond.notify_all()

    def _resolve(self, result: Any = None, error: BaseException | None = None,
                 route: str | None = None) -> None:
        with self._cond:
            super()._resolve(result, error, route)
            self._cond.notify_all()

    def chunks(self, timeout: float | None = None) -> Iterator[Any]:
        """Yield result chunks as they land; returns when the ticket
        resolves.  Raises the ticket's error (chunks already yielded were
        still byte-exact — a prefix of the result)."""
        i = 0
        while True:
            with self._cond:
                while len(self._chunks) <= i and not self._event.is_set():
                    if not self._cond.wait(timeout):
                        raise TimeoutError(
                            f"stream for client {self.client!r} stalled")
                have = len(self._chunks) > i
                chunk = self._chunks[i] if have else None
            if have:
                i += 1
                yield chunk
                continue
            if self._error is not None:
                raise self._error
            return

    def result(self, timeout: float | None = None) -> Any:
        if not self._event.wait(timeout):
            raise TimeoutError(f"query for client {self.client!r} not served")
        if self._error is not None:
            raise self._error
        if self._result is None and self._chunks:
            self._result = (self._chunks[0] if len(self._chunks) == 1
                            else jnp.concatenate(self._chunks, axis=0))
        return self._result


@dataclasses.dataclass
class LaneStats:
    """Per-lane serving counters + bounded latency reservoirs.

    ``latency`` samples submit→resolve seconds; ``queue_wait`` the
    submit→drain share of it; ``service`` the remainder (compile + device +
    finalize).  ``result_bytes`` sums each served op's own output size
    (:meth:`~repro.core.requests.ProjectOp.result_bytes` and siblings; for
    streams, the bytes actually pushed) — the lane's *output* volume,
    distinct from the engine's bus-beat scan charges."""

    served: int = 0
    failed: int = 0
    deadline_misses: int = 0
    shed: int = 0  # admissions this lane refused with ServerOverloaded
    result_bytes: int = 0
    latency: LatencyReservoir = dataclasses.field(default_factory=_reservoir)
    queue_wait: LatencyReservoir = dataclasses.field(default_factory=_reservoir)
    service: LatencyReservoir = dataclasses.field(default_factory=_reservoir)


@dataclasses.dataclass
class ServerStats:
    """Serving-layer counters (the engine's own PMU counts the bytes).

    Totals here; the per-lane split (including every latency reservoir)
    lives in ``lanes["express"]`` / ``lanes["bulk"]``.  ``latency`` is the
    all-lanes reservoir — ``mean_latency_s``/``latency_max_s`` read from it,
    keeping the historical fields as exact properties."""

    submitted: int = 0
    served: int = 0
    failed: int = 0
    ticks: int = 0
    ticks_overlapped: int = 0  # begin_tick entered with a pass still in flight
    max_queue_depth: int = 0
    table_groups: int = 0  # cold same-table view groups across all ticks
    table_groups_shared: int = 0  # of those, served by a multi-view shared scan
    bytes_saved: int = 0  # row-store bytes avoided vs per-query cold execution
    # SLO / admission-control counters
    deadline_misses: int = 0  # tickets resolved with DeadlineExceeded
    shed: int = 0  # admissions refused with ServerOverloaded
    degraded: int = 0  # admissions demoted to the bulk lane at the bound
    # fault-tolerance counters (docs/reliability.md)
    retries: int = 0  # per-ticket transient-fault retry attempts
    poisoned: int = 0  # tickets that exhausted retries -> quarantined plans
    streams: int = 0  # streaming tickets served
    stream_chunks: int = 0  # chunks pushed across all streams
    # write-path counters
    writes_submitted: int = 0
    writes_applied: int = 0
    inserts: int = 0
    updates: int = 0
    deletes: int = 0
    rows_written: int = 0  # rows inserted + replacement rows + rows deleted
    latency: LatencyReservoir = dataclasses.field(default_factory=_reservoir)
    lanes: dict[str, LaneStats] = dataclasses.field(
        default_factory=lambda: {lane: LaneStats() for lane in LANES})

    @property
    def shared_scan_ratio(self) -> float:
        """Fraction of cold table-groups that coalesced into a shared scan."""
        return self.table_groups_shared / max(self.table_groups, 1)

    @property
    def latency_sum_s(self) -> float:
        return self.latency.sum

    @property
    def latency_max_s(self) -> float:
        return self.latency.max

    @property
    def mean_latency_s(self) -> float:
        return self.latency.sum / max(self.served, 1)


@dataclasses.dataclass
class _WritePayload:
    """One admitted write: insert (columns), update (rows+values), delete (rows)."""

    kind: str  # "insert" | "update" | "delete"
    table: RelationalTable
    columns: Mapping[str, np.ndarray] | None = None
    rows: np.ndarray | None = None
    values: Mapping[str, np.ndarray] | None = None


@dataclasses.dataclass
class _Admitted:
    ticket: QueryTicket
    node: PlanNode | None
    path: str
    colstore: Mapping[str, np.ndarray] | None
    right_colstore: Mapping[str, np.ndarray] | None
    write: _WritePayload | None = None
    lane: str = "bulk"
    stream: bool = False
    stream_chunk_rows: int | None = None
    options: CompileOptions | None = None


@dataclasses.dataclass
class _InflightTick:
    """begin_tick's handle on a tick whose bulk pass is still on the device.

    ``processed`` counts everything the tick already settled (writes, express
    tickets, expired/failed admissions); ``reads``/``compiled``/``tokens``
    are the launched bulk queries awaiting ``finish_tick``."""

    processed: int
    reads: list[_Admitted] = dataclasses.field(default_factory=list)
    compiled: list[PhysicalQuery | None] = dataclasses.field(default_factory=list)
    tokens: list[Any] = dataclasses.field(default_factory=list)
    finished: bool = False


class QueryServer:
    """Admission queues + pipelined tick executor over one relational engine.

    ``snapshot_reads`` controls whether reads are compiled with the tick's
    post-write snapshot timestamp (fused MVCC visibility; project-shaped
    plans then return ``(packed, mask)``).  The default, ``None``, is
    **auto, per table**: reads of tables this server has never written keep
    the historical unpinned contract (nothing about their results changes,
    regardless of unrelated write traffic), while a table's first applied
    write pins every subsequent read of *that table* — without pinning, a
    read after an update/delete would count old *and* replacement row
    versions, because unpinned scans have no MVCC test.  Pass
    ``True``/``False`` to force either mode globally; plans that cannot
    carry a snapshot (joins, row/col host paths) always compile unpinned.

    ``mesh`` / ``num_shards`` construct a mesh-sharded backend
    (:class:`repro.core.distributed.ShardedEngine`) instead of the default
    single-device engine: each shard owns a contiguous row range on its own
    device, a tick's fused pass runs per shard, and only reduced results
    cross the interconnect (``engine_bytes_collective`` in
    :meth:`snapshot`).  Mutually exclusive with passing ``engine`` — a
    pre-built engine already fixes the backend.  Pipelining, lanes,
    deadlines, and streaming work identically on both backends.

    Serving-loop knobs (see ``docs/serving.md`` for tuning guidance):

    * ``lanes`` — auto-classify tickets into express/bulk priority lanes
      (``False``: single-lane FIFO, the pre-pipelining behavior).
    * ``pipeline`` — double-buffer ticks in ``drain()``/the background loop
      (``False``: strictly serial ticks; ``run_tick()`` is always serial).
    * ``express_result_bytes`` — auto-classification threshold: a read whose
      estimated result is at most this rides the express lane.
    * ``max_queue`` — admission bound across both lanes (``None``:
      unbounded); ``overload`` — ``"shed"`` (refuse with
      :class:`ServerOverloaded`) or ``"degrade"`` (demote to bulk, strip the
      deadline; hard-sheds at ``2 * max_queue``).

    Reliability knobs (see ``docs/reliability.md``):

    * ``wal`` — a :class:`repro.core.wal.WriteAheadLog`; when set, every
      applied write appends a checksummed record (after an automatic
      per-table checkpoint record) *before* the host store mutates.
    * ``max_retries`` — per-ticket bound on transient-fault retries.
    * ``poison_cooldown_ticks`` — how many ticks a retry-exhausted plan
      signature stays quarantined (:class:`PoisonedPlanError`).
    """

    def __init__(
        self,
        engine: RelationalMemoryEngine | None = None,
        max_batch: int = 64,
        snapshot_reads: bool | None = None,
        mesh=None,
        num_shards: int | None = None,
        lanes: bool = True,
        pipeline: bool = True,
        express_result_bytes: int = 4096,
        max_queue: int | None = None,
        overload: str = "shed",
        wal=None,
        max_retries: int = 2,
        poison_cooldown_ticks: int = 8,
    ):
        if engine is not None and (mesh is not None or num_shards is not None):
            raise ValueError(
                "pass either a pre-built engine or mesh/num_shards, not both"
            )
        if engine is None and (mesh is not None or num_shards is not None):
            from repro.core.distributed import ShardedEngine  # deferred import

            engine = ShardedEngine(mesh=mesh, num_shards=num_shards)
        if overload not in ("shed", "degrade"):
            raise ValueError(f"unknown overload policy {overload!r}; "
                             "want 'shed' or 'degrade'")
        self.engine = engine if engine is not None else RelationalMemoryEngine()
        self.max_batch = max_batch
        self.snapshot_reads = snapshot_reads
        self.lanes = lanes
        self.pipeline = pipeline
        self.express_result_bytes = express_result_bytes
        self.max_queue = max_queue
        self.overload = overload
        self.wal = wal
        self.max_retries = max_retries
        self.poison_cooldown_ticks = poison_cooldown_ticks
        # tables with a checkpoint record already in the WAL (the first
        # logged write per table writes one); touched only on the tick thread
        self._wal_checkpointed: set[int] = set()
        # poison quarantine: plan signature -> remaining cooldown ticks
        self._poisoned: dict[Any, int] = {}
        self.stats = ServerStats()
        self._lock = threading.Lock()
        self._express: deque[_Admitted] = deque()
        self._bulk: deque[_Admitted] = deque()
        # consecutive express-saturated ticks with bulk work waiting — the
        # anti-starvation trigger in _drain_batch
        self._express_streak = 0
        # ticks begun but not yet finished — touched only on the tick thread
        self._open_ticks = 0
        # tables that have taken a write through this server (auto snapshot
        # pinning is per-table: reads of never-written tables keep their
        # historical result shapes); touched only on the tick thread
        self._written_uids: set[int] = set()
        # per-client running (count, sum_s, max_s) — scalars, not a sample
        # list: a long-running server must not grow per served query
        self._client_latency: dict[str, list[float]] = {}
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()

    # ------------------------------------------------------------ admission
    def submit(
        self,
        query: PlanNode | PlanBuilder,
        client: str = "anon",
        path: str = "rme",
        colstore: Mapping[str, np.ndarray] | None = None,
        right_colstore: Mapping[str, np.ndarray] | None = None,
        lane: str | None = None,
        deadline_s: float | None = None,
        stream: bool = False,
        stream_chunk_rows: int | None = None,
        options: CompileOptions | None = None,
        optimize: bool | None = None,
    ) -> QueryTicket:
        """Admit a logical plan; returns immediately with a ticket.

        ``lane`` overrides the automatic express/bulk classification;
        ``deadline_s`` bounds submit→resolve (expired tickets fail with
        :class:`DeadlineExceeded`); ``stream=True`` returns a
        :class:`StreamingTicket` whose packed result arrives chunk-by-chunk
        (projection-shaped rme plans only; always bulk lane).  May raise
        :class:`ServerOverloaded` when ``max_queue`` is set.

        ``options`` is the full :class:`~repro.core.planner.CompileOptions`
        passthrough — when given it wins over the individual ``path`` /
        ``colstore`` / ``right_colstore`` / ``stream`` / ``stream_chunk_rows``
        parameters (``snapshot_ts`` inside it is still overridden by the
        tick's own pin).  ``optimize=False`` skips the logical rewrite
        passes for this query regardless of where the options came from.
        """
        if lane is not None and lane not in LANES:
            raise ValueError(f"unknown lane {lane!r}; want one of {LANES}")
        node = query.build() if isinstance(query, PlanBuilder) else query
        if options is not None:
            path = options.path
            colstore = options.colstore
            right_colstore = options.right_colstore
            stream = options.stream
            stream_chunk_rows = options.stream_chunk_rows
        else:
            options = CompileOptions(
                path=path, colstore=colstore, right_colstore=right_colstore,
                stream=stream, stream_chunk_rows=stream_chunk_rows,
            )
        if optimize is not None:
            options = dataclasses.replace(options, optimize=optimize)
        if stream:
            lane = "bulk"  # a chunked large output is bulk by definition
        elif lane is None:
            lane = self._classify(node)
        if not self.lanes:
            lane = "bulk"
        ticket_cls = StreamingTicket if stream else QueryTicket
        return self._admit(_Admitted(
            ticket_cls(client, lane, deadline_s), node, path,
            colstore, right_colstore, lane=lane, stream=stream,
            stream_chunk_rows=stream_chunk_rows, options=options,
        ))

    def submit_insert(
        self,
        table: RelationalTable,
        columns: Mapping[str, np.ndarray],
        client: str = "anon",
    ) -> QueryTicket:
        """Admit an insert; the ticket resolves to the new physical row indices.

        The rows become visible to every read admitted into (or after) the
        tick that applies the write — and cost O(rows) upload bytes, since
        the device row store ships them as a tail chunk.
        """
        return self._admit_write(_WritePayload("insert", table,
                                               columns=dict(columns)), client)

    def submit_update(
        self,
        table: RelationalTable,
        rows: np.ndarray,
        values: Mapping[str, np.ndarray],
        client: str = "anon",
    ) -> QueryTicket:
        """Admit an MVCC update of the given physical rows; resolves to the
        replacement rows' indices.  Old versions stay readable at earlier
        snapshots."""
        return self._admit_write(_WritePayload("update", table,
                                               rows=np.asarray(rows),
                                               values=dict(values)), client)

    def submit_delete(
        self,
        table: RelationalTable,
        rows: np.ndarray,
        client: str = "anon",
    ) -> QueryTicket:
        """Admit an MVCC delete of the given physical rows; resolves to ``None``.
        Costs O(rows) timestamp words of upload, never a table re-ship."""
        return self._admit_write(_WritePayload("delete", table,
                                               rows=np.asarray(rows)), client)

    def _admit_write(self, w: _WritePayload, client: str) -> QueryTicket:
        # writes always ride the express lane: applying them first is what
        # defines the tick snapshot, and they carry no deadline — a write
        # must apply or be refused at admission, never be silently dropped
        lane = "express" if self.lanes else "bulk"
        return self._admit(_Admitted(
            QueryTicket(client, lane), None, "write", None, None,
            write=w, lane=lane,
        ))

    def _classify(self, node: PlanNode) -> str:
        """Express iff the result is point-sized: a fused aggregate's 8-byte
        scalar pair, or a group-by whose ``(G, 2)`` partials fit
        ``express_result_bytes``.  Projections, filters, and joins move
        O(rows) and ride bulk.  (An unroutable plan classifies bulk and
        fails with its real compile error in its tick.)"""
        if not self.lanes:
            return "bulk"
        try:
            shape = decompose(node)
        except Exception:
            return "bulk"
        if shape.kind == "aggregate":
            return "express"
        if (shape.kind == "groupby"
                and shape.group.num_groups * 8 <= self.express_result_bytes):
            return "express"
        return "bulk"

    def _admit(self, adm: _Admitted) -> QueryTicket:
        with self._lock:
            if self.max_queue is not None:
                depth = len(self._express) + len(self._bulk)
                if depth >= self.max_queue:
                    # writes cannot be degraded (a demoted write would still
                    # have to apply) and a degrading server still hard-sheds
                    # at twice the bound, or queue memory would be unbounded
                    if (self.overload == "shed" or adm.write is not None
                            or depth >= 2 * self.max_queue):
                        self.stats.shed += 1
                        self.stats.lanes[adm.lane].shed += 1
                        raise ServerOverloaded(
                            f"admission queue at {depth} >= bound "
                            f"{self.max_queue} (policy: {self.overload}; "
                            f"shed lane: {adm.lane}; depths: "
                            f"express={len(self._express)} "
                            f"bulk={len(self._bulk)})"
                        )
                    adm.lane = "bulk"
                    adm.ticket.lane = "bulk"
                    adm.ticket.deadline_s = None
                    self.stats.degraded += 1
            queue = self._express if adm.lane == "express" else self._bulk
            queue.append(adm)
            self.stats.submitted += 1
            if adm.write is not None:
                self.stats.writes_submitted += 1
            self.stats.max_queue_depth = max(
                self.stats.max_queue_depth,
                len(self._express) + len(self._bulk),
            )
        return adm.ticket

    @property
    def queue_depth(self) -> int:
        with self._lock:
            return len(self._express) + len(self._bulk)

    # --------------------------------------------------------------- writes
    def _log_write(self, w: _WritePayload) -> None:
        """Write-ahead: append the write's record — after an automatic
        checkpoint record on the table's first logged write — *before* the
        host store mutates.  A crash between append and apply replays one
        extra (idempotent-by-replay) record; an acknowledged write is never
        lost."""
        if self.wal is None:
            return
        if w.table.uid not in self._wal_checkpointed:
            self.wal.append(w.table.uid, "checkpoint",
                            w.table.checkpoint_payload())
            self._wal_checkpointed.add(w.table.uid)
        if w.kind == "insert":
            payload = {"columns": dict(w.columns)}
        elif w.kind == "update":
            payload = {"rows": w.rows, "values": dict(w.values)}
        else:
            payload = {"rows": w.rows}
        self.wal.append(w.table.uid, w.kind, payload)

    def _apply_write(self, w: _WritePayload) -> Any:
        self._log_write(w)
        if w.kind == "insert":
            rows = w.table.append(w.columns)
            self.stats.inserts += 1
            self.stats.rows_written += len(rows)
            return rows
        if w.kind == "update":
            rows = w.table.update(w.rows, w.values)
            self.stats.updates += 1
            self.stats.rows_written += len(rows)
            return rows
        if w.kind == "delete":
            n_deleted = w.table.delete(w.rows)
            self.stats.deletes += 1
            self.stats.rows_written += n_deleted  # live rows only, not ids
            return None
        raise ValueError(f"unknown write kind {w.kind!r}")

    def _run_writes(self, batch: list[_Admitted]) -> None:
        """Apply the tick's writes in admission order, resolving their tickets.

        Runs before any read compiles, so the tick's reads all observe one
        consistent post-write state — the tick's snapshot.  A failing write
        resolves its own ticket with the error and never blocks the reads.
        """
        for req in batch:
            if req.write is None:
                continue
            try:
                result = self._apply_write(req.write)
            except Exception as e:
                self._fail(req, e)
                continue
            self._written_uids.add(req.write.table.uid)
            self.stats.writes_applied += 1
            self._serve(req, result, route=f"write-{req.write.kind}")

    # ------------------------------------------------------------ execution
    def _account_cold_groups(self, ops) -> None:
        """Shared-scan ratio + bytes-saved credit for this tick's op batch.

        Cold ops (projections not served by the reorg cache, plus every
        filter/aggregate/group-by) are grouped per table, the way
        ``execute_many`` will coalesce them; a group of ≥2 distinct lowered
        requests becomes one shared scan whose cost is the union geometry
        over all enabled words, while a per-query execution would have paid
        every request's own pass.
        """
        by_table: dict[int, tuple[Any, dict]] = {}
        for op in ops:
            if isinstance(op, ProjectOp):
                # served from the cache — a full hot hit or a tail-only delta
                # serve — means the op never joins the shared pass, so it
                # must not be priced as a full cold scan here
                if self.engine.projection_is_cached(op.table, op.view.geometry):
                    continue
            entry = by_table.setdefault(op.table.uid, (op.table, {}))
            entry[1].setdefault(op.lower())
        for table, reqs in by_table.values():
            self.stats.table_groups += 1
            if len(reqs) >= 2:
                self.stats.table_groups_shared += 1
                independent = sum(
                    self.engine.scan_bytes(table, (r,)) for r in reqs
                )
                union = self.engine.scan_bytes(table, tuple(reqs))
                self.stats.bytes_saved += independent - union
            # a lone cold request is priced identically either way

    def _serve(self, req: _Admitted, result: Any, route: str | None) -> None:
        req.ticket._resolve(result=result, route=route)
        self.stats.served += 1
        self.stats.lanes[req.lane].served += 1
        self._record_latency(req.ticket)

    def _fail(self, req: _Admitted, error: BaseException) -> None:
        self.stats.failed += 1
        self.stats.lanes[req.lane].failed += 1
        req.ticket._resolve(error=error)

    def _expire(self, req: _Admitted, when: str) -> bool:
        """Resolve an expired ticket with :class:`DeadlineExceeded`; the
        caller skips whatever work remained for it."""
        if not req.ticket.expired():
            return False
        lane = self.stats.lanes[req.lane]
        lane.deadline_misses += 1
        self.stats.deadline_misses += 1
        self._fail(req, DeadlineExceeded(
            f"client {req.ticket.client!r}: deadline {req.ticket.deadline_s}s "
            f"exceeded at {when}"
        ))
        return True

    def _drain_batch(self) -> list[_Admitted]:
        """Pop one tick's batch: the express lane first (up to ``max_batch``),
        bulk filling only the *remainder* — a saturated express tick admits
        no bulk work, so a point read's tick never carries an O(rows) scan
        in its fused pass.  Sustained saturation still can't starve
        analytics: after 3 consecutive express-only ticks with bulk waiting,
        one bulk slot is forced through."""
        now = time.perf_counter()
        with self._lock:
            n_exp = min(self.max_batch, len(self._express))
            batch = [self._express.popleft() for _ in range(n_exp)]
            n_bulk = min(max(self.max_batch - n_exp, 0), len(self._bulk))
            if n_bulk == 0 and self._bulk and self._express_streak >= 3:
                n_bulk = 1
            if n_exp and not n_bulk and self._bulk:
                self._express_streak += 1
            else:
                self._express_streak = 0
            batch += [self._bulk.popleft() for _ in range(n_bulk)]
        for req in batch:
            req.ticket.admitted_at = now
            req.ticket.queue_wait_s = now - req.ticket.submitted_at
        return batch

    # ------------------------------------------------- fault recovery layer
    @staticmethod
    def _plan_sig(req: _Admitted, pq: PhysicalQuery | None):
        """A stable signature of the plan's physical shape — what poison
        quarantine keys on.  Lowered requests hash structurally (frozen
        dataclasses), so two submissions of the same query shape collide
        here even from different clients.  ``None`` (unkeyable) disables
        quarantine for this plan."""
        if pq is None:
            return None
        try:
            return (req.path, tuple(
                (op.table.uid, op.lower()) for op in pq.ops
            ))
        except Exception:
            return None

    def _poison(self, req: _Admitted, pq: PhysicalQuery | None) -> None:
        """Quarantine a retry-exhausted plan signature for the cooldown."""
        self.stats.poisoned += 1
        sig = self._plan_sig(req, pq)
        if sig is not None:
            self._poisoned[sig] = self.poison_cooldown_ticks

    def _retry_read(self, req: _Admitted, pq: PhysicalQuery,
                    err: BaseException) -> tuple[bool, Any]:
        """Bounded retry of one query's individual execution after a
        transient fault.  Success returns ``(True, result)``; a permanent
        or persistent failure resolves the ticket typed (quarantining the
        plan when retries were exhausted) and returns ``(False, None)``."""
        for _ in range(self.max_retries):
            self.stats.retries += 1
            try:
                return True, pq.run()
            except faults.TransientFault as e:
                err = e
            except Exception as e:
                self._fail(req, e)
                return False, None
        self._poison(req, pq)
        self._fail(req, err)
        return False, None

    def _retry_stream(self, req: _Admitted, pq: PhysicalQuery,
                      err: BaseException) -> tuple[bool, Any]:
        """Stream retry: only safe while *no* chunk reached the client —
        each attempt drains a fresh ``pq.stream()`` iterator.  Once a
        prefix is out, a restart would duplicate it, so the ticket resolves
        typed instead (``chunks()`` documents yielded chunks as a byte-
        exact prefix of the result)."""
        for _ in range(self.max_retries):
            if req.ticket._chunks:
                # a prefix reached the client: fail typed, don't poison —
                # the fault was positional, not necessarily deterministic
                self._fail(req, err)
                return False, None
            self.stats.retries += 1
            try:
                return True, self._serve_stream(req, pq.stream())
            except faults.TransientFault as e:
                err = e
            except Exception as e:
                self._fail(req, e)
                return False, None
        if not req.ticket._chunks:
            self._poison(req, pq)
        self._fail(req, err)
        return False, None

    def _compile_reads(self, reads: list[_Admitted]) -> list[PhysicalQuery | None]:
        compiled: list[PhysicalQuery | None] = []
        for req in reads:
            try:
                snapshot_ts = None
                if (self._pin_read(req.node)
                        and _snapshot_capable(req.node, req.path)):
                    # the tick's snapshot: the post-write clock of the plan's
                    # tables (per-table clocks; writes already applied) — for
                    # a join, the max over both sides, so every row live in
                    # either table right now is visible.  Plans that cannot
                    # carry a snapshot — host-path baselines, joins whose
                    # columns the device route cannot express — compile
                    # unpinned; they still observe the tick-consistent
                    # post-write state (writes ran first).  A *streamed* read
                    # of a written table fails its ticket instead: the
                    # per-chunk contract has no visibility channel.
                    snapshot_ts = max(
                        t.now() for t in _plan_tables(req.node)
                    )
                base = req.options or CompileOptions(
                    path=req.path, colstore=req.colstore,
                    right_colstore=req.right_colstore, stream=req.stream,
                    stream_chunk_rows=req.stream_chunk_rows,
                )
                if snapshot_ts is not None:
                    base = dataclasses.replace(base, snapshot_ts=snapshot_ts)
                pq = compile_plan(req.node, self.engine, options=base)
                sig = self._plan_sig(req, pq)
                if sig is not None and sig in self._poisoned:
                    compiled.append(None)
                    self._fail(req, PoisonedPlanError(
                        f"plan shape quarantined for "
                        f"{self._poisoned[sig]} more tick(s) after "
                        f"exhausting {self.max_retries} retries"
                    ))
                    continue
                compiled.append(pq)
            except Exception as e:  # compile errors belong to the client
                compiled.append(None)
                self._fail(req, e)
        return compiled

    def _launch_reads(
        self, reads: list[_Admitted], compiled: list[PhysicalQuery | None],
    ) -> list[Any] | None:
        """Enqueue one lane's device pass: coalesce every scan op into one
        ``execute_many_async`` batch, then ``launch`` each query on its
        slice.  No host syncs.  Returns the per-query finalize tokens — or
        ``None`` when the shared step failed and every ticket was already
        settled by the per-query fallback."""
        ops, spans = [], []
        for pq in compiled:
            if pq is None:
                spans.append((0, 0))
                continue
            spans.append((len(ops), len(pq.ops)))
            ops.extend(pq.ops)
        self._account_cold_groups(ops)
        try:
            handle = (self.engine.execute_many_async(ops) if ops else None)
        except Exception:
            # the shared step failed (one op's lowering error, OOM on the
            # union geometry, ...).  One bad client must not poison the
            # tick: fall back to executing each query individually, so every
            # healthy ticket still resolves with its result and only the
            # offender carries the error.  (PMU counters may over-charge the
            # aborted shared attempt — accounting noise, not a result bug.)
            for req, pq in zip(reads, compiled):
                if pq is None:
                    continue
                try:
                    result = pq.run()
                except faults.TransientFault as e:
                    ok, result = self._retry_read(req, pq, e)
                    if not ok:
                        continue
                except Exception as e:
                    self._fail(req, e)
                    continue
                self._note_result_bytes(req, pq)
                self._serve(req, result, route=pq.route)
            return None

        packed = handle.results if handle is not None else []
        tokens: list[Any] = []
        for i, (req, pq) in enumerate(zip(reads, compiled)):
            if pq is None:
                tokens.append(None)
                continue
            off, k = spans[i]
            try:
                if pq.stream is not None:
                    # eager call: snapshots the chunk list against THIS
                    # tick's state, so a pipelined next tick's writes can't
                    # leak into the stream drained at finish_tick
                    tokens.append(pq.stream())
                else:
                    tokens.append(pq.launch(packed[off: off + k]))
            except faults.TransientFault as e:
                # a launch-time transient (e.g. a faulted upload): retry the
                # query individually; either way it is settled here, so
                # finalize must skip it
                tokens.append(None)
                compiled[i] = None
                if pq.stream is not None:
                    ok, result = self._retry_stream(req, pq, e)
                else:
                    ok, result = self._retry_read(req, pq, e)
                if ok:
                    self._note_result_bytes(req, pq)
                    self._serve(req, result, route=pq.route)
            except Exception as e:
                tokens.append(None)
                compiled[i] = None
                self._fail(req, e)
        return tokens

    def _finalize_reads(
        self, reads: list[_Admitted], compiled: list[PhysicalQuery | None],
        tokens: list[Any],
    ) -> None:
        """The blocking half: pull each query's result (or iterate its chunk
        stream), resolve tickets, and charge per-lane accounting.  A ticket
        whose deadline lapsed while its pass was in flight resolves with
        :class:`DeadlineExceeded` — its device work completed, but the SLO
        answer is a typed miss, not a stale success."""
        for req, pq, token in zip(reads, compiled, tokens):
            if pq is None:
                continue
            if self._expire(req, "finalize"):
                continue
            try:
                if pq.stream is not None:
                    result = self._serve_stream(req, token)
                else:
                    result = pq.finalize(token)
            except faults.TransientFault as e:
                if pq.stream is not None:
                    ok, result = self._retry_stream(req, pq, e)
                else:
                    # re-run the whole query individually: the launched
                    # pass's tokens are tainted by the fault, a fresh
                    # pq.run() is the clean per-query fallback path
                    ok, result = self._retry_read(req, pq, e)
                if not ok:
                    continue
            except Exception as e:
                self._fail(req, e)
                continue
            self._note_result_bytes(req, pq)
            self._serve(req, result, route=pq.route)

    def _serve_stream(self, req: _Admitted, chunk_iter) -> None:
        """Drain the query's chunk iterator (created at launch) into its
        StreamingTicket: each chunk is visible to ``chunks()`` the moment
        its scan lands, while the remaining chunks are still being
        produced."""
        ticket = req.ticket
        lane = self.stats.lanes[req.lane]
        for chunk in chunk_iter:
            ticket._push(chunk)
            self.stats.stream_chunks += 1
            lane.result_bytes += int(chunk.nbytes)
        self.stats.streams += 1
        return None  # StreamingTicket.result() concatenates its chunks

    def _note_result_bytes(self, req: _Admitted, pq: PhysicalQuery) -> None:
        if pq.stream is None:  # streams charge per pushed chunk instead
            self.stats.lanes[req.lane].result_bytes += sum(
                op.result_bytes() for op in pq.ops
            )

    def begin_tick(self) -> _InflightTick | None:
        """The non-blocking half of a tick: drain one batch, apply its
        writes, *enqueue* the tick's shared pass (compile +
        ``execute_many_async`` + per-query launch — no host syncs for the
        bulk lane), and serve the express lane to completion.  Returns the
        in-flight handle for :meth:`finish_tick`, or ``None`` if nothing
        was queued.

        Express reads are finalized here: their results are scalar-sized,
        so pulling them is O(1) host work, and serving them ahead of the
        bulk lane's O(rows) transfers is what keeps a point read's latency
        independent of how much analytics traffic shares the tick.
        """
        batch = self._drain_batch()
        if not batch:
            return None
        self.stats.ticks += 1
        if self._open_ticks > 0:
            self.stats.ticks_overlapped += 1
        if self._poisoned:  # quarantine cooldowns tick down per served tick
            self._poisoned = {sig: left - 1
                              for sig, left in self._poisoned.items()
                              if left > 1}

        self._run_writes(batch)
        live = [req for req in batch
                if req.write is None and not self._expire(req, "admission")]
        express = [req for req in live if req.lane == "express"]
        bulk = [req for req in live if req.lane == "bulk"]

        # Both lanes compile into ONE op batch: same-table work still fuses
        # into a single shared pass per table regardless of lane (the
        # one-pass invariant the engine tests pin down).  Lanes differ in
        # *finalize order*, not in scan count — express results are pulled
        # here, bulk's (typically much larger) host transfers wait for
        # finish_tick.
        reads = express + bulk
        compiled = self._compile_reads(reads)
        tokens = self._launch_reads(reads, compiled)
        tick = _InflightTick(processed=len(batch))
        if tokens is not None:
            n = len(express)
            self._finalize_reads(reads[:n], compiled[:n], tokens[:n])
            if bulk:
                tick.reads = reads[n:]
                tick.compiled = compiled[n:]
                tick.tokens = tokens[n:]
        self._open_ticks += 1
        return tick

    def finish_tick(self, tick: _InflightTick | None) -> int:
        """The blocking half: finalize the tick's bulk pass and resolve its
        tickets (streamed queries push their chunks here).  Returns the
        number of requests the tick processed; idempotent per tick."""
        if tick is None:
            return 0
        if tick.finished:
            return 0
        tick.finished = True
        self._open_ticks -= 1
        if tick.reads:
            # sweep deadlines BEFORE any O(rows) bulk transfer: a ticket
            # that expired while its pass was in flight is resolved typed
            # here and its finalize/transfer work is skipped entirely —
            # the result is dropped, not pulled then discarded
            for i, req in enumerate(tick.reads):
                if (tick.compiled[i] is not None
                        and self._expire(req, "finish_tick")):
                    tick.compiled[i] = None
            self._finalize_reads(tick.reads, tick.compiled, tick.tokens)
        return tick.processed

    def run_tick(self) -> int:
        """Serve one batch start-to-finish: drain ≤ ``max_batch`` requests,
        apply writes, serve the express lane, execute and finalize the bulk
        lane.  Returns the number of requests processed (served + failed).
        The serial spelling of ``begin_tick()`` + ``finish_tick()`` — same
        results, no overlap."""
        return self.finish_tick(self.begin_tick())

    def _pin_read(self, node: PlanNode) -> bool:
        """Should this read carry the tick snapshot?  Auto mode pins exactly
        the tables this server has written — a mutated table must not
        double-count row versions, while reads of never-written tables keep
        their historical (unpinned) result shapes no matter what unrelated
        traffic does.  A join pins when *either* side has been written."""
        if self.snapshot_reads is not None:
            return self.snapshot_reads
        return any(t.uid in self._written_uids
                   for t in _plan_tables(node))

    def _record_latency(self, ticket: QueryTicket) -> None:
        lat = ticket.latency_s
        self.stats.latency.add(lat)
        lane = self.stats.lanes[ticket.lane]
        lane.latency.add(lat)
        if ticket.queue_wait_s is not None:
            lane.queue_wait.add(ticket.queue_wait_s)
            lane.service.add(max(lat - ticket.queue_wait_s, 0.0))
        with self._lock:  # client_latencies() iterates under the lock
            ent = self._client_latency.setdefault(ticket.client, [0, 0.0, 0.0])
            ent[0] += 1
            ent[1] += lat
            ent[2] = max(ent[2], lat)

    def drain(self) -> int:
        """Run ticks until the admission queues are empty; returns total
        processed.  With ``pipeline=True`` ticks are double-buffered: tick
        N+1's drain/writes/compile/launch run before tick N's finalize
        blocks, so host-side tick work overlaps the in-flight device pass.
        """
        total = 0
        if not self.pipeline:
            while True:
                n = self.run_tick()
                if n == 0:
                    return total
                total += n
        inflight: _InflightTick | None = None
        while True:
            nxt = self.begin_tick()
            total += self.finish_tick(inflight)
            if nxt is None:
                return total
            inflight = nxt

    # ------------------------------------------------------ background loop
    def start(self, idle_wait_s: float = 0.001) -> None:
        """Serve ticks on a background thread until :meth:`stop` (pipelined
        per the ``pipeline`` flag, like :meth:`drain`)."""
        if self._thread is not None:
            raise RuntimeError("server already started")
        self._stop.clear()

        def loop() -> None:
            inflight: _InflightTick | None = None
            while not self._stop.is_set():
                nxt = self.begin_tick() if self.pipeline else self.run_tick()
                if self.pipeline:
                    self.finish_tick(inflight)
                    inflight = nxt
                if not nxt:
                    self._stop.wait(idle_wait_s)
            self.finish_tick(inflight)  # settle the last in-flight tick

        self._thread = threading.Thread(target=loop, name="query-server", daemon=True)
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None

    def __enter__(self) -> "QueryServer":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------ reporting
    def client_latencies(self) -> dict[str, dict[str, float]]:
        """Per-client latency summary: count / mean / max seconds."""
        with self._lock:
            return {
                client: {
                    "count": count,
                    "mean_s": total / count,
                    "max_s": max_s,
                }
                for client, (count, total, max_s) in self._client_latency.items()
            }

    def snapshot(self) -> dict[str, Any]:
        """One flat dict of serving + engine counters (for logs/benchmarks).

        Per-lane keys are prefixed ``express_``/``bulk_``; the ``*_ms``
        percentiles read the lane's bounded reservoirs (exact until the cap,
        unbiased beyond).  ``docs/metrics.md`` documents every key."""
        e = self.engine.stats
        out = {
            "queue_depth": self.queue_depth,
            "submitted": self.stats.submitted,
            "served": self.stats.served,
            "failed": self.stats.failed,
            "ticks": self.stats.ticks,
            "ticks_overlapped": self.stats.ticks_overlapped,
            "max_queue_depth": self.stats.max_queue_depth,
            "shared_scan_ratio": self.stats.shared_scan_ratio,
            "bytes_saved": self.stats.bytes_saved,
            "mean_latency_s": self.stats.mean_latency_s,
            "max_latency_s": self.stats.latency_max_s,
            "deadline_misses": self.stats.deadline_misses,
            "shed": self.stats.shed,
            "degraded": self.stats.degraded,
            "retries": self.stats.retries,
            "poisoned": self.stats.poisoned,
            "poison_quarantined": len(self._poisoned),
            "streams": self.stats.streams,
            "stream_chunks": self.stats.stream_chunks,
            "writes_applied": self.stats.writes_applied,
            "rows_written": self.stats.rows_written,
        }
        for name, lane in self.stats.lanes.items():
            out[f"{name}_served"] = lane.served
            out[f"{name}_failed"] = lane.failed
            out[f"{name}_deadline_misses"] = lane.deadline_misses
            out[f"{name}_shed"] = lane.shed
            out[f"{name}_result_bytes"] = lane.result_bytes
            out[f"{name}_p50_ms"] = lane.latency.percentile(50) * 1e3
            out[f"{name}_p95_ms"] = lane.latency.percentile(95) * 1e3
            out[f"{name}_p99_ms"] = lane.latency.percentile(99) * 1e3
            out[f"{name}_queue_wait_p95_ms"] = lane.queue_wait.percentile(95) * 1e3
            out[f"{name}_service_p95_ms"] = lane.service.percentile(95) * 1e3
        out.update({
            "engine_shared_scans": e.shared_scans,
            "engine_hot_hits": e.hot_hits,
            "engine_delta_hits": e.delta_hits,
            "engine_cold_misses": e.cold_misses,
            "engine_bytes_from_dram": e.bytes_from_dram,
            "engine_bytes_uploaded": e.bytes_uploaded,
            "engine_uploads": e.uploads,
            "engine_bytes_uploaded_delta": e.bytes_uploaded_delta,
            "engine_delta_uploads": e.delta_uploads,
            "engine_bytes_collective": e.bytes_collective,
            "engine_collective_ops": e.collective_ops,
            "engine_retries": e.retries,
            "engine_failovers": e.failovers,
            "engine_bytes_failover": e.bytes_failover,
            "engine_bytes_saved_compression": e.bytes_saved_compression,
            "engine_decodes": e.decodes,
            "engine_decode_cache_hits": e.decode_cache_hits,
        })
        out.update(self.engine.breaker.snapshot())
        if hasattr(self.engine, "shard_health"):
            out["engine_shards_quarantined"] = sum(
                1 for s in self.engine.shard_health() if s != "healthy"
            )
        if self.wal is not None:
            out["wal_records"] = self.wal.record_count
            out["wal_bytes"] = self.wal.nbytes
        return out


def _plan_tables(node: PlanNode) -> list[RelationalTable]:
    """Every base table a plan reads (both sides of a join)."""
    tables, stack = [], [node]
    while stack:
        n = stack.pop()
        if isinstance(n, Scan):
            tables.append(n.table)
        stack.extend(n.children())
    return tables


def _snapshot_capable(node: PlanNode, path: str) -> bool:
    """Whether ``compile_plan`` accepts a ``snapshot_ts`` for this request:
    rme-path plans only (the row/col host baselines have no MVCC visibility
    channel — see planner._check_snapshot_path).  Joins pin through the
    device hash route when its column constraints hold (int32 keys, 4-byte
    payloads); an inexpressible join compiles unpinned rather than failing
    its ticket."""
    if path != "rme":
        return False
    try:
        shape = decompose(node)
    except Exception:
        return False
    if shape.kind == "join":
        return _device_join_expressible(shape)
    return True
