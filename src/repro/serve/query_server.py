"""Concurrent query serving: many clients, one engine, shared scans per tick —
and, since the write-path HTAP work, live writes interleaved with them.

The paper's closing argument (§8) is that native column access "can vastly
simplify the software logic" of an analytics engine.  This module is the
multi-tenant half of that story: a :class:`QueryServer` owns one
:class:`~repro.core.engine.RelationalMemoryEngine` and admits *logical plans*
(:mod:`repro.core.plan`) from any number of concurrent clients.  Requests are
not executed as they arrive — they queue, and each serving **tick** drains a
batch, compiles every plan (:func:`repro.core.planner.compile_plan`), and
coalesces all of the batch's scan ops into **one** ``execute_many`` call:
same-table work from different clients — projections, fused filters, fused
aggregates, and group-bys alike — rides a single shared Fetch-Unit stream
(the heterogeneous one-pass kernel ``rme_scan_multi``), so a mixed-kind
same-table tick performs exactly one row-store pass instead of one per op
kind.  Nothing in the tick syncs with the host until finalize.

The write path (HTAP)
---------------------
Clients also submit **write tickets** — :meth:`QueryServer.submit_insert` /
``submit_update`` / ``submit_delete`` — into the same admission queue.  A
tick applies its writes *first*, in admission order, then serves every read
of the tick from the resulting state: one consistent post-write snapshot per
tick, so readers never block on writers and writers never wait for readers
(MVCC gives pinned readers their own view regardless).  Once a server has
admitted any write (or always, with ``snapshot_reads=True``), the snapshot
is explicit — each read is compiled with ``snapshot_ts`` set to its table's
post-write clock, fusing the MVCC visibility test in-scan (see
:func:`repro.core.planner.compile_plan`; note this changes project-shaped
results to the ``(packed, mask)`` filter contract).  Because the engine's
row store is delta-chunked, a tick's writes
cost O(delta) host→device bytes: appended rows ship as tail chunks, deletes
and updates ship only patched timestamp words, and hot views survive appends
via incremental tail scans instead of cold rebuilds.

Threading model: ``submit*`` is thread-safe and non-blocking (clients get a
:class:`QueryTicket` and block on ``result()`` at their leisure); all engine
*and table* work happens on whichever single thread calls ``run_tick`` —
either the caller's (deterministic, what the tests drive) or the background
serving thread started by ``start()``/the ``serving()`` context manager.  JAX
traces, device buffers, and the host row stores are therefore never touched
from two threads at once.

Accounting: the server reports engine-level :class:`~repro.core.engine.
EngineStats` plus its own :class:`ServerStats` — queue depth, shared-scan
ratio (cold table-groups served by a genuine multi-view scan),
``bytes_saved`` (the row-store bytes a per-query cold execution of the same
traffic would have moved minus what the shared scans actually moved), and
the write-side counters (writes applied per kind, rows written).  The
engine's ``bytes_uploaded_delta``/``delta_uploads`` split shows what the
write path actually shipped host→device.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any, Mapping

import numpy as np

from repro.core.engine import RelationalMemoryEngine
from repro.core.plan import Join, PlanBuilder, PlanNode, Scan, decompose
from repro.core.planner import (
    PhysicalQuery,
    _device_join_expressible,
    compile_plan,
)
from repro.core.requests import ProjectOp
from repro.core.table import RelationalTable


class QueryTicket:
    """A client's handle on one admitted request; resolved at end of its tick.

    Read tickets resolve to their query result; write tickets resolve to the
    new physical row indices (insert/update) or ``None`` (delete).
    """

    __slots__ = ("client", "submitted_at", "latency_s", "route",
                 "_event", "_result", "_error")

    def __init__(self, client: str):
        self.client = client
        self.submitted_at = time.perf_counter()
        self.latency_s: float | None = None
        self.route: str | None = None
        self._event = threading.Event()
        self._result: Any = None
        self._error: BaseException | None = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None) -> Any:
        """Block until served; re-raises compile/execution errors."""
        if not self._event.wait(timeout):
            raise TimeoutError(f"query for client {self.client!r} not served")
        if self._error is not None:
            raise self._error
        return self._result

    def _resolve(self, result: Any = None, error: BaseException | None = None,
                 route: str | None = None) -> None:
        self.latency_s = time.perf_counter() - self.submitted_at
        self.route = route
        self._result, self._error = result, error
        self._event.set()


@dataclasses.dataclass
class ServerStats:
    """Serving-layer counters (the engine's own PMU counts the bytes)."""

    submitted: int = 0
    served: int = 0
    failed: int = 0
    ticks: int = 0
    max_queue_depth: int = 0
    table_groups: int = 0  # cold same-table view groups across all ticks
    table_groups_shared: int = 0  # of those, served by a multi-view shared scan
    bytes_saved: int = 0  # row-store bytes avoided vs per-query cold execution
    latency_sum_s: float = 0.0
    latency_max_s: float = 0.0
    # write-path counters
    writes_submitted: int = 0
    writes_applied: int = 0
    inserts: int = 0
    updates: int = 0
    deletes: int = 0
    rows_written: int = 0  # rows inserted + replacement rows + rows deleted

    @property
    def shared_scan_ratio(self) -> float:
        """Fraction of cold table-groups that coalesced into a shared scan."""
        return self.table_groups_shared / max(self.table_groups, 1)

    @property
    def mean_latency_s(self) -> float:
        return self.latency_sum_s / max(self.served, 1)


@dataclasses.dataclass
class _WritePayload:
    """One admitted write: insert (columns), update (rows+values), delete (rows)."""

    kind: str  # "insert" | "update" | "delete"
    table: RelationalTable
    columns: Mapping[str, np.ndarray] | None = None
    rows: np.ndarray | None = None
    values: Mapping[str, np.ndarray] | None = None


@dataclasses.dataclass
class _Admitted:
    ticket: QueryTicket
    node: PlanNode | None
    path: str
    colstore: Mapping[str, np.ndarray] | None
    right_colstore: Mapping[str, np.ndarray] | None
    write: _WritePayload | None = None


class QueryServer:
    """Admission queue + tick executor over one relational memory engine.

    ``snapshot_reads`` controls whether reads are compiled with the tick's
    post-write snapshot timestamp (fused MVCC visibility; project-shaped
    plans then return ``(packed, mask)``).  The default, ``None``, is
    **auto, per table**: reads of tables this server has never written keep
    the historical unpinned contract (nothing about their results changes,
    regardless of unrelated write traffic), while a table's first applied
    write pins every subsequent read of *that table* — without pinning, a
    read after an update/delete would count old *and* replacement row
    versions, because unpinned scans have no MVCC test.  Pass
    ``True``/``False`` to force either mode globally; plans that cannot
    carry a snapshot (joins, row/col host paths) always compile unpinned.

    ``mesh`` / ``num_shards`` construct a mesh-sharded backend
    (:class:`repro.core.distributed.ShardedEngine`) instead of the default
    single-device engine: each shard owns a contiguous row range on its own
    device, a tick's fused pass runs per shard, and only reduced results
    cross the interconnect (``engine_bytes_collective`` in
    :meth:`snapshot`).  Mutually exclusive with passing ``engine`` — a
    pre-built engine already fixes the backend.
    """

    def __init__(
        self,
        engine: RelationalMemoryEngine | None = None,
        max_batch: int = 64,
        snapshot_reads: bool | None = None,
        mesh=None,
        num_shards: int | None = None,
    ):
        if engine is not None and (mesh is not None or num_shards is not None):
            raise ValueError(
                "pass either a pre-built engine or mesh/num_shards, not both"
            )
        if engine is None and (mesh is not None or num_shards is not None):
            from repro.core.distributed import ShardedEngine  # deferred import

            engine = ShardedEngine(mesh=mesh, num_shards=num_shards)
        self.engine = engine if engine is not None else RelationalMemoryEngine()
        self.max_batch = max_batch
        self.snapshot_reads = snapshot_reads
        self.stats = ServerStats()
        self._lock = threading.Lock()
        self._queue: deque[_Admitted] = deque()
        # tables that have taken a write through this server (auto snapshot
        # pinning is per-table: reads of never-written tables keep their
        # historical result shapes); touched only on the tick thread
        self._written_uids: set[int] = set()
        # per-client running (count, sum_s, max_s) — scalars, not a sample
        # list: a long-running server must not grow per served query
        self._client_latency: dict[str, list[float]] = {}
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()

    # ------------------------------------------------------------ admission
    def submit(
        self,
        query: PlanNode | PlanBuilder,
        client: str = "anon",
        path: str = "rme",
        colstore: Mapping[str, np.ndarray] | None = None,
        right_colstore: Mapping[str, np.ndarray] | None = None,
    ) -> QueryTicket:
        """Admit a logical plan; returns immediately with a ticket."""
        node = query.build() if isinstance(query, PlanBuilder) else query
        return self._admit(_Admitted(
            QueryTicket(client), node, path, colstore, right_colstore
        ))

    def submit_insert(
        self,
        table: RelationalTable,
        columns: Mapping[str, np.ndarray],
        client: str = "anon",
    ) -> QueryTicket:
        """Admit an insert; the ticket resolves to the new physical row indices.

        The rows become visible to every read admitted into (or after) the
        tick that applies the write — and cost O(rows) upload bytes, since
        the device row store ships them as a tail chunk.
        """
        return self._admit(_Admitted(
            QueryTicket(client), None, "write", None, None,
            write=_WritePayload("insert", table, columns=dict(columns)),
        ))

    def submit_update(
        self,
        table: RelationalTable,
        rows: np.ndarray,
        values: Mapping[str, np.ndarray],
        client: str = "anon",
    ) -> QueryTicket:
        """Admit an MVCC update of the given physical rows; resolves to the
        replacement rows' indices.  Old versions stay readable at earlier
        snapshots."""
        return self._admit(_Admitted(
            QueryTicket(client), None, "write", None, None,
            write=_WritePayload("update", table, rows=np.asarray(rows),
                                values=dict(values)),
        ))

    def submit_delete(
        self,
        table: RelationalTable,
        rows: np.ndarray,
        client: str = "anon",
    ) -> QueryTicket:
        """Admit an MVCC delete of the given physical rows; resolves to ``None``.
        Costs O(rows) timestamp words of upload, never a table re-ship."""
        return self._admit(_Admitted(
            QueryTicket(client), None, "write", None, None,
            write=_WritePayload("delete", table, rows=np.asarray(rows)),
        ))

    def _admit(self, adm: _Admitted) -> QueryTicket:
        with self._lock:
            self._queue.append(adm)
            self.stats.submitted += 1
            if adm.write is not None:
                self.stats.writes_submitted += 1
            self.stats.max_queue_depth = max(
                self.stats.max_queue_depth, len(self._queue)
            )
        return adm.ticket

    @property
    def queue_depth(self) -> int:
        with self._lock:
            return len(self._queue)

    # --------------------------------------------------------------- writes
    def _apply_write(self, w: _WritePayload) -> Any:
        if w.kind == "insert":
            rows = w.table.append(w.columns)
            self.stats.inserts += 1
            self.stats.rows_written += len(rows)
            return rows
        if w.kind == "update":
            rows = w.table.update(w.rows, w.values)
            self.stats.updates += 1
            self.stats.rows_written += len(rows)
            return rows
        if w.kind == "delete":
            n_deleted = w.table.delete(w.rows)
            self.stats.deletes += 1
            self.stats.rows_written += n_deleted  # live rows only, not ids
            return None
        raise ValueError(f"unknown write kind {w.kind!r}")

    def _run_writes(self, batch: list[_Admitted]) -> None:
        """Apply the tick's writes in admission order, resolving their tickets.

        Runs before any read compiles, so the tick's reads all observe one
        consistent post-write state — the tick's snapshot.  A failing write
        resolves its own ticket with the error and never blocks the reads.
        """
        for req in batch:
            if req.write is None:
                continue
            try:
                result = self._apply_write(req.write)
            except Exception as e:
                self.stats.failed += 1
                req.ticket._resolve(error=e)
                continue
            self._written_uids.add(req.write.table.uid)
            self.stats.writes_applied += 1
            self.stats.served += 1
            req.ticket._resolve(result=result, route=f"write-{req.write.kind}")
            self._record_latency(req.ticket)

    # ------------------------------------------------------------ execution
    def _account_cold_groups(self, ops) -> None:
        """Shared-scan ratio + bytes-saved credit for this tick's op batch.

        Cold ops (projections not served by the reorg cache, plus every
        filter/aggregate/group-by) are grouped per table, the way
        ``execute_many`` will coalesce them; a group of ≥2 distinct lowered
        requests becomes one shared scan whose cost is the union geometry
        over all enabled words, while a per-query execution would have paid
        every request's own pass.
        """
        by_table: dict[int, tuple[Any, dict]] = {}
        for op in ops:
            if isinstance(op, ProjectOp):
                # served from the cache — a full hot hit or a tail-only delta
                # serve — means the op never joins the shared pass, so it
                # must not be priced as a full cold scan here
                if self.engine.projection_is_cached(op.table, op.view.geometry):
                    continue
            entry = by_table.setdefault(op.table.uid, (op.table, {}))
            entry[1].setdefault(op.lower())
        for table, reqs in by_table.values():
            self.stats.table_groups += 1
            if len(reqs) >= 2:
                self.stats.table_groups_shared += 1
                independent = sum(
                    self.engine.scan_bytes(table, (r,)) for r in reqs
                )
                union = self.engine.scan_bytes(table, tuple(reqs))
                self.stats.bytes_saved += independent - union
            # a lone cold request is priced identically either way

    def run_tick(self) -> int:
        """Serve one batch: drain ≤ ``max_batch`` requests, apply writes,
        coalesce and execute reads.

        Returns the number of requests processed (served + failed).  Writes
        apply first (admission order), so every read of the tick sees the
        same post-write snapshot; then all device work of the read batch is
        enqueued before any query's finalize blocks, and every kind of
        same-table op fuses into the shared pass, so one tick costs at most
        one scan per distinct table — plus O(delta) upload bytes for the
        writes it applied.
        """
        with self._lock:
            n = min(self.max_batch, len(self._queue))
            batch = [self._queue.popleft() for _ in range(n)]
        if not batch:
            return 0
        self.stats.ticks += 1

        self._run_writes(batch)
        reads = [req for req in batch if req.write is None]
        if not reads:
            return len(batch)

        compiled: list[PhysicalQuery | None] = []
        for req in reads:
            try:
                snapshot_ts = None
                if (self._pin_read(req.node)
                        and _snapshot_capable(req.node, req.path)):
                    # the tick's snapshot: the post-write clock of the plan's
                    # tables (per-table clocks; writes already applied) — for
                    # a join, the max over both sides, so every row live in
                    # either table right now is visible.  Plans that cannot
                    # carry a snapshot — host-path baselines, joins whose
                    # columns the device route cannot express — compile
                    # unpinned; they still observe the tick-consistent
                    # post-write state (writes ran first)
                    snapshot_ts = max(
                        t.now() for t in _plan_tables(req.node)
                    )
                compiled.append(compile_plan(
                    self.engine, req.node, path=req.path,
                    colstore=req.colstore, right_colstore=req.right_colstore,
                    snapshot_ts=snapshot_ts,
                ))
            except Exception as e:  # compile errors belong to the client
                compiled.append(None)
                self.stats.failed += 1
                req.ticket._resolve(error=e)

        # one engine batch for every scan op in the tick: cross-client
        # same-table work — projections, filters, aggregates, group-bys —
        # coalesces into one heterogeneous shared scan (the engine counts it)
        ops, spans = [], []
        for pq in compiled:
            if pq is None:
                spans.append((0, 0))
                continue
            spans.append((len(ops), len(pq.ops)))
            ops.extend(pq.ops)
        self._account_cold_groups(ops)
        try:
            packed = self.engine.execute_many(ops) if ops else []
        except Exception:
            # the shared step failed (one op's lowering error, OOM on the
            # union geometry, ...).  One bad client must not poison the
            # tick: fall back to executing each query individually, so every
            # healthy ticket still resolves with its result and only the
            # offender carries the error.  (PMU counters may over-charge the
            # aborted shared attempt — accounting noise, not a result bug.)
            for req, pq in zip(reads, compiled):
                if pq is None:
                    continue
                try:
                    result = pq.run()
                except Exception as e:
                    self.stats.failed += 1
                    req.ticket._resolve(error=e)
                    continue
                req.ticket._resolve(result=result, route=pq.route)
                self.stats.served += 1
                self._record_latency(req.ticket)
            return len(batch)

        tokens: list[Any] = []
        for i, (req, pq) in enumerate(zip(reads, compiled)):
            if pq is None:
                tokens.append(None)
                continue
            off, k = spans[i]
            try:
                tokens.append(pq.launch(packed[off : off + k]))
            except Exception as e:
                tokens.append(None)
                compiled[i] = None
                self.stats.failed += 1
                req.ticket._resolve(error=e)

        for req, pq, token in zip(reads, compiled, tokens):
            if pq is None:
                continue
            try:
                result = pq.finalize(token)
            except Exception as e:
                self.stats.failed += 1
                req.ticket._resolve(error=e)
                continue
            req.ticket._resolve(result=result, route=pq.route)
            self.stats.served += 1
            self._record_latency(req.ticket)
        return len(batch)

    def _pin_read(self, node: PlanNode) -> bool:
        """Should this read carry the tick snapshot?  Auto mode pins exactly
        the tables this server has written — a mutated table must not
        double-count row versions, while reads of never-written tables keep
        their historical (unpinned) result shapes no matter what unrelated
        traffic does.  A join pins when *either* side has been written."""
        if self.snapshot_reads is not None:
            return self.snapshot_reads
        return any(t.uid in self._written_uids
                   for t in _plan_tables(node))

    def _record_latency(self, ticket: QueryTicket) -> None:
        lat = ticket.latency_s
        self.stats.latency_sum_s += lat
        self.stats.latency_max_s = max(self.stats.latency_max_s, lat)
        with self._lock:  # client_latencies() iterates under the lock
            ent = self._client_latency.setdefault(ticket.client, [0, 0.0, 0.0])
            ent[0] += 1
            ent[1] += lat
            ent[2] = max(ent[2], lat)

    def drain(self) -> int:
        """Run ticks until the admission queue is empty; returns total processed."""
        total = 0
        while True:
            n = self.run_tick()
            if n == 0:
                return total
            total += n

    # ------------------------------------------------------ background loop
    def start(self, idle_wait_s: float = 0.001) -> None:
        """Serve ticks on a background thread until :meth:`stop`."""
        if self._thread is not None:
            raise RuntimeError("server already started")
        self._stop.clear()

        def loop() -> None:
            while not self._stop.is_set():
                if self.run_tick() == 0:
                    self._stop.wait(idle_wait_s)

        self._thread = threading.Thread(target=loop, name="query-server", daemon=True)
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None

    def __enter__(self) -> "QueryServer":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------ reporting
    def client_latencies(self) -> dict[str, dict[str, float]]:
        """Per-client latency summary: count / mean / max seconds."""
        with self._lock:
            return {
                client: {
                    "count": count,
                    "mean_s": total / count,
                    "max_s": max_s,
                }
                for client, (count, total, max_s) in self._client_latency.items()
            }

    def snapshot(self) -> dict[str, Any]:
        """One flat dict of serving + engine counters (for logs/benchmarks)."""
        e = self.engine.stats
        return {
            "queue_depth": self.queue_depth,
            "submitted": self.stats.submitted,
            "served": self.stats.served,
            "failed": self.stats.failed,
            "ticks": self.stats.ticks,
            "max_queue_depth": self.stats.max_queue_depth,
            "shared_scan_ratio": self.stats.shared_scan_ratio,
            "bytes_saved": self.stats.bytes_saved,
            "mean_latency_s": self.stats.mean_latency_s,
            "max_latency_s": self.stats.latency_max_s,
            "writes_applied": self.stats.writes_applied,
            "rows_written": self.stats.rows_written,
            "engine_shared_scans": e.shared_scans,
            "engine_hot_hits": e.hot_hits,
            "engine_delta_hits": e.delta_hits,
            "engine_cold_misses": e.cold_misses,
            "engine_bytes_from_dram": e.bytes_from_dram,
            "engine_bytes_uploaded": e.bytes_uploaded,
            "engine_uploads": e.uploads,
            "engine_bytes_uploaded_delta": e.bytes_uploaded_delta,
            "engine_delta_uploads": e.delta_uploads,
            "engine_bytes_collective": e.bytes_collective,
            "engine_collective_ops": e.collective_ops,
        }


def _plan_tables(node: PlanNode) -> list[RelationalTable]:
    """Every base table a plan reads (both sides of a join)."""
    tables, stack = [], [node]
    while stack:
        n = stack.pop()
        if isinstance(n, Scan):
            tables.append(n.table)
        stack.extend(n.children())
    return tables


def _snapshot_capable(node: PlanNode, path: str) -> bool:
    """Whether ``compile_plan`` accepts a ``snapshot_ts`` for this request:
    rme-path plans only (the row/col host baselines have no MVCC visibility
    channel — see planner._check_snapshot_path).  Joins pin through the
    device hash route when its column constraints hold (int32 keys, 4-byte
    payloads); an inexpressible join compiles unpinned rather than failing
    its ticket."""
    if path != "rme":
        return False
    if isinstance(node, Join):
        try:
            return _device_join_expressible(decompose(node))
        except Exception:
            return False
    return True
