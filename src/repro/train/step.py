"""Train-step factory: loss → grads → clipped AdamW update, fully sharded.

``make_train_step`` closes over the model and optimizer config and returns a
pure ``(state, batch) -> (state, metrics)`` suitable for ``jax.jit`` with
explicit in/out shardings (the dry-run lowers exactly this function).
Gradient accumulation wraps the loss in an inner ``lax.scan`` over
microbatches; gradient compression (bf16 cast before the DP all-reduce) is a
flag — grads are produced in bf16 and upcast inside the optimizer, so the
cross-replica reduction moves half the bytes.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.distributed.partitioning import logical_spec, params_partition_specs

from .optimizer import AdamWConfig, adamw_init, adamw_update, opt_state_specs


@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any
    step: int = 0


def make_train_step(
    model,
    opt_cfg: AdamWConfig,
    grad_accum: int = 1,
    grad_dtype: str | None = None,  # "bfloat16" => compressed DP all-reduce
) -> Callable:
    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        params, opt = state["params"], state["opt"]
        if grad_accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def micro(carry, mb):
                acc, loss_acc = carry
                (l, _), g = grad_fn(params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, loss_acc + l), None

            # the f32 accumulator inherits each parameter's sharding — the
            # carry would otherwise be free for XLA to replicate
            from repro.distributed.partitioning import (
                current_rules, params_partition_specs,
            )

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            if current_rules() is not None:
                specs = params_partition_specs(
                    jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype),
                                 params)
                )
                zero = jax.tree.map(
                    lambda z, s: jax.lax.with_sharding_constraint(z, s),
                    zero, specs,
                )
            mbs = jax.tree.map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum) + x.shape[1:]),
                batch,
            )
            (grads, loss), _ = jax.lax.scan(micro, (zero, jnp.zeros(())), mbs)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = loss / grad_accum
            metrics = {}
        if grad_dtype is not None:
            grads = jax.tree.map(
                lambda g: g.astype(jnp.dtype(grad_dtype)), grads
            )
        new_params, new_opt, opt_metrics = adamw_update(params, grads, opt, opt_cfg)
        out = {"loss": loss, **metrics, **opt_metrics}
        return {"params": new_params, "opt": new_opt}, out

    return train_step


def init_train_state(model, key) -> dict:
    params = model.init(key)
    return {"params": params, "opt": adamw_init(params)}


def train_state_specs(params_shapes) -> dict:
    """Partition specs for the full train state (params TP, moments ZeRO-1)."""
    return {
        "params": params_partition_specs(params_shapes),
        "opt": opt_state_specs(params_shapes),
    }


def batch_specs(batch_shapes) -> dict:
    """Data batches are sharded over the batch axes on dim 0."""
    def spec(x):
        return logical_spec("batch", *([None] * (len(x.shape) - 1)), shape=x.shape)

    return jax.tree.map(spec, batch_shapes)
