"""Training substrate: AdamW + ZeRO-1, train-step factory, trainer loop."""

from .optimizer import AdamWConfig, adamw_init, adamw_update, opt_state_specs
from .step import TrainState, make_train_step, train_state_specs
from .trainer import Trainer, TrainerConfig

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "opt_state_specs",
    "TrainState", "make_train_step", "train_state_specs",
    "Trainer", "TrainerConfig",
]
