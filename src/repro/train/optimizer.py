"""AdamW with global-norm clipping and ZeRO-1 moment sharding.

Pure-function optimizer (no optax dependency): ``adamw_init`` builds the
moment pytree, ``adamw_update`` applies one step.  ZeRO-1 comes from
*sharding*, not algorithm: ``opt_state_specs`` assigns each moment tensor the
parameter's TP spec plus the ``zero`` (data) axis on its first shardable dim,
so moments occupy 1/(data×model) of their replicated size while parameters
stay TP-sharded/DP-replicated.  XLA inserts the all-gather of the sharded
update into the parameter layout — the classic ZeRO-1 schedule.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.partitioning import (
    current_mesh_shape,
    current_rules,
    params_partition_specs,
)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to min_lr_ratio·lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.decay_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def adamw_init(params) -> dict:
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(x.astype(jnp.float32) ** 2) for x in jax.tree.leaves(tree))
    )


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """One AdamW step. Returns (params, state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        # moment math in f32, stored back in the moment dtype (bf16 for the
        # MoE giants) — otherwise bf16 + f32 silently promotes the optimizer
        # state to f32 in the output, doubling its footprint and breaking
        # the donated-buffer aliasing of the train step
        mu_f = b1 * mu.astype(jnp.float32) + (1 - b1) * g
        nu_f = b2 * nu.astype(jnp.float32) + (1 - b2) * g * g
        mhat = mu_f / c1
        vhat = nu_f / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, mu_f.astype(mu.dtype), nu_f.astype(nu.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, metrics


def _with_zero_axis(spec: P, shape: tuple[int, ...]) -> P:
    """Add the ZeRO ('zero' rule) axes to the first unsharded, divisible dim.

    FSDP-sharded weights already consume the data axis — those moments are
    left as-is (they are already fully sharded); the zero axis only lands on
    leaves (biases, norm scales, stacked vectors) the FSDP rules skipped.
    """
    rules = current_rules() or {}
    zero = rules.get("zero")
    if not zero:
        return spec
    used: set[str] = set()
    for e in spec:
        if e is None:
            continue
        for a in (e if isinstance(e, (tuple, list)) else (e,)):
            used.add(a)
    zero = tuple(a for a in zero if a not in used)
    if not zero:
        return spec
    sizes = current_mesh_shape()
    n = 1
    for a in zero:
        n *= sizes.get(a, 1)
    if n <= 1:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for d, e in enumerate(entries):
        if e is None and shape[d] % n == 0 and shape[d] > 0:
            entries[d] = zero if len(zero) > 1 else zero[0]
            return P(*entries)
    return spec


def opt_state_specs(params_shapes) -> dict:
    """Partition specs for the optimizer state (ZeRO-1 over the data axis)."""
    base = params_partition_specs(params_shapes)
    flat_s, tdef = jax.tree.flatten(base)
    flat_p = jax.tree.leaves(params_shapes)
    zeroed = [
        _with_zero_axis(s, tuple(p.shape)) for s, p in zip(flat_s, flat_p)
    ]
    moments = jax.tree.unflatten(tdef, zeroed)
    return {"mu": moments, "nu": moments, "step": P()}
