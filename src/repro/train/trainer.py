"""Trainer loop: preemption-safe checkpoints, elastic restore, stragglers.

Fault-tolerance posture (1000+ node design, exercised here single-process):

* **Checkpoint/restart** — CheckpointManager cadence + a final checkpoint on
  SIGTERM/SIGINT (preemption notice).  Restore reshards onto whatever mesh
  the restart got (``shardings`` pytree), and the data pipeline seeks to the
  restored step so the batch stream is bit-identical.
* **Straggler mitigation** — per-step wall times feed a rolling median; steps
  slower than ``straggler_factor ×`` median are logged and counted.  On a real
  pod this signal feeds the scheduler (hot-spare swap); here it feeds metrics
  and the watchdog's slow-step counter, and the hook is exposed for tests.
* **Elasticity** — nothing in the loop binds to a device count: state specs
  and the jitted step are rebuilt per-mesh by the launcher; a restore onto a
  differently-shaped mesh only changes the shardings argument.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Iterator

import jax
import numpy as np

from repro.ckpt import CheckpointManager


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    ckpt_keep: int = 3
    log_every: int = 10
    straggler_factor: float = 2.0
    straggler_window: int = 32


class Trainer:
    def __init__(
        self,
        step_fn: Callable,  # jitted (state, batch) -> (state, metrics)
        state: Any,
        batches: Iterator[dict],
        cfg: TrainerConfig,
        state_shardings=None,
        on_straggler: Callable[[int, float, float], None] | None = None,
    ):
        self.step_fn = step_fn
        self.state = state
        self.batches = batches
        self.cfg = cfg
        self.state_shardings = state_shardings
        self.on_straggler = on_straggler
        self.manager = CheckpointManager(
            cfg.ckpt_dir, keep=cfg.ckpt_keep, every_steps=cfg.ckpt_every
        )
        self.step = 0
        self.history: list[dict] = []
        self._times: list[float] = []
        self._preempted = False
        self.straggler_steps: list[int] = []

    # ------------------------------------------------------------- lifecycle
    def try_restore(self) -> bool:
        """Resume from the latest checkpoint if one exists (elastic restart)."""
        try:
            step, state = self.manager.restore(self.state, self.state_shardings)
        except FileNotFoundError:
            return False
        self.state = state
        self.step = step
        return True

    def _handle_preemption(self, signum, frame):  # pragma: no cover - signal path
        self._preempted = True

    def _watch_stragglers(self, dt: float) -> None:
        self._times.append(dt)
        window = self._times[-self.cfg.straggler_window :]
        if len(window) >= 8:
            med = float(np.median(window[:-1]))
            if dt > self.cfg.straggler_factor * med:
                self.straggler_steps.append(self.step)
                if self.on_straggler is not None:
                    self.on_straggler(self.step, dt, med)

    # ------------------------------------------------------------------ run
    def run(self) -> list[dict]:
        prev_term = signal.signal(signal.SIGTERM, self._handle_preemption)
        prev_int = signal.getsignal(signal.SIGINT)
        try:
            for batch in self.batches:
                if self.step >= self.cfg.total_steps or self._preempted:
                    break
                t0 = time.perf_counter()
                self.state, metrics = self.step_fn(self.state, batch)
                jax.block_until_ready(jax.tree.leaves(self.state)[0])
                dt = time.perf_counter() - t0
                self.step += 1
                self._watch_stragglers(dt)
                if self.step % self.cfg.log_every == 0 or self.step == 1:
                    row = {k: float(v) for k, v in metrics.items()}
                    row.update(step=self.step, sec=dt)
                    self.history.append(row)
                if self.manager.should_save(self.step):
                    self.manager.save(self.step, self.state)
            # preemption or completion: always leave a resumable checkpoint
            self.manager.save(self.step, self.state)
        finally:
            signal.signal(signal.SIGTERM, prev_term)
            signal.signal(signal.SIGINT, prev_int)
        return self.history
