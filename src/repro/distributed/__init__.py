"""Distribution substrate: logical axis rules, collectives, pipeline."""

from .partitioning import (  # noqa: F401
    axis_rules,
    current_rules,
    logical_spec,
    lsc,
    param_partition_spec,
    set_axis_rules,
)
