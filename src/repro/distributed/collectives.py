"""Compressed cross-replica gradient reduction (shard_map building blocks).

Two compression levels for the DP all-reduce, both standard large-cluster
tricks:

* **bf16** — cast before ``psum`` (2× fewer bytes on the wire; unbiased).
* **int8 + error feedback** — per-tensor scale quantization with a residual
  carried between steps, so quantization error is re-injected instead of
  lost; converges like full precision for SGD-family optimizers.

These are used by the manual-DP training mode and by tests; the default pjit
path gets bf16 compression by producing grads in bf16 (see train/step.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def psum_bf16(x: jax.Array, axis_name) -> jax.Array:
    """All-reduce in bf16, accumulate result back in f32."""
    return jax.lax.psum(x.astype(jnp.bfloat16), axis_name).astype(jnp.float32)


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def psum_int8_ef(
    x: jax.Array, residual: jax.Array, axis_name
) -> tuple[jax.Array, jax.Array]:
    """Error-feedback int8 all-reduce: returns (reduced, new_residual).

    The residual (same shape as x) carries this step's quantization error
    into the next step's gradient — the EF-SGD/1-bit-Adam scheme.  The wire
    cost is 1 byte/elem + one scalar vs 4 bytes/elem.
    """
    comp = x + residual
    q, scale = quantize_int8(comp)
    new_residual = comp - dequantize_int8(q, scale)
    # int8 psum would overflow; sum the dequantized values (wire format is
    # int8 + scalar — the reduction itself runs in f32 on-chip as usual)
    reduced = jax.lax.psum(dequantize_int8(q, scale), axis_name)
    return reduced, new_residual


def tree_psum_compressed(
    grads, residuals, axis_name, mode: str = "bf16"
):
    """Apply compressed psum leaf-wise over a gradient pytree."""
    if mode == "none":
        return jax.tree.map(lambda g: jax.lax.psum(g, axis_name), grads), residuals
    if mode == "bf16":
        return jax.tree.map(lambda g: psum_bf16(g, axis_name), grads), residuals
    if mode == "int8_ef":
        flat_g, tdef = jax.tree.flatten(grads)
        flat_r = jax.tree.leaves(residuals)
        out = [psum_int8_ef(g, r, axis_name) for g, r in zip(flat_g, flat_r)]
        return (
            jax.tree.unflatten(tdef, [o[0] for o in out]),
            jax.tree.unflatten(tdef, [o[1] for o in out]),
        )
    raise ValueError(f"unknown compression mode {mode!r}")
