"""GPipe pipeline parallelism over the ``pod`` axis (collective-permute ring).

The default dry-run folds ``pod`` into data parallelism (one code path for
all 40 cells); this module provides the alternative mapping where the two
pods form two pipeline stages.  Schedule: GPipe with M microbatches —
forward fills the ring stage by stage via ``ppermute``, activations flow
pod→pod over the (slow) inter-pod links exactly once per microbatch per
stage boundary, which is the property that makes PP attractive between pods:
O(activations) inter-pod traffic instead of O(gradients) for pure DP.

Implementation: ``shard_map`` over ``pod``; each stage holds its slice of
the stacked layer params; microbatches stream with a standard skew of
``n_stages - 1`` bubble steps.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from repro.compat import pcast, shard_map
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(
    fn: Callable,  # (stage_params, x) -> x  : one stage's layer stack
    mesh: Mesh,
    n_microbatches: int,
    axis: str = "pod",
) -> Callable:
    """Wrap a per-stage function into a GPipe forward over ``axis``.

    ``stage_params`` must be sharded stage-major on dim 0 (P(axis, ...));
    ``x`` microbatched on dim 0 into ``n_microbatches`` slices, batch-sharded
    on nothing (each stage sees every microbatch in turn).
    """
    n_stages = mesh.shape[axis]

    def wrapped(stage_params, x):
        def local(params_local, x_local):
            # params_local: (1, ...) this stage's params; x_local: full batch
            params_local = jax.tree.map(lambda a: a[0], params_local)
            stage = lax.axis_index(axis)
            mb = x_local.reshape((n_microbatches, -1) + x_local.shape[1:])
            n_ticks = n_microbatches + n_stages - 1
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

            def tick(carry, t):
                inflight, out = carry
                # stage 0 injects microbatch t (if any); others take the ring
                take = jnp.clip(t, 0, n_microbatches - 1)
                injected = mb[take]
                x_in = jnp.where(stage == 0, injected, inflight)
                y = fn(params_local, x_in)
                # last stage writes its result for microbatch (t - n_stages + 1)
                widx = t - (n_stages - 1)
                ok = (widx >= 0) & (stage == n_stages - 1)
                updated = lax.dynamic_update_index_in_dim(
                    out, y, jnp.clip(widx, 0, n_microbatches - 1), 0
                )
                out = jnp.where(ok, updated, out)
                nxt = lax.ppermute(y, axis, perm)
                return (nxt, out), None

            # carries become pod-varying inside the loop; mark them as such
            zero = pcast(jnp.zeros_like(mb[0]), (axis,), to="varying")
            out0 = pcast(jnp.zeros_like(mb), (axis,), to="varying")
            (_, out), _ = lax.scan(
                tick, (zero, out0), jnp.arange(n_ticks)
            )
            # every stage holds an `out` buffer; only the last stage's is
            # real — broadcast it by masking + psum (a one-source all-gather)
            if n_stages > 1:
                mask = (stage == n_stages - 1).astype(out.dtype)
                out = lax.psum(out * mask, axis)
            return out.reshape((-1,) + out.shape[2:])

        return shard_map(
            local,
            mesh=mesh,
            in_specs=(P(axis), P()),
            out_specs=P(),
        )(stage_params, x)

    return wrapped
