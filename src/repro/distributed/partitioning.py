"""Logical axis rules — one model definition, many meshes.

Model code annotates arrays with *logical* axis names (``batch``, ``seq``,
``heads``, ``embed``, ``mlp``, ``vocab``, ``expert``, ``kv_seq`` …).  The
launcher installs a mapping from logical names to physical mesh axes; the same
model then lowers unchanged for the single-pod ``(data, model)`` mesh, the
multi-pod ``(pod, data, model)`` mesh (``pod`` folded into the batch axes),
a pipeline mesh, or the 1-device CPU test mesh (no rules → no constraints).

This is the MaxText/Flax "logical axis" pattern reduced to ~150 lines with no
framework dependency.  Divisibility is checked per array: a 4-way GQA KV-head
dim on a 16-way ``model`` axis silently degrades to replicated — the standard
TP behaviour for narrow KV.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Mapping, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

# Default rule sets for the production meshes (DESIGN.md §5).
SINGLE_POD_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("data",),
    "model": ("model",),  # generic TP dim for weight matrices
    "expert": ("model",),
    "expert_ff": ("data",),  # per-expert hidden dim: weights-stationary FSDP
    "heads": ("model",),
    "kv_heads": ("model",),  # dropped per-array when not divisible
    "mlp": ("model",),
    "vocab": ("model",),
    "kv_seq": ("model",),  # decode-time KV sequence sharding (SP)
    "fsdp": ("data",),  # weight-matrix sharding over the batch axes (ZeRO-3)
    "zero": ("data",),  # ZeRO-1 optimizer-state axis (non-FSDP leaves)
}
MULTI_POD_RULES = dict(SINGLE_POD_RULES, batch=("pod", "data"), fsdp=("pod", "data"))


def set_axis_rules(
    rules: Mapping[str, Sequence[str]] | None,
    mesh_shape: Mapping[str, int] | None = None,
) -> None:
    _state.rules = None if rules is None else {k: tuple(v) for k, v in rules.items()}
    _state.mesh_shape = dict(mesh_shape) if mesh_shape else {}


def current_rules() -> dict[str, tuple[str, ...]] | None:
    return getattr(_state, "rules", None)


def current_mesh_shape() -> dict[str, int]:
    return getattr(_state, "mesh_shape", {}) or {}


@contextlib.contextmanager
def axis_rules(
    rules: Mapping[str, Sequence[str]] | None,
    mesh_shape: Mapping[str, int] | None = None,
):
    prev_r, prev_m = current_rules(), current_mesh_shape()
    set_axis_rules(rules, mesh_shape)
    try:
        yield
    finally:
        set_axis_rules(prev_r, prev_m)


def rules_for_mesh(mesh: Mesh) -> dict[str, tuple[str, ...]]:
    names = set(mesh.axis_names)
    base = MULTI_POD_RULES if "pod" in names else SINGLE_POD_RULES
    return {k: tuple(a for a in v if a in names) for k, v in base.items()}


def _axes_size(phys: Sequence[str]) -> int:
    sizes = current_mesh_shape()
    total = 1
    for a in phys:
        total *= sizes.get(a, 1)
    return total


def logical_spec(*names: str | None, shape: Sequence[int] | None = None) -> P:
    """Translate logical axis names to a PartitionSpec under the active rules.

    A mesh axis is used at most once per spec (first logical name wins):
    e.g. a KV cache (batch, kv_heads, kv_seq, d) with both ``kv_heads`` and
    ``kv_seq`` mapping to ``model`` shards heads when divisible and falls
    back to sequence sharding for narrow-KV GQA — the useful behaviour in
    both regimes, derived from one annotation.
    """
    rules = current_rules()
    if rules is None:
        return P()
    out = []
    used: set[str] = set()
    for d, n in enumerate(names):
        phys = rules.get(n) if n is not None else None
        if phys:
            phys = tuple(a for a in phys if a not in used)
        if not phys:
            out.append(None)
            continue
        if shape is not None and shape[d] % max(_axes_size(phys), 1) != 0:
            out.append(None)
            continue
        used.update(phys)
        out.append(phys if len(phys) > 1 else phys[0])
    return P(*out)


def lsc(x: jax.Array, *names: str | None) -> jax.Array:
    """Logical ``with_sharding_constraint``; no-op when no rules are active."""
    if current_rules() is None:
        return x
    return jax.lax.with_sharding_constraint(x, logical_spec(*names, shape=x.shape))


# --------------------------------------------------------------- parameters
_COL_NAMES = ("wq", "w_in", "w_gate", "w_up", "w_x", "w_a", "w_branch",
              "w_bcdt", "w_zx")
# KV projections are deliberately NOT column-sharded (§Perf iteration 4):
# with GQA KV narrower than the model axis, sharding k·hd columns forces an
# all-gather of K/V activations every layer.  The matrices are small —
# FSDP row-sharding alone holds the memory — and replicated columns mean
# every device computes its full K/V locally: zero per-layer KV collectives.
_KV_NAMES = ("wk", "wv")
_ROW_NAMES = ("wo", "w_out", "w_down")


def param_partition_spec(path: tuple[str, ...], leaf) -> P:
    """Partition spec for a parameter leaf, derived from its tree path.

    TP over ``model`` + FSDP over the batch axes (``fsdp`` rule) — the
    MaxText-style 2D weight sharding that makes 100B+ parameter states fit
    16 GB chips.  Naming convention of the model zoo → rule table:

      token_embedding      (vocab, embed)        -> (vocab, fsdp)
      lm_head              (embed, vocab)        -> (fsdp, vocab)
      q/k/v/in/gate/up w   (embed, tp-dim)       -> (fsdp, model)
      out/down w           (tp-dim, embed)       -> (model, fsdp)
      expert tensors       (expert, in, out)     -> (expert, fsdp, None)
      stacked unit params  (n_units, *inner)     -> (None, *inner-spec)
      biases / norm scales / conv kernels        -> replicated
    """
    rules = current_rules()
    if rules is None:
        return P()
    shape = tuple(leaf.shape)
    # stacked per-unit params: strip the scan dim and recurse
    if path and path[0] in ("units", "tail", "enc_units") and len(shape) >= 1:
        if path[0] == "tail":  # tail layers are unstacked: no scan dim
            inner = param_partition_spec(
                path[1:], jax.ShapeDtypeStruct(shape, jnp.float32)
            )
            return inner
        inner = param_partition_spec(
            path[1:], jax.ShapeDtypeStruct(shape[1:], jnp.float32)
        )
        return P(None, *inner)
    name = path[-1] if path else ""
    joined = "/".join(path)

    def ok(dim: int, logical: str, used: set | None = None) -> Any:
        phys = rules.get(logical)
        if phys and used:
            phys = tuple(a for a in phys if a not in used)
        if phys and shape[dim] % max(_axes_size(phys), 1) == 0:
            if used is not None:
                used.update(phys)
            return phys if len(phys) > 1 else phys[0]
        return None

    if "token_embedding" in name and len(shape) == 2:
        used: set[str] = set()
        v = ok(0, "vocab", used)
        return P(v, ok(1, "fsdp", used))
    if name == "lm_head" and len(shape) == 2:
        used = set()
        v = ok(1, "vocab", used)
        return P(ok(0, "fsdp", used), v)
    if "expert" in joined and len(shape) == 3:
        # EP over model + FSDP over data on d_model.  §Perf iteration 2
        # tried weights-stationary sharding (FF dim over data, activations
        # psum'd) — refuted for top-8 MoE: the dispatch buffer is k× the
        # token bytes, so psum(buf) ≫ all-gather(weights).  The gather form
        # with reduced grad-accum wins on both MoE archs.
        used = set()
        e = ok(0, "expert", used)
        return P(e, ok(1, "fsdp", used), None)
    # int8-quantized serving weights {"q","s"}: TP-only, never FSDP — the
    # whole point of quantization is that the weights fit without a second
    # sharding axis, so the decode step has no weight all-gathers at all
    if name in ("q", "s") and len(path) >= 2:
        wname = path[-2]
        if name == "s" or len(shape) == 2:
            # scale (1, out) or weight (in, out)
            if wname in _ROW_NAMES and name == "q":
                return P(ok(0, "model", set()), None)
            if wname in _ROW_NAMES:  # row-weight scale: out dim is d_model
                return P(None, None)
            if wname in _KV_NAMES:
                return P(None, None)
            return P(None, ok(1, "model", set()))
        return P(*([None] * len(shape)))
    if len(shape) == 2:
        base = name.split(".")[-1]
        if any(base == c for c in _KV_NAMES):
            return P(ok(0, "fsdp", set()), None)
        if any(base == c or base.startswith(c) for c in _COL_NAMES):
            used = set()
            m = ok(1, "model", used)
            return P(ok(0, "fsdp", used), m)
        if any(base == r or base.startswith(r) for r in _ROW_NAMES):
            used = set()
            m = ok(0, "model", used)
            return P(m, ok(1, "fsdp", used))
    return P(*([None] * len(shape)))


def params_partition_specs(params_shapes) -> dict:
    """Map a params shape-pytree to partition specs via tree paths."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    specs = []
    for kp, leaf in flat:
        path = tuple(
            p.key if hasattr(p, "key") else str(getattr(p, "idx", p)) for p in kp
        )
        specs.append(param_partition_spec(path, leaf))
    return jax.tree_util.tree_unflatten(treedef, specs)


def named_sharding(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)
