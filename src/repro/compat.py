"""Version-portable aliases for jax APIs that moved between releases.

The repo targets current jax, but the hermetic CI image pins an older
release; everything that moved namespaces between the two goes through this
module so call sites stay clean:

* ``shard_map`` — ``jax.shard_map`` (new) vs ``jax.experimental.shard_map``
  (old; replication checking relaxed to match the new default semantics).
* ``set_mesh`` — ``jax.sharding.set_mesh``/``use_mesh`` context manager (new)
  vs entering the ``Mesh`` itself (old with-mesh semantics).
* ``pcast`` — varying-axis casts are a no-op under the old replication
  system, which infers replicated→varying transitions itself.
"""

from __future__ import annotations

import contextlib

import jax
from jax import lax

try:
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - depends on installed jax
    from jax._src import mesh as _mesh_lib
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, /, *, mesh=None, **kwargs):
        # new jax resolves a missing mesh from the ambient set_mesh context;
        # the old API requires it explicitly, so pull it from thread state
        if mesh is None:
            mesh = _mesh_lib.thread_resources.env.physical_mesh
            if mesh.empty:
                raise ValueError("shard_map: no mesh given and none active")
        kwargs.setdefault("check_rep", False)
        return _shard_map(f, mesh=mesh, **kwargs)

try:
    pcast = lax.pcast
except AttributeError:  # pragma: no cover - depends on installed jax

    def pcast(x, axes, to):  # noqa: ARG001 - mirror the new signature
        return x

if hasattr(jax.sharding, "set_mesh"):
    set_mesh = jax.sharding.set_mesh
elif hasattr(jax.sharding, "use_mesh"):  # pragma: no cover
    set_mesh = jax.sharding.use_mesh
else:  # pragma: no cover - depends on installed jax

    @contextlib.contextmanager
    def set_mesh(mesh):
        with mesh:
            yield mesh


__all__ = ["pcast", "set_mesh", "shard_map"]
