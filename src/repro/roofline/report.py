"""Render the §Roofline markdown table from dry-run cell JSONs.

Usage: PYTHONPATH=src python -m repro.roofline.report results/dryrun [mesh]
"""

from __future__ import annotations

import glob
import json
import os
import sys


def load_cells(directory: str) -> list[dict]:
    cells = []
    for f in sorted(glob.glob(os.path.join(directory, "*.json"))):
        cells.append(json.load(open(f)))
    return cells


def fmt_table(cells: list[dict], mesh: str = "pod16x16") -> str:
    lines = [
        "| arch | shape | compute (s) | memory (s) | collective (s) | "
        "dominant | peak GiB/dev | model TFLOP | useful | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    rows = [c for c in cells if c.get("mesh") == mesh or (
        c.get("status", "").startswith("SKIP") and c.get("mesh") == mesh)]
    rows.sort(key=lambda c: (c["arch"], order.get(c["shape"], 9)))
    for c in rows:
        if c.get("status", "ok").startswith("SKIP"):
            lines.append(
                f"| {c['arch']} | {c['shape']} | — | — | — | SKIP "
                f"(full attention @500k) | — | — | — | — |"
            )
            continue
        t = c["terms"]
        lines.append(
            "| {arch} | {shape} | {c:.3f} | {m:.3f} | {k:.3f} | {dom} | "
            "{peak:.1f} | {mf:.1f} | {useful:.2f} | {frac:.3f} |".format(
                arch=c["arch"], shape=c["shape"], c=t["compute"],
                m=t["memory"], k=t["collective"], dom=c["dominant"],
                peak=c["memory"]["peak_bytes"] / 2**30,
                mf=c["model_flops"] / 1e12,
                useful=c.get("useful_flops_ratio", 0),
                frac=c.get("roofline_fraction", 0),
            )
        )
    return "\n".join(lines)


def fmt_compare(base_dir: str, opt_dir: str, mesh: str = "pod16x16") -> str:
    """Before/after table for §Perf (step-time lower bound per cell)."""
    base = {(c["arch"], c["shape"]): c for c in load_cells(base_dir)
            if c.get("mesh") == mesh and not c.get("status", "ok").startswith("SKIP")}
    opt = {(c["arch"], c["shape"]): c for c in load_cells(opt_dir)
           if c.get("mesh") == mesh and not c.get("status", "ok").startswith("SKIP")}
    lines = [
        "| arch | shape | LB before (s) | LB after (s) | speedup | "
        "dominant before→after |",
        "|---|---|---|---|---|---|",
    ]
    for key in sorted(base):
        if key not in opt:
            continue
        b, o = base[key], opt[key]
        lb_b = b.get("step_time_lower_bound_s", 0)
        lb_o = o.get("step_time_lower_bound_s", 0)
        if not lb_b or not lb_o:
            continue
        lines.append(
            f"| {key[0]} | {key[1]} | {lb_b:.3f} | {lb_o:.3f} | "
            f"{lb_b/lb_o:.2f}× | {b['dominant']}→{o['dominant']} |"
        )
    return "\n".join(lines)


def main() -> None:
    directory = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    mesh = sys.argv[2] if len(sys.argv) > 2 else "pod16x16"
    if len(sys.argv) > 3 and sys.argv[3] == "--compare":
        print(fmt_compare(sys.argv[4], directory, mesh))
        return
    print(fmt_table(load_cells(directory), mesh))


if __name__ == "__main__":
    main()
