"""Three-term roofline from the compiled dry-run (no real hardware needed).

    compute term    = HLO_FLOPs    / (chips × peak_FLOP/s)
    memory term     = HLO_bytes    / (chips × HBM_bw)
    collective term = wire_bytes   / (chips × link_bw)

All three numerators are *global* quantities = per-device × chips (an SPMD
module describes one participant), so the terms reduce to per-device values
over per-chip rates — that is what we compute.

Why not ``compiled.cost_analysis()`` alone: XLA's cost analysis visits each
``while`` body ONCE, so a model whose 80 layers live inside a ``lax.scan``
under-counts FLOPs/bytes by ~80× (verified empirically on this backend; see
EXPERIMENTS.md §Dry-run notes).  We therefore re-derive FLOPs and bytes from
the optimized HLO module printed with operand shapes, weighting every
computation by its loop trip count (``known_trip_count`` backend config on
each ``while`` op, falling back to the `i < C` constant in the loop
condition).  Raw cost_analysis numbers are retained in the report for
reference.

Counting conventions (uniform across cells, so ratios are meaningful):
  * FLOPs: 2 × |out| × contraction for every ``dot``; other ops are ignored
    (elementwise work is bandwidth-, not compute-bound).
  * HBM bytes: Σ (operand + output bytes) of every top-level op in
    control-flow computations, skipping no-data ops (parameter, tuple,
    get-tuple-element, constant, bitcast, reshape).  Fusion-internal ops
    never touch HBM and are skipped; the fusion call site carries the
    traffic.
  * Collective wire bytes (per chip, ring model): all-gather and
    all-to-all move out×(N-1)/N, reduce-scatter out×(N-1) (its output is the
    scattered shard), all-reduce 2×out×(N-1)/N, collective-permute out.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

# ----------------------------------------------------------- hardware model
@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12  # bf16 FLOP/s per chip
    hbm_bw: float = 819e9  # bytes/s per chip
    ici_bw: float = 50e9  # bytes/s per link (per-chip, one direction)
    hbm_bytes: float = 16e9


HW = Hardware()

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_TRIP_RE = re.compile(r"known_trip_count[\"']?\s*:\s*\{\s*[\"']n[\"']\s*:\s*[\"']?(\d+)")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_NO_DATA_OPS = (
    "parameter(", "constant(", "get-tuple-element(", "tuple(", "bitcast(",
    "reshape(", "after-all(", "partition-id(", "replica-id(", "iota(",
    # control flow: the callee's ops are counted (trip-weighted) instead;
    # counting the carried tuple here would bill the whole loop state per step
    " while(", "conditional(", "optimization-barrier(",
)


def _dims_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _shape_bytes(dtype: str, dims: str) -> int:
    return _dims_elems(dims) * _DTYPE_BYTES.get(dtype, 0)


def _split_lhs_rhs(line: str) -> tuple[str, str]:
    parts = line.split(" = ", 1)
    return (parts[0], parts[1]) if len(parts) == 2 else ("", line)


def _out_bytes(line: str) -> int:
    """Output-buffer size: largest shape before the opcode on the RHS."""
    _, rhs = _split_lhs_rhs(line)
    opcode_at = re.search(r"[a-z][a-z0-9\-\.\$_]*\(", rhs)
    region = rhs[: opcode_at.start()] if opcode_at else rhs
    sizes = [_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(region)]
    return max(sizes) if sizes else 0


def _operand_sizes(line: str) -> list[int]:
    """Operand sizes: shapes inside the top-level call parens."""
    _, rhs = _split_lhs_rhs(line)
    m = re.search(r"\(([^)]*)\)", rhs[rhs.find("("):] if "(" in rhs else rhs)
    if not m:
        return []
    return [_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(m.group(1))]


def _operand_bytes(line: str) -> int:
    return sum(_operand_sizes(line))


def _hbm_bytes(line: str) -> int:
    """Modeled HBM traffic of one top-level op (non-fusion).

    * dynamic-slice / gather: read+write the *slice*, never the source
      buffer (a scan iteration reads one layer of a stacked buffer).
    * dynamic-update-slice: read+write the *update*; the target is aliased.
    * otherwise: output + operands, dropping one operand byte-identical to
      the output (in-place threading through a loop carry).
    """
    out = _out_bytes(line)
    if re.search(r"\bdynamic-slice\(|\bgather\(", line):
        return 2 * out
    ops = _operand_sizes(line)
    if re.search(r"\bdynamic-update-slice\(", line):
        small = [b for b in ops if b != max(ops)] if ops else []
        return 2 * sum(small)
    if ops:
        big = max(ops)
        if big == out and out > 0:
            ops.remove(big)
    return out + sum(ops)


_PARAM_RE = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*([a-z0-9]+)\[([0-9,]*)\][^=]*parameter\(")


_PASSTHROUGH_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*[a-z0-9]+\[[0-9,]*\][^=]*"
    r"(convert|bitcast|copy|reshape|transpose)\(\s*[a-z0-9]+\[[0-9,]*\][^%]*%([\w\.\-]+)\)"
)


def _fusion_effective_bytes(lines: list[str]) -> int:
    """Modeled HBM traffic of one fusion execution, from its body.

    Fusion internals stay in registers/VMEM; HBM traffic is the body's
    *parameters* (read) and its root (write) — except parameters that are
    only dynamic-sliced (read: slice size) or are dynamic-update-slice
    targets (aliased: read 0, write: update size).  This is what makes a
    scan over stacked layer weights cost one layer per iteration instead of
    the whole stack.

    Pure layout/dtype chains (convert/bitcast/copy/reshape/transpose) are
    followed transparently: XLA CPU emulates a bf16 dynamic-update-slice by
    upcasting the whole buffer to f32 and back — a lowering artifact a TPU
    (native bf16 DUS) never pays, so the convert must not turn an aliased
    update into a whole-buffer rewrite in the model.
    """
    params: dict[str, int] = {}
    alias: dict[str, str] = {}  # passthrough def -> source name
    sliced_reads: dict[str, int] = {}
    dus_targets: set[str] = set()
    dus_defs: set[str] = set()
    root_bytes = 0
    root_name = None
    dus_update_bytes = 0

    def resolve(name: str) -> str:
        seen = set()
        while name in alias and name not in seen:
            seen.add(name)
            name = alias[name]
        return name

    for line in lines:
        pm = _PARAM_RE.match(line)
        if pm:
            params[pm.group(1)] = _shape_bytes(pm.group(2), pm.group(3))
            continue
        am = _PASSTHROUGH_RE.match(line)
        if am:
            alias[am.group(1)] = am.group(3)
        is_root = line.startswith("ROOT")
        def_name = None
        dm = re.match(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=", line)
        if dm:
            def_name = dm.group(1)
        if re.search(r"\bdynamic-slice\(", line):
            m = re.search(r"dynamic-slice\(\s*[a-z0-9]+\[[0-9,]*\][^%]*%([\w\.\-]+)", line)
            if m:
                src = resolve(m.group(1))
                if src in params:
                    sliced_reads[src] = sliced_reads.get(src, 0) + _out_bytes(line)
        if re.search(r"\bdynamic-update-slice\(", line):
            m = re.search(
                r"dynamic-update-slice\(\s*[a-z0-9]+\[[0-9,]*\][^%]*%([\w\.\-]+)", line
            )
            if m:
                tgt = resolve(m.group(1))
                if tgt in params:
                    dus_targets.add(tgt)
            sizes = _operand_sizes(line)
            if sizes:
                dus_update_bytes += sum(b for b in sizes if b != max(sizes))
            if def_name:
                dus_defs.add(def_name)
        if is_root:
            root_bytes = _out_bytes(line)
            root_name = def_name
    reads = 0
    for name, size in params.items():
        if name in dus_targets:
            continue
        if name in sliced_reads:
            reads += min(sliced_reads[name], size)
        else:
            reads += size
    # a root that is (a passthrough of) a dynamic-update-slice writes only
    # the update; the rest of the buffer is aliased
    root_is_dus = root_name is not None and (
        root_name in dus_defs or resolve(root_name) in dus_defs
    )
    write = dus_update_bytes if root_is_dus and dus_update_bytes else root_bytes
    return reads + write


def _dot_flops(line: str) -> int:
    """2 × |out| × contraction-size for a dot op with printed operand shapes."""
    _, rhs = _split_lhs_rhs(line)
    out_at = re.search(r"[a-z][a-z0-9\-\.\$_]*\(", rhs)
    out_shapes = _SHAPE_RE.findall(rhs[: out_at.start()] if out_at else rhs)
    if not out_shapes:
        return 0
    out_elems = max(_dims_elems(s) for _, s in out_shapes)
    m = re.search(r"\(([^)]*)\)", rhs[out_at.start():] if out_at else rhs)
    operands = _SHAPE_RE.findall(m.group(1)) if m else []
    if not operands:
        return 0
    lhs_dims = operands[0][1].split(",") if operands[0][1] else []
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    contraction = 1
    if mc and mc.group(1):
        for idx in mc.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                contraction *= int(lhs_dims[i])
    return 2 * out_elems * contraction


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)  # iota form: [n_groups, group_size]<=[...]
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_EXPLICIT_RE.search(line)  # explicit {{0,1},{2,3}}
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 1


def _wire_bytes(kind: str, line: str) -> int:
    """Per-chip wire bytes under the ring model (see module docstring)."""
    out = _out_bytes(line)
    if kind == "collective-permute":  # point-to-point: no replica_groups
        return out
    n = _group_size(line)
    if n <= 1:
        return 0
    if kind == "all-gather":
        return out * (n - 1) // n
    if kind == "reduce-scatter":
        return out * (n - 1)
    if kind == "all-reduce":
        return 2 * out * (n - 1) // n
    return out * (n - 1) // n  # all-to-all


@dataclasses.dataclass
class _Computation:
    name: str
    lines: list[str] = dataclasses.field(default_factory=list)
    flops: int = 0
    bytes_: int = 0
    collective_bytes: dict[str, int] = dataclasses.field(default_factory=dict)
    counts: dict[str, int] = dataclasses.field(default_factory=dict)
    constants: list[int] = dataclasses.field(default_factory=list)
    # (callee, kind, trip_count) — kind in {"while", "call", "fusion"}
    calls: list[tuple[str, str, int]] = dataclasses.field(default_factory=list)
    is_fusion_body: bool = False


def _parse_module(hlo: str) -> tuple[dict[str, _Computation], str | None]:
    comps: dict[str, _Computation] = {}
    fusion_bodies: set[str] = set()
    cur: _Computation | None = None
    entry = None
    for raw in hlo.splitlines():
        line = raw.strip()
        if not line or line.startswith(("//", "#")):
            continue
        if line.endswith("{") and " = " not in line.split("(", 1)[0]:
            m = _HEADER_RE.match(line)
            if m:
                cur = _Computation(m.group(2))
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
                continue
        if cur is None or line == "}":
            continue
        cur.lines.append(line)
        for m in re.finditer(r"\bs32\[\]\s+constant\((\d+)\)", line):
            cur.constants.append(int(m.group(1)))
        # ---- flops (dots are counted wherever they live, incl. fusions)
        if re.search(r"\bdot\(", line):
            cur.flops += _dot_flops(line)
        # ---- collectives
        matched = None
        for kind in _COLLECTIVES:
            if re.search(rf"\b{kind}(-start)?\(", line):
                matched = kind
                break
        if matched and "-done" not in line:
            b = _wire_bytes(matched, line)
            cur.collective_bytes[matched] = (
                cur.collective_bytes.get(matched, 0) + b
            )
            cur.counts[matched] = cur.counts.get(matched, 0) + 1
        # ---- call-graph edges
        if " while(" in line:
            body = re.search(r"body=%?([\w\.\-]+)", line)
            cond = re.search(r"condition=%?([\w\.\-]+)", line)
            tm = _TRIP_RE.search(line)
            trip = int(tm.group(1)) if tm else 0  # 0 -> resolve from condition
            if body:
                cur.calls.append((body.group(1), "while", trip))
                if not trip and cond:
                    cur.calls.append((cond.group(1), "cond_of:" + body.group(1), 0))
            continue
        for name in re.findall(r"calls=%?([\w\.\-]+)", line):
            fusion_bodies.add(name)
            cur.calls.append((name, "fusion", 1))
        for name in re.findall(r"to_apply=%?([\w\.\-]+)", line):
            cur.calls.append((name, "call", 1))
        for grp in re.findall(r"branch_computations=\{([^}]*)\}", line):
            for name in re.findall(r"%?([\w\.\-]+)", grp):
                cur.calls.append((name, "call", 1))
    for name in fusion_bodies:
        if name in comps:
            comps[name].is_fusion_body = True
    # second pass: HBM bytes. Fusion bodies get effective-read accounting;
    # other computations bill their top-level non-fusion ops.
    for comp in comps.values():
        if comp.is_fusion_body:
            comp.bytes_ = _fusion_effective_bytes(comp.lines)
            continue
        total = 0
        for line in comp.lines:
            if " = " not in line or any(op in line for op in _NO_DATA_OPS):
                continue
            if re.search(r"\bfusion\(", line):
                continue  # billed through the callee's effective bytes
            total += _hbm_bytes(line)
        comp.bytes_ = total
    return comps, entry


def hlo_stats(hlo: str) -> dict[str, Any]:
    """Trip-count-weighted FLOPs / HBM bytes / collective wire bytes."""
    comps, entry_name = _parse_module(hlo)
    flops = 0
    hbm = 0
    coll = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    entry = comps.get(entry_name) if entry_name else None
    if entry is None:
        for c in comps.values():
            flops += c.flops
            hbm += c.bytes_
            for k, b in c.collective_bytes.items():
                coll[k] += b
                counts[k] += c.counts.get(k, 0)
        return {"flops": flops, "hbm_bytes": hbm,
                "collectives": {**coll, "total": sum(coll.values())},
                "op_counts": counts, "trip_weighted": False}

    stack: set[str] = set()

    def walk(comp: _Computation, mult: int) -> None:
        nonlocal flops, hbm
        if comp.name in stack or mult <= 0:
            return
        stack.add(comp.name)
        flops += comp.flops * mult
        hbm += comp.bytes_ * mult
        for k, b in comp.collective_bytes.items():
            coll[k] += b * mult
            counts[k] += comp.counts.get(k, 0) * mult
        for callee, kind, trip in comp.calls:
            if kind.startswith("cond_of:"):
                continue
            sub = comps.get(callee)
            if sub is None:
                continue
            m = mult
            if kind == "while":
                if not trip:  # fall back to the `i < C` condition constant
                    cond_names = [
                        c for c, k, _ in comp.calls if k == f"cond_of:{callee}"
                    ]
                    for cn in cond_names:
                        cc = comps.get(cn)
                        if cc and cc.constants:
                            trip = max(cc.constants)
                    trip = trip or 1
                m = mult * trip
            walk(sub, m)
        stack.discard(comp.name)

    walk(entry, 1)
    return {
        "flops": flops,
        "hbm_bytes": hbm,
        "collectives": {**coll, "total": sum(coll.values())},
        "op_counts": counts,
        "trip_weighted": True,
    }


def collective_bytes(hlo: str) -> dict[str, Any]:
    """Back-compat wrapper: collective wire bytes only."""
    stats = hlo_stats(hlo)
    return {**stats["collectives"], "op_counts": stats["op_counts"]}


def compiled_hlo_text(compiled) -> str:
    """Optimized HLO with operand shapes (needed for dot FLOP counting)."""
    try:
        from jax._src.lib import _jax as xe  # jaxlib

        opts = xe.HloPrintOptions()
        opts.print_operand_shape = True
        opts.print_backend_config = True
        mods = compiled.runtime_executable().hlo_modules()
        return "\n".join(m.to_string(opts) for m in mods)
    except Exception:  # noqa: BLE001 — fall back to the public printer
        return compiled.as_text()


# ------------------------------------------------------------------ report
@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_per_device: float
    bytes_per_device: float
    collective: dict[str, Any]
    memory: dict[str, float]
    model_flops: float  # 6·N·D (or 6·N_active·D) for the whole step
    xla_cost_analysis: dict[str, float] | None = None
    status: str = "ok"

    def terms(self, hw: Hardware = HW) -> dict[str, float]:
        return roofline_terms(
            self.flops_per_device, self.bytes_per_device,
            self.collective.get("total", 0), hw,
        )

    def summary(self, hw: Hardware = HW) -> dict[str, Any]:
        t = self.terms(hw)
        dominant = max(t, key=t.get)
        useful = (
            self.model_flops / (self.flops_per_device * self.n_devices)
            if self.flops_per_device else 0.0
        )
        bound = max(t.values())
        return {
            **t,
            "dominant": dominant,
            "useful_flops_ratio": useful,
            "roofline_fraction": (t["compute"] / bound) if bound else 0.0,
            "step_time_lower_bound_s": bound,
        }


def roofline_terms(
    flops_per_device: float,
    bytes_per_device: float,
    collective_bytes_per_device: float,
    hw: Hardware = HW,
) -> dict[str, float]:
    return {
        "compute": flops_per_device / hw.peak_flops,
        "memory": bytes_per_device / hw.hbm_bw,
        "collective": collective_bytes_per_device / hw.ici_bw,
    }


def analyze_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                     n_devices: int, model_flops: float) -> CellResult:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    xla_cost = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
    }
    mem = compiled.memory_analysis()
    memory = {
        "argument_bytes": float(getattr(mem, "argument_size_in_bytes", 0)),
        "output_bytes": float(getattr(mem, "output_size_in_bytes", 0)),
        "temp_bytes": float(getattr(mem, "temp_size_in_bytes", 0)),
        "alias_bytes": float(getattr(mem, "alias_size_in_bytes", 0)),
        "peak_bytes": float(getattr(mem, "argument_size_in_bytes", 0))
        + float(getattr(mem, "temp_size_in_bytes", 0))
        + float(getattr(mem, "output_size_in_bytes", 0))
        - float(getattr(mem, "alias_size_in_bytes", 0)),
    }
    stats = hlo_stats(compiled_hlo_text(compiled))
    return CellResult(
        arch=arch, shape=shape, mesh=mesh_name, n_devices=n_devices,
        flops_per_device=float(stats["flops"]),
        bytes_per_device=float(stats["hbm_bytes"]),
        collective={**stats["collectives"], "op_counts": stats["op_counts"]},
        memory=memory, model_flops=model_flops, xla_cost_analysis=xla_cost,
    )
