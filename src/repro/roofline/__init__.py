"""Roofline analysis from compiled dry-run artifacts."""

from .analysis import (
    HW,
    CellResult,
    collective_bytes,
    analyze_compiled,
    roofline_terms,
)

__all__ = ["HW", "CellResult", "collective_bytes", "analyze_compiled", "roofline_terms"]
