"""HTAP data substrate: row-major record store + ephemeral-projection batches."""

from .pipeline import RecordStore, TrainPipeline, synthetic_corpus

__all__ = ["RecordStore", "TrainPipeline", "synthetic_corpus"]
