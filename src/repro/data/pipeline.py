"""Training data as Relational Memory — the paper's HTAP story, verbatim.

Sample records are ingested **row-major** into an MVCC row store (OLTP side:
appends are one row write; relabeling/filtering are in-place updates).  The
training loop consumes **ephemeral projections** of exactly the fields it
needs (OLAP side): ``(tokens, labels)`` for training, ``tokens`` for eval,
``+ weight`` for weighted runs.  No columnar copy of the corpus is ever
materialized, and any ingest during training silently invalidates hot views
through the engine's epoch/version machinery.

Record layout (one row per sample):
    doc_id   int32     source document
    split    int32     0=train 1=eval
    weight   float32   per-sample loss weight
    tokens   char[4S]  S int32 token ids
    labels   char[4S]  S int32 label ids
    (+ hidden MVCC ts_begin/ts_end)

A projection of (tokens, labels) moves 8S+? bytes of the 8S+12 byte payload;
an eval projection of tokens moves half of that — the projectivity economics
of the paper, now in a training pipeline.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.core import (
    Column,
    RelationalMemoryEngine,
    RelationalTable,
    TableSchema,
)


def record_schema(seq_len: int) -> TableSchema:
    return TableSchema.of(
        Column("doc_id", "int32"),
        Column("split", "int32"),
        Column("weight", "float32"),
        Column("tokens", "char", 4 * seq_len),
        Column("labels", "char", 4 * seq_len),
    )


def _pack_ids(ids: np.ndarray, seq_len: int) -> np.ndarray:
    """(n, S) int32 -> (n,) byte-string column values."""
    ids = np.ascontiguousarray(ids.astype(np.int32))
    return ids.view(np.uint8).reshape(ids.shape[0], 4 * seq_len).view(
        np.dtype((np.bytes_, 4 * seq_len))
    ).reshape(-1)


class RecordStore:
    """Row-major sample store with OLTP ingest and RME-projected reads."""

    def __init__(self, seq_len: int, engine: RelationalMemoryEngine | None = None,
                 capacity: int = 1024):
        self.seq_len = seq_len
        self.schema = record_schema(seq_len)
        self.table = RelationalTable(self.schema, capacity=capacity)
        self.engine = engine or RelationalMemoryEngine(revision="xla")

    # ------------------------------------------------------------------ OLTP
    def ingest(
        self,
        tokens: np.ndarray,  # (n, S) int32
        labels: np.ndarray,  # (n, S) int32
        doc_ids: np.ndarray | None = None,
        split: int = 0,
        weights: np.ndarray | None = None,
    ) -> np.ndarray:
        n, s = tokens.shape
        if s != self.seq_len:
            raise ValueError(f"sample length {s} != store seq_len {self.seq_len}")
        return self.table.append({
            "doc_id": (doc_ids if doc_ids is not None
                       else np.arange(n)).astype(np.int32),
            "split": np.full(n, split, np.int32),
            "weight": (weights if weights is not None
                       else np.ones(n)).astype(np.float32),
            "tokens": _pack_ids(tokens, self.seq_len),
            "labels": _pack_ids(labels, self.seq_len),
        })

    def reweight(self, rows: np.ndarray, weights: np.ndarray) -> np.ndarray:
        """In-place OLTP update (MVCC: old versions end, new rows appended)."""
        return self.table.update(rows, {"weight": weights.astype(np.float32)})

    # ------------------------------------------------------------------ OLAP
    def _ids_matrix(self, view, name: str, rows: np.ndarray) -> np.ndarray:
        off, w = view.column_words(name)
        packed = np.asarray(view.packed())
        return packed[rows][:, off : off + w]

    def project(self, columns: tuple[str, ...], snapshot_ts: int | None = None):
        """Register an ephemeral column-group view (never materialized)."""
        return self.engine.register(self.table, columns, snapshot_ts)

    @property
    def n_rows(self) -> int:
        return int(self.table.snapshot_mask().sum())


@dataclasses.dataclass
class TrainPipeline:
    """Deterministic, restart-reproducible batch iterator over a RecordStore.

    The shuffle is a fixed permutation of the snapshot's live rows seeded by
    (seed, epoch): a restarted trainer that seeks to step N reproduces the
    exact batch stream (fault-tolerance requirement), independent of how many
    ingests happened after the snapshot was taken.
    """

    store: RecordStore
    batch_size: int
    seed: int = 0
    drop_remainder: bool = True
    with_weights: bool = False
    snapshot_ts: int | None = None  # pinned at first use; checkpointable

    def batches(self, start_step: int = 0) -> Iterator[dict]:
        cols = ("tokens", "labels") + (("weight",) if self.with_weights else ())
        if self.snapshot_ts is None:
            # pin the MVCC snapshot on first use: every iterator from this
            # pipeline (including post-restart seeks) sees the same rows, no
            # matter how much OLTP ingest happens meanwhile
            self.snapshot_ts = self.store.table.now()
        view = self.store.project(cols, self.snapshot_ts)
        live = np.nonzero(np.asarray(view.valid_mask()))[0]
        n = len(live)
        if n < self.batch_size and self.drop_remainder:
            raise ValueError(f"{n} rows < batch size {self.batch_size}")
        per_epoch = n // self.batch_size
        step = start_step
        while True:
            epoch = step // max(per_epoch, 1)
            rng = np.random.default_rng((self.seed, epoch))
            perm = rng.permutation(n)
            i = step % max(per_epoch, 1)
            rows = live[perm[i * self.batch_size : (i + 1) * self.batch_size]]
            tok = self.store._ids_matrix(view, "tokens", rows)
            lab = self.store._ids_matrix(view, "labels", rows)
            batch = {"tokens": tok, "labels": lab}
            if self.with_weights:
                off, _ = view.column_words("weight")
                batch["weights"] = (
                    np.asarray(view.packed())[rows][:, off].view(np.float32)
                )
            yield batch
            step += 1


def synthetic_corpus(
    n_samples: int, seq_len: int, vocab: int, seed: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """Markov-ish synthetic token stream (shifted labels), reproducible."""
    rng = np.random.default_rng(seed)
    base = rng.integers(0, vocab, (n_samples, seq_len + 1), dtype=np.int64)
    # add local structure so the loss actually decreases during examples
    base[:, 1:] = (base[:, 1:] + base[:, :-1]) % vocab
    tokens = base[:, :-1].astype(np.int32)
    labels = base[:, 1:].astype(np.int32)
    return tokens, labels
