"""Atomic checkpoint save/restore with elastic resharding.

Layout: one ``.npy`` per pytree leaf (path-encoded filename) plus a
``manifest.json`` carrying the step, the tree structure, and bookkeeping.
Writes go to ``<dir>.tmp`` and are published with an atomic ``os.replace`` —
a preempted writer never corrupts the latest checkpoint.  ``restore`` takes
an optional ``shardings`` pytree: leaves are ``device_put`` straight into the
*current* mesh's layout, so a job restarted on a different topology (elastic
scaling) resumes without a separate reshard pass.

On a multi-host cluster the same layout maps onto a shared filesystem /
object store with per-host shard files; the single-process implementation
here writes fully-addressable arrays, which is exactly what the dry-run and
CPU tests exercise.
"""

from __future__ import annotations

import json
import os
import shutil
import time

import jax
import ml_dtypes
import numpy as np

# numpy can't natively save/cast bf16 and fp8; round-trip them as raw views
_VIEW_DTYPES = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _savable(arr: np.ndarray) -> tuple[np.ndarray, str]:
    name = str(arr.dtype)
    if name in _VIEW_DTYPES:
        return arr.view(_VIEW_DTYPES[name][1]), name
    return arr, name


def _restore_view(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _VIEW_DTYPES:
        return arr.view(_VIEW_DTYPES[dtype_name][0])
    return arr


def _flatten(tree):
    """Returns ({path: leaf}, treedef, [paths in flatten order])."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    order = []
    for kp, leaf in flat:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in kp
        )
        out[key] = leaf
        order.append(key)
    return out, treedef, order


def save_checkpoint(directory: str, step: int, tree, extra: dict | None = None) -> str:
    """Atomically write ``tree`` under ``directory/step_<n>``; returns the path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, _, _ = _flatten(tree)
    names = {}
    dtypes = {}
    for i, (key, leaf) in enumerate(sorted(leaves.items())):
        fname = f"leaf_{i:05d}.npy"
        arr, dtype_name = _savable(np.asarray(leaf))
        np.save(os.path.join(tmp, fname), arr)
        names[key] = fname
        dtypes[key] = dtype_name
    manifest = {
        "step": step,
        "time": time.time(),
        "leaves": names,
        "dtypes": dtypes,
        "extra": extra or {},
        "format": 1,
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic publish
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str, like, step: int | None = None, shardings=None
) -> tuple[int, object]:
    """Restore into the structure of ``like``; reshard onto ``shardings``.

    Missing checkpoints raise; structural mismatches raise with the offending
    path (a config change between runs is a hard error, not silent reuse).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_like, treedef, order = _flatten(like)
    if set(manifest["leaves"]) != set(leaves_like):
        missing = set(leaves_like) ^ set(manifest["leaves"])
        raise ValueError(f"checkpoint/model structure mismatch at {sorted(missing)[:5]}")
    shard_leaves = _flatten(shardings)[0] if shardings is not None else {}
    restored = []
    for key in order:  # flatten order, not path-sort order
        arr = np.load(os.path.join(path, manifest["leaves"][key]))
        arr = _restore_view(arr, manifest.get("dtypes", {}).get(key, str(arr.dtype)))
        want = leaves_like[key]
        if tuple(arr.shape) != tuple(want.shape):
            raise ValueError(
                f"shape mismatch at {key}: ckpt {arr.shape} vs model {want.shape}"
            )
        arr = arr.astype(want.dtype)
        if key in shard_leaves and shard_leaves[key] is not None:
            restored.append(jax.device_put(arr, shard_leaves[key]))
        else:
            restored.append(jax.numpy.asarray(arr))
    return step, jax.tree_util.tree_unflatten(treedef, restored)


class CheckpointManager:
    """Retention + cadence policy around save/restore."""

    def __init__(self, directory: str, keep: int = 3, every_steps: int = 100):
        self.directory = directory
        self.keep = keep
        self.every_steps = every_steps
        os.makedirs(directory, exist_ok=True)

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.every_steps == 0

    def save(self, step: int, tree, extra: dict | None = None) -> str:
        path = save_checkpoint(self.directory, step, tree, extra)
        self._gc()
        return path

    def restore(self, like, shardings=None):
        return restore_checkpoint(self.directory, like, shardings=shardings)

    def _gc(self) -> None:
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"))
