"""Ephemeral variables — the paper's software/hardware interface (§3).

An ephemeral variable "does not correspond to a real main memory location";
accessing it sets the RME in motion.  In JAX the natural translation is a
*lazy view object*: registration captures the geometry (the configuration-port
write), and the first data access materializes the packed column group through
the engine — hot out of the reorganization cache, cold through the projection
kernel.  The view is never a copy the user must invalidate: OLTP mutations of
the base table are tracked at delta granularity (``table.append_watermark`` /
``table.mutation_version``), so an append silently turns the next access into
an incremental tail scan merged with the cached block, and deletes/updates —
which only rewrite hidden timestamp words the packed block never contains —
don't perturb it at all; visibility is applied by ``valid_mask``/``column``
against the (delta-synced) device timestamps.
"""

from __future__ import annotations

from typing import TYPE_CHECKING

import jax
import jax.numpy as jnp
import numpy as np

from .schema import TableGeometry
from .table import RelationalTable

if TYPE_CHECKING:  # pragma: no cover
    from .engine import RelationalMemoryEngine


class EphemeralView:
    """A registered column-group view; materialized on access, never stored.

    Supports the accesses the paper's C listings perform on ephemeral
    variables: whole-group reads (``packed()``), per-column reads
    (``column(name)`` — decoded to the column dtype), and row slicing
    (``view[i:j]``), all snapshot-consistent when a snapshot time was given.
    """

    def __init__(
        self,
        engine: "RelationalMemoryEngine",
        table: RelationalTable,
        columns: tuple[str, ...],
        geometry: TableGeometry,
        snapshot_ts: int | None = None,
    ):
        self.engine = engine
        self.table = table
        self.columns = columns
        self.geometry = geometry
        self.snapshot_ts = snapshot_ts
        # packed layout follows physical column order (the RME walks rows
        # front-to-back); map user order -> packed word slices once.
        ordered = sorted(columns, key=table.schema.byte_offset)
        self._packed_slice: dict[str, tuple[int, int]] = {}
        acc = 0
        for name in ordered:
            w = table.schema.column(name).words
            self._packed_slice[name] = (acc, w)
            acc += w

    # ------------------------------------------------------------- accesses
    def packed(self) -> jax.Array:
        """The packed (N, out_words) int32 view — what the CPU cache sees."""
        return self.engine.materialize(self)

    def valid_mask(self) -> jax.Array:
        """MVCC validity of each physical row at the view's snapshot time."""
        ts = self.table.now() if self.snapshot_ts is None else self.snapshot_ts
        return self.engine.valid_mask(self.table, ts)

    def column(self, name: str) -> jax.Array:
        """One projected column, decoded to its schema dtype (live rows only)."""
        if name not in self._packed_slice:
            raise KeyError(f"{name!r} is not part of this ephemeral view {self.columns}")
        packed = self.packed()
        off, w = self._packed_slice[name]
        col = self.table.schema.column(name)
        raw = packed[:, off : off + w]
        mask = np.asarray(self.valid_mask())
        live = np.asarray(raw)[mask]
        codec = self.table.codecs.get(name)
        if codec is not None:
            # decode-on-finalize: the packed block carries raw code words;
            # the engine decodes (and caches per table version) only here,
            # when a client actually reads the column
            token = ("ts", self.snapshot_ts) if self.snapshot_ts is not None else ()
            return self.engine.decode_column(
                self.table, name, live.reshape(-1), token=token
            )
        if col.dtype == "char":
            return live.view(np.uint8).reshape(-1, col.width)
        if col.dtype == "int32":
            return jnp.asarray(live.reshape(-1))
        if col.dtype == "uint32":
            return jnp.asarray(live.reshape(-1).view(np.uint32))
        if col.dtype == "float32":
            return jnp.asarray(live.reshape(-1).view(np.float32))
        # 8-byte types occupy two words little-endian
        return jnp.asarray(live.reshape(-1, 2).view(col.np_dtype).reshape(-1))

    def column_words(self, name: str) -> tuple[int, int]:
        """(word offset, word width) of ``name`` inside the packed view."""
        return self._packed_slice[name]

    def __getitem__(self, idx) -> jax.Array:
        return self.packed()[idx]

    @property
    def shape(self) -> tuple[int, int]:
        return (self.geometry.row_count, self.geometry.out_words_per_row)

    def __repr__(self) -> str:  # pragma: no cover
        return (
            f"EphemeralView(cols={self.columns}, rows={self.geometry.row_count},"
            f" words/row={self.geometry.out_words_per_row}, ts={self.snapshot_ts})"
        )
