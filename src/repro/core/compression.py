"""Column compression codecs the RME natively supports (paper §4).

The paper: "Relational Memory natively supports dictionary and delta (frame of
reference) encoding ... both can be used in row-oriented data and hence, they
can benefit any groups of columns requested by ephemeral variables."  RLE is
explicitly *not* preferred (expensive decode, needs sorted data), so we follow
the paper and implement dictionary + delta/FOR only.

Encoded columns are stored in the row store as plain int32 code words; the
engine projects them like any other column and decoding happens on the packed
view (vectorized, after data movement has already been minimized — the order
the paper intends).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DictCodec:
    """Dictionary encoding: values -> dense int32 codes, decode via gather."""

    dictionary: np.ndarray  # (n_distinct,) original values, sorted

    @staticmethod
    def fit(values: np.ndarray) -> "DictCodec":
        return DictCodec(np.unique(np.asarray(values)))

    def encode(self, values: np.ndarray) -> np.ndarray:
        codes = np.searchsorted(self.dictionary, np.asarray(values))
        if not np.array_equal(self.dictionary[codes], np.asarray(values)):
            raise ValueError("values outside the fitted dictionary")
        return codes.astype(np.int32)

    def decode(self, codes: jax.Array) -> jax.Array:
        return jnp.asarray(self.dictionary)[codes]

    @property
    def bits_saved_per_value(self) -> float:
        """Entropy-style accounting used by the compression benchmark."""
        width = max(int(np.ceil(np.log2(max(len(self.dictionary), 2)))), 1)
        return 32.0 - width


@dataclasses.dataclass(frozen=True)
class DeltaCodec:
    """Frame-of-reference: ``code = value - reference`` per frame of rows."""

    references: np.ndarray  # (n_frames,) int64 frame minima
    frame_rows: int

    @staticmethod
    def fit(values: np.ndarray, frame_rows: int = 1024) -> "DeltaCodec":
        v = np.asarray(values, dtype=np.int64)
        n_frames = -(-len(v) // frame_rows)
        refs = np.empty(n_frames, dtype=np.int64)
        for f in range(n_frames):
            chunk = v[f * frame_rows : (f + 1) * frame_rows]
            refs[f] = chunk.min() if len(chunk) else 0
        return DeltaCodec(refs, frame_rows)

    def encode(self, values: np.ndarray) -> np.ndarray:
        v = np.asarray(values, dtype=np.int64)
        frames = np.arange(len(v)) // self.frame_rows
        delta = v - self.references[frames]
        if delta.max(initial=0) > np.iinfo(np.int32).max:
            raise ValueError("delta overflows int32 code word")
        return delta.astype(np.int32)

    def decode(self, codes: jax.Array) -> jax.Array:
        n = codes.shape[0]
        frames = jnp.arange(n) // self.frame_rows
        # references fold to the default int width (int32 unless x64 is on);
        # FOR frames in this system always fit 32-bit deltas (checked at encode)
        refs = jnp.asarray(self.references.astype(np.int64), dtype=codes.dtype)
        return refs[frames] + codes
