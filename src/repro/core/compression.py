"""Column compression codecs the RME natively supports (paper §4).

The paper: "Relational Memory natively supports dictionary and delta (frame of
reference) encoding ... both can be used in row-oriented data and hence, they
can benefit any groups of columns requested by ephemeral variables."  RLE is
explicitly *not* preferred (expensive decode, needs sorted data), so we follow
the paper and implement dictionary + delta/FOR only.

Encoded columns are stored in the row store as plain int32 code words, and the
execution stack operates on the **raw code words** wherever the codec's order
structure allows it (Lin et al., PAPERS.md — the win is *operating* on encoded
values, not just storing them):

* **Predicates** — the dictionary is sorted (``np.unique``), so it is
  order-preserving: ``value > k`` holds iff ``code > rank(k)``.
  :meth:`DictCodec.translate_pred` / :meth:`DeltaCodec.translate_pred` map a
  value-space ``(op, k)`` to the equivalent code-space constant at *compile
  time* (``requests._pred_fields``), and the fused kernels compare raw words —
  zero decode in-scan.
* **Group-by keys** — dictionary codes are dense ``[0, n)``, so the kernel
  groups by raw code and the planner remaps code-space partials to value
  groups from the dictionary alone (never ``decode()``).
* **Join keys** — two tables whose key columns share one table-level
  dictionary join directly on code words (equal codes ⟺ equal values).
* **FOR sums** — ``sum(values) = base * count + sum(deltas)``: the kernel
  sums raw delta words and the engine applies the affine fix-up on the
  2-scalar result.

Decoding happens only when a client *reads* a packed result
(``EphemeralView.column`` → ``RelationalMemoryEngine.decode_column``, cached
per table version) — the order the paper intends.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

_I32 = np.iinfo(np.int32)

# the all-rows-pass spelling of a translated predicate: the kernels' "none"
# op applies no value test (MVCC visibility still applies when fused)
PASS_ALL = ("none", 0)


@dataclasses.dataclass(frozen=True)
class DictCodec:
    """Dictionary encoding: values -> dense int32 codes, decode via gather.

    The dictionary is kept sorted (``fit`` uses ``np.unique``), which makes
    the code assignment **order-preserving**: range predicates and sort-based
    join probes work on raw codes.  Values may be numeric *or* strings — a
    string column is stored as its int32 code word and only ever decoded on
    result materialization.
    """

    dictionary: np.ndarray  # (n_distinct,) original values, sorted

    @staticmethod
    def fit(values: np.ndarray) -> "DictCodec":
        return DictCodec(np.unique(np.asarray(values)))

    def encode(self, values: np.ndarray) -> np.ndarray:
        values = np.asarray(values)
        if values.size == 0:
            return np.zeros(0, dtype=np.int32)
        if self.dictionary.size == 0:
            raise ValueError("values outside the fitted dictionary")
        codes = np.searchsorted(self.dictionary, values)
        # searchsorted may return n for beyond-max values: clip before the
        # round-trip check so the probe never indexes out of bounds
        safe = np.minimum(codes, self.dictionary.size - 1)
        if not np.array_equal(self.dictionary[safe], values):
            raise ValueError("values outside the fitted dictionary")
        return safe.astype(np.int32)

    def decode(self, codes) -> jax.Array | np.ndarray:
        if self.dictionary.dtype.kind in ("U", "S", "O"):
            # string dictionaries decode host-side (no jax string dtype)
            return np.asarray(self.dictionary)[np.asarray(codes)]
        return jnp.asarray(self.dictionary)[codes]

    def decode_np(self, codes: np.ndarray, rows: np.ndarray | None = None) -> np.ndarray:
        """Host-side decode (table reads; ``rows`` ignored — codes are
        position-independent)."""
        return np.asarray(self.dictionary)[np.asarray(codes)]

    def translate_pred(self, op: str, k) -> tuple[str, int]:
        """Value-space ``col <op> k`` -> the equivalent code-space predicate.

        Order preservation makes both ops a rank lookup, with no op flip:

        * ``gt``: values ``> k`` are exactly codes ``>= rank_right(k)``,
          i.e. ``code > rank_right(k) - 1``.
        * ``lt``: values ``< k`` are exactly codes ``< rank_left(k)``.

        The translated constant always fits int32 (codes live in ``[0, n)``),
        so never-pass and all-pass cases need no special spelling.
        """
        n = self.dictionary.size
        if op == "gt":
            return "gt", int(np.searchsorted(self.dictionary, k, side="right")) - 1
        if op == "lt":
            return "lt", min(int(np.searchsorted(self.dictionary, k, side="left")), n)
        raise ValueError(f"untranslatable predicate op {op!r}")

    @property
    def code_bits(self) -> int:
        """Information width of one code word (0 for ≤1 distinct values)."""
        n = self.dictionary.size
        if n <= 1:
            return 0
        return int(np.ceil(np.log2(n)))

    @property
    def code_bytes(self) -> int:
        """The code word's *effective* byte budget in the union geometry —
        what the compressed stream would move per value."""
        return -(-self.code_bits // 8)

    @property
    def bits_saved_per_value(self) -> float:
        """Entropy-style accounting used by the compression benchmark."""
        width = max(int(np.ceil(np.log2(max(len(self.dictionary), 2)))), 1)
        return 32.0 - width


@dataclasses.dataclass(frozen=True)
class DeltaCodec:
    """Frame-of-reference: ``code = value - reference`` per frame of rows.

    ``code_bits`` records the widest delta the fit produced (32 when
    constructed directly) — the effective word budget of the encoded stream.
    A **single-frame** codec (one global reference — what
    :meth:`fit_global` builds and what tables attach) is additionally
    position-independent, which is what lets appended rows encode against the
    same reference and predicates translate to one affine shift.
    """

    references: np.ndarray  # (n_frames,) int64 frame minima
    frame_rows: int
    code_bits: int = 32

    @staticmethod
    def fit(values: np.ndarray, frame_rows: int = 1024) -> "DeltaCodec":
        v = np.asarray(values, dtype=np.int64)
        n_frames = -(-len(v) // frame_rows)
        refs = np.empty(n_frames, dtype=np.int64)
        for f in range(n_frames):
            chunk = v[f * frame_rows : (f + 1) * frame_rows]
            refs[f] = chunk.min() if len(chunk) else 0
        codec = DeltaCodec(refs, frame_rows)
        bits = _delta_bits(v, refs[np.arange(len(v)) // frame_rows] if len(v) else refs[:0])
        return dataclasses.replace(codec, code_bits=bits)

    @staticmethod
    def fit_global(values: np.ndarray) -> "DeltaCodec":
        """One reference for every row, past and future — the table-level
        FOR codec.  ``frame_rows`` is effectively infinite, so encode/decode
        are position-independent and appends reuse the fitted reference."""
        v = np.asarray(values, dtype=np.int64)
        ref = np.array([v.min() if v.size else 0], dtype=np.int64)
        bits = _delta_bits(v, np.broadcast_to(ref, v.shape)) if v.size else 0
        return DeltaCodec(ref, frame_rows=2**31 - 1, code_bits=bits)

    @property
    def single_frame(self) -> bool:
        return len(self.references) == 1

    @property
    def base(self) -> int:
        """The global reference of a single-frame codec — the ``base`` in the
        ``sum = base * count + sum(deltas)`` aggregation identity."""
        if not self.single_frame:
            raise ValueError("base is defined for single-frame codecs only")
        return int(self.references[0])

    @property
    def code_bytes(self) -> int:
        return -(-self.code_bits // 8)

    def encode(self, values: np.ndarray) -> np.ndarray:
        v = np.asarray(values, dtype=np.int64)
        frames = np.arange(len(v)) // self.frame_rows
        delta = v - self.references[frames]
        if delta.max(initial=0) > _I32.max or delta.min(initial=0) < _I32.min:
            raise ValueError("delta overflows int32 code word")
        if self.code_bits < 32 and v.size:
            # a *fitted* codec's narrow-width claim must stay honest: any
            # delta outside [0, 2^bits) (negative = below the reference)
            # forces the caller to re-fit, never a silent stale claim
            if delta.min() < 0 or delta.max() > (1 << self.code_bits) - 1:
                raise ValueError("values outside the fitted delta range")
        return delta.astype(np.int32)

    def decode(self, codes: jax.Array) -> jax.Array:
        n = codes.shape[0]
        frames = jnp.arange(n) // self.frame_rows
        # references fold to the default int width (int32 unless x64 is on);
        # FOR frames in this system always fit 32-bit deltas (checked at encode)
        refs = jnp.asarray(self.references.astype(np.int64), dtype=codes.dtype)
        return refs[frames] + codes

    def decode_np(self, codes: np.ndarray, rows: np.ndarray | None = None) -> np.ndarray:
        """Host-side decode; ``rows`` gives the codes' physical positions
        (needed by multi-frame codecs — a single-frame codec ignores it)."""
        codes = np.asarray(codes, dtype=np.int64)
        if rows is None:
            frames = np.arange(len(codes)) // self.frame_rows
        else:
            frames = np.asarray(rows) // self.frame_rows
        return (self.references[frames] + codes).astype(np.int32)

    def translate_pred(self, op: str, k) -> tuple[str, int]:
        """Value-space ``col <op> k`` -> delta-space (single-frame only).

        The shift is affine and monotone, so the op never flips: the bound
        becomes ``k - base`` in int64, and bounds that leave the int32 delta
        range collapse to the explicit never-pass / all-pass spellings.
        """
        if not self.single_frame:
            raise ValueError(
                "predicate translation needs a single-frame FOR codec"
            )
        bound = int(k) - self.base
        if op == "gt":
            if bound >= _I32.max:
                return "gt", _I32.max  # no int32 delta exceeds it: never pass
            if bound < _I32.min:
                return PASS_ALL  # every delta exceeds it
            return "gt", bound
        if op == "lt":
            if bound <= _I32.min:
                return "lt", _I32.min  # never pass
            if bound > _I32.max:
                return PASS_ALL
            return "lt", bound
        raise ValueError(f"untranslatable predicate op {op!r}")

    @property
    def bits_saved_per_value(self) -> float:
        return 32.0 - max(self.code_bits, 1)


def _delta_bits(values: np.ndarray, refs: np.ndarray) -> int:
    """Bits needed for the widest delta (0 when every delta is 0)."""
    if values.size == 0:
        return 0
    delta = values - refs
    widest = int(max(delta.max(initial=0), 0))
    if delta.min(initial=0) < 0:
        widest = 32  # out-of-fit negative deltas: no narrow claim
    return 0 if widest == 0 else int(widest).bit_length()


Codec = DictCodec | DeltaCodec


def fit_codec(kind: str, values: np.ndarray) -> Codec:
    """Fit the table-level codec for a column declared ``codec=kind``."""
    if kind == "dict":
        return DictCodec.fit(values)
    if kind == "for":
        return DeltaCodec.fit_global(values)
    raise ValueError(f"unknown codec kind {kind!r}; want 'dict' or 'for'")
