"""Per-query execution-strategy selection (paper §4, "Indexes & Execution
Strategies"): "the query optimizer can decide to execute one query with
indexes and another query with columns, alternating between a row-at-a-time
and column-at-a-time execution strategy depending on what is the best fit."

The planner costs each access path in *bytes through the hierarchy* — the
unit the whole system optimizes — and picks the cheapest:

  row   : N · R                      (full rows; free if the query touches
                                      ~all columns anyway)
  rme   : Σ_j beats(j) · B_w         (bus-beat-exact Eq.(3) bursts; ~packed
                                      bytes + ≤1 beat/(row,col) slack)
  hot   : N · Σ C_j                  (reorganization-cache hit: packed bytes
                                      only — checked against live cache
                                      state, the paper's Fig. 6 hot curve)
  fused : O(1)                       (aggregations the engine answers with a
                                      scalar — Q0/Q3-shaped queries)

Selectivity-aware: a fused aggregate is preferred whenever legal; a hot view
beats everything that must touch DRAM; RME vs row flips exactly at the
projectivity crossover of the paper's Figure 1.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from .descriptor import bytes_moved
from .engine import RelationalMemoryEngine
from .schema import MAX_ENABLED_COLUMNS, TableGeometry, merge_geometries
from .table import RelationalTable


@dataclasses.dataclass(frozen=True)
class Plan:
    path: str  # "fused" | "hot" | "rme" | "row"
    est_bytes: int
    alternatives: dict[str, int]

    def __str__(self) -> str:
        alts = ", ".join(f"{k}={v:,}" for k, v in self.alternatives.items())
        return f"Plan({self.path}, est {self.est_bytes:,} B; {alts})"


def plan_query(
    engine: RelationalMemoryEngine,
    table: RelationalTable,
    columns: Sequence[str],
    aggregate_only: bool = False,
) -> Plan:
    """Choose the access path for a query touching ``columns``."""
    if len(columns) > MAX_ENABLED_COLUMNS:
        # beyond the configuration port's Q cap the engine cannot express the
        # view — and at that projectivity full rows are the right answer
        # anyway (Figure 1)
        n_bytes = table.row_count * table.schema.row_bytes
        return Plan(path="row", est_bytes=n_bytes, alternatives={"row": n_bytes})
    geom = TableGeometry.from_schema(table.schema, columns, table.row_count)
    moved = bytes_moved(geom)
    costs = {
        "row": moved["row_wise"],
        "rme": moved["rme"],
        "hot": moved["columnar"],
    }
    # hot is only available if the reorganization cache holds a live entry;
    # peek() probes without get()'s delete-on-stale side effect — planning a
    # query must not mutate cache state
    key = (table.uid, geom.cache_key(), engine.revision)
    hot_entry = engine.cache.peek(key, table.version)
    if hot_entry is None:
        costs.pop("hot")
    if aggregate_only and len(columns) <= 2:
        costs["fused"] = 8  # the engine returns [sum, count]
    path = min(costs, key=costs.get)
    return Plan(path=path, est_bytes=costs[path], alternatives=costs)


@dataclasses.dataclass(frozen=True)
class BatchPlan:
    """Co-planned query batch over one table (scan-sharing credit applied).

    ``shared`` is True when serving every rme-path view from one multi-output
    scan moves fewer bytes than materializing each independently; the engine's
    ``materialize_many`` is then the chosen executor.  Views the per-query
    planner already routes elsewhere (hot cache, fused aggregate, full-row
    scan) keep their individual plans and costs on both sides of the
    comparison.
    """

    shared: bool
    est_bytes: int  # cost of the chosen strategy
    shared_bytes: int  # union-scan cost: one pass serves all rme views
    independent_bytes: int  # sum of the per-view plans
    per_view: tuple[Plan, ...]

    def __str__(self) -> str:
        return (
            f"BatchPlan({'shared' if self.shared else 'independent'},"
            f" est {self.est_bytes:,} B; shared={self.shared_bytes:,},"
            f" independent={self.independent_bytes:,}, views={len(self.per_view)})"
        )


def plan_batch(
    engine: RelationalMemoryEngine,
    table: RelationalTable,
    groups: Sequence[Sequence[str]],
) -> BatchPlan:
    """Co-plan several column-group queries over ``table``.

    The per-query planner prices each view alone; the batch planner then
    credits a shared scan — every view the RME can express (≤ Q-cap columns,
    not already hot) is priced as part of **one** pass whose bus-beat bytes
    follow the union geometry (overlapping column intervals are fetched once
    for the whole batch), which is exactly what ``materialize_many`` executes.
    A view whose solo plan fell to the row path at the projectivity crossover
    still joins the shared scan: co-planned, its columns ride a stream that is
    already paid for.
    """
    plans = tuple(plan_query(engine, table, list(g)) for g in groups)
    independent = sum(p.est_bytes for p in plans)
    shareable = [
        p.path in ("rme", "row") and len(g) <= MAX_ENABLED_COLUMNS
        for g, p in zip(groups, plans)
    ]
    shared_geoms = [
        TableGeometry.from_schema(table.schema, list(g), table.row_count)
        for g, ok in zip(groups, shareable)
        if ok
    ]
    unshared = sum(p.est_bytes for p, ok in zip(plans, shareable) if not ok)
    if len(shared_geoms) >= 2:
        union = merge_geometries(shared_geoms)
        shared_bytes = bytes_moved(union)["rme"] + unshared
    else:
        shared_bytes = independent
    shared = shared_bytes < independent
    return BatchPlan(
        shared=shared,
        est_bytes=min(shared_bytes, independent),
        shared_bytes=shared_bytes,
        independent_bytes=independent,
        per_view=plans,
    )


def execute_sum(
    engine: RelationalMemoryEngine,
    table: RelationalTable,
    agg_col: str,
    pred_col: str | None = None,
    pred_op: str = "none",
    pred_k=0,
) -> tuple[float, Plan]:
    """Plan + execute a Q0/Q3-shaped query through the chosen path."""
    import jax.numpy as jnp

    cols = [agg_col] + ([pred_col] if pred_col else [])
    plan = plan_query(engine, table, cols, aggregate_only=True)
    if plan.path == "fused":
        s, _ = engine.aggregate(table, agg_col, pred_col, pred_op, pred_k)
        return s, plan
    view = engine.register(table, tuple(cols))
    packed = view.packed()
    off_a, _ = view.column_words(agg_col)
    vals = packed[:, off_a].astype(jnp.float32)
    if pred_col is not None and pred_op != "none":
        off_p, _ = view.column_words(pred_col)
        p = packed[:, off_p]
        mask = p > pred_k if pred_op == "gt" else p < pred_k
        vals = jnp.where(mask, vals, 0.0)
    return float(jnp.sum(vals)), plan
