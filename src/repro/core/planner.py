"""Execution-strategy selection + the logical-plan compiler (paper §4, §8).

Per-query costing (paper §4, "Indexes & Execution Strategies"): "the query
optimizer can decide to execute one query with indexes and another query with
columns, alternating between a row-at-a-time and column-at-a-time execution
strategy depending on what is the best fit."  The planner costs each access
path in *bytes through the hierarchy* — the unit the whole system optimizes —
and picks the cheapest:

  row   : N · R                      (full rows; free if the query touches
                                      ~all columns anyway)
  rme   : Σ_j beats(j) · B_w         (bus-beat-exact Eq.(3) bursts; ~packed
                                      bytes + ≤1 beat/(row,col) slack)
  hot   : N · Σ C_j                  (reorganization-cache hit: packed bytes
                                      only — checked against live cache
                                      state, the paper's Fig. 6 hot curve)
  fused : O(1)                       (aggregations the engine answers with a
                                      scalar — Q0/Q3-shaped queries)

On top of the cost model sits :func:`compile_plan`: it lowers a logical plan
(:mod:`repro.core.plan`) to a :class:`PhysicalQuery` routed to the best
physical path — engine scan ops (projections, fused filters, fused
aggregates, group-by partials), or a host-side fallback when the geometry is
inexpressible (beyond the configuration port's Q cap) or the caller asked for
a baseline path (``"row"`` / ``"col"``).  A compiled query splits into *scan
ops* (batchable across queries — the
:class:`~repro.serve.query_server.QueryServer` hands the ops of a whole tick
to one ``execute_many`` call, where same-table work of **any** kind fuses
into one heterogeneous one-pass scan; a solo query's lone op keeps today's
single-op kernels), a *launch* step that enqueues device work without host
syncs, and a *finalize* step that is the only point allowed to block.

The q5 sorted build-side index cache lives here too (it is physical-execution
state, not operator-surface state): argsort over the build table is the
join's dominant host-side cost, and the build side is usually the stable
dimension table — re-sorting it per probe throws that work away.  Keyed by
(table uid, version, key col, payload col, path) so any OLTP mutation of the
build side invalidates, exactly like the reorg cache (uid, not id(): the
cache is module-global and must never alias a recycled address).  The "col"
path is never cached — its data comes from a caller-supplied colstore the
table's version says nothing about.  FIFO-bounded by bytes, and a dead build
table's entries are dropped by a weakref finalizer so the global cache cannot
pin device arrays of collected tables.
"""

from __future__ import annotations

import dataclasses
import warnings
import weakref
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.common import group_ids
from repro.kernels.rme_join import estimated_partition_bytes

from .descriptor import bytes_moved
from .engine import RelationalMemoryEngine
from .ephemeral import EphemeralView
from .optimizer import optimize_trace, pred_class
from .plan import (
    PlanBuilder,
    PlanError,
    PlanNode,
    Predicate,
    QueryShape,
    decompose,
    describe,
)
from .requests import (
    AggregateOp,
    FilterOp,
    GroupByOp,
    JoinOp,
    JoinResult,
    MultiJoinResult,
    ProjectOp,
    ScanOp,
)
from .schema import MAX_ENABLED_COLUMNS, TableGeometry, merge_geometries
from .table import RelationalTable


@dataclasses.dataclass(frozen=True)
class Plan:
    path: str  # "fused" | "hot" | "rme" | "row"
    est_bytes: int
    alternatives: dict[str, int]

    def __str__(self) -> str:
        alts = ", ".join(f"{k}={v:,}" for k, v in self.alternatives.items())
        return f"Plan({self.path}, est {self.est_bytes:,} B; {alts})"


def plan_query(
    engine: RelationalMemoryEngine,
    table: RelationalTable,
    columns: Sequence[str],
    aggregate_only: bool = False,
) -> Plan:
    """Choose the access path for a query touching ``columns``."""
    if len(columns) > MAX_ENABLED_COLUMNS:
        # beyond the configuration port's Q cap the engine cannot express the
        # view — and at that projectivity full rows are the right answer
        # anyway (Figure 1)
        n_bytes = table.row_count * table.schema.row_bytes
        return Plan(path="row", est_bytes=n_bytes, alternatives={"row": n_bytes})
    geom = TableGeometry.from_schema(table.schema, columns, table.row_count)
    moved = bytes_moved(geom)
    costs = {
        "row": moved["row_wise"],
        "rme": moved["rme"],
        "hot": moved["columnar"],
    }
    # hot is only available if the reorganization cache holds an entry that
    # fully covers the table's current rows; peek_project probes without
    # get()'s delete-on-stale side effect — planning a query must not mutate
    # cache state.  (A partially-covering entry will still be delta-served
    # at execution; costing it as a full rme scan is a conservative bound.)
    hot_entry = engine.peek_project(table, geom)
    if hot_entry is None:
        costs.pop("hot")
    if aggregate_only and len(columns) <= 2:
        costs["fused"] = 8  # the engine returns [sum, count]
    # equal-cost ties resolve toward the engine: a fused scalar beats a hot
    # read beats an rme scan beats full rows — at the same byte count the
    # engine path additionally warms the reorg cache for future hits
    pref = ("fused", "hot", "rme", "row")
    path = min(costs, key=lambda p: (costs[p], pref.index(p)))
    return Plan(path=path, est_bytes=costs[path], alternatives=costs)


@dataclasses.dataclass(frozen=True)
class BatchPlan:
    """Co-planned query batch over one table (scan-sharing credit applied).

    ``shared`` is True when serving every rme-path view from one multi-output
    scan moves fewer bytes than materializing each independently; the engine's
    ``materialize_many`` is then the chosen executor.  Views the per-query
    planner already routes elsewhere (hot cache, fused aggregate, full-row
    scan) keep their individual plans and costs on both sides of the
    comparison.
    """

    shared: bool
    est_bytes: int  # cost of the chosen strategy
    shared_bytes: int  # union-scan cost: one pass serves all rme views
    independent_bytes: int  # sum of the per-view plans
    per_view: tuple[Plan, ...]

    def __str__(self) -> str:
        return (
            f"BatchPlan({'shared' if self.shared else 'independent'},"
            f" est {self.est_bytes:,} B; shared={self.shared_bytes:,},"
            f" independent={self.independent_bytes:,}, views={len(self.per_view)})"
        )


def plan_batch(
    engine: RelationalMemoryEngine,
    table: RelationalTable,
    groups: Sequence[Sequence[str]],
) -> BatchPlan:
    """Co-plan several column-group queries over ``table``.

    The per-query planner prices each view alone; the batch planner then
    credits a shared scan — every view the RME can express (≤ Q-cap columns,
    not already hot) is priced as part of **one** pass whose bus-beat bytes
    follow the union geometry (overlapping column intervals are fetched once
    for the whole batch), which is exactly what ``materialize_many`` executes.
    A view whose solo plan fell to the row path at the projectivity crossover
    still joins the shared scan: co-planned, its columns ride a stream that is
    already paid for.
    """
    plans = tuple(plan_query(engine, table, list(g)) for g in groups)
    independent = sum(p.est_bytes for p in plans)
    shareable = [
        p.path in ("rme", "row") and len(g) <= MAX_ENABLED_COLUMNS
        for g, p in zip(groups, plans)
    ]
    shared_geoms = [
        TableGeometry.from_schema(table.schema, list(g), table.row_count)
        for g, ok in zip(groups, shareable)
        if ok
    ]
    unshared = sum(p.est_bytes for p, ok in zip(plans, shareable) if not ok)
    if len(shared_geoms) >= 2:
        union = merge_geometries(shared_geoms)
        shared_bytes = bytes_moved(union)["rme"] + unshared
    else:
        shared_bytes = independent
    shared = shared_bytes < independent
    return BatchPlan(
        shared=shared,
        est_bytes=min(shared_bytes, independent),
        shared_bytes=shared_bytes,
        independent_bytes=independent,
        per_view=plans,
    )


def execute_sum(
    engine: RelationalMemoryEngine,
    table: RelationalTable,
    agg_col: str,
    pred_col: str | None = None,
    pred_op: str = "none",
    pred_k=0,
) -> tuple[float, Plan]:
    """Plan + execute a Q0/Q3-shaped query through the chosen path."""
    cols = [agg_col] + ([pred_col] if pred_col else [])
    plan = plan_query(engine, table, cols, aggregate_only=True)
    # encoded columns must ride the fused path: the packed-view reduction
    # below reads raw words, which for a codec column are code words
    if plan.path == "fused" or any(c in table.codecs for c in cols):
        s, _ = engine.aggregate(table, agg_col, pred_col, pred_op, pred_k)
        return s, plan
    view = engine.register(table, tuple(cols))
    packed = view.packed()
    off_a, _ = view.column_words(agg_col)
    vals = packed[:, off_a].astype(jnp.float32)
    if pred_col is not None and pred_op != "none":
        off_p, _ = view.column_words(pred_col)
        p = packed[:, off_p]
        mask = _pred_mask(p, pred_op, pred_k)
        vals = jnp.where(mask, vals, 0.0)
    return float(jnp.sum(vals)), plan


# ------------------------------------------------- host-side access paths
def _decode_i32(x: jax.Array, dtype: str) -> jax.Array:
    if dtype == "float32":
        return jax.lax.bitcast_convert_type(x, jnp.float32)
    return x


def _pred_mask(vals: jax.Array, op: str, k) -> jax.Array:
    """The single fused predicate, evaluated host/device-side (gt/lt only —
    the same ops the kernels implement)."""
    return vals > k if op == "gt" else vals < k


def _col_from_rows(table: RelationalTable, name: str) -> jax.Array:
    """Direct row-wise column read: ships every row word, slices one column."""
    col = table.schema.column(name)
    codec = table.codecs.get(name)
    if codec is not None:
        if col.dtype == "str":
            raise PlanError(
                f"string column {name!r} has no host-baseline spelling — "
                "strings execute on their codes through the rme path"
            )
        # host baselines reason in value space: decode the stored codes
        raw = table.words()[:, table.schema.word_offset(name)]
        return jnp.asarray(codec.decode_np(raw, np.arange(table.row_count)))
    words = jnp.asarray(table.words())  # the whole row store moves
    off = table.schema.word_offset(name)
    return _decode_i32(words[:, off], col.dtype)


def _host_col(
    table: RelationalTable,
    colstore: Mapping[str, np.ndarray] | None,
    name: str,
    path: str,
) -> jax.Array:
    """One decoded column through a baseline path (``"row"`` or ``"col"``)."""
    if path == "row":
        return _col_from_rows(table, name)
    if path == "col":
        if colstore is None:
            raise ValueError(f"path 'col' needs a colstore for {name!r}")
        return jnp.asarray(colstore[name])
    raise ValueError(path)


def _host_words(
    table: RelationalTable,
    colstore: Mapping[str, np.ndarray] | None,
    name: str,
    path: str,
) -> jax.Array:
    """One column as raw (N, words) int32 — bit-exact with the packed layout."""
    col = table.schema.column(name)
    if path == "row":
        words = jnp.asarray(table.words())
        off = table.schema.word_offset(name)
        return words[:, off : off + col.words]
    arr = np.asarray(colstore[name])
    if arr.dtype.kind in ("U", "O"):
        raise PlanError(
            f"string column {name!r} has no raw-words host spelling — "
            "strings pack as dictionary codes on the rme path only"
        )
    if arr.dtype.kind == "S":  # char columns travel as raw words
        arr = np.ascontiguousarray(arr).view(np.uint8).reshape(
            table.row_count, -1
        ).view(np.int32)
    return jnp.asarray(arr).reshape(table.row_count, -1).view(jnp.int32)


# ------------------------------------------------- q5 build-side index cache
# One cache, two entry kinds, keyed by (uid, version, key, payload, path):
# the host sort-probe route stores its sorted {key, payload} index under
# path="rme", and the device hash route stores its bucket partition arrays
# (a NamedTuple of four device arrays — see kernels.rme_join.JoinPartitions)
# under path=DEVICE_JOIN_PATH.  Both kinds share the byte bound, the FIFO
# eviction, the version-drop rule, and the weakref lifetime — and both are
# dropped by clear_join_build_cache() / RelationalMemoryEngine.reset(), so
# neither can leak stale device bytes across benchmark repetitions.
DEVICE_JOIN_PATH = "rme-hash"

_BUILD_INDEX_CACHE: dict[tuple, tuple[jax.Array, jax.Array]] = {}
_BUILD_INDEX_CAPACITY = 64 << 20
_build_index_bytes = 0  # incremental occupancy (kept exact by every mutation)
_BUILD_INDEX_FINALIZED: set[int] = set()
JOIN_BUILD_STATS = {"hits": 0, "misses": 0}


def _entry_bytes(entry: tuple[jax.Array, jax.Array]) -> int:
    return sum(a.size * a.dtype.itemsize for a in entry)


def _pop_build_entry(k: tuple) -> None:
    global _build_index_bytes
    entry = _BUILD_INDEX_CACHE.pop(k, None)
    if entry is not None:
        _build_index_bytes -= _entry_bytes(entry)


def clear_join_build_cache() -> None:
    global _build_index_bytes
    _BUILD_INDEX_CACHE.clear()
    _build_index_bytes = 0
    _KEY_UNIQUE_CACHE.clear()
    JOIN_BUILD_STATS["hits"] = 0
    JOIN_BUILD_STATS["misses"] = 0


def _drop_build_entries(uid: int, keep_version: int | None = None) -> None:
    """Drop a table's cached indexes (all of them, or all but one version)."""
    if keep_version is None:
        _BUILD_INDEX_FINALIZED.discard(uid)
    for k in [k for k in _BUILD_INDEX_CACHE
              if k[0] == uid and k[1] != keep_version]:
        _pop_build_entry(k)


def _peek_build_entry(
    r_table: RelationalTable, key: str, r_proj: str, path: str
):
    """Stat-free cache probe for route costing: the join route chooser must
    be able to ask "is the sorted index / partition set warm?" for *both*
    routes without perturbing ``JOIN_BUILD_STATS`` (only the chosen route's
    compile-time probe counts a hit or miss)."""
    return _BUILD_INDEX_CACHE.get(
        (r_table.uid, r_table.version, key, r_proj, path)
    )


def _probe_build_index(
    r_table: RelationalTable, key: str, r_proj: str, path: str
) -> tuple[jax.Array, jax.Array] | None:
    """Warm-path probe, called *before* the build side is materialized — a hit
    must skip the build-side column reads entirely, not just the argsort."""
    if path == "col":  # colstore contents are not keyed by the table version
        return None
    hit = _BUILD_INDEX_CACHE.get((r_table.uid, r_table.version, key, r_proj, path))
    if hit is not None:
        JOIN_BUILD_STATS["hits"] += 1
    else:
        JOIN_BUILD_STATS["misses"] += 1
    return hit


def _insert_build_index(
    entry: tuple[jax.Array, jax.Array],
    r_table: RelationalTable,
    key: str,
    r_proj: str,
    path: str,
) -> None:
    global _build_index_bytes
    if path == "col":
        return
    # versions are monotonic: this table's older entries can never hit again
    _drop_build_entries(r_table.uid, keep_version=r_table.version)
    nbytes = _entry_bytes(entry)
    if nbytes > _BUILD_INDEX_CAPACITY:
        return  # larger than the whole budget: never cached
    # same-key overwrite must release the old bytes first — two identical
    # joins compiled in one serving tick both miss at compile time and both
    # insert at launch, and occupancy must not drift upward
    _pop_build_entry((r_table.uid, r_table.version, key, r_proj, path))
    while _build_index_bytes + nbytes > _BUILD_INDEX_CAPACITY and _BUILD_INDEX_CACHE:
        _pop_build_entry(next(iter(_BUILD_INDEX_CACHE)))
    _BUILD_INDEX_CACHE[(r_table.uid, r_table.version, key, r_proj, path)] = entry
    _build_index_bytes += nbytes
    if r_table.uid not in _BUILD_INDEX_FINALIZED:
        weakref.finalize(r_table, _drop_build_entries, r_table.uid)
        _BUILD_INDEX_FINALIZED.add(r_table.uid)


# ------------------------------------------------------------ plan compiler
@dataclasses.dataclass(frozen=True)
class CompileOptions:
    """Everything :func:`compile_plan` needs beyond the plan and the engine.

    The one compile-surface object (replacing the grown keyword sprawl —
    the old keywords still work for one release, with a
    ``DeprecationWarning``).  Frozen so a server tick can stamp per-tick
    state (the snapshot) with ``dataclasses.replace`` without aliasing the
    client's object.

    * ``path`` — data path of the paper's §6 comparison: ``"rme"`` (the
      engine; the compiler picks the physical route within it), ``"row"``
      or ``"col"`` (host baselines; ``col`` reads ``colstore`` /
      ``right_colstore``).
    * ``snapshot_ts`` — MVCC visibility pin (rme path only).
    * ``join_route`` — override the costed join route choice
      (``"device-hash-join"`` / ``"shared-scan-join"`` /
      ``"flipped-scan-join"``).
    * ``backend`` — fail fast if the engine is not this backend.
    * ``stream`` / ``stream_chunk_rows`` — chunked projection delivery.
    * ``optimize`` — run the :mod:`repro.core.optimizer` passes before
      lowering (``False`` is the differential-testing escape hatch: the
      optimized route must stay byte-identical to this one).
    """

    path: str = "rme"
    colstore: Mapping[str, np.ndarray] | None = None
    right_colstore: Mapping[str, np.ndarray] | None = None
    snapshot_ts: int | None = None
    join_route: str | None = None
    backend: str | None = None
    stream: bool = False
    stream_chunk_rows: int | None = None
    optimize: bool = True


@dataclasses.dataclass
class PhysicalQuery:
    """A logical plan lowered to a physical route.

    Execution splits into three steps so a serving tick can interleave many
    queries without host syncs:

    * ``ops`` — engine-level scan ops the route needs served (projection
      views, fused filters, fused aggregates, group-by partials).  A batch
      executor hands the ops of *all* queries in a tick to one
      ``execute_many`` call — same-table work of **any** kind coalesces into
      one heterogeneous one-pass scan; the results come back aligned with
      ``ops``.  A query compiled alone keeps today's single-op kernels
      (``execute_many`` routes a lone request to them).
    * ``launch(results)`` — enqueue the remaining device work (join probe
      math, reductions over packed views); returns an opaque token, never
      blocks on the host.
    * ``finalize(token)`` — produce the user-facing result; the only step
      allowed to pull scalars to the host.

    A query compiled with ``stream=True`` (projection-shaped, rme path)
    additionally carries ``stream`` — a zero-argument callable returning the
    chunk generator of :meth:`RelationalMemoryEngine.stream_project`.  Such a
    query has no scan ops: its work is the incremental finalize itself, one
    packed chunk per resident (or re-sliced) row-store chunk, and the
    serving layer forwards each chunk to the client's streaming ticket as it
    lands instead of blocking on one monolithic finalize.  ``run()`` on a
    streamed query drains the generator and concatenates — byte-identical to
    the blocking route.

    ``run()`` is the blocking one-shot spelling (what the q0–q5 operator
    wrappers call).
    """

    engine: RelationalMemoryEngine
    shape: QueryShape
    path: str  # requested data path: "rme" | "row" | "col"
    route: str  # chosen physical route, e.g. "fused-aggregate", "shared-scan"
    cost: Plan | None
    ops: tuple[ScanOp, ...]
    _launch: Callable[[Sequence[Any]], Any]
    _finalize: Callable[[Any], Any]
    stream: Callable[[], Any] | None = None  # chunk-generator factory
    # --- optimizer/compile introspection (stamped by compile_plan) ---
    options: "CompileOptions | None" = None
    logical: PlanNode | None = None  # the tree the client submitted
    optimized: PlanNode | None = None  # the tree that was actually lowered
    passes: tuple[str, ...] = ()  # optimizer + planner passes that fired
    # chosen multi-join order: (key, right_proj, est cold build bytes) per
    # spec, in execution order
    join_order: tuple[tuple[str, str, int], ...] = ()

    @property
    def views(self) -> tuple[EphemeralView, ...]:
        """The projection views among ``ops`` (kept for introspection)."""
        return tuple(op.view for op in self.ops if isinstance(op, ProjectOp))

    @property
    def backend(self) -> str:
        """The execution backend this query will run on (``"single"`` /
        ``"sharded"``) — the engine's identity, since routing is dynamic
        dispatch through the engine's serving hooks."""
        return self.engine.backend

    def explain(self) -> str:
        """Human-readable compile report: chosen route, the before/after
        trees, the rewrite passes that fired, the cost-model estimate, and
        (for join chains) the chosen join order with estimated build bytes.
        Everything the optimizer decided, in one inspectable string."""
        lines = [
            f"route: {self.route} (path={self.path},"
            f" backend={self.engine.backend})"
        ]
        if self.logical is not None:
            lines.append(f"logical:   {describe(self.logical)}")
        if self.optimized is not None and self.optimized is not self.logical:
            lines.append(f"optimized: {describe(self.optimized)}")
        lines.append(
            "passes: " + (", ".join(self.passes) if self.passes else "(none)")
        )
        if self.cost is not None:
            lines.append(f"cost: {self.cost}")
        for i, (key, right_proj, est) in enumerate(self.join_order):
            lines.append(
                f"join[{i}]: on {key} -> {right_proj}"
                f" (est cold build {est:,} B)"
            )
        return "\n".join(lines)

    def launch(self, results: Sequence[Any]) -> Any:
        return self._launch(results)

    def finalize(self, token: Any) -> Any:
        return self._finalize(token)

    def run(self) -> Any:
        if self.stream is not None:
            parts = list(self.stream())
            if len(parts) == 1:
                return parts[0]
            out_words = sum(self.shape.table.schema.column(c).words
                            for c in self.shape.columns)
            if not parts:  # an empty table streams zero chunks
                return jnp.zeros((0, out_words), dtype=jnp.int32)
            return jnp.concatenate(parts, 0)
        results = self.engine.execute_many(list(self.ops)) if self.ops else []
        return self._finalize(self._launch(results))


def _pred_args(pred: Predicate | None) -> tuple[str | None, str, Any]:
    if pred is None:
        return None, "none", 0
    return pred.col, pred.op, pred.k


def _check_fused_dtypes(table: RelationalTable, *cols: str | None) -> None:
    """Fused kernels decode 4-byte numeric words; reject anything else at
    compile time, so a bad query fails its own ticket instead of poisoning
    the tick's shared pass."""
    for name in cols:
        if name is None:
            continue
        if name in table.codecs:
            # codec-backed columns store raw int32 code words — exactly what
            # the fused kernels read; predicate constants are code-translated
            # at lowering and results fixed up op-level, never decoded in-scan
            continue
        dtype = table.schema.column(name).dtype
        if dtype not in ("int32", "float32"):
            raise ValueError(
                f"column {name!r}: fused kernels need a 4-byte numeric "
                f"column, got {dtype}"
            )


def _check_snapshot_path(path: str, snapshot_ts: int | None) -> None:
    """Snapshot-pinned reads are an rme-path capability: the fused kernels
    evaluate the MVCC visibility test in-scan from the hidden timestamp
    words.  The host baselines have no timestamp channel (a colstore says
    nothing about row versions), so asking for one is a plan error, not a
    silent wrong answer."""
    if snapshot_ts is not None and path != "rme":
        raise PlanError(
            f"snapshot_ts requires the rme path, not {path!r} "
            "(host baselines carry no MVCC timestamps)"
        )


def _compile_aggregate(
    engine: RelationalMemoryEngine, shape: QueryShape, o: CompileOptions
) -> PhysicalQuery:
    path, colstore, snapshot_ts = o.path, o.colstore, o.snapshot_ts
    agg = shape.agg
    pred_col, pred_op, pred_k = _pred_args(shape.pred)

    def _combine(s: float, c: float):
        if agg.op == "sum":
            return s
        if agg.op == "count":
            return c
        return s / max(c, 1.0)

    if path != "rme":
        def launch(_):
            a = _host_col(shape.table, colstore, agg.col, path).astype(jnp.float32)
            if pred_col is not None:
                p = _host_col(shape.table, colstore, pred_col, path)
                mask = _pred_mask(p, pred_op, pred_k)
            else:
                mask = jnp.ones(a.shape, dtype=bool)
            return jnp.sum(jnp.where(mask, a, 0.0)), jnp.sum(mask)

        return PhysicalQuery(
            engine, shape, path, route=f"host-{path}", cost=None, ops=(),
            _launch=launch,
            _finalize=lambda t: _combine(float(t[0]), float(t[1])),
        )

    cost = plan_query(engine, shape.table, list(shape.columns), aggregate_only=True)
    encoded = any(c is not None and c in shape.table.codecs
                  for c in (agg.col, pred_col))
    if cost.path == "fused" or snapshot_ts is not None or encoded:
        # the aggregate is a scan op: compiled into a tick's batch it rides
        # the shared heterogeneous pass; compiled alone, execute_many routes
        # it to the single-op rme_aggregate kernel.  A snapshot-pinned
        # aggregate *must* take this route — only the fused kernel evaluates
        # the MVCC visibility test, which the materialized-reduction routes
        # (their packed views carry no timestamp words) cannot.
        _check_fused_dtypes(shape.table, agg.col, pred_col)
        op = AggregateOp(shape.table, agg.col, pred_col=pred_col,
                         pred_op=pred_op, pred_k=pred_k,
                         snapshot_ts=snapshot_ts)

        def finalize(out):
            engine.stats.bytes_to_cpu += 8  # the scalar pair crosses on sync
            return _combine(float(out[0]), float(out[1]))

        return PhysicalQuery(
            engine, shape, path, route="fused-aggregate", cost=cost, ops=(op,),
            _launch=lambda results: results[0], _finalize=finalize,
        )

    # hot / rme / row routes reduce a materialized (or sliced) column group
    view = engine.register(shape.table, shape.columns)

    def launch(packed):
        arr = packed[0]
        off_a, _ = view.column_words(agg.col)
        vals = arr[:, off_a].astype(jnp.float32)
        if pred_col is not None:
            off_p, _ = view.column_words(pred_col)
            p = arr[:, off_p]
            mask = _pred_mask(p, pred_op, pred_k)
        else:
            mask = jnp.ones(vals.shape, dtype=bool)
        return jnp.sum(jnp.where(mask, vals, 0.0)), jnp.sum(mask)

    return PhysicalQuery(
        engine, shape, path, route=cost.path, cost=cost, ops=(ProjectOp(view),),
        _launch=launch,
        _finalize=lambda t: _combine(float(t[0]), float(t[1])),
    )


def _compile_groupby(
    engine: RelationalMemoryEngine, shape: QueryShape, o: CompileOptions
) -> PhysicalQuery:
    path, colstore, snapshot_ts = o.path, o.colstore, o.snapshot_ts
    g = shape.group
    pred_col, pred_op, pred_k = _pred_args(shape.pred)

    def _combine(sums: jax.Array, counts: jax.Array) -> jax.Array:
        if g.op == "sum":
            return sums
        return sums / jnp.maximum(counts, 1.0)

    if path != "rme":
        def launch(_):
            a = _host_col(shape.table, colstore, g.agg, path).astype(jnp.float32)
            grp = group_ids(
                _host_col(shape.table, colstore, g.group, path), g.num_groups
            )
            if pred_col is not None:
                p = _host_col(shape.table, colstore, pred_col, path)
                mask = _pred_mask(p, pred_op, pred_k)
            else:
                mask = jnp.ones(a.shape, dtype=bool)
            vals = jnp.where(mask, a, 0.0)
            cnt = mask.astype(jnp.float32)
            sums = jax.ops.segment_sum(vals, grp, num_segments=g.num_groups)
            counts = jax.ops.segment_sum(cnt, grp, num_segments=g.num_groups)
            return sums, counts

        return PhysicalQuery(
            engine, shape, path, route=f"host-{path}", cost=None, ops=(),
            _launch=launch, _finalize=lambda t: _combine(*t),
        )

    # a scan op like the aggregate: joins an open same-table batch's shared
    # pass, or runs on the single-op groupby_sum kernel when compiled alone;
    # a snapshot pins MVCC visibility in-scan
    _check_fused_dtypes(shape.table, g.group, g.agg, pred_col)
    op = GroupByOp(
        shape.table, g.group, g.agg, g.num_groups,
        pred_col=pred_col, pred_op=pred_op, pred_k=pred_k,
        snapshot_ts=snapshot_ts,
    )

    return PhysicalQuery(
        engine, shape, path, route="fused-groupby", cost=None, ops=(op,),
        _launch=lambda results: results[0], _finalize=lambda t: _combine(*t),
    )


def _resident_full_rows(engine: RelationalMemoryEngine, table, cols) -> jax.Array:
    """Column word-slices streamed from the device-resident row store, charged
    to the PMU as one full-row pass — the beyond-Q-cap fallback datapath (no
    per-call host re-upload; the DeviceRowStore keeps the buffer warm)."""
    words = engine.device_words(table)
    parts, out_bytes = [], 0
    for n in cols:
        off = table.schema.word_offset(n)
        w = table.schema.column(n).words
        parts.append(words[:, off : off + w])
        out_bytes += table.schema.column(n).width
    engine.stats.rows_projected += table.row_count
    engine.stats.bytes_from_dram += table.row_count * table.schema.row_bytes
    engine.stats.bytes_to_cpu += table.row_count * out_bytes
    return jnp.concatenate(parts, axis=1)


def _numeric_anchor(table: RelationalTable, cols) -> str | None:
    """A projection column an inert (``"none"``) predicate can anchor on:
    its words must be something the filter kernel could decode, i.e. int32
    code words or a plain 4-byte numeric column."""
    return next(
        (n for n in cols
         if n in table.codecs  # code words are int32, inert op never decodes
         or table.schema.column(n).dtype in ("int32", "float32")),
        None,
    )


def _compile_project(
    engine: RelationalMemoryEngine, shape: QueryShape, o: CompileOptions,
    extra_passes: list[str],
) -> PhysicalQuery:
    path, colstore, snapshot_ts = o.path, o.colstore, o.snapshot_ts
    stream, stream_chunk_rows = o.stream, o.stream_chunk_rows
    table, cols = shape.table, shape.columns
    pred_col, pred_op, pred_k = _pred_args(shape.pred)
    if (o.optimize and path == "rme" and shape.pred is not None
            and len(cols) <= MAX_ENABLED_COLUMNS
            and pred_class(table, shape.pred) == "all"):
        # a provably all-pass predicate on a (packed, mask) plan: the tree
        # rewriter must keep the Filter (dropping it would change the result
        # type), but the *lowering* can go inert — anchor the predicate on a
        # projection column with op "none", whose mask is all-true (AND the
        # MVCC visibility under a snapshot, same as snapshot-project).  The
        # real predicate column leaves the union geometry: strictly fewer
        # bus-beat bytes whenever it was not already projected.
        anchor = _numeric_anchor(table, cols)
        if anchor is not None:
            pred_col, pred_op, pred_k = anchor, "none", 0
            extra_passes.append("eliminate-trivial-pred")

    if stream:
        # incremental delivery: the packed projection arrives one row-store
        # chunk at a time (RelationalMemoryEngine.stream_project), so a
        # large output resolves its ticket chunk-by-chunk instead of in one
        # blocking finalize.  The streamed contract is the plain packed
        # block — per-chunk, with no visibility channel — so only the
        # predicate-free, snapshot-free rme projection qualifies; anything
        # else must say what a partial (masked) chunk means and doesn't.
        if path != "rme":
            raise PlanError(f"streamed results need the rme path, not {path!r}")
        if shape.pred is not None or snapshot_ts is not None:
            raise PlanError(
                "streamed results serve plain projections only — a "
                "predicate or MVCC snapshot needs the (packed, mask) "
                "contract, which has no per-chunk spelling"
            )
        if len(cols) > MAX_ENABLED_COLUMNS:
            raise PlanError(
                f"streamed projection of {len(cols)} columns exceeds the "
                f"configuration port's Q cap ({MAX_ENABLED_COLUMNS})"
            )
        view = engine.register(table, cols)
        return PhysicalQuery(
            engine, shape, path, route="stream-project", cost=None, ops=(),
            _launch=lambda _: None, _finalize=lambda t: t,
            stream=lambda: engine.stream_project(
                view, chunk_rows=stream_chunk_rows
            ),
        )

    if shape.pred is not None:
        # fused selection+projection: rows failing the predicate are zeroed
        # in-scan, a validity bitmap travels alongside (rme_filter kernel)
        if path == "rme":
            if len(cols) > MAX_ENABLED_COLUMNS:
                # the configuration port cannot express the output group:
                # stream full rows from the resident store, predicate applied
                # engine-side — same (packed, mask) contract as the kernel
                def launch(_):
                    words = engine.device_words(table)
                    codec = table.codecs.get(pred_col)
                    # beyond-Q-cap fallback matches the fused contract: the
                    # predicate compares raw code words against the
                    # code-translated constant (packed rows stay encoded)
                    op_, k_ = (codec.translate_pred(pred_op, pred_k)
                               if codec is not None else (pred_op, pred_k))
                    p = _decode_i32(
                        words[:, table.schema.word_offset(pred_col)],
                        "int32" if codec is not None
                        else table.schema.column(pred_col).dtype,
                    )
                    mask = (_pred_mask(p, op_, k_) if op_ != "none"
                            else jnp.ones(p.shape, dtype=bool))
                    if snapshot_ts is not None:
                        mask = mask & engine.valid_mask(table, snapshot_ts)
                    packed = _resident_full_rows(engine, table, cols)
                    return jnp.where(mask[:, None], packed, 0), mask

                return PhysicalQuery(
                    engine, shape, path, route="row-fallback", cost=None,
                    ops=(), _launch=launch, _finalize=lambda t: t,
                )

            # a scan op with the rme_filter contract: (packed, mask) — joins
            # an open same-table batch's shared pass, or runs on the
            # single-op filter_project kernel when compiled alone (the
            # projected group may be any dtype; only the predicate decodes);
            # a snapshot fuses the MVCC visibility test into the same mask
            _check_fused_dtypes(table, pred_col)
            view = engine.register(table, cols, snapshot_ts=snapshot_ts)
            op = FilterOp(view, pred_col, pred_op, pred_k, snapshot_ts)

            return PhysicalQuery(
                engine, shape, path, route="fused-filter", cost=None, ops=(op,),
                _launch=lambda results: results[0], _finalize=lambda t: t,
            )

        def launch(_):
            p = _host_col(table, colstore, pred_col, path)
            mask = _pred_mask(p, pred_op, pred_k)
            parts = [_host_words(table, colstore, n, path) for n in cols]
            packed = jnp.concatenate(parts, axis=1)
            return jnp.where(mask[:, None], packed, 0), mask

        return PhysicalQuery(
            engine, shape, path, route=f"host-{path}", cost=None, ops=(),
            _launch=launch, _finalize=lambda t: t,
        )

    if path == "rme":
        if snapshot_ts is not None:
            # a snapshot-pinned projection needs the validity bitmap the
            # plain packed block cannot carry: route through the filter
            # kernel with a pass-everything predicate — the result is the
            # rme_filter contract, (packed with invisible rows zeroed, mask).
            # The inert predicate still names a column whose words the kernel
            # can decode, so it must be 4-byte numeric; a group without one
            # (or beyond the Q cap) takes the resident-row fallback below.
            pred_anchor = _numeric_anchor(table, cols)
            if len(cols) <= MAX_ENABLED_COLUMNS and pred_anchor is not None:
                view = engine.register(table, cols, snapshot_ts=snapshot_ts)
                op = FilterOp(view, pred_anchor, "none", 0, snapshot_ts)
                return PhysicalQuery(
                    engine, shape, path, route="snapshot-project", cost=None,
                    ops=(op,),
                    _launch=lambda results: results[0], _finalize=lambda t: t,
                )

            def launch(_):
                mask = engine.valid_mask(table, snapshot_ts)
                packed = _resident_full_rows(engine, table, cols)
                return jnp.where(mask[:, None], packed, 0), mask

            return PhysicalQuery(
                engine, shape, path, route="row-fallback", cost=None, ops=(),
                _launch=launch, _finalize=lambda t: t,
            )

        cost = plan_query(engine, table, list(cols))
        if cost.path in ("rme", "hot"):
            view = engine.register(table, cols)
            return PhysicalQuery(
                engine, shape, path, route=cost.path, cost=cost,
                ops=(ProjectOp(view),),
                _launch=lambda packed: packed[0], _finalize=lambda t: t,
            )

        # inexpressible (beyond the Q cap) or genuinely cheaper as full rows:
        # the engine streams whole rows — from the *device-resident* store
        # (no per-call host re-upload), charged to the PMU as a full-row pass
        return PhysicalQuery(
            engine, shape, path, route="row-fallback", cost=cost, ops=(),
            _launch=lambda _: _resident_full_rows(engine, table, cols),
            _finalize=lambda t: t,
        )

    def launch(_):
        parts = [_host_words(table, colstore, n, path) for n in cols]
        return jnp.concatenate(parts, axis=1)

    return PhysicalQuery(
        engine, shape, path, route=f"host-{path}", cost=None, ops=(),
        _launch=launch, _finalize=lambda t: t,
    )


def _sort_probe(
    s_key: jax.Array,
    s_val: jax.Array,
    cached: tuple[jax.Array, jax.Array] | None,
    read_build: Callable[[], tuple[jax.Array, jax.Array]],
    r_table: RelationalTable,
    key: str,
    r_proj: str,
    path: str,
) -> JoinResult:
    """Probe-side join math shared by the rme and host routes: reuse the
    cached sorted build index, or build + insert it from ``read_build()``
    (only called on a miss — a warm hit must skip the build-side reads)."""
    if cached is not None:
        rk_sorted, rv_sorted = cached
    else:
        r_key, r_val = read_build()
        order = jnp.argsort(r_key)
        rk_sorted, rv_sorted = r_key[order], r_val[order]
        _insert_build_index((rk_sorted, rv_sorted), r_table, key, r_proj, path)
    pos = jnp.clip(jnp.searchsorted(rk_sorted, s_key), 0, rk_sorted.shape[0] - 1)
    matched = rk_sorted[pos] == s_key
    return JoinResult(
        s_proj=s_val,
        r_proj=jnp.where(matched, rv_sorted[pos], 0),
        matched=matched,
    )


def _spec_device_expressible(table: RelationalTable, spec) -> bool:
    """Can the device hash route serve one join spec?  The probe kernel reads
    raw single-word columns and hashes the key with integer modulo, so both
    key columns must be int32 (or dict-encoded — raw codes are int32 and
    equal codes mean equal values iff both sides share one table-level
    dictionary) and both payloads plain 4-byte numeric (the probe emits 0
    for unmatched rows, and 0 is a valid code word, so encoded payloads are
    out)."""
    for t, name in ((table, spec.left_proj),
                    (spec.right_table, spec.right_proj)):
        col = t.schema.column(name)
        if (col.words != 1 or col.dtype not in ("int32", "float32")
                or name in t.codecs):
            return False
    for t in (table, spec.right_table):
        if t.schema.column(spec.key).words != 1:
            return False
    a = table.codecs.get(spec.key)
    b = spec.right_table.codecs.get(spec.key)
    if a is not None or b is not None:
        from .compression import DictCodec
        if not (isinstance(a, DictCodec) and isinstance(b, DictCodec)):
            return False
        return a is b or bool(np.array_equal(a.dictionary, b.dictionary))
    return (table.schema.column(spec.key).dtype == "int32"
            and spec.right_table.schema.column(spec.key).dtype == "int32")


def _device_join_expressible(shape: QueryShape) -> bool:
    """Whole-shape device-route check: every spec of the (possibly multi-)
    join chain must be expressible, and a probe-side predicate must sit on a
    4-byte numeric column (the fused probe scan evaluates it in-scan)."""
    if shape.pred is not None:
        try:
            _check_fused_dtypes(shape.table, shape.pred.col)
        except ValueError:
            return False
    return all(_spec_device_expressible(shape.table, s) for s in shape.joins)


# host check for the flipped route's build-side uniqueness, cached per table
# version (an append/update bumps version and naturally re-checks)
_KEY_UNIQUE_CACHE: dict[tuple, bool] = {}


def _key_unique(table: RelationalTable, key: str) -> bool:
    ck = (table.uid, table.version, key)
    hit = _KEY_UNIQUE_CACHE.get(ck)
    if hit is None:
        raw = np.asarray(table.words())[:, table.schema.word_offset(key)]
        hit = bool(np.unique(raw).size == table.row_count)
        _KEY_UNIQUE_CACHE[ck] = hit
    return hit


FLIP_JOIN_PATH = "rme-flip"


def _flip_applicable(shape: QueryShape, snapshot_ts: int | None) -> bool:
    """Can the flipped sort-probe serve this join?  Flipping makes the
    *probe* table the build side, so its key must be duplicate-free (each
    build-side row lands in at most one probe slot), single-word and
    non-string on both sides; predicates and snapshots have no flipped
    spelling (the scatter carries no visibility channel)."""
    j = shape.join
    if (len(shape.joins) != 1 or shape.pred is not None
            or snapshot_ts is not None):
        return False
    for t in (shape.table, j.right_table):
        col = t.schema.column(j.key)
        if col.words != 1 or col.dtype == "str":
            return False
    return _key_unique(shape.table, j.key)


def _side_ship_bytes(engine: RelationalMemoryEngine, table: RelationalTable,
                     cols: list[str]) -> int:
    """Modeled cost of scanning + shipping one side's {key, payload} packed
    block to the CPU — zero when the reorg cache already holds it."""
    geom = TableGeometry.from_schema(table.schema, cols, table.row_count)
    if engine.peek_project(table, geom) is not None:
        return 0
    return bytes_moved(geom)["rme"] + table.row_count * geom.out_bytes_per_row


def _join_route(
    engine: RelationalMemoryEngine, shape: QueryShape, snapshot_ts: int | None
) -> str:
    """Choose the join's physical route by modeled bytes through the
    hierarchy, mirroring :func:`plan_query`:

    * ``device-hash-join``: probe bus beats over the {key, payload} union
      (the probe's output never crosses toward the CPU) + the
      partition-array upload when the build cache is cold for this
      build-table version.
    * ``shared-scan-join``: the probe-side scan **and** its packed block
      shipped up the hierarchy for the CPU-side sort-probe, plus the same
      pair for the build side when the sorted index is cold — each term
      dropping to zero when the reorg cache / build cache already holds it.
    * ``flipped-scan-join`` (build/probe sides swapped): ship the *right*
      table per call and keep the sorted index over the *left* — the win
      when the probe side is the big stable relation and its flip index is
      warm.  Only sound when the probe key is duplicate-free
      (:func:`_flip_applicable`); chosen only when strictly cheaper than
      the standard orientation.

    A snapshot-pinned or probe-predicated join has no host spelling (the
    sort-probe carries no MVCC channel; the shared-scan view carries no
    predicate column), so it must take the device route or fail at compile
    time.
    """
    j = shape.join
    s_table, r_table = shape.table, j.right_table
    expressible = _device_join_expressible(shape)
    if snapshot_ts is not None or shape.pred is not None:
        if not expressible:
            raise PlanError(
                ("snapshot_ts" if snapshot_ts is not None
                 else "probe-predicated") +
                " join needs device-expressible columns "
                "(int32 keys, 4-byte numeric payloads)"
            )
        return "device-hash-join"
    s_geom = TableGeometry.from_schema(
        s_table.schema, [j.left_proj, j.key], s_table.row_count
    )
    probe_beats = bytes_moved(s_geom)["rme"]
    host = 0
    if engine.peek_project(s_table, s_geom) is None:
        host += probe_beats + s_table.row_count * s_geom.out_bytes_per_row
    if _peek_build_entry(r_table, j.key, j.right_proj, "rme") is None:
        host += _side_ship_bytes(engine, r_table, [j.key, j.right_proj])
    host_route = "shared-scan-join"
    if _flip_applicable(shape, snapshot_ts):
        flipped = _side_ship_bytes(engine, r_table, [j.key, j.right_proj])
        if _peek_build_entry(s_table, j.key, j.left_proj,
                             FLIP_JOIN_PATH) is None:
            flipped += _side_ship_bytes(engine, s_table,
                                        [j.left_proj, j.key])
        # strictly cheaper only: at a tie the standard orientation keeps the
        # build index on the (assumed-stable) dimension side
        if flipped < host:
            host, host_route = flipped, "flipped-scan-join"
    if not expressible:
        return host_route
    device = probe_beats
    if _peek_build_entry(r_table, j.key, j.right_proj, DEVICE_JOIN_PATH) is None:
        device += estimated_partition_bytes(r_table.row_count)
    # ties resolve toward the device: at equal bytes the offloaded probe
    # additionally leaves the CPU free (the paper's whole argument)
    return "device-hash-join" if device <= host else host_route


def _join_probe_key(table: RelationalTable, key: str,
                    codes: jax.Array) -> jax.Array:
    """Sort-probe key spelling: mismatched per-table dictionaries mean codes
    are not comparable across tables, so the host routes decode them first —
    the one honest decode in the join stack."""
    codec = table.codecs.get(key)
    if codec is None:
        return codes
    return jnp.asarray(codec.decode(codes))


def _compile_flipped_join(
    engine: RelationalMemoryEngine, shape: QueryShape, o: CompileOptions
) -> PhysicalQuery:
    """Build/probe sides swapped: scan the *right* table per call, keep the
    sorted index (key, probe slot, probe payload) over the *left*.  Each
    right row scatters its payload into the probe slot its key owns — sound
    because the flipped build side (the probe table) is duplicate-free on
    the key.  Emits the standard per-probe-row :class:`JoinResult`, so the
    orientations are interchangeable and the differential suite can pin
    byte equality across them."""
    j = shape.join
    s_table, r_table = shape.table, j.right_table
    if not _flip_applicable(shape, o.snapshot_ts):
        raise PlanError(
            "flipped-scan-join needs a duplicate-free single-word non-string "
            "probe-side key and no predicate/snapshot"
        )
    cached = _probe_build_index(s_table, j.key, j.left_proj, FLIP_JOIN_PATH)
    rv_view = engine.register(r_table, (j.key, j.right_proj))
    lv = None if cached is not None else engine.register(
        s_table, (j.left_proj, j.key)
    )
    ops = (ProjectOp(rv_view),) if lv is None else (
        ProjectOp(rv_view), ProjectOp(lv)
    )

    def launch(packed):
        r_packed = packed[0]
        rk = _join_probe_key(r_table, j.key,
                             r_packed[:, rv_view.column_words(j.key)[0]])
        rv = r_packed[:, rv_view.column_words(j.right_proj)[0]]
        if cached is not None:
            lk_sorted, slot_sorted, s_vals = cached
        else:
            l_packed = packed[1]
            lk = _join_probe_key(s_table, j.key,
                                 l_packed[:, lv.column_words(j.key)[0]])
            s_vals = l_packed[:, lv.column_words(j.left_proj)[0]]
            order = jnp.argsort(lk)
            lk_sorted, slot_sorted = lk[order], order.astype(jnp.int32)
            _insert_build_index((lk_sorted, slot_sorted, s_vals),
                                s_table, j.key, j.left_proj, FLIP_JOIN_PATH)
        n_left = s_vals.shape[0]
        if n_left == 0 or rk.shape[0] == 0:
            return JoinResult(
                s_proj=s_vals,
                r_proj=jnp.zeros(n_left, rv.dtype),
                matched=jnp.zeros(n_left, dtype=bool),
            )
        pos = jnp.clip(jnp.searchsorted(lk_sorted, rk), 0, n_left - 1)
        hit = lk_sorted[pos] == rk
        slot = jnp.where(hit, slot_sorted[pos], n_left)  # n_left drops
        r_proj = jnp.zeros(n_left, rv.dtype).at[slot].set(
            jnp.where(hit, rv, 0), mode="drop"
        )
        matched = jnp.zeros(n_left, dtype=bool).at[slot].set(
            hit, mode="drop"
        )
        return JoinResult(s_proj=s_vals, r_proj=r_proj, matched=matched)

    return PhysicalQuery(
        engine, shape, o.path, route="flipped-scan-join", cost=None,
        ops=ops, _launch=launch, _finalize=lambda t: t,
    )


def _mask_join_pred(res: JoinResult, mask: jax.Array) -> JoinResult:
    """Apply a probe-side predicate mask to a finished join result — the
    same zero-fill contract as the fused route's ``_finish_join``."""
    return JoinResult(
        s_proj=jnp.where(mask, res.s_proj, 0),
        r_proj=jnp.where(mask, res.r_proj, 0),
        matched=res.matched & mask,
    )


def _compile_join(
    engine: RelationalMemoryEngine, shape: QueryShape, o: CompileOptions
) -> PhysicalQuery:
    """Equi-join (paper §6 / §8).  On the rme path the compiler chooses
    between three physical routes by modeled bytes (:func:`_join_route`, or
    the caller's ``join_route`` override):

    * ``device-hash-join`` — the §8 offload: the build side lives as cached
      device hash buckets (one build per build-table version), and the probe
      is a Pallas grid pass over the probe rows — straight from the device
      row-store chunks when the join is alone on its table, or fused into
      the tick's shared scan when co-tick ops touch the same table.  MVCC
      visibility tests fuse in on both sides, so this is also the only route
      that can serve a ``snapshot_ts`` join — and the only one whose probe
      scan can fuse a probe-side predicate pushed below the join.
    * ``shared-scan-join`` — the paper's §6 sort-probe: RME slims both sides
      to {key, payload}, the CPU joins "once good locality has been
      achieved" (MXU/VPU-friendly static shapes; a TPU adaptation noted in
      DESIGN.md).
    * ``flipped-scan-join`` — the sort-probe with build/probe sides swapped
      (:func:`_compile_flipped_join`): the cost model's build-side choice.
    """
    j = shape.join
    s_table, r_table = shape.table, j.right_table
    path, snapshot_ts = o.path, o.snapshot_ts
    pred_col, pred_op, pred_k = _pred_args(shape.pred)

    if path == "rme":
        route = o.join_route or _join_route(engine, shape, snapshot_ts)
        if shape.pred is not None and route != "device-hash-join":
            raise PlanError(
                "a probe-side join predicate fuses into the probe scan — "
                "device-hash-join only"
            )
        if route == "flipped-scan-join":
            return _compile_flipped_join(engine, shape, o)
        if route == "device-hash-join":
            if pred_col is not None:
                _check_fused_dtypes(s_table, pred_col)
            # probe the partition cache before touching the build side at
            # all: a warm hit skips the build-side reads and the build
            partitions = _probe_build_index(
                r_table, j.key, j.right_proj, DEVICE_JOIN_PATH
            )
            sv = engine.register(s_table, (j.left_proj, j.key),
                                 snapshot_ts=snapshot_ts)
            op = JoinOp(sv, j.left_proj, j.key, r_table, j.right_proj,
                        snapshot_ts=snapshot_ts, partitions=partitions,
                        pred_col=pred_col, pred_op=pred_op, pred_k=pred_k)
            return PhysicalQuery(
                engine, shape, path, route="device-hash-join", cost=None,
                ops=(op,),
                _launch=lambda results: results[0], _finalize=lambda t: t,
            )

    # probe the sorted-index cache before touching the build side at all: a
    # warm hit skips the build-side column reads, not just the argsort
    cached = _probe_build_index(r_table, j.key, j.right_proj, path)

    if path == "rme":
        # a string key reaching this route means the device route was not
        # expressible — i.e. the two dictionaries differ — and string codes
        # cannot decode into the sort-probe's numeric key space
        if any(t.schema.column(j.key).dtype == "str"
               for t in (s_table, r_table)):
            raise PlanError(
                f"string join key {j.key!r} needs one shared table-level "
                "dictionary on both tables (device hash route)"
            )

        sv = engine.register(s_table, (j.left_proj, j.key))
        rv = None if cached is not None else engine.register(
            r_table, (j.key, j.right_proj)
        )
        ops = (ProjectOp(sv),) if rv is None else (ProjectOp(sv), ProjectOp(rv))

        def launch(packed):
            def read_build():
                r_packed = packed[1]
                return (_join_probe_key(
                            r_table, j.key,
                            r_packed[:, rv.column_words(j.key)[0]]),
                        r_packed[:, rv.column_words(j.right_proj)[0]])

            s_packed = packed[0]
            return _sort_probe(
                _join_probe_key(s_table, j.key,
                                s_packed[:, sv.column_words(j.key)[0]]),
                s_packed[:, sv.column_words(j.left_proj)[0]],
                cached, read_build, r_table, j.key, j.right_proj, path,
            )

        return PhysicalQuery(
            engine, shape, path, route="shared-scan-join", cost=None,
            ops=ops, _launch=launch, _finalize=lambda t: t,
        )

    def launch(_):
        def read_build():
            return (_host_col(r_table, o.right_colstore, j.key, path),
                    _host_col(r_table, o.right_colstore, j.right_proj, path))

        res = _sort_probe(
            _host_col(s_table, o.colstore, j.key, path),
            _host_col(s_table, o.colstore, j.left_proj, path),
            cached, read_build, r_table, j.key, j.right_proj, path,
        )
        if pred_col is not None:
            # host baselines reason in value space: the probe-side predicate
            # evaluates on the decoded column and masks the finished result
            p = _host_col(s_table, o.colstore, pred_col, path)
            res = _mask_join_pred(res, _pred_mask(p, pred_op, pred_k))
        return res

    return PhysicalQuery(
        engine, shape, path, route=f"host-{path}", cost=None, ops=(),
        _launch=launch, _finalize=lambda t: t,
    )


def _compile_multi_join(
    engine: RelationalMemoryEngine, shape: QueryShape, o: CompileOptions
) -> tuple[PhysicalQuery, tuple[tuple[str, str, int], ...]]:
    """A left-deep join chain: cost-ordered device probes over one shared
    probe view.

    The chain's joins are independent per probe row (each spec matches the
    probe row's key against its own build table), so the compiler turns the
    chain into N :class:`JoinOp`\\ s over **one** union probe view — their
    probe-side scan requests are identical, so the whole chain costs a
    single pass over {every left_proj, every key} — and orders the build
    sides by estimated cold build bytes (a warm partition cache costs
    nothing; a cold build is priced by
    :func:`~repro.kernels.rme_join.estimated_partition_bytes`).  The chosen
    order and the per-spec estimates are surfaced on
    ``PhysicalQuery.join_order`` / ``explain()``.
    """
    if o.path != "rme":
        raise PlanError(
            f"a {len(shape.joins)}-join chain compiles on the rme path only"
            " (host baselines serve single joins)"
        )
    s_table = shape.table
    for spec in shape.joins:
        if not _spec_device_expressible(s_table, spec):
            raise PlanError(
                f"join chain spec on key {spec.key!r} is not "
                "device-expressible (int32/shared-dict single-word keys, "
                "plain 4-byte payloads)"
            )
    pred_col, pred_op, pred_k = _pred_args(shape.pred)
    if pred_col is not None:
        _check_fused_dtypes(s_table, pred_col)

    def build_cost(spec) -> int:
        if _peek_build_entry(spec.right_table, spec.key, spec.right_proj,
                             DEVICE_JOIN_PATH) is not None:
            return 0
        return estimated_partition_bytes(spec.right_table.row_count)

    costs = [build_cost(s) for s in shape.joins]
    order = sorted(range(len(shape.joins)), key=lambda i: (costs[i], i))
    sv = engine.register(s_table, shape.columns, snapshot_ts=o.snapshot_ts)
    ops, slot = [], {}
    for rank, i in enumerate(order):
        spec = shape.joins[i]
        slot[i] = rank
        partitions = _probe_build_index(
            spec.right_table, spec.key, spec.right_proj, DEVICE_JOIN_PATH
        )
        ops.append(JoinOp(sv, spec.left_proj, spec.key, spec.right_table,
                          spec.right_proj, snapshot_ts=o.snapshot_ts,
                          partitions=partitions, pred_col=pred_col,
                          pred_op=pred_op, pred_k=pred_k))
    join_order = tuple(
        (shape.joins[i].key, shape.joins[i].right_proj, costs[i])
        for i in order
    )

    def finalize(results):
        matched = results[0].matched
        for r in results[1:]:
            matched = matched & r.matched
        inner = results[slot[0]]  # the client's first join: the chain's s_proj
        return MultiJoinResult(
            s_proj=jnp.where(matched, inner.s_proj, 0),
            r_projs=tuple(
                jnp.where(matched, results[slot[i]].r_proj, 0)
                for i in range(len(shape.joins))
            ),
            matched=matched,
        )

    pq = PhysicalQuery(
        engine, shape, o.path, route="device-hash-join", cost=None,
        ops=tuple(ops), _launch=lambda results: results, _finalize=finalize,
    )
    return pq, join_order


def _compile_const_empty(
    engine: RelationalMemoryEngine, shape: QueryShape, o: CompileOptions
) -> PhysicalQuery:
    """Constant-false elimination: a predicate that provably passes no row
    (:func:`repro.core.optimizer.pred_class` → ``"never"``) compiles to a
    zero-op constant result honoring the kind's contract — no scan, no
    bus-beat bytes.  Reported as the ``eliminate-empty`` pass."""
    table = shape.table
    if shape.kind == "aggregate":
        # sum/count/avg over zero rows are all 0.0 (avg guards count with 1)
        return PhysicalQuery(
            engine, shape, o.path, route="const-empty", cost=None, ops=(),
            _launch=lambda _: None, _finalize=lambda t: 0.0,
        )
    if shape.kind == "groupby":
        g = shape.group

        return PhysicalQuery(
            engine, shape, o.path, route="const-empty", cost=None, ops=(),
            _launch=lambda _: None,
            _finalize=lambda t: jnp.zeros(g.num_groups, jnp.float32),
        )
    out_words = sum(table.schema.column(c).words for c in shape.columns)

    def launch(_):
        rows = table.row_count  # at launch time, like every other route
        return (jnp.zeros((rows, out_words), jnp.int32),
                jnp.zeros(rows, dtype=bool))

    return PhysicalQuery(
        engine, shape, o.path, route="const-empty", cost=None, ops=(),
        _launch=launch, _finalize=lambda t: t,
    )


_LEGACY_COMPILE_KWARGS = (
    "path", "colstore", "right_colstore", "snapshot_ts", "join_route",
    "backend", "stream", "stream_chunk_rows",
)


def compile_plan(
    node: PlanNode | PlanBuilder | RelationalMemoryEngine,
    engine: RelationalMemoryEngine | PlanNode | PlanBuilder | None = None,
    options: CompileOptions | None = None,
    *,
    optimize: bool | None = None,
    **legacy,
) -> PhysicalQuery:
    """Lower a logical plan to a :class:`PhysicalQuery`.

    Canonical spelling::

        compile_plan(plan, engine, options=CompileOptions(...))

    ``options`` carries every compile knob (path, snapshot, join route,
    backend pin, streaming — see :class:`CompileOptions`); ``optimize=``
    is a direct escape hatch overriding ``options.optimize`` (the
    differential suites compile every case both ways and pin byte
    equality).  The legacy spelling ``compile_plan(engine, plan,
    path=..., snapshot_ts=..., ...)`` is still accepted for one release:
    the argument order is sniffed, and the old keywords are folded into a
    :class:`CompileOptions` with a :class:`DeprecationWarning`.

    With ``optimize`` on (the default), the :mod:`repro.core.optimizer`
    passes canonicalize the tree first (pushdown, pruning, predicate
    normalization, trivial-predicate elimination) and the planner adds its
    own plan-level eliminations (``eliminate-empty`` for provably-false
    predicates; the inert-predicate lowering for provably-true ones).  The
    compiled query records the before/after trees and the passes that fired
    — ``PhysicalQuery.explain()`` prints the whole decision.
    """
    if isinstance(node, RelationalMemoryEngine):  # legacy (engine, plan) order
        node, engine = engine, node
    if not isinstance(engine, RelationalMemoryEngine):
        raise TypeError(
            "compile_plan needs a plan and an engine: "
            "compile_plan(plan, engine, options=...)"
        )
    if legacy:
        unknown = set(legacy) - set(_LEGACY_COMPILE_KWARGS)
        if unknown:
            raise TypeError(
                f"compile_plan() got unexpected keyword(s) {sorted(unknown)}"
            )
        if options is not None:
            raise TypeError(
                "pass either options=CompileOptions(...) or the legacy "
                "keywords, not both"
            )
        warnings.warn(
            "compile_plan(engine, plan, path=..., snapshot_ts=..., ...) "
            "keywords are deprecated; pass "
            "options=CompileOptions(...) instead",
            DeprecationWarning, stacklevel=2,
        )
        options = CompileOptions(**legacy)
    o = options if options is not None else CompileOptions()
    if optimize is not None:
        o = dataclasses.replace(o, optimize=optimize)

    if o.path not in ("rme", "row", "col"):
        raise ValueError(f"unknown path {o.path!r}; want rme, row or col")
    if o.backend is not None and o.backend != engine.backend:
        raise PlanError(
            f"plan compiled for backend {o.backend!r} but the engine is "
            f"{engine.backend!r}"
        )
    _check_snapshot_path(o.path, o.snapshot_ts)
    logical = node.node if isinstance(node, PlanBuilder) else node
    tree, applied = (optimize_trace(logical) if o.optimize
                     else (logical, ()))
    shape = decompose(tree)
    if o.stream and shape.kind != "project":
        raise PlanError(
            f"stream=True serves projection-shaped plans only, not "
            f"{shape.kind!r} (scalar/grouped results have nothing to chunk)"
        )
    extra: list[str] = []
    join_order: tuple[tuple[str, str, int], ...] = ()
    if (o.optimize and o.path == "rme" and shape.pred is not None
            and shape.kind in ("project", "aggregate", "groupby")
            and pred_class(shape.table, shape.pred) == "never"):
        pq = _compile_const_empty(engine, shape, o)
        extra.append("eliminate-empty")
    elif shape.kind == "aggregate":
        pq = _compile_aggregate(engine, shape, o)
    elif shape.kind == "groupby":
        pq = _compile_groupby(engine, shape, o)
    elif shape.kind == "join":
        if len(shape.joins) > 1:
            pq, join_order = _compile_multi_join(engine, shape, o)
        else:
            pq = _compile_join(engine, shape, o)
    else:
        pq = _compile_project(engine, shape, o, extra)
    pq.options = o
    pq.logical = logical
    pq.optimized = tree
    pq.passes = tuple(applied) + tuple(extra)
    pq.join_order = join_order
    return pq
