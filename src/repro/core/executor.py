"""Batch executor — coalesce heterogeneous scan ops, scan each table once.

The paper's RME amortizes its one expensive DRAM pass across everything the
Fetch Units can extract from it; a query batch that touches one table several
times — whether as projections, predicated filters, fused aggregates, or
group-bys — should pay for that pass once, not once per op.
:class:`BatchExecutor` is the host-side queue that makes this shape easy to
hit: callers queue work (``add()``/``add_columns()`` for projection views,
``add_filter()``/``add_aggregate()``/``add_groupby()`` for the offload
operators, or ``add_op()`` for a pre-built scan op), then ``submit()``
coalesces everything per table and dispatches
:meth:`RelationalMemoryEngine.execute_many`, which runs the heterogeneous
one-pass kernel (``rme_scan_multi``) — one row-store stream per table, every
op's output emitted from it, bus-beat bytes charged to the shared scan
exactly once via the union geometry.

Results come back in submission order, each matching its op's single-op
contract (packed blocks, ``(packed, mask)`` pairs, ``[sum, count]`` pairs,
``(sums, counts)`` vectors), and every projection lands in the reorganization
cache, so post-batch accesses through the normal ``view.packed()`` path are
hot.

Write-path semantics: a batch always observes the table state at
``submit()`` time — the engine syncs each table's device copy first,
shipping only the write delta (appended rows as tail chunks, patched
timestamp words from the patch log), and a multi-chunk table is streamed one
fused pass per chunk with partials combined.  Ops that carry a
``snapshot_ts`` (filters, aggregates, group-bys) evaluate the MVCC
visibility test in-scan, so a pinned snapshot returns byte-identical results
no matter how many writes landed since; ops without one see every physical
row (all versions) — pass a snapshot when the table takes updates/deletes.
"""

from __future__ import annotations

from typing import Sequence

import jax

from .ephemeral import EphemeralView
from .requests import AggregateOp, FilterOp, GroupByOp, ProjectOp, ScanOp
from .table import RelationalTable


class BatchExecutor:
    """Queue of pending scan ops, flushed as one shared scan per table."""

    def __init__(self, engine):
        self.engine = engine
        self._pending: list[ScanOp] = []

    def _check_engine(self, view: EphemeralView) -> None:
        if view.engine is not self.engine:
            raise ValueError("view was registered with a different engine")

    def add_op(self, op: ScanOp) -> ScanOp:
        """Queue a pre-built scan op for the next ``submit()``."""
        if isinstance(op, (ProjectOp, FilterOp)):
            self._check_engine(op.view)
        self._pending.append(op)
        return op

    def add(self, view: EphemeralView) -> EphemeralView:
        """Queue an already-registered view (projection) for ``submit()``."""
        self._check_engine(view)
        self._pending.append(ProjectOp(view))
        return view

    def add_columns(
        self,
        table: RelationalTable,
        columns: Sequence[str],
        snapshot_ts: int | None = None,
        frame: int = 0,
    ) -> EphemeralView:
        """Register a view (configuration-port write) and queue it."""
        return self.add(
            self.engine.register(table, columns, snapshot_ts=snapshot_ts, frame=frame)
        )

    def add_filter(
        self,
        table: RelationalTable,
        columns: Sequence[str],
        pred_col: str,
        pred_op: str = "gt",
        pred_k=0,
        snapshot_ts: int | None = None,
    ) -> FilterOp:
        """Queue a fused selection+projection over ``columns``."""
        view = self.engine.register(table, columns, snapshot_ts=snapshot_ts)
        op = FilterOp(view, pred_col, pred_op, pred_k, snapshot_ts)
        self._pending.append(op)
        return op

    def add_aggregate(
        self,
        table: RelationalTable,
        agg_col: str,
        pred_col: str | None = None,
        pred_op: str = "none",
        pred_k=0,
        snapshot_ts: int | None = None,
    ) -> AggregateOp:
        """Queue a fused ``SELECT SUM(agg), COUNT(*) WHERE pred``."""
        op = AggregateOp(table, agg_col, pred_col, pred_op, pred_k, snapshot_ts)
        self._pending.append(op)
        return op

    def add_groupby(
        self,
        table: RelationalTable,
        group_col: str,
        agg_col: str,
        num_groups: int,
        pred_col: str | None = None,
        pred_op: str = "none",
        pred_k=0,
        snapshot_ts: int | None = None,
    ) -> GroupByOp:
        """Queue a fused group-by partial over a static group domain."""
        op = GroupByOp(table, group_col, agg_col, num_groups,
                       pred_col, pred_op, pred_k, snapshot_ts)
        self._pending.append(op)
        return op

    def submit(self) -> list:
        """Flush the queue: one shared scan per distinct table, results in order.

        The queue is cleared only after the batch succeeds — a failing op
        leaves everything pending so the caller can inspect or retry.
        """
        if not self._pending:
            return []
        results = self.engine.execute_many(self._pending)
        self._pending = []
        return results

    def __len__(self) -> int:
        return len(self._pending)


def materialize_batch(engine, views: Sequence[EphemeralView]) -> list[jax.Array]:
    """One-shot convenience: coalesce ``views`` per table and materialize them."""
    return engine.materialize_many(views)


def execute_batch(engine, ops: Sequence[ScanOp]) -> list:
    """One-shot convenience: coalesce heterogeneous ``ops`` and execute them."""
    return engine.execute_many(ops)
