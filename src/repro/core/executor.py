"""Scan-sharing batch executor — coalesce ephemeral views, scan each table once.

The paper's RME amortizes its one expensive DRAM pass across everything the
Fetch Units can extract from it; a query batch that registers several views
over the same table (q5 registers two, the fig9/fig10 suites run Q0–Q5
back-to-back over one relation) should pay for that pass once, not once per
view.  :class:`BatchExecutor` is the host-side queue that makes this shape
easy to hit: callers ``add()`` views (or ``add_columns()`` to register and
queue in one step), then ``submit()`` coalesces the pending views per table
and dispatches :meth:`RelationalMemoryEngine.materialize_many`, which runs the
multi-output kernel — one row-store stream per table, every view's packed
block emitted from it, bus-beat bytes charged to the shared scan exactly once.

Results come back in submission order, and every view lands in the
reorganization cache, so post-batch accesses through the normal
``view.packed()`` path are hot.
"""

from __future__ import annotations

from typing import Sequence

import jax

from .ephemeral import EphemeralView
from .table import RelationalTable


class BatchExecutor:
    """Queue of pending ephemeral views, flushed as one shared scan per table."""

    def __init__(self, engine):
        self.engine = engine
        self._pending: list[EphemeralView] = []

    def add(self, view: EphemeralView) -> EphemeralView:
        """Queue an already-registered view for the next ``submit()``."""
        if view.engine is not self.engine:
            raise ValueError("view was registered with a different engine")
        self._pending.append(view)
        return view

    def add_columns(
        self,
        table: RelationalTable,
        columns: Sequence[str],
        snapshot_ts: int | None = None,
        frame: int = 0,
    ) -> EphemeralView:
        """Register a view (configuration-port write) and queue it."""
        return self.add(
            self.engine.register(table, columns, snapshot_ts=snapshot_ts, frame=frame)
        )

    def submit(self) -> list[jax.Array]:
        """Flush the queue: one shared scan per distinct table, results in order.

        The queue is cleared only after the batch succeeds — a failing view
        leaves everything pending so the caller can inspect or retry.
        """
        if not self._pending:
            return []
        results = self.engine.materialize_many(self._pending)
        self._pending = []
        return results

    def __len__(self) -> int:
        return len(self._pending)


def materialize_batch(engine, views: Sequence[EphemeralView]) -> list[jax.Array]:
    """One-shot convenience: coalesce ``views`` per table and materialize them."""
    return engine.materialize_many(views)
