"""Logical query plans — the declarative layer the paper's §8 argues for.

The closing argument of the paper is that native column access "can vastly
simplify the software logic": once the RME serves any column group at row-store
cost, the *software* no longer hand-routes each query — it states the query
shape and lets a planner pick the datapath.  This module is that statement
layer: a small immutable operator tree (Scan / Filter / Project / Aggregate /
GroupBy / Join) plus a fluent :func:`plan` builder, deliberately scoped to the
query shapes the engine can serve natively (the Relational Memory Benchmark,
Listing 5 — Q0 through Q5).

Nothing here executes.  :func:`repro.core.planner.compile_plan` lowers a tree
to a :class:`~repro.core.planner.PhysicalQuery` routed through fused offload
kernels, shared-scan materialization, or host-side fallback; the
:class:`~repro.serve.query_server.QueryServer` admission-queues trees from many
clients and coalesces their scans.  Plans are backend-agnostic: the same tree
compiles unchanged for the single-device engine and the mesh-sharded backend
(``compile_plan(..., backend=...)`` only *validates* the pairing — see
:class:`repro.core.distributed.ShardedEngine`).  :func:`decompose` is the shared front end:
it flattens a tree into the canonical ``QueryShape`` both consumers route on,
rejecting shapes the physical layer cannot serve (:class:`PlanError`).
"""

from __future__ import annotations

import dataclasses

from .table import RelationalTable

AGG_OPS = ("sum", "count", "avg")
GROUP_OPS = ("sum", "avg")
PRED_OPS = ("gt", "lt")


class PlanError(ValueError):
    """A logical plan the physical layer cannot serve (shape, ops, columns)."""


# ------------------------------------------------------------------ nodes
@dataclasses.dataclass(frozen=True, eq=False)
class PlanNode:
    """Base of the logical operator tree. Immutable; identity comparison."""

    def children(self) -> tuple["PlanNode", ...]:
        return ()

    def map_children(self, fn) -> "PlanNode":
        """Rebuild this node with ``fn`` applied to each child.

        Returns ``self`` unchanged when ``fn`` is the identity on every
        child — rewrite passes rely on that to detect fixpoints cheaply.
        This is the single structural hook :mod:`repro.core.optimizer`
        builds its visitor/rewriter protocol on.
        """
        return self


@dataclasses.dataclass(frozen=True, eq=False)
class Scan(PlanNode):
    """Leaf: the row store of one relation (always a row store, paper §4)."""

    table: RelationalTable


@dataclasses.dataclass(frozen=True, eq=False)
class Filter(PlanNode):
    """``WHERE col <op> k`` — one predicate, matching the fused kernels."""

    child: PlanNode
    col: str
    op: str
    k: int | float = 0

    def __post_init__(self):
        if self.op not in PRED_OPS:
            raise PlanError(f"filter op {self.op!r}; want one of {PRED_OPS}")

    def children(self) -> tuple[PlanNode, ...]:
        return (self.child,)

    def map_children(self, fn) -> PlanNode:
        child = fn(self.child)
        return self if child is self.child else dataclasses.replace(self, child=child)


@dataclasses.dataclass(frozen=True, eq=False)
class Project(PlanNode):
    """``SELECT col, ...`` — a column group (an ephemeral-view registration)."""

    child: PlanNode
    columns: tuple[str, ...]

    def __post_init__(self):
        if not self.columns:
            raise PlanError("projection needs at least one column")
        if len(set(self.columns)) != len(self.columns):
            raise PlanError(f"duplicate columns in projection {self.columns}")

    def children(self) -> tuple[PlanNode, ...]:
        return (self.child,)

    def map_children(self, fn) -> PlanNode:
        child = fn(self.child)
        return self if child is self.child else dataclasses.replace(self, child=child)


@dataclasses.dataclass(frozen=True, eq=False)
class Aggregate(PlanNode):
    """``SELECT <op>(col)`` — a scalar the engine can answer near-memory."""

    child: PlanNode
    col: str
    op: str = "sum"

    def __post_init__(self):
        if self.op not in AGG_OPS:
            raise PlanError(f"aggregate op {self.op!r}; want one of {AGG_OPS}")

    def children(self) -> tuple[PlanNode, ...]:
        return (self.child,)

    def map_children(self, fn) -> PlanNode:
        child = fn(self.child)
        return self if child is self.child else dataclasses.replace(self, child=child)


@dataclasses.dataclass(frozen=True, eq=False)
class GroupBy(PlanNode):
    """``SELECT <op>(agg) ... GROUP BY group`` over a static group domain."""

    child: PlanNode
    group: str
    agg: str
    op: str = "avg"
    num_groups: int = 64

    def __post_init__(self):
        if self.op not in GROUP_OPS:
            raise PlanError(f"group-by op {self.op!r}; want one of {GROUP_OPS}")
        if self.num_groups <= 0:
            raise PlanError("num_groups must be positive (static accumulators)")

    def children(self) -> tuple[PlanNode, ...]:
        return (self.child,)

    def map_children(self, fn) -> PlanNode:
        child = fn(self.child)
        return self if child is self.child else dataclasses.replace(self, child=child)


@dataclasses.dataclass(frozen=True, eq=False)
class Join(PlanNode):
    """``SELECT L.left_proj, R.right_proj FROM L JOIN R ON L.key = R.key``.

    The build side ``right`` is assumed duplicate-free on ``key`` (primary
    key), as in the paper's setup; the build side must be a plain scan — the
    RME's role is slimming each side to {key, payload} before the CPU joins.
    The probe side may be another Join (a left-deep chain the planner orders
    by cost) or a Filter over the probe scan (a probe-side predicate fused
    into the probe pass).
    """

    left: PlanNode
    right: PlanNode
    key: str
    left_proj: str
    right_proj: str

    def children(self) -> tuple[PlanNode, ...]:
        return (self.left, self.right)

    def map_children(self, fn) -> PlanNode:
        left, right = fn(self.left), fn(self.right)
        if left is self.left and right is self.right:
            return self
        return dataclasses.replace(self, left=left, right=right)


# ---------------------------------------------------------------- builder
class PlanBuilder:
    """Fluent plan construction: ``plan(t).filter("A3", "gt", 0).sum("A1")``.

    Each method returns a new builder over an extended tree; ``build()``
    returns the root node.  Builders are accepted anywhere a node is (the
    compiler and server call ``build()`` themselves).
    """

    def __init__(self, node: PlanNode):
        self.node = node

    def build(self) -> PlanNode:
        return self.node

    def filter(self, col: str, op: str, k: int | float = 0) -> "PlanBuilder":
        return PlanBuilder(Filter(self.node, col, op, k))

    def project(self, *columns: str) -> "PlanBuilder":
        return PlanBuilder(Project(self.node, tuple(columns)))

    def aggregate(self, col: str, op: str = "sum") -> "PlanBuilder":
        return PlanBuilder(Aggregate(self.node, col, op))

    def sum(self, col: str) -> "PlanBuilder":
        return self.aggregate(col, "sum")

    def avg(self, col: str) -> "PlanBuilder":
        return self.aggregate(col, "avg")

    def count(self, col: str) -> "PlanBuilder":
        return self.aggregate(col, "count")

    def groupby(
        self, group: str, agg: str, op: str = "avg", num_groups: int = 64
    ) -> "PlanBuilder":
        return PlanBuilder(GroupBy(self.node, group, agg, op, num_groups))

    def join(
        self,
        right: "PlanBuilder | PlanNode | RelationalTable",
        key: str,
        left_proj: str,
        right_proj: str,
    ) -> "PlanBuilder":
        if isinstance(right, RelationalTable):
            right = Scan(right)
        elif isinstance(right, PlanBuilder):
            right = right.node
        return PlanBuilder(Join(self.node, right, key, left_proj, right_proj))

    def __repr__(self) -> str:  # pragma: no cover
        return f"PlanBuilder({describe(self.node)})"


def plan(table: RelationalTable) -> PlanBuilder:
    """Start a plan over ``table``'s row store.

    Plans are pure descriptions — nothing reads the table until
    :func:`repro.core.planner.compile_plan` lowers the tree and the resulting
    :class:`~repro.core.planner.PhysicalQuery` runs.  Execution therefore
    observes the table state (and, on the rme path, the optional
    ``snapshot_ts`` passed to ``compile_plan``) at *run* time: through the
    :class:`~repro.serve.query_server.QueryServer` that means the post-write
    snapshot of the tick that serves the plan, while writes that land after
    the tick cost the engine only their delta (tail-chunk uploads, timestamp
    patches) — never a re-materialization of the plan's inputs.
    """
    return PlanBuilder(Scan(table))


# ----------------------------------------------------------- decomposition
@dataclasses.dataclass(frozen=True)
class Predicate:
    """The single fused predicate the kernels evaluate in-scan."""

    col: str
    op: str
    k: int | float


@dataclasses.dataclass(frozen=True)
class JoinSpec:
    right_table: RelationalTable
    key: str
    left_proj: str
    right_proj: str


@dataclasses.dataclass(frozen=True, eq=False)
class QueryShape:
    """Canonical flattened query: what the physical layer routes on.

    ``kind`` is one of ``"project"`` (with or without a fused predicate),
    ``"aggregate"``, ``"groupby"``, ``"join"``.  ``columns`` is the column
    group the rme datapath would enable for this query — the planner costs
    and the server coalesces on exactly this set.  ``joins`` carries every
    spec of a left-deep join chain innermost-first (``join`` aliases the
    first spec for single-join consumers); for ``kind == "join"`` the
    optional ``pred`` is a probe-side predicate fused into the probe pass.
    """

    kind: str
    table: RelationalTable
    columns: tuple[str, ...]
    pred: Predicate | None = None
    agg: Aggregate | None = None
    group: GroupBy | None = None
    join: JoinSpec | None = None
    joins: tuple[JoinSpec, ...] = ()


def _base_scan(node: PlanNode) -> Scan:
    if not isinstance(node, Scan):
        raise PlanError(f"expected a plain Scan, got {type(node).__name__}")
    return node


def _ordered(table: RelationalTable, columns) -> tuple[str, ...]:
    """Physical (byte-offset) order — the packed layout the RME emits."""
    for name in columns:
        table.schema.column(name)  # raises KeyError for unknown columns
    return tuple(sorted(set(columns), key=table.schema.byte_offset))


def _collapse_filters(table: RelationalTable, filters) -> Predicate | None:
    """Collapse a stack of Filters into the single fused predicate.

    Identical spellings collapse; two *distinct* predicates still exceed
    what the fused kernels evaluate and raise :class:`PlanError`.
    """
    preds: list[Predicate] = []
    for f in filters:
        table.schema.column(f.col)  # admission-time check, like _ordered
        preds.append(Predicate(f.col, f.op, f.k))
    uniq = list(dict.fromkeys(preds))
    if len(uniq) > 1:
        raise PlanError("at most one distinct Filter per plan (fused predicate)")
    return uniq[0] if uniq else None


def _decompose_join(root: Join, outer_filters: list[Filter]) -> QueryShape:
    """Flatten a left-deep join chain (plus probe-side Filters) to a shape."""
    specs: list[JoinSpec] = []
    filters = list(outer_filters)
    node: PlanNode = root
    while True:
        if isinstance(node, Join):
            right = _base_scan(node.right)
            _ordered(right.table, (node.key, node.right_proj))  # validate names
            specs.append(
                JoinSpec(right.table, node.key, node.left_proj, node.right_proj)
            )
            node = node.left
        elif isinstance(node, Filter):
            filters.append(node)
            node = node.child
        elif isinstance(node, Scan):
            break
        else:
            raise PlanError(f"expected a plain Scan, got {type(node).__name__}")
    table = node.table
    specs.reverse()  # innermost (first-applied) join first
    for spec in specs:
        _ordered(table, (spec.left_proj, spec.key))  # probe names, base table
    pred = _collapse_filters(table, filters)
    cols = _ordered(
        table, tuple(c for s in specs for c in (s.left_proj, s.key))
    )
    return QueryShape(
        kind="join", table=table, columns=cols, pred=pred,
        join=specs[0], joins=tuple(specs),
    )


def decompose(node: PlanNode | PlanBuilder) -> QueryShape:
    """Flatten a plan tree into the canonical :class:`QueryShape`.

    Accepted shapes (the Relational Memory Benchmark queries, plus the
    orderings rewrite passes produce):
    ``[Aggregate|GroupBy]? <- (Project|Filter)* <- Scan`` — Project and
    Filter commute freely and names always resolve against the base scan's
    schema, so every reordering of the same operators yields the same shape
    — or a left-deep Join chain ``Filter* <- Join(... Join(Filter* <- Scan,
    Scan) ..., Scan)``.  Repeated identical Filters collapse to the single
    fused predicate (two distinct predicates raise); nested Projects keep
    the outermost as the output group; Projects under Aggregate/GroupBy
    widen the scanned column group (the optimizer's pruning pass removes
    them).
    """
    if isinstance(node, PlanBuilder):
        node = node.node

    agg: Aggregate | None = None
    group: GroupBy | None = None
    if isinstance(node, Aggregate):
        agg, node = node, node.child
    elif isinstance(node, GroupBy):
        group, node = node, node.child

    projects: list[Project] = []
    filters: list[Filter] = []
    while not isinstance(node, (Scan, Join)):
        if isinstance(node, Project):
            projects.append(node)
            node = node.child
        elif isinstance(node, Filter):
            filters.append(node)
            node = node.child
        elif isinstance(node, (Aggregate, GroupBy)):
            raise PlanError(
                f"{type(node).__name__} must be the plan root, not an input"
            )
        else:
            raise PlanError(f"unsupported plan node {type(node).__name__}")

    if isinstance(node, Join):
        if agg is not None or group is not None:
            raise PlanError(
                f"{'Aggregate' if agg is not None else 'GroupBy'} over a Join"
                " is not supported"
            )
        if projects:
            raise PlanError("Project above a Join is not supported")
        return _decompose_join(node, filters)
    table = node.table
    pred = _collapse_filters(table, filters)
    proj_cols = tuple(c for p in projects for c in p.columns)

    if agg is not None:
        cols = _ordered(
            table, (agg.col,) + ((pred.col,) if pred else ()) + proj_cols
        )
        return QueryShape("aggregate", table, cols, pred=pred, agg=agg)
    if group is not None:
        cols = _ordered(
            table,
            (group.group, group.agg) + ((pred.col,) if pred else ()) + proj_cols,
        )
        return QueryShape("groupby", table, cols, pred=pred, group=group)
    # the scan must also read the predicate column, but the *output* group is
    # the (outermost) projection — columns is what the fused filter emits
    out = projects[0].columns if projects else table.schema.names
    _ordered(table, proj_cols)  # inner projections: validate, outermost wins
    return QueryShape("project", table, _ordered(table, out), pred=pred)


def describe(node: PlanNode | PlanBuilder) -> str:
    """One-line pretty form, root first: ``Sum(A1) <- Filter(A3 gt 0) <- Scan``."""
    if isinstance(node, PlanBuilder):
        node = node.node
    if isinstance(node, Scan):
        return f"Scan[{node.table.row_count}x{len(node.table.schema.columns)}]"
    if isinstance(node, Filter):
        return f"Filter({node.col} {node.op} {node.k}) <- {describe(node.child)}"
    if isinstance(node, Project):
        return f"Project({','.join(node.columns)}) <- {describe(node.child)}"
    if isinstance(node, Aggregate):
        return f"{node.op.title()}({node.col}) <- {describe(node.child)}"
    if isinstance(node, GroupBy):
        return (
            f"GroupBy({node.group}, {node.op}({node.agg}), G={node.num_groups})"
            f" <- {describe(node.child)}"
        )
    if isinstance(node, Join):
        return (
            f"Join(on {node.key}: {node.left_proj}, {node.right_proj})"
            f" <- [{describe(node.left)} | {describe(node.right)}]"
        )
    return type(node).__name__
