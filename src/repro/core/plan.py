"""Logical query plans — the declarative layer the paper's §8 argues for.

The closing argument of the paper is that native column access "can vastly
simplify the software logic": once the RME serves any column group at row-store
cost, the *software* no longer hand-routes each query — it states the query
shape and lets a planner pick the datapath.  This module is that statement
layer: a small immutable operator tree (Scan / Filter / Project / Aggregate /
GroupBy / Join) plus a fluent :func:`plan` builder, deliberately scoped to the
query shapes the engine can serve natively (the Relational Memory Benchmark,
Listing 5 — Q0 through Q5).

Nothing here executes.  :func:`repro.core.planner.compile_plan` lowers a tree
to a :class:`~repro.core.planner.PhysicalQuery` routed through fused offload
kernels, shared-scan materialization, or host-side fallback; the
:class:`~repro.serve.query_server.QueryServer` admission-queues trees from many
clients and coalesces their scans.  Plans are backend-agnostic: the same tree
compiles unchanged for the single-device engine and the mesh-sharded backend
(``compile_plan(..., backend=...)`` only *validates* the pairing — see
:class:`repro.core.distributed.ShardedEngine`).  :func:`decompose` is the shared front end:
it flattens a tree into the canonical ``QueryShape`` both consumers route on,
rejecting shapes the physical layer cannot serve (:class:`PlanError`).
"""

from __future__ import annotations

import dataclasses

from .table import RelationalTable

AGG_OPS = ("sum", "count", "avg")
GROUP_OPS = ("sum", "avg")
PRED_OPS = ("gt", "lt")


class PlanError(ValueError):
    """A logical plan the physical layer cannot serve (shape, ops, columns)."""


# ------------------------------------------------------------------ nodes
@dataclasses.dataclass(frozen=True, eq=False)
class PlanNode:
    """Base of the logical operator tree. Immutable; identity comparison."""

    def children(self) -> tuple["PlanNode", ...]:
        return ()


@dataclasses.dataclass(frozen=True, eq=False)
class Scan(PlanNode):
    """Leaf: the row store of one relation (always a row store, paper §4)."""

    table: RelationalTable


@dataclasses.dataclass(frozen=True, eq=False)
class Filter(PlanNode):
    """``WHERE col <op> k`` — one predicate, matching the fused kernels."""

    child: PlanNode
    col: str
    op: str
    k: int | float = 0

    def __post_init__(self):
        if self.op not in PRED_OPS:
            raise PlanError(f"filter op {self.op!r}; want one of {PRED_OPS}")

    def children(self) -> tuple[PlanNode, ...]:
        return (self.child,)


@dataclasses.dataclass(frozen=True, eq=False)
class Project(PlanNode):
    """``SELECT col, ...`` — a column group (an ephemeral-view registration)."""

    child: PlanNode
    columns: tuple[str, ...]

    def __post_init__(self):
        if not self.columns:
            raise PlanError("projection needs at least one column")
        if len(set(self.columns)) != len(self.columns):
            raise PlanError(f"duplicate columns in projection {self.columns}")

    def children(self) -> tuple[PlanNode, ...]:
        return (self.child,)


@dataclasses.dataclass(frozen=True, eq=False)
class Aggregate(PlanNode):
    """``SELECT <op>(col)`` — a scalar the engine can answer near-memory."""

    child: PlanNode
    col: str
    op: str = "sum"

    def __post_init__(self):
        if self.op not in AGG_OPS:
            raise PlanError(f"aggregate op {self.op!r}; want one of {AGG_OPS}")

    def children(self) -> tuple[PlanNode, ...]:
        return (self.child,)


@dataclasses.dataclass(frozen=True, eq=False)
class GroupBy(PlanNode):
    """``SELECT <op>(agg) ... GROUP BY group`` over a static group domain."""

    child: PlanNode
    group: str
    agg: str
    op: str = "avg"
    num_groups: int = 64

    def __post_init__(self):
        if self.op not in GROUP_OPS:
            raise PlanError(f"group-by op {self.op!r}; want one of {GROUP_OPS}")
        if self.num_groups <= 0:
            raise PlanError("num_groups must be positive (static accumulators)")

    def children(self) -> tuple[PlanNode, ...]:
        return (self.child,)


@dataclasses.dataclass(frozen=True, eq=False)
class Join(PlanNode):
    """``SELECT L.left_proj, R.right_proj FROM L JOIN R ON L.key = R.key``.

    The build side ``right`` is assumed duplicate-free on ``key`` (primary
    key), as in the paper's setup; both sides must be plain scans — the RME's
    role is slimming each side to {key, payload} before the CPU joins.
    """

    left: PlanNode
    right: PlanNode
    key: str
    left_proj: str
    right_proj: str

    def children(self) -> tuple[PlanNode, ...]:
        return (self.left, self.right)


# ---------------------------------------------------------------- builder
class PlanBuilder:
    """Fluent plan construction: ``plan(t).filter("A3", "gt", 0).sum("A1")``.

    Each method returns a new builder over an extended tree; ``build()``
    returns the root node.  Builders are accepted anywhere a node is (the
    compiler and server call ``build()`` themselves).
    """

    def __init__(self, node: PlanNode):
        self.node = node

    def build(self) -> PlanNode:
        return self.node

    def filter(self, col: str, op: str, k: int | float = 0) -> "PlanBuilder":
        return PlanBuilder(Filter(self.node, col, op, k))

    def project(self, *columns: str) -> "PlanBuilder":
        return PlanBuilder(Project(self.node, tuple(columns)))

    def aggregate(self, col: str, op: str = "sum") -> "PlanBuilder":
        return PlanBuilder(Aggregate(self.node, col, op))

    def sum(self, col: str) -> "PlanBuilder":
        return self.aggregate(col, "sum")

    def avg(self, col: str) -> "PlanBuilder":
        return self.aggregate(col, "avg")

    def count(self, col: str) -> "PlanBuilder":
        return self.aggregate(col, "count")

    def groupby(
        self, group: str, agg: str, op: str = "avg", num_groups: int = 64
    ) -> "PlanBuilder":
        return PlanBuilder(GroupBy(self.node, group, agg, op, num_groups))

    def join(
        self,
        right: "PlanBuilder | PlanNode | RelationalTable",
        key: str,
        left_proj: str,
        right_proj: str,
    ) -> "PlanBuilder":
        if isinstance(right, RelationalTable):
            right = Scan(right)
        elif isinstance(right, PlanBuilder):
            right = right.node
        return PlanBuilder(Join(self.node, right, key, left_proj, right_proj))

    def __repr__(self) -> str:  # pragma: no cover
        return f"PlanBuilder({describe(self.node)})"


def plan(table: RelationalTable) -> PlanBuilder:
    """Start a plan over ``table``'s row store.

    Plans are pure descriptions — nothing reads the table until
    :func:`repro.core.planner.compile_plan` lowers the tree and the resulting
    :class:`~repro.core.planner.PhysicalQuery` runs.  Execution therefore
    observes the table state (and, on the rme path, the optional
    ``snapshot_ts`` passed to ``compile_plan``) at *run* time: through the
    :class:`~repro.serve.query_server.QueryServer` that means the post-write
    snapshot of the tick that serves the plan, while writes that land after
    the tick cost the engine only their delta (tail-chunk uploads, timestamp
    patches) — never a re-materialization of the plan's inputs.
    """
    return PlanBuilder(Scan(table))


# ----------------------------------------------------------- decomposition
@dataclasses.dataclass(frozen=True)
class Predicate:
    """The single fused predicate the kernels evaluate in-scan."""

    col: str
    op: str
    k: int | float


@dataclasses.dataclass(frozen=True)
class JoinSpec:
    right_table: RelationalTable
    key: str
    left_proj: str
    right_proj: str


@dataclasses.dataclass(frozen=True, eq=False)
class QueryShape:
    """Canonical flattened query: what the physical layer routes on.

    ``kind`` is one of ``"project"`` (with or without a fused predicate),
    ``"aggregate"``, ``"groupby"``, ``"join"``.  ``columns`` is the column
    group the rme datapath would enable for this query — the planner costs
    and the server coalesces on exactly this set.
    """

    kind: str
    table: RelationalTable
    columns: tuple[str, ...]
    pred: Predicate | None = None
    agg: Aggregate | None = None
    group: GroupBy | None = None
    join: JoinSpec | None = None


def _base_scan(node: PlanNode) -> Scan:
    if not isinstance(node, Scan):
        raise PlanError(f"expected a plain Scan, got {type(node).__name__}")
    return node


def _ordered(table: RelationalTable, columns) -> tuple[str, ...]:
    """Physical (byte-offset) order — the packed layout the RME emits."""
    for name in columns:
        table.schema.column(name)  # raises KeyError for unknown columns
    return tuple(sorted(set(columns), key=table.schema.byte_offset))


def decompose(node: PlanNode | PlanBuilder) -> QueryShape:
    """Flatten a plan tree into the canonical :class:`QueryShape`.

    Accepted shapes (exactly the Relational Memory Benchmark queries):
    ``[Aggregate|GroupBy]? <- Project? <- Filter? <- Scan`` with Project and
    Filter commuting, or ``Join(Scan, Scan)``.  At most one Filter (the fused
    kernels evaluate a single predicate) and at most one Project.
    """
    if isinstance(node, PlanBuilder):
        node = node.node
    if isinstance(node, Join):
        left = _base_scan(node.left)
        right = _base_scan(node.right)
        cols = _ordered(left.table, (node.left_proj, node.key))
        _ordered(right.table, (node.key, node.right_proj))  # validate names
        return QueryShape(
            kind="join",
            table=left.table,
            columns=cols,
            join=JoinSpec(right.table, node.key, node.left_proj, node.right_proj),
        )

    agg: Aggregate | None = None
    group: GroupBy | None = None
    if isinstance(node, Aggregate):
        agg, node = node, node.child
    elif isinstance(node, GroupBy):
        group, node = node, node.child

    project: Project | None = None
    pred: Predicate | None = None
    while not isinstance(node, Scan):
        if isinstance(node, Project):
            if project is not None:
                raise PlanError("at most one Project per plan")
            project, node = node, node.child
        elif isinstance(node, Filter):
            if pred is not None:
                raise PlanError("at most one Filter per plan (fused predicate)")
            pred, node = Predicate(node.col, node.op, node.k), node.child
        elif isinstance(node, (Aggregate, GroupBy, Join)):
            raise PlanError(
                f"{type(node).__name__} must be the plan root, not an input"
            )
        else:
            raise PlanError(f"unsupported plan node {type(node).__name__}")
    table = node.table

    if agg is not None:
        cols = _ordered(table, (agg.col,) + ((pred.col,) if pred else ()))
        if project is not None:
            raise PlanError("Project under Aggregate is redundant; drop it")
        return QueryShape("aggregate", table, cols, pred=pred, agg=agg)
    if group is not None:
        if project is not None:
            raise PlanError("Project under GroupBy is redundant; drop it")
        cols = _ordered(
            table,
            (group.group, group.agg) + ((pred.col,) if pred else ()),
        )
        return QueryShape("groupby", table, cols, pred=pred, group=group)
    out = project.columns if project is not None else table.schema.names
    if pred is not None:
        table.schema.column(pred.col)  # admission-time check, like _ordered
    # the scan must also read the predicate column, but the *output* group is
    # the projection — columns is what the fused filter kernel emits
    return QueryShape("project", table, _ordered(table, out), pred=pred)


def describe(node: PlanNode | PlanBuilder) -> str:
    """One-line pretty form, root first: ``Sum(A1) <- Filter(A3 gt 0) <- Scan``."""
    if isinstance(node, PlanBuilder):
        node = node.node
    if isinstance(node, Scan):
        return f"Scan[{node.table.row_count}x{len(node.table.schema.columns)}]"
    if isinstance(node, Filter):
        return f"Filter({node.col} {node.op} {node.k}) <- {describe(node.child)}"
    if isinstance(node, Project):
        return f"Project({','.join(node.columns)}) <- {describe(node.child)}"
    if isinstance(node, Aggregate):
        return f"{node.op.title()}({node.col}) <- {describe(node.child)}"
    if isinstance(node, GroupBy):
        return (
            f"GroupBy({node.group}, {node.op}({node.agg}), G={node.num_groups})"
            f" <- {describe(node.child)}"
        )
    if isinstance(node, Join):
        return (
            f"Join(on {node.key}: {node.left_proj}, {node.right_proj})"
            f" <- [{describe(node.left)} | {describe(node.right)}]"
        )
    return type(node).__name__
