"""Distributed relational operators — rows sharded like parallel DRAM banks.

The paper exploits "the inherent parallelism of memory cells — e.g., by
issuing outstanding parallel requests to separate DRAM banks" (§1).  At
cluster scale the analogous parallelism is *row-range sharding across chips*:
each device owns a contiguous row range of the table (a "bank"), runs the RME
datapath locally, and only reduced results (scalars, group accumulators,
broadcast build sides) cross the interconnect.

Everything here is ``shard_map`` over an explicit mesh axis so the same code
lowers for the 1-device CPU test run, the 256-chip single-pod mesh, and the
512-chip multi-pod mesh (the dry-run exercises the latter two).
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from repro.compat import shard_map
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.kernels import ref as R
from repro.kernels.rme_project import project_xla

from .schema import TableGeometry

# The engine datapath inside shard_map is the XLA fused-gather revision:
# Pallas interpret-mode kernels don't lower under SPMD partitioning on CPU,
# and on real TPUs the same call sites swap in the MLP kernel.


def _row_axes(mesh: Mesh, axes: str | Sequence[str]) -> tuple[str, ...]:
    return (axes,) if isinstance(axes, str) else tuple(axes)


def pad_rows_to(words: np.ndarray | jax.Array, shards: int) -> jax.Array:
    """Pad the row count to a multiple of ``shards`` (padded rows are zero;
    zero rows are invalid under MVCC since ts_begin=0 <= ts < ts_end=0 fails,
    and aggregates mask them via the explicit row-count bound)."""
    n = words.shape[0]
    pad = (-n) % shards
    if pad:
        words = jnp.concatenate(
            [jnp.asarray(words), jnp.zeros((pad, words.shape[1]), words.dtype)], 0
        )
    return jnp.asarray(words)


def dist_project(
    words: jax.Array, geom: TableGeometry, mesh: Mesh, axes: str | Sequence[str] = "data"
) -> jax.Array:
    """Row-sharded packed projection: each shard reorganizes its own bank.

    No cross-device traffic at all — the reorganized view stays sharded the
    same way the base table is, ready for downstream sharded consumers.
    """
    axes = _row_axes(mesh, axes)

    def local(w):
        return project_xla(w, geom)

    return shard_map(
        local, mesh=mesh, in_specs=P(axes, None), out_specs=P(axes, None)
    )(words)


def dist_aggregate(
    words: jax.Array,
    mesh: Mesh,
    agg_word: int,
    agg_dtype: str = "int32",
    pred_word: int = 0,
    pred_dtype: str = "int32",
    pred_op: str = "none",
    pred_k=0,
    valid_rows: int | None = None,
    axes: str | Sequence[str] = "data",
) -> jax.Array:
    """Distributed Q0/Q3: per-bank fused masked sum, one scalar ``psum``.

    ``valid_rows`` masks padding introduced by :func:`pad_rows_to`.
    Returns float32 ``[sum, count]`` replicated on every device.
    """
    axes = _row_axes(mesh, axes)
    n_total = words.shape[0]
    n_valid = n_total if valid_rows is None else valid_rows

    def local(w):
        shard_rows = w.shape[0]
        idx = jax.lax.axis_index(axes)
        base = idx * shard_rows
        rows = base + jnp.arange(shard_rows)
        valid = rows < n_valid
        vals = R._decode(w[:, agg_word], agg_dtype).astype(jnp.float32)
        mask = R._predicate(R._decode(w[:, pred_word], pred_dtype), pred_op, pred_k)
        mask = mask & valid
        part = jnp.stack([jnp.sum(jnp.where(mask, vals, 0.0)), jnp.sum(mask)])
        return jax.lax.psum(part, axes)

    return shard_map(
        local, mesh=mesh, in_specs=P(axes, None), out_specs=P()
    )(words)


def dist_groupby(
    words: jax.Array,
    mesh: Mesh,
    group_word: int,
    agg_word: int,
    num_groups: int,
    agg_dtype: str = "int32",
    pred_word: int | None = None,
    pred_dtype: str = "int32",
    pred_op: str = "none",
    pred_k=0,
    valid_rows: int | None = None,
    axes: str | Sequence[str] = "data",
) -> tuple[jax.Array, jax.Array]:
    """Distributed Q4: per-bank one-hot contraction, (G,2) ``psum`` combine."""
    axes = _row_axes(mesh, axes)
    n_valid = words.shape[0] if valid_rows is None else valid_rows

    def local(w):
        shard_rows = w.shape[0]
        idx = jax.lax.axis_index(axes)
        rows = idx * shard_rows + jnp.arange(shard_rows)
        valid = rows < n_valid
        g = jnp.remainder(w[:, group_word], num_groups)
        vals = R._decode(w[:, agg_word], agg_dtype).astype(jnp.float32)
        mask = valid
        if pred_word is not None:
            mask = mask & R._predicate(
                R._decode(w[:, pred_word], pred_dtype), pred_op, pred_k
            )
        fm = mask.astype(jnp.float32)
        onehot = (g[:, None] == jnp.arange(num_groups)[None, :]).astype(jnp.float32)
        contrib = jnp.stack([vals * fm, fm], axis=1)
        acc = jax.lax.dot_general(
            onehot, contrib, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return jax.lax.psum(acc, axes)

    out = shard_map(local, mesh=mesh, in_specs=P(axes, None), out_specs=P())(words)
    return out[:, 0], out[:, 1]


def dist_join(
    s_words: jax.Array,
    r_words: jax.Array,
    mesh: Mesh,
    s_geom: TableGeometry,
    r_geom: TableGeometry,
    s_key_word: int,
    s_val_word: int,
    r_key_word: int,
    r_val_word: int,
    axes: str | Sequence[str] = "data",
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Distributed broadcast equi-join.

    Both tables are row-sharded.  Each shard RME-projects its slim {key, val}
    pair; the (small) build side R is all-gathered — the only collective — and
    every shard probes its local S rows.  Word offsets index the *packed*
    projected views.  Returns sharded (s_val, matched r_val, match mask).
    """
    axes = _row_axes(mesh, axes)

    def local(s_w, r_w):
        s_p = project_xla(s_w, s_geom)
        r_p = project_xla(r_w, r_geom)
        r_all = jax.lax.all_gather(r_p, axes, tiled=True)  # broadcast build side
        r_key, r_val = r_all[:, r_key_word], r_all[:, r_val_word]
        s_key, s_val = s_p[:, s_key_word], s_p[:, s_val_word]
        order = jnp.argsort(r_key)
        rk, rv = r_key[order], r_val[order]
        pos = jnp.clip(jnp.searchsorted(rk, s_key), 0, rk.shape[0] - 1)
        matched = rk[pos] == s_key
        return s_val, jnp.where(matched, rv[pos], 0), matched

    return shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axes, None), P(axes, None)),
        out_specs=(P(axes), P(axes), P(axes)),
    )(s_words, r_words)


def table_sharding(mesh: Mesh, axes: str | Sequence[str] = "data") -> NamedSharding:
    """Row-range sharding for a table buffer (rows over the data axis)."""
    return NamedSharding(mesh, P(_row_axes(mesh, axes), None))
