"""Mesh-sharded serving — rows sharded like parallel DRAM banks.

The paper exploits "the inherent parallelism of memory cells — e.g., by
issuing outstanding parallel requests to separate DRAM banks" (§1).  At
cluster scale the analogous parallelism is *row-range sharding across chips*:
each device owns a contiguous row range of the table (a "bank"), runs the RME
datapath locally, and only reduced results (scalars, group accumulators,
broadcast build sides) cross the interconnect.

Two layers live here:

* **Free sharded operators** (``dist_project`` / ``dist_aggregate`` /
  ``dist_groupby`` / ``dist_join``) — ``shard_map`` over an explicit mesh
  axis, so the same code lowers for the 1-device CPU test run, the 256-chip
  single-pod mesh, and the 512-chip multi-pod mesh (the dry-run exercises
  the latter two).  The engine datapath inside ``shard_map`` is the XLA
  fused-gather revision: Pallas interpret-mode kernels don't lower under
  SPMD partitioning on CPU, and on real TPUs the same call sites swap in
  the MLP kernel.
* **The sharded execution backend** (:class:`ShardedRowStore` +
  :class:`ShardedEngine`) — a first-class drop-in for the single-device
  engine.  Each shard keeps its own delta-chunked base+tail buffers
  (appends upload only to the owning shard, timestamp patches rewrite only
  the owning shard's words), a tick's one fused ``rme_scan_multi`` pass
  runs **per shard** as a plain per-device call (no SPMD lowering — every
  Pallas revision works per shard exactly as it does per chunk), and only
  reduced results cross the interconnect: aggregate/group-by partials
  combine via the kernel layer's associative
  :func:`~repro.kernels.rme_scan_multi.combine_chunk_outputs`, packed and
  filter blocks stay shard-resident until finalize, and joins broadcast
  only the (small) cached build-partition set.  ``EngineStats`` charges the
  interconnect explicitly (``bytes_collective`` / ``collective_ops``) —
  O(result/build) bytes by construction, never O(rows).

The serving loop's pipelined primitives are inherited unchanged:
``execute_many_async`` wraps this class's ``execute_many`` (whose per-shard
passes already enqueue without a host sync — blocking happens only when a
result is pulled), and ``stream_project`` iterates ``device_chunks``, which
:meth:`ShardedRowStore.chunks` yields in global row order (ownership
segments sorted by starting row), so streamed chunks concatenate to the
same packed block on both backends.
"""

from __future__ import annotations

import dataclasses
import time
import weakref
from typing import Iterator, Sequence

import jax
import jax.numpy as jnp
from repro.compat import shard_map
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.kernels import ref as R
from repro.kernels import rme_join as KJ
from repro.kernels import rme_scan_multi as KR
from repro.kernels.common import group_ids
from repro.kernels.rme_project import project_xla

from . import faults
from .engine import (
    MAX_TAIL_CHUNKS,
    DeviceRowStore,
    EngineStats,
    RelationalMemoryEngine,
)
from .requests import JoinOp, JoinResult
from .schema import WORD, TableGeometry
from .table import RelationalTable


def _row_axes(mesh: Mesh, axes: str | Sequence[str]) -> tuple[str, ...]:
    return (axes,) if isinstance(axes, str) else tuple(axes)


def pad_rows_to(words: np.ndarray | jax.Array, shards: int) -> jax.Array:
    """Pad the row count to a multiple of ``shards`` with zero rows.

    Padding must be *masked*, never trusted to be inert: every sharded
    operator takes ``valid_rows`` (the true row count) and excludes padded
    positions explicitly — packed projections zero them, aggregates and
    group-bys drop them from the masked reduction, and the join refuses to
    match them on either side (a padded row's key word is 0, which is a
    perfectly legitimate key).  MVCC rows get a second, independent guard:
    ts_begin=0 <= ts < ts_end=0 can never hold.
    """
    n = words.shape[0]
    pad = (-n) % shards
    if pad:
        words = jnp.concatenate(
            [jnp.asarray(words), jnp.zeros((pad, words.shape[1]), words.dtype)], 0
        )
    return jnp.asarray(words)


def _shard_valid(axes: tuple[str, ...], shard_rows: int, n_valid) -> jax.Array:
    """Per-shard mask of globally-valid row positions (False on padding)."""
    idx = jax.lax.axis_index(axes)
    rows = idx * shard_rows + jnp.arange(shard_rows)
    return rows < n_valid


def dist_project(
    words: jax.Array,
    geom: TableGeometry,
    mesh: Mesh,
    axes: str | Sequence[str] = "data",
    valid_rows: int | None = None,
) -> jax.Array:
    """Row-sharded packed projection: each shard reorganizes its own bank.

    No cross-device traffic at all — the reorganized view stays sharded the
    same way the base table is, ready for downstream sharded consumers.
    ``valid_rows`` (the pre-padding row count) zeroes padded output rows so
    consumers never see fabricated rows.
    """
    axes = _row_axes(mesh, axes)
    n_valid = words.shape[0] if valid_rows is None else valid_rows

    def local(w):
        out = project_xla(w, geom)
        valid = _shard_valid(axes, w.shape[0], n_valid)
        return jnp.where(valid[:, None], out, 0)

    return shard_map(
        local, mesh=mesh, in_specs=P(axes, None), out_specs=P(axes, None)
    )(words)


def dist_aggregate(
    words: jax.Array,
    mesh: Mesh,
    agg_word: int,
    agg_dtype: str = "int32",
    pred_word: int = 0,
    pred_dtype: str = "int32",
    pred_op: str = "none",
    pred_k=0,
    valid_rows: int | None = None,
    axes: str | Sequence[str] = "data",
) -> jax.Array:
    """Distributed Q0/Q3: per-bank fused masked sum, one scalar ``psum``.

    ``valid_rows`` masks padding introduced by :func:`pad_rows_to`.
    Returns float32 ``[sum, count]`` replicated on every device.
    """
    axes = _row_axes(mesh, axes)
    n_total = words.shape[0]
    n_valid = n_total if valid_rows is None else valid_rows

    def local(w):
        valid = _shard_valid(axes, w.shape[0], n_valid)
        vals = R._decode(w[:, agg_word], agg_dtype).astype(jnp.float32)
        mask = R._predicate(R._decode(w[:, pred_word], pred_dtype), pred_op, pred_k)
        mask = mask & valid
        part = jnp.stack([jnp.sum(jnp.where(mask, vals, 0.0)), jnp.sum(mask)])
        return jax.lax.psum(part, axes)

    return shard_map(
        local, mesh=mesh, in_specs=P(axes, None), out_specs=P()
    )(words)


def dist_groupby(
    words: jax.Array,
    mesh: Mesh,
    group_word: int,
    agg_word: int,
    num_groups: int,
    agg_dtype: str = "int32",
    pred_word: int | None = None,
    pred_dtype: str = "int32",
    pred_op: str = "none",
    pred_k=0,
    valid_rows: int | None = None,
    axes: str | Sequence[str] = "data",
) -> tuple[jax.Array, jax.Array]:
    """Distributed Q4: per-bank one-hot contraction, (G,2) ``psum`` combine.

    Group ids come from the shared :func:`repro.kernels.common.group_ids`
    lowering — the same floored modulo every fused kernel and the reference
    oracle use, so sharded and fused group-bys agree on negative and
    overflowing keys.
    """
    axes = _row_axes(mesh, axes)
    n_valid = words.shape[0] if valid_rows is None else valid_rows

    def local(w):
        valid = _shard_valid(axes, w.shape[0], n_valid)
        g = group_ids(w[:, group_word], num_groups)
        vals = R._decode(w[:, agg_word], agg_dtype).astype(jnp.float32)
        mask = valid
        if pred_word is not None:
            mask = mask & R._predicate(
                R._decode(w[:, pred_word], pred_dtype), pred_op, pred_k
            )
        fm = mask.astype(jnp.float32)
        onehot = (g[:, None] == jnp.arange(num_groups)[None, :]).astype(jnp.float32)
        contrib = jnp.stack([vals * fm, fm], axis=1)
        acc = jax.lax.dot_general(
            onehot, contrib, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return jax.lax.psum(acc, axes)

    out = shard_map(local, mesh=mesh, in_specs=P(axes, None), out_specs=P())(words)
    return out[:, 0], out[:, 1]


def dist_join(
    s_words: jax.Array,
    r_words: jax.Array,
    mesh: Mesh,
    s_geom: TableGeometry,
    r_geom: TableGeometry,
    s_key_word: int,
    s_val_word: int,
    r_key_word: int,
    r_val_word: int,
    s_valid_rows: int | None = None,
    r_valid_rows: int | None = None,
    axes: str | Sequence[str] = "data",
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Distributed broadcast equi-join.

    Both tables are row-sharded.  Each shard RME-projects its slim {key, val}
    pair; the (small) build side R is all-gathered — the only collective — and
    every shard probes its local S rows.  Word offsets index the *packed*
    projected views.  Returns sharded (s_val, matched r_val, match mask).

    Padding discipline: padded rows carry key word 0, and 0 is a legitimate
    key, so both sides carry explicit validity.  The gathered build side is
    sorted valid-rows-first among equal keys (``lexsort``) so the probe's
    left-position lookup lands on a real row whenever one exists, and a
    match requires the build row *and* the probe row to be valid.
    """
    axes = _row_axes(mesh, axes)
    n_s = s_words.shape[0] if s_valid_rows is None else s_valid_rows
    n_r = r_words.shape[0] if r_valid_rows is None else r_valid_rows

    def local(s_w, r_w):
        s_p = project_xla(s_w, s_geom)
        r_p = project_xla(r_w, r_geom)
        s_valid = _shard_valid(axes, s_w.shape[0], n_s)
        r_valid_local = _shard_valid(axes, r_w.shape[0], n_r)
        r_all = jax.lax.all_gather(r_p, axes, tiled=True)  # broadcast build side
        r_valid = jax.lax.all_gather(r_valid_local, axes, tiled=True)
        r_key, r_val = r_all[:, r_key_word], r_all[:, r_val_word]
        s_key, s_val = s_p[:, s_key_word], s_p[:, s_val_word]
        # primary sort by key; valid rows first among equal keys, so the
        # left position of a present key is always its valid copy
        order = jnp.lexsort((~r_valid, r_key))
        rk, rv, rva = r_key[order], r_val[order], r_valid[order]
        pos = jnp.clip(jnp.searchsorted(rk, s_key), 0, rk.shape[0] - 1)
        matched = (rk[pos] == s_key) & rva[pos] & s_valid
        return (
            jnp.where(s_valid, s_val, 0),
            jnp.where(matched, rv[pos], 0),
            matched,
        )

    return shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axes, None), P(axes, None)),
        out_specs=(P(axes), P(axes), P(axes)),
    )(s_words, r_words)


def table_sharding(mesh: Mesh, axes: str | Sequence[str] = "data") -> NamedSharding:
    """Row-range sharding for a table buffer (rows over the data axis)."""
    return NamedSharding(mesh, P(_row_axes(mesh, axes), None))


# ===================================================================== backend
def shard_ranges(n_rows: int, shards: int) -> tuple[tuple[int, int], ...]:
    """Contiguous balanced row ranges: ``(start, n)`` per shard.

    The first ``n_rows % shards`` shards take one extra row, so shard sizes
    differ by at most one and their concatenation is ``[0, n_rows)`` in
    order — the row-range ownership map of the sharded backend.
    """
    base, extra = divmod(n_rows, shards)
    out, start = [], 0
    for s in range(shards):
        n = base + (1 if s < extra else 0)
        out.append((start, n))
        start += n
    return tuple(out)


@dataclasses.dataclass
class _ShardChunk:
    """One shard-resident buffer: rows the shard owns, with their global ids.

    ``segments`` maps the chunk's local rows, in order, back to global row
    ranges ``(global_start, n_rows)``.  A freshly uploaded chunk has one
    segment; shard-local compaction concatenates chunk buffers device-side
    and their segment lists along with them, so ownership survives merging
    of non-adjacent ranges (round-robin appends make a shard's ranges
    non-contiguous).
    """

    words: jax.Array
    segments: tuple[tuple[int, int], ...]

    @property
    def rows(self) -> int:
        return self.words.shape[0]


@dataclasses.dataclass
class _ShardedEntry:
    """One table's sharded device residency: per-shard chunk lists.

    ``rows`` / ``patch_seq`` are the same sync watermarks as the
    single-device ``_StoreEntry`` (the base class's ``contains`` reads them
    unchanged); ``next_owner`` round-robins append ownership so sustained
    ingest spreads across banks.
    """

    shards: list[list[_ShardChunk]]
    rows: int
    patch_seq: int
    next_owner: int = 0


class ShardedRowStore(DeviceRowStore):
    """Per-shard delta-chunked row-store buffers — one bank per shard.

    The single-device :class:`DeviceRowStore` keeps a table as base + tail
    chunks on one device; this subclass splits the base into one contiguous
    row range per shard (:func:`shard_ranges`) and keeps the whole delta
    machinery *per shard*:

    * a **full upload** places each shard's range on that shard's device
      (``devices[s]``; ``None`` = logical shard on the default device),
    * an **append** uploads the new tail rows to exactly one owning shard
      (round-robin), O(new rows) bytes to one bank — no other shard moves,
    * a **delete/update** replays the patch log against only the chunks
      whose segments own the touched rows — O(touched rows) words,
    * **compaction** is shard-local and device-side (charges nothing).

    Host-side consumers (``get`` / ``tail`` / ``chunks``) reassemble global
    row order from the ownership segments, gathering to the root device;
    these gathers model the host-side merge of per-bank results and are
    charged by their callers (``bytes_to_cpu``), not as collectives.  The
    scan path never pays them: :meth:`shard_parts` hands the engine the raw
    per-shard chunk lists.
    """

    def __init__(self, stats: EngineStats | None = None, delta: bool = True,
                 num_shards: int = 1, devices: Sequence | None = None):
        super().__init__(stats, delta=delta)
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        self.num_shards = num_shards
        self._devices = (list(devices) if devices is not None
                         else [None] * num_shards)
        if len(self._devices) != num_shards:
            raise ValueError("devices must have one entry per shard")
        self._root = next((d for d in self._devices if d is not None), None)

    # ---------------------------------------------------------- placement
    def _place(self, arr: jax.Array, shard: int) -> jax.Array:
        dev = self._devices[shard]
        return arr if dev is None else jax.device_put(arr, dev)

    def _to_root(self, arr: jax.Array) -> jax.Array:
        return arr if self._root is None else jax.device_put(arr, self._root)

    # ----------------------------------------------------------------- sync
    def _full_upload(self, table: RelationalTable) -> _ShardedEntry:
        faults.maybe_fault("upload", table=table.uid, delta=False)
        host = table.words()
        shards: list[list[_ShardChunk]] = [[] for _ in range(self.num_shards)]
        for s, (start, n) in enumerate(
            shard_ranges(table.row_count, self.num_shards)
        ):
            if n:
                shards[s].append(_ShardChunk(
                    self._place(jnp.asarray(host[start:start + n]), s),
                    ((start, n),),
                ))
        ent = _ShardedEntry(shards, table.row_count, table.mutation_version)
        if table.uid not in self._finalized:
            weakref.finalize(
                table, self._finalize_entry, weakref.ref(self), table.uid
            )
            self._finalized.add(table.uid)
        self._buffers[table.uid] = ent
        self._charge(host.size * host.itemsize, is_delta=False)
        return ent

    def _apply_patches(self, ent: _ShardedEntry, table: RelationalTable,
                       patches: list[np.ndarray]) -> int:
        """Rewrite patched ``__ts_end`` words inside the owning shards only.

        Global patch indices route through each chunk's ownership segments;
        a shard owning none of the touched rows is never touched itself.
        Returns the bytes shipped (one word per patched row).
        """
        idx = np.concatenate([p[p < ent.rows] for p in patches]) if patches else \
            np.empty(0, dtype=np.int64)
        if idx.size == 0:
            return 0
        vals = np.asarray(table.ts_end_at(idx))
        ts_word = table.ts_end_word
        for chunks in ent.shards:
            for c, chunk in enumerate(chunks):
                local, lvals, off = [], [], 0
                for g0, n in chunk.segments:
                    sel = (idx >= g0) & (idx < g0 + n)
                    if sel.any():
                        local.append(idx[sel] - g0 + off)
                        lvals.append(vals[sel])
                    off += n
                if local:
                    li = np.concatenate(local)
                    lv = np.concatenate(lvals)
                    chunks[c] = _ShardChunk(
                        chunk.words.at[jnp.asarray(li), ts_word].set(
                            jnp.asarray(lv)
                        ),
                        chunk.segments,
                    )
        return idx.size * WORD

    def _sync(self, table: RelationalTable) -> _ShardedEntry:
        """Bring the sharded copy current: deltas land only in owning shards."""
        ent = self._buffers.get(table.uid)
        if ent is not None and not self.delta and (
            ent.rows != table.row_count
            or ent.patch_seq != table.mutation_version
        ):
            ent = None  # baseline mode: any change → whole-table re-upload
        if ent is None:
            return self._full_upload(table)
        patches = (table.patches_since(ent.patch_seq)
                   if ent.patch_seq != table.mutation_version else [])
        if patches is None:  # lagged past the trimmed patch log: full re-sync
            return self._full_upload(table)
        if patches or table.row_count > ent.rows:
            # before any entry mutation: a fault here leaves every shard at
            # its pre-sync state, so a bare retry re-syncs cleanly
            faults.maybe_fault("upload", table=table.uid, delta=True)
        moved = self._apply_patches(ent, table, patches)
        ent.patch_seq = table.mutation_version
        if table.row_count > ent.rows:
            tail = table.tail_words(ent.rows)
            owner = ent.next_owner
            ent.shards[owner].append(_ShardChunk(
                self._place(jnp.asarray(tail), owner),
                ((ent.rows, tail.shape[0]),),
            ))
            ent.next_owner = (owner + 1) % self.num_shards
            ent.rows = table.row_count
            moved += tail.size * tail.itemsize
        self._charge(moved, is_delta=True)
        for s, chunks in enumerate(ent.shards):
            if len(chunks) > MAX_TAIL_CHUNKS:
                # shard-local device-side compaction: segments ride along,
                # so merged non-adjacent ranges keep their global ids
                ent.shards[s] = [_ShardChunk(
                    jnp.concatenate([c.words for c in chunks], axis=0),
                    tuple(seg for c in chunks for seg in c.segments),
                )]
        return ent

    # ------------------------------------------------------------ accessors
    @staticmethod
    def _pieces(ent: _ShardedEntry) -> Iterator[tuple[int, jax.Array]]:
        """Every resident ``(global_start, rows)`` piece, unordered."""
        for chunks in ent.shards:
            for chunk in chunks:
                off = 0
                for start, n in chunk.segments:
                    yield start, chunk.words[off:off + n]
                    off += n

    def _gathered(self, ent: _ShardedEntry,
                  from_row: int = 0) -> list[jax.Array]:
        """Root-device pieces in global row order, from ``from_row`` on."""
        parts = []
        for start, w in sorted(self._pieces(ent), key=lambda p: p[0]):
            if start + w.shape[0] > from_row:
                parts.append(self._to_root(w[max(from_row - start, 0):]))
        return parts

    def get(self, table: RelationalTable) -> jax.Array:
        """The table's row store as one root-device array (synced first).

        The sharded layout stays authoritative — this is the host-side merge
        view for single-buffer consumers (validity masks, host fallbacks),
        assembled from the ownership segments on every call.
        """
        parts = self._gathered(self._sync(table))
        if not parts:
            return jnp.zeros((0, table.row_words), dtype=jnp.int32)
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)

    def chunks(self, table: RelationalTable) -> tuple[jax.Array, ...]:
        """Global-order chunk views (synced first), for chunk-iterating
        consumers that are not shard-aware."""
        parts = self._gathered(self._sync(table))
        if not parts:
            return (jnp.zeros((0, table.row_words), dtype=jnp.int32),)
        return tuple(parts)

    def tail(self, table: RelationalTable, start_row: int) -> jax.Array:
        """Rows ``[start_row, row_count)`` in global order, on the root."""
        parts = self._gathered(self._sync(table), from_row=start_row)
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)

    def shard_parts(self, table: RelationalTable) -> list[list[_ShardChunk]]:
        """The synced per-shard chunk lists — the sharded scan operand.

        Index ``s`` is shard ``s``'s resident chunks on its own device (an
        empty list for a shard that owns no rows yet); nothing is gathered.
        """
        return [list(chunks) for chunks in self._sync(table).shards]

    @property
    def occupancy_bytes(self) -> int:
        return sum(
            c.words.size * c.words.dtype.itemsize
            for ent in self._buffers.values()
            for chunks in ent.shards for c in chunks
        )


def _empty_scan_result(req: "KR.ScanRequest"):
    """The canonical output of a fused request over zero rows — what a
    0-row table (no chunks on any shard) must still answer with."""
    if isinstance(req, KR.ProjectRequest):
        return jnp.zeros((0, req.geom.out_words_per_row), jnp.int32)
    if isinstance(req, KR.FilterRequest):
        return (jnp.zeros((0, req.geom.out_words_per_row), jnp.int32),
                jnp.zeros((0,), bool))
    if isinstance(req, KR.AggregateRequest):
        return jnp.zeros(2, jnp.float32)
    return (jnp.zeros(req.num_groups, jnp.float32),
            jnp.zeros(req.num_groups, jnp.float32))


class ShardedEngine(RelationalMemoryEngine):
    """The mesh-sharded execution backend — same results, per-bank datapath.

    Drop-in for :class:`RelationalMemoryEngine`: the whole serving surface
    (``execute_many``, ``materialize``, the planner's physical routes, the
    ``QueryServer``) runs unchanged on top of two overridden hooks —

    * :meth:`_serve_scan` — a tick's fused request tuple runs as **one
      fused pass per shard** (plain per-device ``scan_multi`` calls over
      the shard's resident chunks; no SPMD lowering, so every Pallas
      revision and the XLA fallback work per shard exactly as per chunk).
      Aggregate/group-by partials combine shard-locally, then once across
      shards via the associative ``combine_chunk_outputs`` — those reduced
      partials are the *only* scan bytes crossing the interconnect, charged
      to ``bytes_collective``.  Packed/filter blocks stay shard-resident
      and reassemble into global row order only at finalize (charged as
      ``bytes_to_cpu`` by the existing accounting, like any packed view).
    * :meth:`_join_direct` — the build side's cached Fibonacci-hash
      partitions are broadcast once per build version to every shard (the
      join's only collective, O(build rows)); each shard probes its own
      rows in place.

    ``mesh`` places shard ``s``'s buffers on ``mesh.devices.flat[s]``;
    ``num_shards`` without a mesh runs the identical code path as logical
    shards on the current device (the 1-device CPU case).  Both must be
    byte-identical to the single-device engine; exact float equality of
    re-associated sums holds whenever the sums are exactly representable
    (int32 payloads below 2^24 — the engine's test envelope).
    """

    def __init__(self, mesh: Mesh | None = None,
                 num_shards: int | None = None,
                 shard_retries: int = 2,
                 retry_backoff_s: float = 0.0,
                 quarantine_after: int = 3,
                 quarantine_probe_every: int = 4,
                 **kwargs):
        super().__init__(**kwargs)
        if mesh is not None:
            devices = list(mesh.devices.flat)
            if num_shards is None:
                num_shards = len(devices)
            if num_shards > len(devices):
                raise ValueError(
                    f"num_shards={num_shards} exceeds mesh size {len(devices)}"
                )
            devices = devices[:num_shards]
        else:
            num_shards = 1 if num_shards is None else num_shards
            devices = [None] * num_shards
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        self.mesh = mesh
        self.num_shards = num_shards
        self._devices = devices
        self.rowstore = ShardedRowStore(
            self.stats, delta=self.delta,
            num_shards=num_shards, devices=devices,
        )
        # broadcast replicas of join build partitions, one set per build
        # version: (table uid, mutation version) -> (source parts, replicas)
        self._bcast_parts: dict[tuple, tuple] = {}
        # failover policy (docs/reliability.md): transient shard-pass faults
        # retry with exponential backoff, then — or immediately on a
        # permanent fault — the shard's chunks re-execute on the root
        # device; repeated failures quarantine the shard (straight to
        # failover) with periodic half-open probes back to health
        self.shard_retries = shard_retries
        self.retry_backoff_s = retry_backoff_s
        self.quarantine_after = quarantine_after
        self.quarantine_probe_every = quarantine_probe_every
        self._health = [
            {"state": "healthy", "failures": 0, "skips": 0}
            for _ in range(self.num_shards)
        ]

    @property
    def backend(self) -> str:
        return "sharded"

    def reset(self) -> None:
        """Single-device reset plus the per-shard broadcast-replica cache."""
        super().reset()
        self._bcast_parts.clear()

    # ------------------------------------------------------------- gathers
    def _to_root(self, x):
        """Move one (pytree of) array(s) to the root shard's device."""
        root = self._devices[0]
        return x if root is None else jax.device_put(x, root)

    # ------------------------------------------------------- the scan hook
    def _serve_scan(self, table: RelationalTable,
                    reqs: tuple["KR.ScanRequest", ...],
                    shared: bool = False) -> list:
        """One fused pass per shard; only reduced partials cross shards.

        Requests are chunk-agnostic (word offsets, row-position-local), so
        the identical lowered tuple streams over every shard's chunks.  A
        lone request takes the same path — per-bank parallelism applies to
        solo queries too, and the per-shard pass count stays exactly one
        (``shared`` is accepted for the base-class hook contract; the
        subsumption layer runs in ``execute_many`` before this hook, so
        both backends see the same covering-collapsed request set).

        Every per-shard pass runs through :meth:`_shard_pass` (bounded
        retry → root-device failover → quarantine), and the cross-shard
        combine of reduced partials through :meth:`_combine_collective` —
        both byte-identical to the healthy run by construction.
        """
        faults.maybe_fault("scan_launch", table=table.uid)
        shards = self.rowstore.shard_parts(table)
        block_rows = self._fused_block_rows(reqs, table.row_words)
        per_shard: list[tuple[list[_ShardChunk], list[list]]] = []
        for s, chunks in enumerate(shards):
            if not chunks:
                continue
            outs = self._shard_pass(table, s, chunks, reqs, block_rows)
            per_shard.append((chunks, outs))
            for c in chunks:
                self.charge_scan(table, reqs, row_count=c.rows)
        self.stats.shared_scans += 1
        self.stats.rows_projected += table.row_count
        active = len(per_shard)
        results = []
        for r, req in enumerate(reqs):
            if not per_shard:
                # a 0-row table owns no chunks on any shard: emit the same
                # canonical empty/zero outputs the single-device pass yields
                results.append(self._to_root(_empty_scan_result(req)))
                continue
            reduced = KR.reduced_result_bytes(req)
            if reduced is not None:
                # shard-local combine first, then one cross-shard combine of
                # the O(result)-sized partials — the modeled collective
                partials = [
                    self._to_root(KR.combine_chunk_outputs(
                        req, [chunk_outs[r] for chunk_outs in outs]
                    ))
                    for _, outs in per_shard
                ]
                if active > 1:
                    self.stats.bytes_collective += (active - 1) * reduced
                    self.stats.collective_ops += 1
                    results.append(self._combine_collective(req, partials))
                else:
                    results.append(KR.combine_chunk_outputs(req, partials))
            else:
                # blocked output: reassemble global row order from the
                # ownership segments (finalize gather, not a collective)
                pieces = []
                for chunks, outs in per_shard:
                    for chunk, chunk_outs in zip(chunks, outs):
                        out = chunk_outs[r]
                        off = 0
                        for start, n in chunk.segments:
                            piece = (
                                (out[0][off:off + n], out[1][off:off + n])
                                if isinstance(req, KR.FilterRequest)
                                else out[off:off + n]
                            )
                            pieces.append((start, piece))
                            off += n
                pieces.sort(key=lambda p: p[0])
                parts = [self._to_root(p) for _, p in pieces]
                results.append(KR.combine_chunk_outputs(req, parts))
        return results

    # -------------------------------------------------- failover machinery
    def _shard_pass(self, table: RelationalTable, shard: int, chunks,
                    reqs: tuple["KR.ScanRequest", ...],
                    block_rows: int) -> list[list]:
        """One shard's fused pass with bounded retry, failover, quarantine.

        A transient fault retries up to ``shard_retries`` times with
        ``retry_backoff_s * 2**attempt`` backoff; a permanent fault — or
        retry exhaustion — re-executes this shard's chunks on the root
        device via :meth:`_failover_pass` (byte-identical results; the tick
        completes without the shard).  ``quarantine_after`` consecutive
        failed passes quarantine the shard: subsequent passes go straight
        to failover, with every ``quarantine_probe_every``-th pass probing
        the shard half-open.  A successful pass restores full health.
        """
        health = self._health[shard]
        if health["state"] == "quarantined":
            health["skips"] += 1
            if health["skips"] % self.quarantine_probe_every != 0:
                return self._failover_pass(shard, chunks, reqs)
        attempt = 0
        while True:
            try:
                faults.maybe_fault("shard_pass", shard=shard,
                                   table=table.uid)
                outs = KR.scan_shard(
                    [c.words for c in chunks], reqs,
                    revision=self.revision, block_rows=block_rows,
                    interpret=self.interpret,
                )
            except Exception as err:
                permanent = isinstance(err, faults.PermanentFault)
                if not permanent and attempt < self.shard_retries:
                    self.stats.retries += 1
                    if self.retry_backoff_s:
                        time.sleep(self.retry_backoff_s * (2 ** attempt))
                    attempt += 1
                    continue
                health["failures"] += 1
                if health["failures"] >= self.quarantine_after:
                    health["state"] = "quarantined"
                return self._failover_pass(shard, chunks, reqs)
            health["state"] = "healthy"
            health["failures"] = 0
            health["skips"] = 0
            return outs

    def _failover_pass(self, shard: int, chunks,
                       reqs: tuple["KR.ScanRequest", ...]) -> list[list]:
        """Re-execute a failed shard's chunks on the root device.

        The fused-gather XLA path serves the same request tuple over the
        same chunk rows, so the per-chunk outputs — and everything combined
        from them — are byte-identical to the healthy shard pass (the
        xla-revision equality suite is the standing proof).  Charged as one
        ``failovers`` event plus the shard's row bytes re-shipped across
        the interconnect (``bytes_failover``).
        """
        outs = []
        moved = 0
        for c in chunks:
            words = self._to_root(c.words)
            outs.append(KR.scan_multi_xla(words, tuple(reqs)))
            moved += c.words.size * c.words.dtype.itemsize
        self.stats.failovers += 1
        self.stats.bytes_failover += moved
        return outs

    def _combine_collective(self, req: "KR.ScanRequest", partials):
        """The cross-shard combine with bounded transient retry.

        The partials are already materialized on the root device, so a
        retry just re-runs the O(result)-sized combine.  A permanent fault
        (or retry exhaustion) propagates typed — the serving layer turns it
        into a per-ticket error.
        """
        attempt = 0
        while True:
            try:
                faults.maybe_fault("collective_combine")
                return KR.combine_chunk_outputs(req, partials)
            except faults.TransientFault:
                if attempt >= self.shard_retries:
                    raise
                self.stats.retries += 1
                if self.retry_backoff_s:
                    time.sleep(self.retry_backoff_s * (2 ** attempt))
                attempt += 1

    def shard_health(self) -> list[str]:
        """Per-shard health states (``"healthy"`` / ``"quarantined"``)."""
        return [h["state"] for h in self._health]

    # ------------------------------------------------------- the join hook
    def _shard_partitions(self, right_table: RelationalTable, parts):
        """Broadcast replicas of the build partitions, one per shard.

        Cached per build-table version: the first probe after a build (or a
        build-side write) pays one ``(shards - 1) * parts.nbytes``
        interconnect charge; every warm probe reuses the device-resident
        replicas for free — the same residency contract as the partitions
        themselves.
        """
        key = (right_table.uid, right_table.mutation_version)
        hit = self._bcast_parts.get(key)
        if hit is not None and hit[0] is parts:
            return hit[1]
        replicas = KJ.broadcast_partitions(parts, self._devices)
        if self.num_shards > 1:
            self.stats.bytes_collective += (self.num_shards - 1) * parts.nbytes
            self.stats.collective_ops += 1
        self._bcast_parts[key] = (parts, replicas)
        return replicas

    def _join_direct(self, op: JoinOp) -> JoinResult:
        """Solo join, sharded: every shard probes its own rows in place.

        Only the broadcast build partitions cross the interconnect — probe
        rows never move, and the per-probe-row outputs reassemble into
        global row order exactly like blocked scan outputs.
        """
        table = op.table
        parts = self._op_partitions(op)
        replicas = self._shard_partitions(op.right_table, parts)
        shards = self.rowstore.shard_parts(table)
        key_word = table.schema.word_offset(op.key)
        val_word = table.schema.word_offset(op.left_proj)
        snap = op.snapshot_ts is not None
        ts_word = table.ts_begin_word if snap else -1
        acc_req = op.lower()  # its intervals are exactly the probe footprint
        self.stats.rows_projected += table.row_count
        pieces = []
        for s, chunks in enumerate(shards):
            for chunk in chunks:
                out = self._probe_join(
                    chunk.words, replicas[s], key_word, val_word, ts_word,
                    op.snapshot_ts or 0, snap,
                    route=(table.uid, "join"),
                )
                self.charge_scan(table, (acc_req,), row_count=chunk.rows)
                off = 0
                for start, n in chunk.segments:
                    pieces.append((start, tuple(o[off:off + n] for o in out)))
                    off += n
        pieces.sort(key=lambda p: p[0])
        if not pieces:  # a 0-row probe table owns no chunks on any shard
            return JoinResult(
                s_proj=jnp.zeros(0, jnp.int32),
                r_proj=jnp.zeros(0, jnp.int32),
                matched=jnp.zeros(0, bool),
            )
        return JoinResult.concat(
            [JoinResult(*self._to_root(t)) for _, t in pieces]
        )
