"""Relational Memory core: the paper's contribution as a composable JAX module.

Layers (bottom-up):
  schema      — table layouts + RME geometry (configuration port, Table 1)
  descriptor  — Requestor Eq. (1)-(6) + byte-exact software fetch model
  table       — row-major MVCC row store (the single source of truth)
  ephemeral   — ephemeral variables (lazy column-group views)
  engine      — the RME: epoch-validated reorg cache + device row store +
                revision datapaths + scan-sharing batch materialization
  executor    — BatchExecutor: coalesce pending views, one shared scan/table
  plan        — logical plan IR (Scan/Filter/Project/Aggregate/GroupBy/Join)
  optimizer   — logical rewrite passes (pushdown, pruning, pred normalization)
  planner     — byte-cost path selection + compile_plan: plan -> PhysicalQuery
  operators   — Q0-Q5 over interchangeable rme/row/col access paths (thin
                plan constructors since the plan-IR refactor)
  distributed — shard_map row-bank parallel operators for the cluster meshes
  compression — dictionary + delta/FOR codecs (paper §4)
  faults      — deterministic fault injection + lowering circuit breaker
  wal         — checksummed write-ahead log for crash-consistent writes
"""

from .schema import (
    WORD, Column, TableGeometry, TableSchema, benchmark_schema,
    geometry_from_intervals, merge_geometries, paper_schema,
)
from .table import TS_INF, RelationalTable, columnar_copy
from .descriptor import BUS_WIDTH, Descriptor, bytes_moved, descriptor_arrays, descriptors, fetch_model
from .ephemeral import EphemeralView
from .requests import (
    AggregateOp, FilterOp, GroupByOp, JoinOp, JoinResult, ProjectOp, ScanOp,
)
from .engine import DeviceRowStore, EngineStats, RelationalMemoryEngine, ReorgCache
from .executor import BatchExecutor, execute_batch, materialize_batch
from .plan import (
    Aggregate, Filter, GroupBy, Join, PlanBuilder, PlanError, PlanNode,
    Project, Scan, decompose, plan,
)
from .optimizer import PASSES, Rewrite, optimize, optimize_trace, pred_class
from .planner import CompileOptions, PhysicalQuery, compile_plan
from .faults import (
    CircuitBreaker, FaultError, FaultPlan, PermanentFault, TransientFault,
    fault_plan,
)
from .wal import WriteAheadLog
from . import compression, distributed, executor, faults, operators, optimizer, planner, wal

__all__ = [
    "BUS_WIDTH", "WORD", "TS_INF",
    "Column", "TableSchema", "TableGeometry", "benchmark_schema",
    "geometry_from_intervals", "merge_geometries", "paper_schema",
    "RelationalTable", "columnar_copy",
    "Descriptor", "descriptors", "descriptor_arrays", "fetch_model", "bytes_moved",
    "EphemeralView", "DeviceRowStore", "EngineStats", "RelationalMemoryEngine",
    "ReorgCache", "BatchExecutor", "execute_batch", "materialize_batch",
    "AggregateOp", "FilterOp", "GroupByOp", "JoinOp", "JoinResult",
    "ProjectOp", "ScanOp",
    "Aggregate", "Filter", "GroupBy", "Join", "PlanBuilder", "PlanError",
    "PlanNode", "Project", "Scan", "decompose", "plan",
    "PASSES", "Rewrite", "optimize", "optimize_trace", "pred_class",
    "CompileOptions", "PhysicalQuery", "compile_plan",
    "CircuitBreaker", "FaultError", "FaultPlan", "PermanentFault",
    "TransientFault", "fault_plan", "WriteAheadLog",
    "compression", "distributed", "executor", "faults", "operators",
    "optimizer", "planner", "wal",
]
