"""Requestor descriptor generation — the paper's Eq. (1)–(6), verbatim.

The Requestor walks the table geometry and, for every (row ``i``, enabled column
``j``), emits a descriptor telling a Fetch Unit which bus-aligned burst to read
from main memory and where the extracted bytes land in the Reorganization
Buffer:

    P_{i,j}      = R*i + sum_{k<=j} O_{A_k}                    (1)
    R^addr_{i,j} = (P_{i,j} // B_w) * B_w                      (2)
    R^burst_{i,j}= ceil(((P_{i,j} % B_w) + C_{A_j}) / B_w)     (3)
    W^addr_{i,j} = i * sum_k C_{A_k} + sum_{k<j} C_{A_k}       (4)
    E^s_{i,j}    = P_{i,j} % B_w                               (5)
    E^e_{i,j}    = (P_{i,j} + C_{A_j}) % B_w                   (6)

Eq. (4) appears in the paper with ``(i-1)`` because rows there are 1-indexed; we
use 0-based ``i``.  ``B_w`` is the platform bus width (16 B on the ZCU102).

On TPU this exact math drives nothing at runtime — BlockSpec index maps play the
Requestor's role at tile granularity — but we keep the scalar model because (a)
it is the testable specification of what the kernels must produce, and (b) the
software Fetch-Unit model (``fetch_model``) is the byte-exact oracle used by the
property tests.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from .schema import TableGeometry

BUS_WIDTH = 16  # B_w of the paper's platform; configurable per call.


@dataclasses.dataclass(frozen=True)
class Descriptor:
    """One Fetch-Unit work item (row i, enabled column j)."""

    i: int
    j: int
    r_addr: int  # main-memory burst start (bus aligned)
    r_burst: int  # number of bus beats
    w_addr: int  # byte position in the reorganization buffer
    e_start: int  # leading bytes to discard
    e_end: int  # trailing *valid* byte bound within the last beat (paper Eq. 6)


def descriptors(
    geom: TableGeometry, bus_width: int = BUS_WIDTH, rows: range | None = None
) -> list[Descriptor]:
    """Generate descriptors exactly as the Requestor would (row-major order)."""
    abs_offs = geom.abs_offsets
    out_off = []
    acc = 0
    for w in geom.col_widths:
        out_off.append(acc)
        acc += w
    out_row = geom.out_bytes_per_row
    descs = []
    for i in rows if rows is not None else range(geom.row_count):
        for j in range(geom.q):
            p = geom.row_bytes * i + abs_offs[j]  # Eq. (1)
            r_addr = (p // bus_width) * bus_width  # Eq. (2)
            r_burst = -(-((p % bus_width) + geom.col_widths[j]) // bus_width)  # Eq. (3)
            w_addr = i * out_row + out_off[j]  # Eq. (4), 0-based
            e_s = p % bus_width  # Eq. (5)
            e_e = (p + geom.col_widths[j]) % bus_width  # Eq. (6)
            descs.append(Descriptor(i, j, r_addr, r_burst, w_addr, e_s, e_e))
    return descs


def descriptor_arrays(
    geom: TableGeometry, bus_width: int = BUS_WIDTH
) -> dict[str, np.ndarray]:
    """Vectorized Eq. (1)-(6) over the whole (N, Q) grid; used by benches/tests."""
    i = np.arange(geom.row_count, dtype=np.int64)[:, None]
    offs = np.asarray(geom.abs_offsets, dtype=np.int64)[None, :]
    widths = np.asarray(geom.col_widths, dtype=np.int64)[None, :]
    out_off = np.asarray(
        [sum(geom.col_widths[:j]) for j in range(geom.q)], dtype=np.int64
    )[None, :]
    p = geom.row_bytes * i + offs
    return {
        "P": p,
        "r_addr": (p // bus_width) * bus_width,
        "r_burst": -(-((p % bus_width) + widths) // bus_width),
        "w_addr": i * geom.out_bytes_per_row + out_off,
        "e_start": p % bus_width,
        "e_end": (p + widths) % bus_width,
    }


def fetch_model(
    memory: np.ndarray, geom: TableGeometry, bus_width: int = BUS_WIDTH
) -> tuple[np.ndarray, int]:
    """Software model of the Requestor + Fetch Units + Reorganization Buffer.

    ``memory`` is the raw row-major table as a flat ``uint8`` array of at least
    ``R*N`` bytes.  Returns ``(reorg_buffer, beats)`` where ``reorg_buffer`` is
    the packed projection (``N * sum(C)`` bytes) and ``beats`` counts the total
    bus beats issued — the paper's data-movement metric (a fetch unit never
    reads more than the bus-aligned span covering its column chunk).
    """
    if memory.dtype != np.uint8:
        memory = memory.view(np.uint8)
    out = np.zeros(geom.row_count * geom.out_bytes_per_row, dtype=np.uint8)
    beats = 0
    for d in descriptors(geom, bus_width):
        burst = memory[d.r_addr : d.r_addr + d.r_burst * bus_width]
        width = geom.col_widths[d.j]
        chunk = burst[d.e_start : d.e_start + width]  # Column Extractor
        out[d.w_addr : d.w_addr + width] = chunk  # Writer
        beats += d.r_burst
    return out, beats


def bytes_moved(geom: TableGeometry, bus_width: int = BUS_WIDTH) -> dict[str, int]:
    """Exact data-movement accounting for the three access paths of §6.

    - ``row_wise``: a direct scan of the row store pulls every row in full
      cache lines (the paper's 'direct row-wise access').
    - ``columnar``: a perfect column store moves only the projected bytes.
    - ``rme``: bus-beat-accurate bytes the RME pulls from DRAM (Eq. 3 bursts).

    The burst count of Eq. (3) depends on the row index only through
    ``P mod B_w``, which is periodic in ``i`` with period
    ``B_w / gcd(R, B_w)`` — so the (N, Q) descriptor sweep collapses to one
    period per column.  This keeps the hot engine paths (every cold
    materialization and every co-planned batch charges bus beats) O(Q · B_w)
    instead of O(N · Q); ``descriptor_arrays`` remains the brute-force oracle
    the tests check this closed form against.
    """
    n = geom.row_count
    period = bus_width // math.gcd(geom.row_bytes, bus_width)
    beats = 0
    full, rem = divmod(n, period)
    for off, width in zip(geom.abs_offsets, geom.col_widths):
        bursts = [
            -(-(((geom.row_bytes * i + off) % bus_width) + width) // bus_width)
            for i in range(period)
        ]
        beats += full * sum(bursts) + sum(bursts[:rem])
    cache_line = 64
    n_lines = -(-geom.row_bytes * n // cache_line)
    return {
        "row_wise": n_lines * cache_line,
        "columnar": n * geom.out_bytes_per_row,
        "rme": beats * bus_width,
    }
