"""Logical plan optimizer — rewrite passes over the :mod:`repro.core.plan` IR.

The paper's closing argument (§8) is that native column access lets the
software layer *state* a query and leave the datapath choice to a planner.
This module is the missing middle of that story: a small visitor/rewriter
protocol (every :class:`~repro.core.plan.PlanNode` exposes ``map_children``;
a pass is a :class:`Rewrite` applied bottom-up to fixpoint) and four concrete
passes that canonicalize client spellings before costing and lowering:

* **pushdown-filter** — sinks Filters below Projects and below a Join's
  probe side, so predicates always sit against the scan they gate.
* **prune-columns** — drops Projects that only widen the scanned column
  group (under Aggregate/GroupBy, and inner Projects under the outermost
  one).  Because the rme union geometry enables exactly the shape's column
  set, pruning directly shrinks ``bytes_from_dram``.
* **normalize-pred** — canonicalizes predicate constants through the
  compression layer's code-space translation: on a dict-encoded column every
  value-space constant with the same translated code collapses to the
  dictionary value of that code, and float constants over int32 columns snap
  to the equivalent integer spelling.  Canonical spellings make distinct
  client spellings *equal*, which is what lets decompose collapse repeated
  filters and the engine's subsumption layer share scans across tickets.
* **eliminate-trivial-pred** — removes all-pass predicates where the result
  contract permits it (under Aggregate/GroupBy and on a Join's probe spine):
  the predicate word leaves the union geometry, again shrinking bytes.

Constant-*false* elimination is the planner's half of the story: it calls
:func:`pred_class` on the canonical shape and routes a provably-empty plan
to a zero-op constant result (``repro.core.planner``), reported as the
``eliminate-empty`` pass in ``PhysicalQuery.explain()``.

Everything here is pure tree-to-tree: no pass reads row data — only schemas
and fitted codecs (dictionary ranks, FOR references), which are exactly the
compile-time artifacts the lowering layer already consults.
"""

from __future__ import annotations

import math

import numpy as np

from .compression import DeltaCodec, DictCodec
from .plan import (
    Aggregate,
    Filter,
    GroupBy,
    Join,
    PlanBuilder,
    PlanNode,
    Predicate,
    Project,
    Scan,
)
from .table import RelationalTable

_I32 = np.iinfo(np.int32)


def rewrite(node: PlanNode, fn) -> PlanNode:
    """Apply ``fn`` to every node bottom-up, rebuilding only changed spines.

    ``fn`` takes a node (whose children are already rewritten) and returns a
    replacement — or the node itself for "no change".  Identity is the
    fixpoint signal: an untouched subtree comes back as the *same* object.
    """

    def rec(n: PlanNode) -> PlanNode:
        return fn(n.map_children(rec))

    return rec(node)


def base_table(node: PlanNode) -> RelationalTable | None:
    """The base (probe-side) scan table of a subtree, if it has one.

    Follows first children — through Filter/Project chains and down a join
    chain's probe spine — mirroring how ``decompose`` resolves column names.
    """
    while not isinstance(node, Scan):
        kids = node.children()
        if not kids:
            return None
        node = kids[0]
    return node.table


# ----------------------------------------------------------- classification
def pred_class(table: RelationalTable, pred: Predicate) -> str:
    """Classify a predicate as ``"never"``, ``"all"``, or ``"some"``.

    Works in the *translated* domain: for encoded columns the codec maps the
    value-space constant into code space first (the same translation
    ``requests._pred_fields`` applies at lowering), so the classification is
    exact for dictionary ranks and FOR shifts.  Columns this cannot reason
    about (float32, string dictionaries) classify as ``"some"``.
    """
    try:
        col = table.schema.column(pred.col)
    except KeyError:
        return "some"
    codec = table.codecs.get(pred.col)
    if isinstance(codec, DictCodec):
        if codec.dictionary.dtype.kind in ("U", "S", "O"):
            return "some"
        n = int(codec.dictionary.size)
        op, c = codec.translate_pred(pred.op, pred.k)
        if op == "gt":
            if c >= n - 1:
                return "never"
            return "all" if c < 0 else "some"
        if c <= 0:
            return "never"
        return "all" if c >= n else "some"
    if isinstance(codec, DeltaCodec):
        if not codec.single_frame:
            return "some"
        op, k = codec.translate_pred(pred.op, pred.k)
        if op == "none":
            return "all"
        if (op == "gt" and k >= _I32.max) or (op == "lt" and k <= _I32.min):
            return "never"
        return "some"
    if col.dtype != "int32":
        return "some"
    k = pred.k
    if isinstance(k, float) and not math.isfinite(k):
        return "some"
    if pred.op == "gt":
        if k >= _I32.max:
            return "never"
        return "all" if k < _I32.min else "some"
    if k <= _I32.min:
        return "never"
    return "all" if k > _I32.max else "some"


# ------------------------------------------------------------------ passes
class Rewrite:
    """One optimizer pass: a named whole-tree rewrite.

    ``apply`` must return the *same object* when nothing changed — that is
    how :func:`optimize` detects the fixpoint and how ``explain()`` knows
    which passes actually fired.
    """

    name = "rewrite"

    def apply(self, node: PlanNode) -> PlanNode:
        raise NotImplementedError


class PushdownFilter(Rewrite):
    """Sink Filters below Projects and below a Join's probe side."""

    name = "pushdown-filter"

    def apply(self, node: PlanNode) -> PlanNode:
        def rule(n: PlanNode) -> PlanNode:
            if not isinstance(n, Filter):
                return n
            child = n.child
            if isinstance(child, Project):
                pushed = rule(Filter(child.child, n.col, n.op, n.k))
                return Project(pushed, child.columns)
            if isinstance(child, Join):
                table = base_table(child)
                if table is not None and n.col in table.schema.names:
                    pushed = rule(Filter(child.left, n.col, n.op, n.k))
                    return child.map_children(
                        lambda c: pushed if c is child.left else c
                    )
            return n

        return rewrite(node, rule)


def _strip_projects(node: PlanNode) -> PlanNode:
    """Remove Project nodes along a Filter/Project chain (stops at Scan/Join)."""
    if isinstance(node, Project):
        return _strip_projects(node.child)
    if isinstance(node, Filter):
        child = _strip_projects(node.child)
        return node if child is node.child else Filter(child, node.col, node.op, node.k)
    return node


class PruneColumns(Rewrite):
    """Drop Projects that only widen the scanned column group.

    A Project under an Aggregate/GroupBy contributes nothing to the result —
    it only forces extra columns into the union geometry; under another
    Project the outermost defines the output.  Removing them shrinks
    ``shape.columns`` and with it the bytes the rme datapath enables.
    """

    name = "prune-columns"

    def apply(self, node: PlanNode) -> PlanNode:
        def rule(n: PlanNode) -> PlanNode:
            if isinstance(n, (Aggregate, GroupBy, Project)):
                return n.map_children(_strip_projects)
            return n

        return rewrite(node, rule)


class NormalizePred(Rewrite):
    """Canonicalize predicate constants via the codec's code-space map.

    * float constants over int32-backed columns snap to the equivalent
      integer bound (``gt 3.5`` ≡ ``gt 3``, ``lt 3.5`` ≡ ``lt 4``);
    * on a numeric dict-encoded column, every constant translating to the
      same code rank rewrites to that rank's dictionary value — two clients
      spelling ``gt 7`` and ``gt 9`` over ``{3, 12, 40}`` now produce equal
      Filters, which decompose collapses and the subsumption layer shares.
    """

    name = "normalize-pred"

    def apply(self, node: PlanNode) -> PlanNode:
        def rule(n: PlanNode) -> PlanNode:
            if not isinstance(n, Filter):
                return n
            table = base_table(n)
            if table is None or n.col not in table.schema.names:
                return n
            if table.schema.column(n.col).dtype != "int32":
                return n
            k = n.k
            if isinstance(k, float):
                if not math.isfinite(k):
                    return n
                k = math.floor(k) if n.op == "gt" else math.ceil(k)
            codec = table.codecs.get(n.col)
            if isinstance(codec, DictCodec) and codec.dictionary.dtype.kind not in (
                "U", "S", "O"
            ):
                pred = Predicate(n.col, n.op, k)
                if pred_class(table, pred) == "some":
                    _, c = codec.translate_pred(n.op, k)
                    k = int(codec.dictionary[c])
            if k == n.k:
                return n
            return Filter(n.child, n.col, n.op, k)

        return rewrite(node, rule)


def _drop_all_pass(node: PlanNode, table: RelationalTable | None) -> PlanNode:
    """Remove all-pass Filters along a chain (contract-safe contexts only)."""
    if isinstance(node, Filter):
        child = _drop_all_pass(node.child, table)
        if (
            table is not None
            and node.col in table.schema.names
            and pred_class(table, Predicate(node.col, node.op, node.k)) == "all"
        ):
            return child
        return node if child is node.child else Filter(
            child, node.col, node.op, node.k
        )
    if isinstance(node, Project):
        child = _drop_all_pass(node.child, table)
        return node if child is node.child else Project(child, node.columns)
    return node


class EliminateTrivialPred(Rewrite):
    """Drop all-pass predicates where the result contract allows it.

    Safe under Aggregate/GroupBy (the scalar/partials are predicate-free
    anyway) and on a Join's probe spine (the probe mask of an all-pass
    predicate is all-true).  *Not* applied to bare filter plans — their
    contract is (packed, mask), and dropping the Filter would change the
    result type.  The predicate word leaves the union geometry, so the scan
    moves strictly fewer bytes.
    """

    name = "eliminate-trivial-pred"

    def apply(self, node: PlanNode) -> PlanNode:
        def rule(n: PlanNode) -> PlanNode:
            if isinstance(n, (Aggregate, GroupBy)):
                return n.map_children(lambda c: _drop_all_pass(c, base_table(c)))
            if isinstance(n, Join):
                return n.map_children(
                    lambda c: _drop_all_pass(c, base_table(c))
                    if c is n.left
                    else c
                )
            return n

        return rewrite(node, rule)


#: The default pass pipeline, in application order.  Public API: pass a
#: custom sequence to :func:`optimize` to run a subset (or your own
#: :class:`Rewrite` subclasses).
PASSES: tuple[Rewrite, ...] = (
    PushdownFilter(),
    PruneColumns(),
    NormalizePred(),
    EliminateTrivialPred(),
)

_MAX_ROUNDS = 8


def optimize_trace(
    node: PlanNode | PlanBuilder, passes: tuple[Rewrite, ...] = PASSES
) -> tuple[PlanNode, tuple[str, ...]]:
    """Run ``passes`` to fixpoint; return (optimized tree, passes that fired)."""
    if isinstance(node, PlanBuilder):
        node = node.node
    applied: list[str] = []
    for _ in range(_MAX_ROUNDS):
        changed = False
        for p in passes:
            out = p.apply(node)
            if out is not node:
                node = out
                changed = True
                if p.name not in applied:
                    applied.append(p.name)
        if not changed:
            break
    return node, tuple(applied)


def optimize(
    node: PlanNode | PlanBuilder, passes: tuple[Rewrite, ...] = PASSES
) -> PlanNode:
    """Canonicalize a logical plan (the tree the unoptimized route would run
    is semantically identical — the differential suite pins byte equality)."""
    return optimize_trace(node, passes)[0]
