"""Table schemas and RME geometry (paper §5, Table 1).

The paper's RME is configured with the *geometry* of a row-major table:
row size ``R`` (bytes), row count ``N``, the number of enabled columns ``Q``,
per-column widths ``C_Aj`` and per-column relative offsets ``O_Aj`` (offset from
the *previous* enabled column), and a frame number ``F``.

TPU adaptation: TPU vector memory is not byte addressed; the natural granule is a
4-byte lane word.  All column widths and offsets must therefore be multiples of
4 bytes (``WORD`` below).  This mirrors the paper's own bus-width alignment
(``B_w = 16`` bytes on the ZCU102) one level down: descriptors there are
bus-aligned, here they are word/lane aligned.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

WORD = 4  # bytes per TPU lane word; all layout math is word-aligned.

# The configuration port's Q cap (paper Table 1: at most 11 enabled columns).
# Per-view geometries and the planner both honor it; union geometries built
# for shared-scan *accounting* may exceed it (see merge_geometries).
MAX_ENABLED_COLUMNS = 11

# numpy dtypes allowed for decoded columns. char fields are fixed-width byte
# strings handled as raw words.
_SUPPORTED = {
    "int32": (np.int32, 4),
    "float32": (np.float32, 4),
    "int64": (np.int64, 8),
    "float64": (np.float64, 8),
    "uint32": (np.uint32, 4),
}


@dataclasses.dataclass(frozen=True)
class Column:
    """One attribute of a relation.

    ``dtype`` is one of the supported scalar names, ``"char"`` (fixed-width
    byte string; ``width`` gives the field size in bytes, word aligned), or
    ``"str"`` — a variable-length string column stored as one int32
    dictionary-code word (paper §4: encoded columns live in the row store as
    narrow code words; decoding happens on result materialization).

    ``codec`` optionally declares table-level compression for the stored
    words: ``"dict"`` (order-preserving dictionary, int32 or str values) or
    ``"for"`` (global frame-of-reference, int32 only).  ``"str"`` columns are
    dictionary-coded by construction, so their ``codec`` is forced to
    ``"dict"``.  The codec itself (dictionary / reference) is fitted and
    owned by the :class:`~repro.core.table.RelationalTable` at ingest.
    """

    name: str
    dtype: str = "int32"
    width: int | None = None  # bytes; inferred for scalar dtypes
    codec: str | None = None  # "dict" | "for" | None

    def __post_init__(self):
        if self.dtype == "char":
            if self.width is None or self.width % WORD != 0 or self.width <= 0:
                raise ValueError(
                    f"char column {self.name!r} needs a positive word-aligned width,"
                    f" got {self.width}"
                )
        elif self.dtype == "str":
            if self.width not in (None, WORD):
                raise ValueError(
                    f"str column {self.name!r} is one code word ({WORD}B), got"
                    f" width {self.width}"
                )
            object.__setattr__(self, "width", WORD)
            if self.codec not in (None, "dict"):
                raise ValueError(
                    f"str column {self.name!r} is dictionary-coded; codec"
                    f" {self.codec!r} is not expressible"
                )
            object.__setattr__(self, "codec", "dict")
        elif self.dtype in _SUPPORTED:
            expect = _SUPPORTED[self.dtype][1]
            if self.width is None:
                object.__setattr__(self, "width", expect)
            elif self.width != expect:
                raise ValueError(
                    f"column {self.name!r}: dtype {self.dtype} is {expect}B, got width"
                    f" {self.width}"
                )
        else:
            raise ValueError(f"unsupported dtype {self.dtype!r} for column {self.name!r}")
        if self.codec is not None:
            if self.codec not in ("dict", "for"):
                raise ValueError(
                    f"column {self.name!r}: unknown codec {self.codec!r};"
                    " want 'dict' or 'for'"
                )
            if self.dtype not in ("int32", "str"):
                raise ValueError(
                    f"column {self.name!r}: codec {self.codec!r} needs an"
                    f" int32 or str column, not {self.dtype}"
                )
            if self.codec == "for" and self.dtype != "int32":
                raise ValueError(
                    f"column {self.name!r}: FOR encoding needs int32 values"
                )

    @property
    def words(self) -> int:
        return self.width // WORD

    @property
    def np_dtype(self) -> np.dtype:
        if self.dtype == "char":
            return np.dtype((np.bytes_, self.width))
        if self.dtype == "str":
            return np.dtype(object)  # decoded values are numpy str arrays
        return np.dtype(_SUPPORTED[self.dtype][0])


@dataclasses.dataclass(frozen=True)
class TableSchema:
    """Physical row layout: columns are stored back-to-back, row-major."""

    columns: tuple[Column, ...]

    def __post_init__(self):
        names = [c.name for c in self.columns]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate column names in {names}")

    @staticmethod
    def of(*cols: Column | tuple) -> "TableSchema":
        out = []
        for c in cols:
            out.append(c if isinstance(c, Column) else Column(*c))
        return TableSchema(tuple(out))

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(c.name for c in self.columns)

    @property
    def row_bytes(self) -> int:
        return sum(c.width for c in self.columns)

    @property
    def row_words(self) -> int:
        return self.row_bytes // WORD

    def column(self, name: str) -> Column:
        for c in self.columns:
            if c.name == name:
                return c
        raise KeyError(name)

    def index(self, name: str) -> int:
        for i, c in enumerate(self.columns):
            if c.name == name:
                return i
        raise KeyError(name)

    def byte_offset(self, name: str) -> int:
        off = 0
        for c in self.columns:
            if c.name == name:
                return off
            off += c.width
        raise KeyError(name)

    def word_offset(self, name: str) -> int:
        return self.byte_offset(name) // WORD


@dataclasses.dataclass(frozen=True)
class TableGeometry:
    """The RME configuration-port contents (paper Table 1).

    Offsets ``O_Aj`` follow the paper's convention: the offset in bytes of the
    j-th enabled column *relative to the previous enabled column's offset*
    (``O_A0`` is absolute).  Absolute offsets are therefore the prefix sums.
    """

    row_bytes: int  # R
    row_count: int  # N
    col_widths: tuple[int, ...]  # C_Aj  (bytes)
    col_rel_offsets: tuple[int, ...]  # O_Aj  (bytes, relative chain)
    frame: int = 0  # F
    max_columns: int = MAX_ENABLED_COLUMNS  # the configuration port's Q cap

    def __post_init__(self):
        q = len(self.col_widths)
        if q == 0 or q != len(self.col_rel_offsets):
            raise ValueError("col_widths / col_rel_offsets mismatch or empty")
        if q > self.max_columns:
            raise ValueError(f"Q={q} exceeds max enabled columns {self.max_columns}")
        if self.row_bytes % WORD or any(w % WORD for w in self.col_widths) or any(
            o % WORD for o in self.col_rel_offsets
        ):
            raise ValueError("geometry must be word aligned (TPU adaptation)")
        offs = self.abs_offsets
        for o, w in zip(offs, self.col_widths):
            if o + w > self.row_bytes:
                raise ValueError(
                    f"column at offset {o} width {w} exceeds row size {self.row_bytes}"
                )
        if any(
            offs[j] < offs[j - 1] + self.col_widths[j - 1] for j in range(1, q)
        ):
            raise ValueError("enabled columns must be non-overlapping and ordered")

    @property
    def q(self) -> int:  # Q
        return len(self.col_widths)

    @property
    def abs_offsets(self) -> tuple[int, ...]:
        """Absolute byte offset of each enabled column: prefix sums of O_Aj."""
        out, acc = [], 0
        for o in self.col_rel_offsets:
            acc += o
            out.append(acc)
        return tuple(out)

    @property
    def out_bytes_per_row(self) -> int:
        return sum(self.col_widths)

    @property
    def out_words_per_row(self) -> int:
        return self.out_bytes_per_row // WORD

    @property
    def row_words(self) -> int:
        return self.row_bytes // WORD

    # --- word-granule view used by the TPU kernels -------------------------
    @property
    def col_word_offsets(self) -> tuple[int, ...]:
        return tuple(o // WORD for o in self.abs_offsets)

    @property
    def col_word_widths(self) -> tuple[int, ...]:
        return tuple(w // WORD for w in self.col_widths)

    @property
    def out_word_offsets(self) -> tuple[int, ...]:
        """Word offset of each enabled column within a packed output row."""
        out, acc = [], 0
        for w in self.col_word_widths:
            out.append(acc)
            acc += w
        return tuple(out)

    def cache_key(self) -> tuple:
        return (
            self.row_bytes,
            self.row_count,
            self.col_widths,
            self.col_rel_offsets,
            self.frame,
        )

    def layout_key(self) -> tuple:
        """The geometry's identity *minus the row count* — what delta-aware
        caches key on.  Two views of the same column group over the same row
        layout share one cache slot even as the table grows; the rows a
        cached block actually covers travel in the entry's version token
        (see :class:`repro.core.engine.ReorgCache`)."""
        return (
            self.row_bytes,
            self.col_widths,
            self.col_rel_offsets,
            self.frame,
        )

    @staticmethod
    def from_schema(
        schema: TableSchema, names: Sequence[str], row_count: int, frame: int = 0
    ) -> "TableGeometry":
        """Build the config-port contents for a column group over ``schema``.

        Enabled columns are sorted by physical offset (the RME walks rows
        front-to-back); the projected order follows physical order, matching the
        paper's packed layout.
        """
        cols = sorted(names, key=schema.byte_offset)
        if len(set(cols)) != len(cols):
            raise ValueError(f"duplicate columns in {names}")
        abs_offs = [schema.byte_offset(n) for n in cols]
        widths = [schema.column(n).width for n in cols]
        rel = [abs_offs[0]] + [abs_offs[j] - abs_offs[j - 1] for j in range(1, len(cols))]
        return TableGeometry(
            row_bytes=schema.row_bytes,
            row_count=row_count,
            col_widths=tuple(widths),
            col_rel_offsets=tuple(rel),
            frame=frame,
        )


def geometry_from_intervals(
    intervals: Sequence[tuple[int, int]], row_bytes: int, row_count: int
) -> TableGeometry:
    """The union accounting geometry over ``(byte_offset, byte_width)`` spans.

    Overlapping and *adjacent* intervals collapse into one burst chain — the
    single definition of the shared-scan charging rule, used by both
    :func:`merge_geometries` (multi-view batches) and the heterogeneous
    one-pass scan's ``union_geometry`` (mixed op batches).  ``max_columns``
    is lifted to whatever the merge produces: this is an accounting geometry,
    not a configuration-port write, so the paper's Q cap does not apply.
    """
    if not intervals:
        raise ValueError("geometry_from_intervals needs at least one interval")
    spans = sorted((o, o + w) for o, w in intervals)
    merged: list[list[int]] = []
    for s, e in spans:
        if merged and s <= merged[-1][1]:
            merged[-1][1] = max(merged[-1][1], e)
        else:
            merged.append([s, e])
    widths = tuple(e - s for s, e in merged)
    rel = [merged[0][0]]
    for j in range(1, len(merged)):
        rel.append(merged[j][0] - merged[j - 1][0])
    return TableGeometry(
        row_bytes=row_bytes,
        row_count=row_count,
        col_widths=widths,
        col_rel_offsets=tuple(rel),
        max_columns=max(len(merged), MAX_ENABLED_COLUMNS),
    )


def merge_geometries(geoms: Sequence[TableGeometry]) -> TableGeometry:
    """Union geometry of several views over one row layout (the shared scan).

    When the engine serves a batch of ephemeral views from a single Fetch-Unit
    stream, the bytes it pulls from the row store are governed by the *union*
    of the enabled-column byte intervals (see :func:`geometry_from_intervals`),
    so co-planned views are charged for the shared scan exactly once.
    """
    if not geoms:
        raise ValueError("merge_geometries needs at least one geometry")
    row_bytes = geoms[0].row_bytes
    if any(g.row_bytes != row_bytes for g in geoms):
        raise ValueError("cannot merge geometries over different row layouts")
    return geometry_from_intervals(
        [(o, w) for g in geoms for o, w in zip(g.abs_offsets, g.col_widths)],
        row_bytes=row_bytes,
        row_count=max(g.row_count for g in geoms),
    )


def paper_schema() -> TableSchema:
    """The exact row layout from the paper's Listing 1 (64-byte rows)."""
    return TableSchema.of(
        Column("key", "int64"),
        Column("text_fld1", "char", 8),
        Column("text_fld2", "char", 12),
        Column("text_fld3", "char", 20),  # paper lists 20B; keeps row at 64B? see note
        Column("num_fld1", "int32"),
        Column("num_fld2", "int32"),
        Column("num_fld3", "int32"),
        Column("num_fld4", "int32"),
    )
    # Note: the paper's Listing 1 sums to >64B with five 8-byte longs; its
    # benchmark (§6.2) instead uses 64B rows of 4B columns.  We follow the
    # benchmark geometry here and keep Listing 1's field names.


def benchmark_schema(row_bytes: int = 64, col_bytes: int = 4) -> TableSchema:
    """The synthetic benchmark table (§6.2): n equal-width numeric columns."""
    if row_bytes % col_bytes:
        raise ValueError("row_bytes must be a multiple of col_bytes")
    n = row_bytes // col_bytes
    cols = []
    for i in range(n):
        if col_bytes == 4:
            cols.append(Column(f"A{i + 1}", "int32"))
        else:
            cols.append(Column(f"A{i + 1}", "char", col_bytes))
    return TableSchema.of(*cols)
