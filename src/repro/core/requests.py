"""Engine-level scan ops — what one consumer wants from a table's scan.

The kernel layer (:mod:`repro.kernels.rme_scan_multi`) speaks word offsets
and static specs; callers speak tables, ephemeral views, and column names.
A *scan op* is the engine-level spelling: it names the table (and, for
packed outputs, the registered :class:`~repro.core.ephemeral.EphemeralView`)
plus the operator parameters, and :meth:`lower` translates it to the kernel
request via the table's schema.  :meth:`RelationalMemoryEngine.execute_many`
coalesces any mix of these per table into one heterogeneous one-pass scan
(or routes a lone op to its single-op kernel).

Ops use identity equality (two clients asking the same aggregate are two
ops); de-duplication happens at the kernel-request level, where equal lowered
requests — same enabled words, same predicate, same snapshot — share one
output slot in the fused pass.

Every op also knows its **result size**: :meth:`result_bytes` is the bytes
of the op's own output under its single-op contract (packed block + validity
mask for filters, the 8-byte ``[sum, count]`` pair for aggregates, ``(G, 2)``
partials for group-bys, the three per-probe-row arrays for joins).  This is
an *output* estimate — orthogonal to the bus-beat scan cost the engine's PMU
charges — and it is what the serving layer's priority lanes account against:
an express ticket's defining property is a result small enough to finalize
immediately, and the per-lane ``result_bytes`` counters in ``ServerStats``
make that visible.

Chunk and snapshot semantics: a lowered request is *chunk-agnostic* — it
names word offsets within a row, never row positions — so ``execute_many``
can stream the same request tuple over every resident chunk of a
delta-chunked table and combine the outputs
(:func:`repro.kernels.rme_scan_multi.scan_multi_chunked`).  ``snapshot_ts``
on the predicated ops fuses the MVCC visibility test against the hidden
timestamp words, which the write path keeps current at O(patched rows)
upload cost; an op without a snapshot sees every physical row version.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import rme_scan_multi as KR
from repro.kernels.common import group_ids

from .compression import DeltaCodec, DictCodec
from .ephemeral import EphemeralView
from .table import RelationalTable


@dataclasses.dataclass
class JoinResult:
    """Static-shape join output: one slot per probe row + match validity.

    Every join route — host sort-probe, device hash-partition probe, XLA
    fallback — emits exactly this contract, so routes are interchangeable
    and tests can assert cross-route equality.  Under a ``snapshot_ts``,
    probe rows invisible at the snapshot carry zeros and ``matched=False``.
    """

    s_proj: jax.Array  # projected column from the probe side S
    r_proj: jax.Array  # matched column from the build side R (0 where no match)
    matched: jax.Array  # bool mask

    @classmethod
    def concat(cls, parts: Sequence["JoinResult"]) -> "JoinResult":
        """Row-wise concatenation of per-chunk (or per-shard-segment) join
        outputs back into probe-table row order.  Join outputs are row-local
        — one slot per probe row, no cross-row state — so chunked and
        sharded probes reassemble exactly like blocked scan outputs."""
        if len(parts) == 1:
            return parts[0]
        return cls(
            s_proj=jnp.concatenate([p.s_proj for p in parts]),
            r_proj=jnp.concatenate([p.r_proj for p in parts]),
            matched=jnp.concatenate([p.matched for p in parts]),
        )


@dataclasses.dataclass(frozen=True, eq=False)
class ProjectOp:
    """Materialize a registered view's packed column group."""

    view: EphemeralView

    @property
    def table(self) -> RelationalTable:
        return self.view.table

    def lower(self) -> KR.ProjectRequest:
        return KR.ProjectRequest(self.view.geometry)

    def result_bytes(self) -> int:
        g = self.view.geometry
        return g.row_count * g.out_bytes_per_row


def _pred_fields(table: RelationalTable, pred_col: str | None, pred_op: str,
                 pred_k, snapshot_ts: int | None, default_word: int,
                 default_dtype: str) -> dict:
    schema = table.schema
    if pred_col is None:
        pred_word, pred_dtype = default_word, default_dtype
    else:
        pred_word = schema.word_offset(pred_col)
        pred_dtype = schema.column(pred_col).dtype
        codec = table.codecs.get(pred_col)
        if codec is not None:
            # compile-time predicate translation (paper §4): the stored words
            # are raw int32 codes, and the codec's order structure maps the
            # value-space constant to the equivalent code-space constant —
            # the kernel compares codes, zero decode in-scan
            pred_dtype = "int32"
            if pred_op != "none":
                pred_op, pred_k = codec.translate_pred(pred_op, pred_k)
    return dict(
        pred_word=pred_word,
        pred_dtype=pred_dtype,
        pred_op=pred_op,
        pred_k=pred_k,
        ts_word=schema.row_words if snapshot_ts is not None else -1,
        ts=0 if snapshot_ts is None else snapshot_ts,
    )


@dataclasses.dataclass(frozen=True, eq=False)
class FilterOp:
    """Fused selection + projection: packed block with failing rows zeroed
    plus a validity bitmap (the ``rme_filter`` contract)."""

    view: EphemeralView
    pred_col: str
    pred_op: str = "gt"
    pred_k: int | float = 0
    snapshot_ts: int | None = None

    @property
    def table(self) -> RelationalTable:
        return self.view.table

    def lower(self) -> KR.FilterRequest:
        schema = self.table.schema
        return KR.FilterRequest(
            self.view.geometry,
            **_pred_fields(
                self.table, self.pred_col, self.pred_op, self.pred_k,
                self.snapshot_ts, schema.word_offset(self.pred_col),
                schema.column(self.pred_col).dtype,
            ),
        )

    def result_bytes(self) -> int:
        # (packed block, bool validity mask) — the rme_filter contract
        g = self.view.geometry
        return g.row_count * (g.out_bytes_per_row + 1)


@dataclasses.dataclass(frozen=True, eq=False)
class AggregateOp:
    """Fused ``SELECT SUM(agg), COUNT(*) WHERE pred``: a ``[sum, count]``
    scalar pair, nothing else leaves the engine."""

    table: RelationalTable
    agg_col: str
    pred_col: str | None = None
    pred_op: str = "none"
    pred_k: int | float = 0
    snapshot_ts: int | None = None

    def lower(self) -> KR.AggregateRequest:
        schema = self.table.schema
        agg_word = schema.word_offset(self.agg_col)
        agg_dtype = _agg_lower_dtype(self.table, self.agg_col)
        return KR.AggregateRequest(
            agg_word=agg_word,
            agg_dtype=agg_dtype,
            **_pred_fields(self.table, self.pred_col, self.pred_op,
                           self.pred_k, self.snapshot_ts, agg_word, agg_dtype),
        )

    def result_bytes(self) -> int:
        return 8  # the [sum, count] float pair


@dataclasses.dataclass(frozen=True, eq=False)
class GroupByOp:
    """Fused ``SELECT SUM(agg), COUNT(*) ... GROUP BY group`` partials."""

    table: RelationalTable
    group_col: str
    agg_col: str
    num_groups: int
    pred_col: str | None = None
    pred_op: str = "none"
    pred_k: int | float = 0
    snapshot_ts: int | None = None

    def lower(self) -> KR.GroupByRequest:
        schema = self.table.schema
        agg_word = schema.word_offset(self.agg_col)
        agg_dtype = _agg_lower_dtype(self.table, self.agg_col)
        group_codec = self.table.codecs.get(self.group_col)
        num_groups = self.num_groups
        if group_codec is not None:
            # group on raw codes: dictionary codes are dense [0, n), so the
            # kernel's modulo grouping is the identity over the code domain
            # and the op-level finalize remaps the per-code partials into the
            # caller's value groups from the dictionary alone
            if not isinstance(group_codec, DictCodec):
                raise ValueError(
                    "group-by keys need a dict codec (FOR codes are not "
                    "group identities)"
                )
            n = int(group_codec.dictionary.size)
            if (group_codec.dictionary.dtype.kind in ("U", "S", "O")
                    and self.num_groups < n):
                raise ValueError(
                    f"num_groups={self.num_groups} cannot cover the "
                    f"{n}-entry string dictionary"
                )
            num_groups = max(n, 1)
        return KR.GroupByRequest(
            group_word=schema.word_offset(self.group_col),
            agg_word=agg_word,
            num_groups=num_groups,
            agg_dtype=agg_dtype,
            **_pred_fields(self.table, self.pred_col, self.pred_op,
                           self.pred_k, self.snapshot_ts, agg_word, agg_dtype),
        )

    def result_bytes(self) -> int:
        return self.num_groups * 8  # (G, 2) float partials


@dataclasses.dataclass(frozen=True, eq=False)
class JoinOp:
    """Device-resident equi-join: probe-side scan + bucketed build probe.

    The op names the registered probe-side ``{left_proj, key}`` view, the
    build table, and (optionally) the hash partitions the planner found in
    the build cache at compile time (``None`` means build-and-insert at
    execution, exactly like the sorted-index closure of the host route).

    :meth:`lower` emits only the **probe-side scan request** — a plain
    ``ProjectRequest`` (or, snapshot-pinned, a ``FilterRequest`` with an
    inert predicate whose mask is the MVCC visibility) — so a join admitted
    into a mixed tick coalesces into the same heterogeneous one-pass scan as
    co-tick filters/aggregates on the probe table; the bucket probe itself
    runs on the packed output (``RelationalMemoryEngine._finish_join``).  A
    join that is *alone* on its table skips the packed materialization
    entirely: the engine streams the probe kernel straight over the
    device row-store chunks (``_join_direct``).
    """

    view: EphemeralView  # probe-side {left_proj, key} registered view
    left_proj: str
    key: str
    right_table: RelationalTable
    right_proj: str
    snapshot_ts: int | None = None
    partitions: object | None = None  # JoinPartitions from the build cache
    # probe-side predicate pushed below the join (optimizer): fused into the
    # probe scan exactly like a FilterOp's — unmatched/filtered rows carry
    # zeros and matched=False in the JoinResult
    pred_col: str | None = None
    pred_op: str = "none"
    pred_k: int | float = 0

    @property
    def table(self) -> RelationalTable:
        return self.view.table

    def lower(self) -> KR.ProjectRequest | KR.FilterRequest:
        check_join_encoding(self.table, self.right_table, self.key,
                            self.left_proj, self.right_proj)
        if self.snapshot_ts is None and self.pred_op == "none":
            return KR.ProjectRequest(self.view.geometry)
        # predicated (or snapshot-pinned) probe: the request's mask is the
        # fused predicate AND the rows' MVCC visibility at the snapshot.
        # With no real predicate this degenerates to the inert spelling over
        # the (int32) key column whose mask is visibility alone.
        pred_col = self.pred_col if self.pred_op != "none" else self.key
        return KR.FilterRequest(
            self.view.geometry,
            **_pred_fields(self.table, pred_col, self.pred_op, self.pred_k,
                           self.snapshot_ts, 0, "int32"),
        )

    def result_bytes(self) -> int:
        # JoinResult: s_proj (4B) + r_proj (4B) + matched (1B) per probe row
        return self.view.geometry.row_count * 9


@dataclasses.dataclass
class MultiJoinResult:
    """A left-deep join chain's output: the shared probe projection, one
    build-side column per join (in the *client's* spelling order), and the
    conjunction of the per-join match masks.  Rows failing any join (or the
    probe-side predicate/snapshot) carry zeros and ``matched=False`` in every
    column — the same zero-fill contract as :class:`JoinResult`."""

    s_proj: jax.Array
    r_projs: tuple[jax.Array, ...]
    matched: jax.Array


ScanOp = ProjectOp | FilterOp | AggregateOp | GroupByOp | JoinOp


def _agg_lower_dtype(table: RelationalTable, agg_col: str) -> str:
    """The kernel-visible dtype of an aggregate column, codec-aware.

    A FOR-encoded column sums on its raw int32 deltas (the affine fix-up is
    applied by :func:`finalize_scan_result`); dictionary codes carry no
    additive structure, so summing them would be silent garbage — reject."""
    codec = table.codecs.get(agg_col)
    if codec is None:
        return table.schema.column(agg_col).dtype
    if isinstance(codec, DictCodec):
        raise ValueError(
            f"column {agg_col!r} is dict-encoded: codes are ranks, not "
            "addends — aggregate a FOR-encoded or plain column instead"
        )
    return "int32"  # FOR deltas are plain int32 words


def check_join_encoding(left: RelationalTable, right: RelationalTable,
                        key: str, left_proj: str, right_proj: str) -> None:
    """Execute-time guard for the device join route on encoded tables.

    Raw code words are join identities only when *both* key columns encode
    through one table-level dictionary (equal codes ⟺ equal values) — a
    re-fit on either side between compile and execute breaks that, which is
    why :meth:`JoinOp.lower` re-checks on every execution.  Projected
    payloads must be plain numeric: the probe emits zeros for unmatched
    rows, and zero is a valid code word."""
    for table, col in ((left, left_proj), (right, right_proj)):
        if col in table.codecs:
            raise ValueError(
                f"join payload column {col!r} must be plain numeric "
                "(unmatched rows emit 0, which is a valid code word)"
            )
    a, b = left.codecs.get(key), right.codecs.get(key)
    if a is None and b is None:
        return
    if a is None or b is None:
        raise ValueError(
            f"join key {key!r} is encoded on one side only — codes cannot "
            "compare against plain values"
        )
    if not (isinstance(a, DictCodec) and isinstance(b, DictCodec)):
        raise ValueError("join keys need dict codecs (FOR deltas are not "
                         "join identities)")
    if a is not b and not np.array_equal(a.dictionary, b.dictionary):
        raise ValueError(
            f"join key {key!r} needs one shared table-level dictionary "
            "(fit both tables with the same DictCodec)"
        )


def _remap_group_partials(codec: DictCodec, num_groups: int, sums, counts):
    """Per-code group-by partials -> the caller's value-group domain.

    The kernel grouped on raw codes (dense ``[0, n_dict)``); the dictionary
    alone determines where each code's partial lands, so this touches no row
    data and never decodes.  Integer dictionaries re-bucket by the shared
    ``group_ids`` lowering over the *values*; string dictionaries have no
    modulo semantics — each distinct string is its own group, zero-padded up
    to the caller's ``num_groups`` (coverage checked at lowering)."""
    d = codec.dictionary
    if d.size == 0:
        zeros = jnp.zeros(num_groups, jnp.float32)
        return zeros, zeros
    if d.dtype.kind in ("U", "S", "O"):
        pad = num_groups - int(d.size)
        if pad > 0:
            sums = jnp.concatenate([sums, jnp.zeros(pad, sums.dtype)])
            counts = jnp.concatenate([counts, jnp.zeros(pad, counts.dtype)])
        return sums, counts
    g = group_ids(jnp.asarray(d.astype(np.int32)), num_groups)
    return (jax.ops.segment_sum(sums, g, num_segments=num_groups),
            jax.ops.segment_sum(counts, g, num_segments=num_groups))


def finalize_scan_result(op: ScanOp, out):
    """Op-level fix-ups after a raw-code fused pass — the only post-scan
    work compressed execution needs, all O(result) and decode-free.

    * ``AggregateOp`` over a FOR column: the kernel summed raw deltas, so
      ``sum = base * count + sum(deltas)`` (paper §4's aggregation identity).
    * ``GroupByOp``: the same affine fix-up per group, then per-code
      partials remap to the caller's group domain via the dictionary.
    * Everything else (including packed filter/project outputs, which carry
      raw codes until a client *reads* them) passes through untouched.

    Applied by ``execute_many`` on both backends — on the sharded engine the
    cross-shard combine happens first, so the remap runs once on the reduced
    partials, never per shard.
    """
    if isinstance(op, AggregateOp):
        codec = op.table.codecs.get(op.agg_col)
        if isinstance(codec, DeltaCodec):
            return jnp.stack([out[0] + codec.base * out[1], out[1]])
        return out
    if isinstance(op, GroupByOp):
        sums, counts = out
        agg_codec = op.table.codecs.get(op.agg_col)
        if isinstance(agg_codec, DeltaCodec):
            sums = sums + agg_codec.base * counts
        group_codec = op.table.codecs.get(op.group_col)
        if isinstance(group_codec, DictCodec):
            sums, counts = _remap_group_partials(
                group_codec, op.num_groups, sums, counts
            )
        return sums, counts
    return out
