"""Engine-level scan ops — what one consumer wants from a table's scan.

The kernel layer (:mod:`repro.kernels.rme_scan_multi`) speaks word offsets
and static specs; callers speak tables, ephemeral views, and column names.
A *scan op* is the engine-level spelling: it names the table (and, for
packed outputs, the registered :class:`~repro.core.ephemeral.EphemeralView`)
plus the operator parameters, and :meth:`lower` translates it to the kernel
request via the table's schema.  :meth:`RelationalMemoryEngine.execute_many`
coalesces any mix of these per table into one heterogeneous one-pass scan
(or routes a lone op to its single-op kernel).

Ops use identity equality (two clients asking the same aggregate are two
ops); de-duplication happens at the kernel-request level, where equal lowered
requests — same enabled words, same predicate, same snapshot — share one
output slot in the fused pass.

Every op also knows its **result size**: :meth:`result_bytes` is the bytes
of the op's own output under its single-op contract (packed block + validity
mask for filters, the 8-byte ``[sum, count]`` pair for aggregates, ``(G, 2)``
partials for group-bys, the three per-probe-row arrays for joins).  This is
an *output* estimate — orthogonal to the bus-beat scan cost the engine's PMU
charges — and it is what the serving layer's priority lanes account against:
an express ticket's defining property is a result small enough to finalize
immediately, and the per-lane ``result_bytes`` counters in ``ServerStats``
make that visible.

Chunk and snapshot semantics: a lowered request is *chunk-agnostic* — it
names word offsets within a row, never row positions — so ``execute_many``
can stream the same request tuple over every resident chunk of a
delta-chunked table and combine the outputs
(:func:`repro.kernels.rme_scan_multi.scan_multi_chunked`).  ``snapshot_ts``
on the predicated ops fuses the MVCC visibility test against the hidden
timestamp words, which the write path keeps current at O(patched rows)
upload cost; an op without a snapshot sees every physical row version.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.kernels import rme_scan_multi as KR

from .ephemeral import EphemeralView
from .table import RelationalTable


@dataclasses.dataclass
class JoinResult:
    """Static-shape join output: one slot per probe row + match validity.

    Every join route — host sort-probe, device hash-partition probe, XLA
    fallback — emits exactly this contract, so routes are interchangeable
    and tests can assert cross-route equality.  Under a ``snapshot_ts``,
    probe rows invisible at the snapshot carry zeros and ``matched=False``.
    """

    s_proj: jax.Array  # projected column from the probe side S
    r_proj: jax.Array  # matched column from the build side R (0 where no match)
    matched: jax.Array  # bool mask

    @classmethod
    def concat(cls, parts: Sequence["JoinResult"]) -> "JoinResult":
        """Row-wise concatenation of per-chunk (or per-shard-segment) join
        outputs back into probe-table row order.  Join outputs are row-local
        — one slot per probe row, no cross-row state — so chunked and
        sharded probes reassemble exactly like blocked scan outputs."""
        if len(parts) == 1:
            return parts[0]
        return cls(
            s_proj=jnp.concatenate([p.s_proj for p in parts]),
            r_proj=jnp.concatenate([p.r_proj for p in parts]),
            matched=jnp.concatenate([p.matched for p in parts]),
        )


@dataclasses.dataclass(frozen=True, eq=False)
class ProjectOp:
    """Materialize a registered view's packed column group."""

    view: EphemeralView

    @property
    def table(self) -> RelationalTable:
        return self.view.table

    def lower(self) -> KR.ProjectRequest:
        return KR.ProjectRequest(self.view.geometry)

    def result_bytes(self) -> int:
        g = self.view.geometry
        return g.row_count * g.out_bytes_per_row


def _pred_fields(table: RelationalTable, pred_col: str | None, pred_op: str,
                 pred_k, snapshot_ts: int | None, default_word: int,
                 default_dtype: str) -> dict:
    schema = table.schema
    if pred_col is None:
        pred_word, pred_dtype = default_word, default_dtype
    else:
        pred_word = schema.word_offset(pred_col)
        pred_dtype = schema.column(pred_col).dtype
    return dict(
        pred_word=pred_word,
        pred_dtype=pred_dtype,
        pred_op=pred_op,
        pred_k=pred_k,
        ts_word=schema.row_words if snapshot_ts is not None else -1,
        ts=0 if snapshot_ts is None else snapshot_ts,
    )


@dataclasses.dataclass(frozen=True, eq=False)
class FilterOp:
    """Fused selection + projection: packed block with failing rows zeroed
    plus a validity bitmap (the ``rme_filter`` contract)."""

    view: EphemeralView
    pred_col: str
    pred_op: str = "gt"
    pred_k: int | float = 0
    snapshot_ts: int | None = None

    @property
    def table(self) -> RelationalTable:
        return self.view.table

    def lower(self) -> KR.FilterRequest:
        schema = self.table.schema
        return KR.FilterRequest(
            self.view.geometry,
            **_pred_fields(
                self.table, self.pred_col, self.pred_op, self.pred_k,
                self.snapshot_ts, schema.word_offset(self.pred_col),
                schema.column(self.pred_col).dtype,
            ),
        )

    def result_bytes(self) -> int:
        # (packed block, bool validity mask) — the rme_filter contract
        g = self.view.geometry
        return g.row_count * (g.out_bytes_per_row + 1)


@dataclasses.dataclass(frozen=True, eq=False)
class AggregateOp:
    """Fused ``SELECT SUM(agg), COUNT(*) WHERE pred``: a ``[sum, count]``
    scalar pair, nothing else leaves the engine."""

    table: RelationalTable
    agg_col: str
    pred_col: str | None = None
    pred_op: str = "none"
    pred_k: int | float = 0
    snapshot_ts: int | None = None

    def lower(self) -> KR.AggregateRequest:
        schema = self.table.schema
        agg_word = schema.word_offset(self.agg_col)
        agg_dtype = schema.column(self.agg_col).dtype
        return KR.AggregateRequest(
            agg_word=agg_word,
            agg_dtype=agg_dtype,
            **_pred_fields(self.table, self.pred_col, self.pred_op,
                           self.pred_k, self.snapshot_ts, agg_word, agg_dtype),
        )

    def result_bytes(self) -> int:
        return 8  # the [sum, count] float pair


@dataclasses.dataclass(frozen=True, eq=False)
class GroupByOp:
    """Fused ``SELECT SUM(agg), COUNT(*) ... GROUP BY group`` partials."""

    table: RelationalTable
    group_col: str
    agg_col: str
    num_groups: int
    pred_col: str | None = None
    pred_op: str = "none"
    pred_k: int | float = 0
    snapshot_ts: int | None = None

    def lower(self) -> KR.GroupByRequest:
        schema = self.table.schema
        agg_word = schema.word_offset(self.agg_col)
        agg_dtype = schema.column(self.agg_col).dtype
        return KR.GroupByRequest(
            group_word=schema.word_offset(self.group_col),
            agg_word=agg_word,
            num_groups=self.num_groups,
            agg_dtype=agg_dtype,
            **_pred_fields(self.table, self.pred_col, self.pred_op,
                           self.pred_k, self.snapshot_ts, agg_word, agg_dtype),
        )

    def result_bytes(self) -> int:
        return self.num_groups * 8  # (G, 2) float partials


@dataclasses.dataclass(frozen=True, eq=False)
class JoinOp:
    """Device-resident equi-join: probe-side scan + bucketed build probe.

    The op names the registered probe-side ``{left_proj, key}`` view, the
    build table, and (optionally) the hash partitions the planner found in
    the build cache at compile time (``None`` means build-and-insert at
    execution, exactly like the sorted-index closure of the host route).

    :meth:`lower` emits only the **probe-side scan request** — a plain
    ``ProjectRequest`` (or, snapshot-pinned, a ``FilterRequest`` with an
    inert predicate whose mask is the MVCC visibility) — so a join admitted
    into a mixed tick coalesces into the same heterogeneous one-pass scan as
    co-tick filters/aggregates on the probe table; the bucket probe itself
    runs on the packed output (``RelationalMemoryEngine._finish_join``).  A
    join that is *alone* on its table skips the packed materialization
    entirely: the engine streams the probe kernel straight over the
    device row-store chunks (``_join_direct``).
    """

    view: EphemeralView  # probe-side {left_proj, key} registered view
    left_proj: str
    key: str
    right_table: RelationalTable
    right_proj: str
    snapshot_ts: int | None = None
    partitions: object | None = None  # JoinPartitions from the build cache

    @property
    def table(self) -> RelationalTable:
        return self.view.table

    def lower(self) -> KR.ProjectRequest | KR.FilterRequest:
        if self.snapshot_ts is None:
            return KR.ProjectRequest(self.view.geometry)
        # inert predicate over the (int32) key column: the request's mask is
        # exactly the probe rows' MVCC visibility at the snapshot
        return KR.FilterRequest(
            self.view.geometry,
            **_pred_fields(self.table, self.key, "none", 0,
                           self.snapshot_ts, 0, "int32"),
        )

    def result_bytes(self) -> int:
        # JoinResult: s_proj (4B) + r_proj (4B) + matched (1B) per probe row
        return self.view.geometry.row_count * 9


ScanOp = ProjectOp | FilterOp | AggregateOp | GroupByOp | JoinOp
