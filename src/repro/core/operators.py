"""Relational operators over the three access paths of the paper's §6.

Every query from the Relational Memory Benchmark (Listing 5) is expressed
against three interchangeable data paths so the benchmarks can reproduce the
paper's comparisons:

* ``"rme"`` — through the engine: ephemeral views / fused near-memory kernels.
  Only the enabled columns' bytes cross toward compute.
* ``"row"`` — *direct row-wise access*: the full row store is shipped and the
  columns are sliced CPU-side (the strided-access baseline the paper beats).
* ``"col"`` — *direct columnar access*: a materialized column-store copy
  (``columnar_copy``), i.e. what adaptive-layout systems maintain.  Tuple
  reconstruction shows up naturally as per-column array traffic.

All paths produce identical results; tests assert cross-path equality and the
benchmarks report time + exact bytes moved per path.

Since the plan-IR refactor, ``q0``–``q5`` are *thin plan constructors*: each
builds a logical plan (:mod:`repro.core.plan`) and hands it to
:func:`repro.core.planner.compile_plan`, which routes it to the best physical
path — fused offload kernels, shared-scan materialization, or host-side
fallback.  The physical execution bodies (and the q5 sorted build-side index
cache) live in :mod:`repro.core.planner`; the names re-exported below keep the
established ``operators`` surface stable for tests and benchmarks.
"""

from __future__ import annotations

from typing import Mapping

import jax
import numpy as np

from .engine import RelationalMemoryEngine
from .plan import plan
from .planner import (  # noqa: F401  (re-exported operator surface)
    _BUILD_INDEX_CACHE,
    JOIN_BUILD_STATS,
    CompileOptions,
    JoinResult,
    clear_join_build_cache,
    compile_plan,
)
from .table import RelationalTable, columnar_copy

PATHS = ("rme", "row", "col")


# ----------------------------------------------------------------- queries
def q0_sum(
    engine: RelationalMemoryEngine,
    table: RelationalTable,
    col: str = "A1",
    path: str = "rme",
    colstore: Mapping[str, np.ndarray] | None = None,
) -> float:
    """Q0: SELECT SUM(A1) FROM S."""
    q = plan(table).sum(col)
    return compile_plan(
        q, engine, options=CompileOptions(path=path, colstore=colstore)
    ).run()


def q1_project(
    engine: RelationalMemoryEngine,
    table: RelationalTable,
    cols: tuple[str, ...],
    path: str = "rme",
    colstore: Mapping[str, np.ndarray] | None = None,
) -> jax.Array:
    """Q1: SELECT A1..Ak FROM S — returns the packed (N, k_words) group.

    The ``col`` path pays tuple reconstruction: k separate column arrays are
    re-interleaved into row order (the paper's increasing cost with
    projectivity); ``row`` ships full rows then slices.
    """
    q = plan(table).project(*cols)
    return compile_plan(
        q, engine, options=CompileOptions(path=path, colstore=colstore)
    ).run()


def q2_select_project(
    engine: RelationalMemoryEngine,
    table: RelationalTable,
    proj: str = "A1",
    pred: str = "A3",
    k: int = 0,
    path: str = "rme",
    colstore: Mapping[str, np.ndarray] | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Q2: SELECT A1 FROM S WHERE A3 > k — returns (values, mask).

    ``values`` are raw packed words on every path (the fused kernel's output
    contract — decode float32 columns with a bitcast, as ``EphemeralView
    .column`` does); previously the row/col baselines decoded while the rme
    kernel did not, so the paths disagreed for non-int32 columns.
    """
    q = plan(table).filter(pred, "gt", k).project(proj)
    packed, mask = compile_plan(
        q, engine, options=CompileOptions(path=path, colstore=colstore)
    ).run()
    return packed[:, 0], mask


def q3_select_aggregate(
    engine: RelationalMemoryEngine,
    table: RelationalTable,
    agg: str = "A2",
    pred: str = "A4",
    k: int = 0,
    path: str = "rme",
    colstore: Mapping[str, np.ndarray] | None = None,
) -> float:
    """Q3: SELECT SUM(A2) FROM S WHERE A4 < k."""
    q = plan(table).filter(pred, "lt", k).sum(agg)
    return compile_plan(
        q, engine, options=CompileOptions(path=path, colstore=colstore)
    ).run()


def q4_groupby_avg(
    engine: RelationalMemoryEngine,
    table: RelationalTable,
    agg: str = "A1",
    pred: str = "A3",
    group: str = "A2",
    k: int = 0,
    num_groups: int = 64,
    path: str = "rme",
    colstore: Mapping[str, np.ndarray] | None = None,
) -> jax.Array:
    """Q4: SELECT AVG(A1) FROM S WHERE A3 < k GROUP BY A2 (group domain mod G)."""
    q = plan(table).filter(pred, "lt", k).groupby(group, agg, "avg", num_groups)
    return compile_plan(
        q, engine, options=CompileOptions(path=path, colstore=colstore)
    ).run()


def q5_hash_join(
    engine: RelationalMemoryEngine,
    s_table: RelationalTable,
    r_table: RelationalTable,
    s_proj: str = "A1",
    key: str = "A2",
    r_proj: str = "A3",
    path: str = "rme",
    s_colstore: Mapping[str, np.ndarray] | None = None,
    r_colstore: Mapping[str, np.ndarray] | None = None,
) -> JoinResult:
    """Q5: SELECT S.A1, R.A3 FROM S JOIN R ON S.A2 = R.A2.

    RME's role (paper §6): project only {key, projected} from each side, so
    the join's data movement shrinks from full rows to two slim columns per
    table; the join itself stays on the CPU ("relying on traditional CPUs for
    data processing once good locality has been achieved").  Both sides go
    through the batch path: one shared scan per table.
    """
    q = plan(s_table).join(r_table, key=key, left_proj=s_proj, right_proj=r_proj)
    return compile_plan(
        q, engine, options=CompileOptions(
            path=path, colstore=s_colstore, right_colstore=r_colstore
        )
    ).run()


def run_query(name: str, *args, **kwargs):
    return {
        "q0": q0_sum,
        "q1": q1_project,
        "q2": q2_select_project,
        "q3": q3_select_aggregate,
        "q4": q4_groupby_avg,
        "q5": q5_hash_join,
    }[name](*args, **kwargs)


def make_colstore(table: RelationalTable, cols) -> dict[str, np.ndarray]:
    """Materialize the 'direct columnar' baseline copy for the given columns."""
    return columnar_copy(table, list(cols))
