"""Relational operators over the three access paths of the paper's §6.

Every query from the Relational Memory Benchmark (Listing 5) is implemented
against three interchangeable data paths so the benchmarks can reproduce the
paper's comparisons:

* ``"rme"`` — through the engine: ephemeral views / fused near-memory kernels.
  Only the enabled columns' bytes cross toward compute.
* ``"row"`` — *direct row-wise access*: the full row store is shipped and the
  columns are sliced CPU-side (the strided-access baseline the paper beats).
* ``"col"`` — *direct columnar access*: a materialized column-store copy
  (``columnar_copy``), i.e. what adaptive-layout systems maintain.  Tuple
  reconstruction shows up naturally as per-column array traffic.

All paths produce identical results; tests assert cross-path equality and the
benchmarks report time + exact bytes moved per path.
"""

from __future__ import annotations

import dataclasses
import weakref
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np

from .engine import RelationalMemoryEngine
from .schema import TableGeometry
from .table import RelationalTable, columnar_copy

PATHS = ("rme", "row", "col")


def _decode_i32(x: jax.Array, dtype: str) -> jax.Array:
    if dtype == "float32":
        return jax.lax.bitcast_convert_type(x, jnp.float32)
    return x


def _col_from_rows(table: RelationalTable, name: str) -> jax.Array:
    """Direct row-wise column read: ships every row word, slices one column."""
    words = jnp.asarray(table.words())  # the whole row store moves
    off = table.schema.word_offset(name)
    col = table.schema.column(name)
    return _decode_i32(words[:, off], col.dtype)


def _col_any(
    engine: RelationalMemoryEngine,
    table: RelationalTable,
    colstore: Mapping[str, np.ndarray] | None,
    view,
    name: str,
    path: str,
) -> jax.Array:
    if path == "rme":
        off, w = view.column_words(name)
        return _decode_i32(view.packed()[:, off], table.schema.column(name).dtype)
    if path == "row":
        return _col_from_rows(table, name)
    if path == "col":
        return jnp.asarray(colstore[name])
    raise ValueError(path)


# ----------------------------------------------------------------- queries
def q0_sum(
    engine: RelationalMemoryEngine,
    table: RelationalTable,
    col: str = "A1",
    path: str = "rme",
    colstore: Mapping[str, np.ndarray] | None = None,
) -> float:
    """Q0: SELECT SUM(A1) FROM S."""
    if path == "rme":
        s, _ = engine.aggregate(table, col)
        return s
    if path == "row":
        return float(jnp.sum(_col_from_rows(table, col).astype(jnp.float32)))
    return float(jnp.sum(jnp.asarray(colstore[col]).astype(jnp.float32)))


def q1_project(
    engine: RelationalMemoryEngine,
    table: RelationalTable,
    cols: tuple[str, ...],
    path: str = "rme",
    colstore: Mapping[str, np.ndarray] | None = None,
) -> jax.Array:
    """Q1: SELECT A1..Ak FROM S — returns the packed (N, k_words) group.

    The ``col`` path pays tuple reconstruction: k separate column arrays are
    re-interleaved into row order (the paper's increasing cost with
    projectivity); ``row`` ships full rows then slices.
    """
    if path == "rme":
        return engine.register(table, cols).packed()
    if path == "row":
        words = jnp.asarray(table.words())
        parts = []
        for name in sorted(cols, key=table.schema.byte_offset):
            off = table.schema.word_offset(name)
            parts.append(words[:, off : off + table.schema.column(name).words])
        return jnp.concatenate(parts, axis=1)
    # columnar: gather each column then reconstruct tuples (interleave)
    parts = []
    for name in sorted(cols, key=table.schema.byte_offset):
        arr = np.asarray(colstore[name])
        if arr.dtype.kind == "S":  # char columns travel as raw words
            arr = np.ascontiguousarray(arr).view(np.uint8).reshape(
                table.row_count, -1
            ).view(np.int32)
        parts.append(jnp.asarray(arr).reshape(table.row_count, -1).view(jnp.int32))
    return jnp.concatenate(parts, axis=1)


def q2_select_project(
    engine: RelationalMemoryEngine,
    table: RelationalTable,
    proj: str = "A1",
    pred: str = "A3",
    k: int = 0,
    path: str = "rme",
    colstore: Mapping[str, np.ndarray] | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Q2: SELECT A1 FROM S WHERE A3 > k — returns (values, mask)."""
    if path == "rme":
        from repro.kernels.ops import filter_project

        geom = TableGeometry.from_schema(table.schema, [proj], table.row_count)
        pw = table.schema.word_offset(pred)
        packed, mask = filter_project(
            engine.device_words(table), geom, pred_word=pw,
            pred_dtype=table.schema.column(pred).dtype, pred_op="gt", pred_k=k,
            block_rows=engine.block_rows, interpret=engine.interpret,
        )
        return packed[:, 0], mask
    view = None
    a = _col_any(engine, table, colstore, view, proj, path)
    b = _col_any(engine, table, colstore, view, pred, path)
    mask = b > k
    return jnp.where(mask, a, 0), mask


def q3_select_aggregate(
    engine: RelationalMemoryEngine,
    table: RelationalTable,
    agg: str = "A2",
    pred: str = "A4",
    k: int = 0,
    path: str = "rme",
    colstore: Mapping[str, np.ndarray] | None = None,
) -> float:
    """Q3: SELECT SUM(A2) FROM S WHERE A4 < k."""
    if path == "rme":
        s, _ = engine.aggregate(table, agg, pred, "lt", k)
        return s
    view = None
    a = _col_any(engine, table, colstore, view, agg, path).astype(jnp.float32)
    b = _col_any(engine, table, colstore, view, pred, path)
    return float(jnp.sum(jnp.where(b < k, a, 0.0)))


def q4_groupby_avg(
    engine: RelationalMemoryEngine,
    table: RelationalTable,
    agg: str = "A1",
    pred: str = "A3",
    group: str = "A2",
    k: int = 0,
    num_groups: int = 64,
    path: str = "rme",
    colstore: Mapping[str, np.ndarray] | None = None,
) -> jax.Array:
    """Q4: SELECT AVG(A1) FROM S WHERE A3 < k GROUP BY A2 (group domain mod G)."""
    if path == "rme":
        from repro.kernels.ops import groupby_sum

        s = table.schema
        sums, counts = groupby_sum(
            engine.device_words(table), group_word=s.word_offset(group),
            agg_word=s.word_offset(agg), num_groups=num_groups,
            agg_dtype=s.column(agg).dtype, pred_word=s.word_offset(pred),
            pred_dtype=s.column(pred).dtype, pred_op="lt", pred_k=k,
            block_rows=engine.block_rows, interpret=engine.interpret,
        )
        return sums / jnp.maximum(counts, 1.0)
    view = None
    a = _col_any(engine, table, colstore, view, agg, path).astype(jnp.float32)
    p = _col_any(engine, table, colstore, view, pred, path)
    g = jnp.remainder(_col_any(engine, table, colstore, view, group, path), num_groups)
    mask = p < k
    vals = jnp.where(mask, a, 0.0)
    cnt = mask.astype(jnp.float32)
    sums = jax.ops.segment_sum(vals, g, num_segments=num_groups)
    counts = jax.ops.segment_sum(cnt, g, num_segments=num_groups)
    return sums / jnp.maximum(counts, 1.0)


@dataclasses.dataclass
class JoinResult:
    """Static-shape join output: one slot per probe row + match validity."""

    s_proj: jax.Array  # projected column from the probe side S
    r_proj: jax.Array  # matched column from the build side R (0 where no match)
    matched: jax.Array  # bool mask


# Sorted build-side index cache for q5: argsort over the build table is the
# join's dominant host-side cost, and the build side is usually the stable
# dimension table — re-sorting it per probe throws that work away.  Keyed by
# (table uid, version, key col, payload col, path) so any OLTP mutation of
# the build side invalidates, exactly like the reorg cache (uid, not id():
# the cache is module-global and must never alias a recycled address).  The
# "col" path is never cached — its data comes from a caller-supplied colstore
# the table's version says nothing about.  FIFO-bounded by bytes, and a dead
# build table's entries are dropped by a weakref finalizer so the global
# cache cannot pin device arrays of collected tables.
_BUILD_INDEX_CACHE: dict[tuple, tuple[jax.Array, jax.Array]] = {}
_BUILD_INDEX_CAPACITY = 64 << 20
_build_index_bytes = 0  # incremental occupancy (kept exact by every mutation)
_BUILD_INDEX_FINALIZED: set[int] = set()
JOIN_BUILD_STATS = {"hits": 0, "misses": 0}


def _entry_bytes(entry: tuple[jax.Array, jax.Array]) -> int:
    return sum(a.size * a.dtype.itemsize for a in entry)


def _pop_build_entry(k: tuple) -> None:
    global _build_index_bytes
    entry = _BUILD_INDEX_CACHE.pop(k, None)
    if entry is not None:
        _build_index_bytes -= _entry_bytes(entry)


def clear_join_build_cache() -> None:
    global _build_index_bytes
    _BUILD_INDEX_CACHE.clear()
    _build_index_bytes = 0
    JOIN_BUILD_STATS["hits"] = 0
    JOIN_BUILD_STATS["misses"] = 0


def _drop_build_entries(uid: int, keep_version: int | None = None) -> None:
    """Drop a table's cached indexes (all of them, or all but one version)."""
    if keep_version is None:
        _BUILD_INDEX_FINALIZED.discard(uid)
    for k in [k for k in _BUILD_INDEX_CACHE
              if k[0] == uid and k[1] != keep_version]:
        _pop_build_entry(k)


def _probe_build_index(
    r_table: RelationalTable, key: str, r_proj: str, path: str
) -> tuple[jax.Array, jax.Array] | None:
    """Warm-path probe, called *before* the build side is materialized — a hit
    must skip the build-side column reads entirely, not just the argsort."""
    if path == "col":  # colstore contents are not keyed by the table version
        return None
    hit = _BUILD_INDEX_CACHE.get((r_table.uid, r_table.version, key, r_proj, path))
    if hit is not None:
        JOIN_BUILD_STATS["hits"] += 1
    else:
        JOIN_BUILD_STATS["misses"] += 1
    return hit


def _insert_build_index(
    entry: tuple[jax.Array, jax.Array],
    r_table: RelationalTable,
    key: str,
    r_proj: str,
    path: str,
) -> None:
    global _build_index_bytes
    if path == "col":
        return
    # versions are monotonic: this table's older entries can never hit again
    _drop_build_entries(r_table.uid, keep_version=r_table.version)
    nbytes = _entry_bytes(entry)
    if nbytes > _BUILD_INDEX_CAPACITY:
        return  # larger than the whole budget: never cached
    while _build_index_bytes + nbytes > _BUILD_INDEX_CAPACITY and _BUILD_INDEX_CACHE:
        _pop_build_entry(next(iter(_BUILD_INDEX_CACHE)))
    _BUILD_INDEX_CACHE[(r_table.uid, r_table.version, key, r_proj, path)] = entry
    _build_index_bytes += nbytes
    if r_table.uid not in _BUILD_INDEX_FINALIZED:
        weakref.finalize(r_table, _drop_build_entries, r_table.uid)
        _BUILD_INDEX_FINALIZED.add(r_table.uid)


def q5_hash_join(
    engine: RelationalMemoryEngine,
    s_table: RelationalTable,
    r_table: RelationalTable,
    s_proj: str = "A1",
    key: str = "A2",
    r_proj: str = "A3",
    path: str = "rme",
    s_colstore: Mapping[str, np.ndarray] | None = None,
    r_colstore: Mapping[str, np.ndarray] | None = None,
) -> JoinResult:
    """Q5: SELECT S.A1, R.A3 FROM S JOIN R ON S.A2 = R.A2.

    RME's role (paper §6): project only {key, projected} from each side, so
    the join's data movement shrinks from full rows to two slim columns per
    table; the join itself stays on the CPU ("relying on traditional CPUs for
    data processing once good locality has been achieved").  The build side is
    assumed duplicate-free on the key (primary key), as in the paper's setup.
    The implementation is a sort-probe equi-join (searchsorted): functionally
    the single-pass hash table build + probe of the paper, but MXU/VPU-friendly
    (no dynamic-size hash buckets) — a TPU adaptation noted in DESIGN.md.
    """
    # probe the sorted-index cache before touching the build side at all: a
    # warm hit skips the build-side column reads, not just the argsort
    cached = _probe_build_index(r_table, key, r_proj, path)
    if path == "rme":
        sv = engine.register(s_table, (s_proj, key))
        if cached is None:
            rv = engine.register(r_table, (key, r_proj))
            # both sides go through the batch path: one shared scan per table
            s_packed, r_packed = engine.materialize_many([sv, rv])
            r_key = r_packed[:, rv.column_words(key)[0]]
            r_val = r_packed[:, rv.column_words(r_proj)[0]]
        else:
            s_packed = sv.packed()
        s_key = s_packed[:, sv.column_words(key)[0]]
        s_val = s_packed[:, sv.column_words(s_proj)[0]]
    else:
        view = None
        s_key = _col_any(engine, s_table, s_colstore, view, key, path)
        s_val = _col_any(engine, s_table, s_colstore, view, s_proj, path)
        if cached is None:
            r_key = _col_any(engine, r_table, r_colstore, view, key, path)
            r_val = _col_any(engine, r_table, r_colstore, view, r_proj, path)

    if cached is not None:
        rk_sorted, rv_sorted = cached
    else:
        order = jnp.argsort(r_key)
        rk_sorted, rv_sorted = r_key[order], r_val[order]
        _insert_build_index((rk_sorted, rv_sorted), r_table, key, r_proj, path)
    pos = jnp.searchsorted(rk_sorted, s_key)
    pos = jnp.clip(pos, 0, rk_sorted.shape[0] - 1)
    matched = rk_sorted[pos] == s_key
    return JoinResult(
        s_proj=s_val,
        r_proj=jnp.where(matched, rv_sorted[pos], 0),
        matched=matched,
    )


def run_query(name: str, *args, **kwargs):
    return {
        "q0": q0_sum,
        "q1": q1_project,
        "q2": q2_select_project,
        "q3": q3_select_aggregate,
        "q4": q4_groupby_avg,
        "q5": q5_hash_join,
    }[name](*args, **kwargs)


def make_colstore(table: RelationalTable, cols) -> dict[str, np.ndarray]:
    """Materialize the 'direct columnar' baseline copy for the given columns."""
    return columnar_copy(table, list(cols))
