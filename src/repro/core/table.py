"""Row-major in-memory relational table with MVCC timestamps (paper §4).

The base data is *always* a row store ("the source data tables are always stored
in physical memory according to the same format — i.e., as a row-store").  Host
numpy plays the role of DRAM: appends and in-place updates are cheap row-wise
operations.  Analytics never touch this buffer directly — they go through
ephemeral column-group views that the RME materializes on the fly (ephemeral.py).

MVCC (paper §4): every row carries two hidden timestamp fields.  ``ts_begin`` is
set at insertion, ``ts_end`` marks deletion/replacement (``TS_INF`` while live).
A snapshot at time ``t`` sees rows with ``ts_begin <= t < ts_end`` — snapshot
isolation, exactly the scheme the paper sketches.
"""

from __future__ import annotations

import itertools
from typing import Mapping, Sequence

import numpy as np

from .schema import Column, TableSchema

# process-unique table identities for engine-side caches: id() values are
# recycled by the allocator, so a dead table's address can resurrect its
# cache entries — uid never repeats
_TABLE_UIDS = itertools.count()

TS_INF = np.iinfo(np.int32).max

_MVCC_COLS = (Column("__ts_begin", "int32"), Column("__ts_end", "int32"))


def _storage_schema(schema: TableSchema) -> TableSchema:
    return TableSchema(schema.columns + _MVCC_COLS)


def _encode_column(col: Column, values: np.ndarray, n: int) -> np.ndarray:
    """Encode ``values`` for ``col`` into an (n, col.words) int32 word array."""
    if col.dtype == "char":
        raw = np.zeros((n, col.width), dtype=np.uint8)
        vals = np.asarray(values, dtype=np.dtype((np.bytes_, col.width)))
        raw[:] = vals.view(np.uint8).reshape(n, col.width)
        return raw.view(np.int32).reshape(n, col.words)
    arr = np.ascontiguousarray(np.asarray(values, dtype=col.np_dtype))
    return arr.view(np.int32).reshape(n, col.words)


def _decode_column(col: Column, words: np.ndarray) -> np.ndarray:
    """Decode an (n, col.words) int32 word array back to ``col``'s dtype."""
    n = words.shape[0]
    raw = np.ascontiguousarray(words, dtype=np.int32)
    if col.dtype == "char":
        return raw.view(np.uint8).reshape(n, col.width).view(
            np.dtype((np.bytes_, col.width))
        ).reshape(n)
    return raw.view(col.np_dtype).reshape(n)


class RelationalTable:
    """Append-friendly row store over int32 words (the 'DRAM' of the system).

    Storage is ``(capacity, row_words)`` int32; the user-visible schema is
    extended with the two MVCC word columns.  ``version`` increments on every
    mutation — the engine uses it (plus its own epoch) to invalidate cached
    reorganized views, mirroring the RME's single-cycle SPM invalidation.
    """

    def __init__(self, schema: TableSchema, capacity: int = 1024):
        self.schema = schema
        self.storage_schema = _storage_schema(schema)
        self._words = np.zeros(
            (max(capacity, 16), self.storage_schema.row_words), dtype=np.int32
        )
        self.row_count = 0
        self.version = 0
        self.uid = next(_TABLE_UIDS)  # never-recycled cache identity
        self._clock = 0

    # ------------------------------------------------------------------ time
    def now(self) -> int:
        return self._clock

    def tick(self) -> int:
        self._clock += 1
        return self._clock

    # --------------------------------------------------------------- storage
    @property
    def row_words(self) -> int:
        return self.storage_schema.row_words

    @property
    def row_bytes(self) -> int:
        return self.storage_schema.row_bytes

    def words(self) -> np.ndarray:
        """The live row-major word buffer (view; do not mutate)."""
        return self._words[: self.row_count]

    def nbytes(self) -> int:
        return self.row_count * self.row_bytes

    def _grow(self, need: int) -> None:
        cap = self._words.shape[0]
        if need <= cap:
            return
        new_cap = max(need, cap * 2)
        grown = np.zeros((new_cap, self.row_words), dtype=np.int32)
        grown[: self.row_count] = self._words[: self.row_count]
        self._words = grown

    # ------------------------------------------------------------------ OLTP
    def append(self, columns: Mapping[str, Sequence | np.ndarray]) -> np.ndarray:
        """Append new rows (insert); returns the new physical row indices."""
        missing = set(self.schema.names) - set(columns)
        if missing:
            raise ValueError(f"missing columns {sorted(missing)}")
        n = len(next(iter(columns.values())))
        ts = self.tick()
        self._grow(self.row_count + n)
        at = self.row_count
        woff = 0
        for col in self.schema.columns:
            enc = _encode_column(col, np.asarray(columns[col.name]), n)
            self._words[at : at + n, woff : woff + col.words] = enc
            woff += col.words
        self._words[at : at + n, woff] = ts  # __ts_begin
        self._words[at : at + n, woff + 1] = TS_INF  # __ts_end
        self.row_count += n
        self.version += 1
        return np.arange(at, at + n)

    def delete(self, rows: np.ndarray) -> None:
        """MVCC delete: end the validity of the given physical rows."""
        ts = self.tick()
        end_col = self.schema.row_words + 1
        live = self._words[rows, end_col] == TS_INF
        self._words[np.asarray(rows)[live], end_col] = ts
        self.version += 1

    def update(self, rows: np.ndarray, values: Mapping[str, np.ndarray]) -> np.ndarray:
        """MVCC update: end old versions, append replacements (paper §4)."""
        rows = np.asarray(rows)
        current = {
            name: self.read_column_at(name, rows) for name in self.schema.names
        }
        current.update({k: np.asarray(v) for k, v in values.items()})
        self.delete(rows)
        return self.append(current)

    # ------------------------------------------------------------------ OLAP
    def snapshot_mask(self, ts: int | None = None) -> np.ndarray:
        """Row-validity mask at snapshot time ``ts`` (defaults to now)."""
        ts = self._clock if ts is None else ts
        begin = self._words[: self.row_count, self.schema.row_words]
        end = self._words[: self.row_count, self.schema.row_words + 1]
        return (begin <= ts) & (ts < end)

    def read_column_at(self, name: str, rows: np.ndarray) -> np.ndarray:
        col = self.schema.column(name)
        woff = self.schema.word_offset(name)
        return _decode_column(col, self._words[rows, woff : woff + col.words])

    def read_column(self, name: str, ts: int | None = None) -> np.ndarray:
        """Direct row-wise read of one column (the slow path the paper beats)."""
        mask = self.snapshot_mask(ts)
        return self.read_column_at(name, np.nonzero(mask)[0])

    def to_rows(self, ts: int | None = None) -> dict[str, np.ndarray]:
        mask = self.snapshot_mask(ts)
        idx = np.nonzero(mask)[0]
        return {n: self.read_column_at(n, idx) for n in self.schema.names}

    # ------------------------------------------------------------- factories
    @staticmethod
    def from_columns(
        schema: TableSchema, columns: Mapping[str, np.ndarray]
    ) -> "RelationalTable":
        n = len(next(iter(columns.values())))
        t = RelationalTable(schema, capacity=n)
        t.append(columns)
        return t


def columnar_copy(table: RelationalTable, names: Sequence[str]) -> dict[str, np.ndarray]:
    """A materialized column-store copy — the paper's 'direct columnar' baseline.

    This is what adaptive-layout systems maintain (and must invalidate); the RME
    makes it unnecessary.  Used only as a comparison point in the benchmarks.
    """
    mask = table.snapshot_mask()
    idx = np.nonzero(mask)[0]
    return {n: table.read_column_at(n, idx) for n in names}
