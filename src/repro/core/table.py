"""Row-major in-memory relational table with MVCC timestamps (paper §4).

The base data is *always* a row store ("the source data tables are always stored
in physical memory according to the same format — i.e., as a row-store").  Host
numpy plays the role of DRAM: appends and in-place updates are cheap row-wise
operations.  Analytics never touch this buffer directly — they go through
ephemeral column-group views that the RME materializes on the fly (ephemeral.py).

MVCC (paper §4): every row carries two hidden timestamp fields.  ``ts_begin`` is
set at insertion, ``ts_end`` marks deletion/replacement (``TS_INF`` while live).
A snapshot at time ``t`` sees rows with ``ts_begin <= t < ts_end`` — snapshot
isolation, exactly the scheme the paper sketches.

Write-path change tracking
--------------------------
The table exposes its mutation history in two orthogonal pieces instead of one
monolithic version counter, because the two kinds of OLTP write touch storage
in structurally different ways:

* **Appends** only ever add rows at the tail.  ``append_watermark`` (an alias
  of ``row_count``) is the high-water mark: physical rows ``[0, w)`` are
  immutable *in their user-column words* once written — all later writes land
  at ``>= w`` or in the hidden ``__ts_end`` word.
* **Destructive mutations** (``delete``, and the delete half of ``update``)
  rewrite exactly one hidden word per touched row (``__ts_end``).
  ``mutation_version`` counts these events, and the **patch log** records the
  physical rows each event touched, so a consumer holding an older device copy
  can replay just the patched timestamp words instead of re-reading the table
  (``patches_since``).

``version`` is the derived pair ``(row_count, mutation_version)``: equal
versions imply byte-identical storage, so it remains a valid cache-invalidation
token for consumers that don't care about deltas (e.g. the q5 build-index
cache), while delta-aware consumers (:class:`~repro.core.engine.DeviceRowStore`,
the reorganization cache) compare the components to ship O(delta) bytes.
"""

from __future__ import annotations

import itertools
from typing import Mapping, Sequence

import numpy as np

from .compression import Codec, DeltaCodec, DictCodec, fit_codec
from .schema import Column, TableSchema

# process-unique table identities for engine-side caches: id() values are
# recycled by the allocator, so a dead table's address can resurrect its
# cache entries — uid never repeats
_TABLE_UIDS = itertools.count()

TS_INF = np.iinfo(np.int32).max

_MVCC_COLS = (Column("__ts_begin", "int32"), Column("__ts_end", "int32"))

# the patch log keeps at most this many delete events; consumers lagging
# further behind fall back to a full re-sync (DeviceRowStore re-upload)
MAX_PATCH_EVENTS = 256


def _storage_schema(schema: TableSchema) -> TableSchema:
    return TableSchema(schema.columns + _MVCC_COLS)


def _encode_column(col: Column, values: np.ndarray, n: int) -> np.ndarray:
    """Encode ``values`` for ``col`` into an (n, col.words) int32 word array."""
    if col.dtype == "char":
        raw = np.zeros((n, col.width), dtype=np.uint8)
        vals = np.asarray(values, dtype=np.dtype((np.bytes_, col.width)))
        raw[:] = vals.view(np.uint8).reshape(n, col.width)
        return raw.view(np.int32).reshape(n, col.words)
    arr = np.ascontiguousarray(np.asarray(values, dtype=col.np_dtype))
    return arr.view(np.int32).reshape(n, col.words)


def _decode_column(col: Column, words: np.ndarray) -> np.ndarray:
    """Decode an (n, col.words) int32 word array back to ``col``'s dtype."""
    n = words.shape[0]
    raw = np.ascontiguousarray(words, dtype=np.int32)
    if col.dtype == "char":
        return raw.view(np.uint8).reshape(n, col.width).view(
            np.dtype((np.bytes_, col.width))
        ).reshape(n)
    return raw.view(col.np_dtype).reshape(n)


class RelationalTable:
    """Append-friendly row store over int32 words (the 'DRAM' of the system).

    Storage is ``(capacity, row_words)`` int32; the user-visible schema is
    extended with the two MVCC word columns.  Mutations are tracked at delta
    granularity: appends advance ``append_watermark`` (= ``row_count``),
    destructive mutations advance ``mutation_version`` and log the patched
    rows, and the derived ``version`` pair invalidates anything cached against
    an older state — mirroring the RME's single-cycle SPM invalidation without
    forcing full re-materialization on O(1) writes.
    """

    def __init__(self, schema: TableSchema, capacity: int = 1024,
                 codecs: Mapping[str, Codec] | None = None):
        self.schema = schema
        self.storage_schema = _storage_schema(schema)
        self._words = np.zeros(
            (max(capacity, 16), self.storage_schema.row_words), dtype=np.int32
        )
        self.row_count = 0
        self.uid = next(_TABLE_UIDS)  # never-recycled cache identity
        self._clock = 0
        # destructive-mutation tracking: one patch-log entry (the touched
        # physical rows) per delete event; the base index supports trimming
        self._patch_log: list[np.ndarray] = []
        self._patch_base = 0
        # table-level codecs (paper §4): encoded columns store int32 code
        # words; ``codecs`` pre-seeds fitted codecs (e.g. one dictionary
        # shared by two tables' join keys), and columns *declaring* a codec
        # in the schema get an empty fit here that the first append re-fits.
        # ``storage_epoch`` counts in-place re-encodes of stored words — the
        # one mutation appends/patches can't describe — so any device copy
        # or derived cache must treat an epoch bump as a full re-sync.
        self.codecs: dict[str, Codec] = {}
        self.storage_epoch = 0
        for name, codec in (codecs or {}).items():
            col = schema.column(name)  # raises KeyError for unknown names
            if col.dtype not in ("int32", "str"):
                raise ValueError(
                    f"column {name!r}: codecs need int32 or str storage,"
                    f" not {col.dtype}"
                )
            self.codecs[name] = codec
        for col in schema.columns:
            if col.codec is not None and col.name not in self.codecs:
                empty = np.array(
                    [], dtype=np.str_ if col.dtype == "str" else np.int32
                )
                self.codecs[col.name] = fit_codec(col.codec, empty)

    # ------------------------------------------------------------------ time
    def now(self) -> int:
        return self._clock

    def tick(self) -> int:
        self._clock += 1
        return self._clock

    # ------------------------------------------------------------- versioning
    @property
    def append_watermark(self) -> int:
        """Rows ``[0, append_watermark)`` exist; their user-column words are
        immutable (only the hidden ``__ts_end`` word may change later)."""
        return self.row_count

    @property
    def mutation_version(self) -> int:
        """Count of destructive-mutation events (``delete`` / ``update``)."""
        return self._patch_base + len(self._patch_log)

    @property
    def version(self) -> tuple[int, int]:
        """``(append_watermark, mutation_version)`` — equal pairs imply
        byte-identical storage.  Kept as the coarse invalidation token for
        consumers without a delta path."""
        return (self.row_count, self.mutation_version)

    @property
    def ts_begin_word(self) -> int:
        return self.schema.row_words

    @property
    def ts_end_word(self) -> int:
        return self.schema.row_words + 1

    def patches_since(self, seq: int) -> list[np.ndarray] | None:
        """Patched-row arrays for mutation events ``(seq, mutation_version]``.

        Returns ``None`` when ``seq`` predates the trimmed log — the caller's
        copy is too old to patch forward and must fully re-sync.  Each entry
        lists physical rows whose ``__ts_end`` word was rewritten by one
        event; replaying them in order (values from :meth:`ts_end_at`)
        reproduces the current timestamp state.
        """
        if seq < self._patch_base:
            return None
        return self._patch_log[seq - self._patch_base :]

    def ts_end_at(self, rows: np.ndarray) -> np.ndarray:
        """Current ``__ts_end`` words of the given physical rows."""
        return self._words[np.asarray(rows), self.ts_end_word]

    def _log_patch(self, rows: np.ndarray) -> None:
        self._patch_log.append(np.asarray(rows, dtype=np.int64))
        if len(self._patch_log) > MAX_PATCH_EVENTS:
            drop = len(self._patch_log) - MAX_PATCH_EVENTS
            del self._patch_log[:drop]
            self._patch_base += drop

    # --------------------------------------------------------------- storage
    @property
    def row_words(self) -> int:
        return self.storage_schema.row_words

    @property
    def row_bytes(self) -> int:
        return self.storage_schema.row_bytes

    def words(self) -> np.ndarray:
        """The live row-major word buffer (view; do not mutate)."""
        return self._words[: self.row_count]

    def tail_words(self, start_row: int) -> np.ndarray:
        """Rows ``[start_row, row_count)`` — the append delta a consumer that
        synced at watermark ``start_row`` still has to ship."""
        return self._words[start_row : self.row_count]

    def nbytes(self) -> int:
        return self.row_count * self.row_bytes

    def _grow(self, need: int) -> None:
        cap = self._words.shape[0]
        if need <= cap:
            return
        new_cap = max(need, cap * 2)
        grown = np.zeros((new_cap, self.row_words), dtype=np.int32)
        grown[: self.row_count] = self._words[: self.row_count]
        self._words = grown

    def _append_rows(self, n: int, ts: int) -> int:
        """Reserve ``n`` tail rows stamped ``[ts, TS_INF)``; returns the start."""
        self._grow(self.row_count + n)
        at = self.row_count
        self._words[at : at + n, self.ts_begin_word] = ts
        self._words[at : at + n, self.ts_end_word] = TS_INF
        return at

    # ------------------------------------------------------------ compression
    def _value_dtype(self, col: Column) -> np.dtype:
        return np.dtype(np.str_ if col.dtype == "str" else np.int32)

    def _encode_stored(self, col: Column, values: np.ndarray, n: int) -> np.ndarray:
        """``values`` -> the (n, col.words) int32 words the row store keeps:
        codec code words for encoded columns, plain words otherwise.  New
        values outside the fitted codec trigger an honest re-fit (never a
        silent corruption): see :meth:`_refit_codec`."""
        codec = self.codecs.get(col.name)
        if codec is None:
            return _encode_column(col, values, n)
        values = np.asarray(values, dtype=self._value_dtype(col))
        try:
            codes = codec.encode(values)
        except ValueError:
            codes = self._refit_codec(col, values)
        return codes.reshape(n, 1)

    def _refit_codec(self, col: Column, values: np.ndarray) -> np.ndarray:
        """Re-fit ``col``'s codec over old ∪ new values and re-encode the
        stored code words in place.

        This is the honest answer to an append/update outside the fitted
        dictionary (or FOR delta range): the alternative — encoding to a
        clipped or aliased code — would silently corrupt.  An in-place
        re-encode is the one storage mutation the append-watermark/patch-log
        contract cannot express, so it bumps ``storage_epoch``, advances the
        patch base past every handed-out sequence (``patches_since`` returns
        ``None`` → device copies fully re-sync), and thereby also bumps
        ``mutation_version`` (join-build and broadcast caches invalidate).
        A FOR column whose value range stops fitting 32-bit deltas falls
        back to plain int32 storage — the codec is dropped, not fudged.
        Returns the new code words for ``values``.
        """
        old = self.codecs[col.name]
        woff = self.schema.word_offset(col.name)
        stored = self._words[: self.row_count, woff]
        if isinstance(old, DictCodec):
            old_values = old.decode_np(stored)
            pool = (np.concatenate([old.dictionary, values])
                    if old.dictionary.size else values)
            merged = DictCodec.fit(pool)
            if self.row_count:
                self._words[: self.row_count, woff] = merged.encode(old_values)
            self.codecs[col.name] = merged
            self._bump_storage_epoch()
            return merged.encode(values)
        assert isinstance(old, DeltaCodec)
        old_values = old.decode_np(stored).astype(np.int64)
        merged_vals = np.concatenate([old_values,
                                      np.asarray(values, dtype=np.int64)])
        new = DeltaCodec.fit_global(merged_vals)
        try:
            restored = new.encode(old_values) if self.row_count else None
            codes = new.encode(np.asarray(values, dtype=np.int64))
        except ValueError:
            # the value range exceeds 32-bit deltas: drop to plain storage
            if self.row_count:
                self._words[: self.row_count, woff] = old_values.astype(np.int32)
            del self.codecs[col.name]
            self._bump_storage_epoch()
            return np.asarray(values, dtype=np.int32)
        if restored is not None:
            self._words[: self.row_count, woff] = restored
        self.codecs[col.name] = new
        self._bump_storage_epoch()
        return codes

    def _bump_storage_epoch(self) -> None:
        mv = self.mutation_version
        self._patch_log.clear()
        self._patch_base = mv + 1  # every older sync token re-syncs in full
        self.storage_epoch += 1

    # ------------------------------------------------------------------ OLTP
    def append(self, columns: Mapping[str, Sequence | np.ndarray]) -> np.ndarray:
        """Append new rows (insert); returns the new physical row indices.

        Appends never touch existing rows: the delta a device-resident copy
        must ship is exactly the new rows' words (see ``append_watermark``).
        """
        missing = set(self.schema.names) - set(columns)
        if missing:
            raise ValueError(f"missing columns {sorted(missing)}")
        n = len(next(iter(columns.values())))
        ts = self.tick()
        at = self._append_rows(n, ts)
        woff = 0
        for col in self.schema.columns:
            enc = self._encode_stored(col, np.asarray(columns[col.name]), n)
            self._words[at : at + n, woff : woff + col.words] = enc
            woff += col.words
        self.row_count += n
        return np.arange(at, at + n)

    def delete(self, rows: np.ndarray) -> int:
        """MVCC delete: end the validity of the given physical rows.

        Only the hidden ``__ts_end`` word of each still-live row is rewritten;
        the touched rows are recorded in the patch log so delta-aware
        consumers upload O(rows) timestamp words, not the whole table.  A
        delete that touches no live row is a no-op (no mutation event).
        Returns the number of rows actually deleted — already-dead or
        duplicated ids don't count.
        """
        ts = self.tick()
        rows = np.asarray(rows)
        live = self._words[rows, self.ts_end_word] == TS_INF
        touched = np.unique(rows[live])
        if touched.size == 0:
            return 0
        self._words[touched, self.ts_end_word] = ts
        self._log_patch(touched)
        return int(touched.size)

    def update(self, rows: np.ndarray, values: Mapping[str, np.ndarray]) -> np.ndarray:
        """MVCC update: end old versions, append replacements (paper §4).

        Columns absent from ``values`` are copied as raw storage words —
        never round-tripped through decode/encode — so untouched columns are
        byte-identical in the replacement rows (and immune to any lossy
        re-encoding) and the copy is one sliced word move instead of a
        per-column decode pass.
        """
        rows = np.asarray(rows)
        n = len(rows)
        user_words = self.schema.row_words
        # encode the touched columns *before* snapshotting raw words: an
        # out-of-codec value re-fits the codec and rewrites stored code words
        # in place, and the raw copy must see the re-encoded state
        enc = {}
        for name, vals in values.items():
            col = self.schema.column(name)  # raises KeyError for unknown names
            enc[name] = self._encode_stored(col, np.asarray(vals), n)
        raw = self._words[rows, :user_words].copy()  # before delete patches ts
        for name, e in enc.items():
            woff = self.schema.word_offset(name)
            raw[:, woff : woff + self.schema.column(name).words] = e
        self.delete(rows)
        ts = self.tick()
        at = self._append_rows(n, ts)
        self._words[at : at + n, :user_words] = raw
        self.row_count += n
        return np.arange(at, at + n)

    # ------------------------------------------------------------------ OLAP
    def snapshot_mask(self, ts: int | None = None) -> np.ndarray:
        """Row-validity mask at snapshot time ``ts`` (defaults to now)."""
        ts = self._clock if ts is None else ts
        begin = self._words[: self.row_count, self.ts_begin_word]
        end = self._words[: self.row_count, self.ts_end_word]
        return (begin <= ts) & (ts < end)

    def read_column_at(self, name: str, rows: np.ndarray) -> np.ndarray:
        col = self.schema.column(name)
        woff = self.schema.word_offset(name)
        words = self._words[rows, woff : woff + col.words]
        codec = self.codecs.get(name)
        if codec is not None:  # code words -> values (host-side, no device)
            return codec.decode_np(words.reshape(-1), np.asarray(rows))
        return _decode_column(col, words)

    def read_column(self, name: str, ts: int | None = None) -> np.ndarray:
        """Direct row-wise read of one column (the slow path the paper beats)."""
        mask = self.snapshot_mask(ts)
        return self.read_column_at(name, np.nonzero(mask)[0])

    def to_rows(self, ts: int | None = None) -> dict[str, np.ndarray]:
        mask = self.snapshot_mask(ts)
        idx = np.nonzero(mask)[0]
        return {n: self.read_column_at(n, idx) for n in self.schema.names}

    # ------------------------------------------------------------- factories
    @staticmethod
    def from_columns(
        schema: TableSchema, columns: Mapping[str, np.ndarray],
        codecs: Mapping[str, Codec] | None = None,
    ) -> "RelationalTable":
        """``codecs`` pre-seeds fitted codecs — the spelling for a dictionary
        *shared* across tables (encoded join keys must agree on one
        table-level dictionary, so both tables are built from the same
        fitted :class:`~repro.core.compression.DictCodec`)."""
        n = len(next(iter(columns.values())))
        t = RelationalTable(schema, capacity=n, codecs=codecs)
        t.append(columns)
        return t

    # ------------------------------------------------------------ durability
    def checkpoint_payload(self) -> dict:
        """The WAL ``checkpoint`` record body: enough state to reconstruct
        this table byte-identically (storage words + MVCC clock)."""
        return {
            "schema": self.schema,
            "words": self._words[: self.row_count].copy(),
            "row_count": self.row_count,
            "clock": self._clock,
            # stored words of encoded columns are code words: the fitted
            # codecs (and the epoch of their last in-place re-encode) are
            # part of the byte-identical reconstruction contract
            "codecs": dict(self.codecs),
            "storage_epoch": self.storage_epoch,
        }

    @staticmethod
    def recover(wal, key) -> "RelationalTable | None":
        """Rebuild the table for ``key`` from a (possibly torn) WAL.

        Restores the latest surviving ``checkpoint`` record, then replays
        every subsequent write record through the real :meth:`append` /
        :meth:`update` / :meth:`delete` methods.  Because the MVCC clock
        ticks only on writes, replaying the same mutation sequence from the
        same checkpoint re-derives the exact same timestamps: the recovered
        table's ``words()`` and ``now()`` are byte-identical to the
        pre-crash table's, as far as the log survived.  Returns ``None``
        when no checkpoint for ``key`` survived the crash (the caller falls
        back to its pre-WAL state).
        """
        table: RelationalTable | None = None
        for rec in wal.records():
            if rec.key != key:
                continue
            if rec.kind == "checkpoint":
                p = rec.payload
                table = RelationalTable(
                    p["schema"], capacity=max(p["row_count"], 16)
                )
                table._words[: p["row_count"]] = p["words"]
                table.row_count = p["row_count"]
                table._clock = p["clock"]
                # restore the codecs the checkpointed code words were
                # encoded with (records from before codec support lack them)
                table.codecs = dict(p.get("codecs", table.codecs))
                table.storage_epoch = p.get("storage_epoch", 0)
            elif table is None:
                continue  # write before any surviving checkpoint: unanchored
            elif rec.kind == "insert":
                table.append(rec.payload["columns"])
            elif rec.kind == "update":
                table.update(rec.payload["rows"], rec.payload["values"])
            elif rec.kind == "delete":
                table.delete(rec.payload["rows"])
            else:
                raise ValueError(f"unknown WAL record kind {rec.kind!r}")
        return table


def columnar_copy(table: RelationalTable, names: Sequence[str]) -> dict[str, np.ndarray]:
    """A materialized column-store copy — the paper's 'direct columnar' baseline.

    This is what adaptive-layout systems maintain (and must invalidate); the RME
    makes it unnecessary.  Used only as a comparison point in the benchmarks.
    """
    mask = table.snapshot_mask()
    idx = np.nonzero(mask)[0]
    return {n: table.read_column_at(n, idx) for n in names}
