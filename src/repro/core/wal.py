"""Write-ahead log for the HTAP write path — crash-consistent host writes.

The row store is the single source of truth (``core/table.py``), and it
lives in volatile host memory; a crash mid-workload loses every applied
write.  Mainlining Databases (Li et al., PAPERS.md) shows the standard
cure for a columnar/HTAP design: append a durable delta log *before* the
store mutates, and replay it on recovery.  This module is that log.

Records are length-framed and CRC-checksummed::

    [u32 body_len][u32 crc32(body)][body = pickle((key, kind, payload))]

``key`` identifies the table (the server uses ``table.uid``), ``kind`` is
``"checkpoint"`` / ``"insert"`` / ``"update"`` / ``"delete"``, and the
payload carries exactly the arguments the matching
:class:`~repro.core.table.RelationalTable` method takes.  The serving
layer (``QueryServer(wal=...)``) appends one ``checkpoint`` record the
first time a table takes a write — the full word buffer, row count, and
MVCC clock at that instant — then one record per applied write, *before*
the host store mutates (write-ahead discipline: a crash between append
and apply replays an extra record, never loses an acknowledged one).

Recovery tolerates a torn tail by construction: :meth:`records` walks the
frames in order and stops cleanly at the first truncated or
checksum-corrupt record, so a crash at *any* byte boundary yields the
longest valid prefix.  :meth:`~repro.core.table.RelationalTable.recover`
replays that prefix into a byte-identical table (identical storage words
*and* MVCC clock — replaying the same mutation sequence re-derives the
same timestamps), from which the engine's delta-chunked device store
rebuilds byte-identical resident chunks on first sync.

The log is an in-memory ``bytearray`` with optional file persistence:
pass ``path=`` to mirror every append to disk (flushed per record), and
``WriteAheadLog.open(path)`` to load one back.  Tests drive the
in-memory form and simulate crashes with :meth:`truncated`.
"""

from __future__ import annotations

import dataclasses
import pickle
import struct
import zlib
from typing import Any, Iterator

_HEADER = struct.Struct("<II")  # (body_len, crc32)


@dataclasses.dataclass(frozen=True)
class WALRecord:
    """One decoded log record (``end`` = byte offset just past its frame)."""

    key: Any
    kind: str
    payload: dict
    offset: int
    end: int


class WriteAheadLog:
    """Append-only checksummed record log (see module docstring)."""

    def __init__(self, path: str | None = None):
        self._buf = bytearray()
        self.path = path
        self._file = open(path, "ab") if path is not None else None

    # ------------------------------------------------------------- writing
    def append(self, key: Any, kind: str, payload: dict) -> int:
        """Frame, checksum, and append one record; returns its index.

        The record is fully in the log (and flushed to ``path``, if any)
        before this returns — the caller may then mutate the host store.
        """
        body = pickle.dumps((key, kind, payload), protocol=pickle.HIGHEST_PROTOCOL)
        frame = _HEADER.pack(len(body), zlib.crc32(body) & 0xFFFFFFFF) + body
        self._buf.extend(frame)
        if self._file is not None:
            self._file.write(frame)
            self._file.flush()
        return self.record_count - 1

    # ------------------------------------------------------------- reading
    def records(self) -> Iterator[WALRecord]:
        """Decode records in order, stopping at the first torn or corrupt
        frame (the surviving prefix of a crashed log)."""
        buf, off = self._buf, 0
        while off + _HEADER.size <= len(buf):
            n, crc = _HEADER.unpack_from(buf, off)
            body = bytes(buf[off + _HEADER.size: off + _HEADER.size + n])
            if len(body) < n:
                return  # torn tail: the final append never completed
            if zlib.crc32(body) & 0xFFFFFFFF != crc:
                return  # corrupt tail: bit rot or a torn in-place write
            key, kind, payload = pickle.loads(body)
            end = off + _HEADER.size + n
            yield WALRecord(key, kind, payload, off, end)
            off = end

    def boundaries(self) -> list[int]:
        """Byte offsets at each record boundary (0, after record 0, ...) —
        the crash points the recovery property test sweeps."""
        out = [0]
        out.extend(rec.end for rec in self.records())
        return out

    @property
    def record_count(self) -> int:
        return sum(1 for _ in self.records())

    @property
    def nbytes(self) -> int:
        return len(self._buf)

    # ------------------------------------------------- crash simulation/IO
    def to_bytes(self) -> bytes:
        return bytes(self._buf)

    @classmethod
    def from_bytes(cls, data: bytes) -> "WriteAheadLog":
        wal = cls()
        wal._buf = bytearray(data)
        return wal

    def truncated(self, nbytes: int) -> "WriteAheadLog":
        """A new log holding only the first ``nbytes`` — a crash that tore
        the tail at an arbitrary byte position."""
        return WriteAheadLog.from_bytes(self._buf[:nbytes])

    def corrupted_tail(self) -> "WriteAheadLog":
        """A new log whose final record's body has one flipped bit — the
        checksum must reject it and recovery must keep the prefix."""
        recs = list(self.records())
        if not recs:
            return WriteAheadLog.from_bytes(self._buf)
        data = bytearray(self._buf)
        data[recs[-1].end - 1] ^= 0x01
        return WriteAheadLog.from_bytes(data)

    @classmethod
    def open(cls, path: str) -> "WriteAheadLog":
        """Load a persisted log for recovery (tolerates a torn tail)."""
        with open(path, "rb") as f:
            data = f.read()
        wal = cls.from_bytes(data)
        return wal

    def close(self) -> None:
        if self._file is not None:
            self._file.close()
            self._file = None
