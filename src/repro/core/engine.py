"""The Relational Memory Engine (RME) — host-side orchestration.

This module is the software incarnation of the paper's Fig. 5 datapath:

* ``register`` plays the **Configuration Port**: it writes the table geometry
  (row size R, row count N, enabled columns Q with widths/offsets, frame F)
  and returns an :class:`~repro.core.ephemeral.EphemeralView` handle.
* The **Reorganization Buffer** (data SPM + metadata SPM) becomes
  :class:`ReorgCache`: reorganized column groups keyed by geometry, validated
  by an *epoch*.  The paper invalidates the whole SPM in one cycle by bumping
  the RME epoch; we do exactly that — ``reset()`` is O(1), it never walks or
  frees entries eagerly.
* **Hot vs cold** accesses (paper Fig. 6) map to cache hit vs kernel launch.
  The engine counts both, plus exact bytes pulled from the row store, so the
  benchmarks report the same cache-efficiency story as the paper's PMU plots.

The engine's compute path is revision-selectable (``bsl``/``pck``/``mlp``
Pallas kernels, or the ``xla`` fused-gather path used when lowering for
non-TPU targets), mirroring the paper's §5.2 hardware revisions.

Scan-sharing batch execution
----------------------------
In the paper, the row store lives next to the RME — it is never copied to get
scanned.  The software analogue is :class:`DeviceRowStore`: each table's word
buffer is uploaded host→device **once** and kept resident, keyed by
``(table.uid, table.version)``, so cold materializations and fused aggregates
stop re-shipping DRAM on every call (``EngineStats.bytes_uploaded`` /
``uploads`` count the transfers that do happen).

The heterogeneous one-pass scan
-------------------------------
On top of that sits :meth:`RelationalMemoryEngine.execute_many` (driven by
:class:`repro.core.executor.BatchExecutor` and the serving layer): pending
scan ops of **any** kind — projections, predicated filters, fused aggregates,
group-by partials (:mod:`repro.core.requests`) — are coalesced per table,
lowered to kernel scan requests (equal requests de-duplicate into one output
slot), and served by the heterogeneous one-pass kernel in
``repro.kernels.rme_scan_multi``: one Fetch-Unit stream per table per batch,
every request's output emitted from that single pass.  This is the paper's §8
extension argument made real for the whole query surface — selection,
aggregation, and group-by offloads share the stream instead of each sweeping
the row store on their own.  Bus-beat bytes are attributed to the shared scan
exactly once via the *union* geometry over all requests' enabled words
(:func:`repro.kernels.rme_scan_multi.union_geometry`), every projection lands
in the :class:`ReorgCache` so subsequent accesses are hot, and a batch whose
modeled VMEM working set exceeds the 2 MB SPM budget auto-halves its row-tile
height before launching (``EngineStats.last_block_rows`` records the choice).
A lone request keeps its single-op kernel — solo queries never pay the fused
formulation.  :meth:`materialize_many` is the projection-only thin wrapper,
and ``aggregate_async`` — the non-blocking sibling of ``aggregate`` — is a
one-op batch through the same path.
"""

from __future__ import annotations

import dataclasses
import weakref
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.kernels import ops as K
from repro.kernels import rme_scan_multi as KR
from repro.kernels.rme_project import vmem_footprint_bytes

from .descriptor import bytes_moved
from .ephemeral import EphemeralView
from .requests import AggregateOp, ProjectOp, ScanOp
from .schema import WORD, TableGeometry
from .table import RelationalTable

# the fused-pass tile guard never shrinks below this (grid overhead dominates)
MIN_FUSED_BLOCK_ROWS = 32


@dataclasses.dataclass
class EngineStats:
    """Counters surfaced to the benchmarks (the 'PMU' of the software RME)."""

    hot_hits: int = 0
    cold_misses: int = 0
    shared_scans: int = 0  # batched multi-view passes over a row store
    rows_projected: int = 0
    bytes_from_dram: int = 0  # bus-beat-accurate bytes the engine pulled
    bytes_to_cpu: int = 0  # packed bytes shipped up the hierarchy
    bytes_uploaded: int = 0  # host→device row-store transfer bytes
    uploads: int = 0  # host→device row-store transfer count
    last_block_rows: int = 0  # row-tile height the fused-pass VMEM guard chose

    def reset(self) -> None:
        self.hot_hits = 0
        self.cold_misses = 0
        self.shared_scans = 0
        self.rows_projected = 0
        self.bytes_from_dram = 0
        self.bytes_to_cpu = 0
        self.bytes_uploaded = 0
        self.uploads = 0
        self.last_block_rows = 0


class ReorgCache:
    """Epoch-validated cache of reorganized views (the two SPMs of Fig. 5).

    An entry is valid iff its stored epoch equals the cache's current epoch —
    the paper's single-cycle invalidation. Entries also carry the source table
    version, so any OLTP mutation (append/update/delete) invalidates affected
    views without touching unrelated tables.
    """

    def __init__(self, capacity_bytes: int = 2 << 20):  # paper: 2 MB data SPM
        self.capacity_bytes = capacity_bytes
        self.epoch = 0
        self._entries: dict[tuple, tuple[int, int, jax.Array]] = {}
        self._bytes = 0

    def reset(self) -> None:
        """Single-cycle SPM invalidation: bump the epoch; entries expire lazily."""
        self.epoch += 1

    def get(self, key: tuple, version: int) -> jax.Array | None:
        hit = self._entries.get(key)
        if hit is None:
            return None
        epoch, ver, arr = hit
        if epoch != self.epoch or ver != version:
            del self._entries[key]
            self._bytes -= arr.size * arr.dtype.itemsize
            return None
        return arr

    def peek(self, key: tuple, version: int) -> jax.Array | None:
        """Hotness probe without side effects: stale entries are left in place.

        The planner uses this — costing a query must not mutate cache state
        (``get`` deletes stale entries as it misses, which made planning a
        write operation).
        """
        hit = self._entries.get(key)
        if hit is None:
            return None
        epoch, ver, arr = hit
        if epoch != self.epoch or ver != version:
            return None
        return arr

    def put(self, key: tuple, version: int, arr: jax.Array) -> None:
        nbytes = arr.size * arr.dtype.itemsize
        if nbytes > self.capacity_bytes:
            return  # larger than the SPM: streamed, never cached (paper §6 scaling)
        old = self._entries.pop(key, None)
        if old is not None:
            self._bytes -= old[2].size * old[2].dtype.itemsize
        # evict stale-epoch entries first, then FIFO until it fits
        for k in [k for k, (e, _, _) in self._entries.items() if e != self.epoch]:
            _, _, a = self._entries.pop(k)
            self._bytes -= a.size * a.dtype.itemsize
        while self._bytes + nbytes > self.capacity_bytes and self._entries:
            oldest = next(iter(self._entries))  # FIFO: evict the oldest insert
            _, _, a = self._entries.pop(oldest)
            self._bytes -= a.size * a.dtype.itemsize
        self._entries[key] = (self.epoch, version, arr)
        self._bytes += nbytes

    @property
    def occupancy_bytes(self) -> int:
        return self._bytes


class DeviceRowStore:
    """Device-resident row-store buffers, keyed by ``(table.uid, version)``.

    The paper's row store sits beside the RME in DRAM; nothing ever copies it
    to scan it.  Our 'DRAM' is host numpy, so the first access to a table must
    ship its word buffer to the device — but only the first: the buffer stays
    resident until the table mutates (version bump), at which point the next
    access uploads the new version and drops the old one.  One buffer is kept
    per table identity (``uid``, never recycled — unlike ``id()``), a weakref
    finalizer drops the buffer when its table is garbage collected, and every
    upload is charged to the engine's PMU (``bytes_uploaded`` / ``uploads``).
    """

    def __init__(self, stats: EngineStats | None = None):
        self.stats = stats
        self._buffers: dict[int, tuple[int, jax.Array]] = {}
        self._finalized: set[int] = set()  # uids with a registered finalizer

    @staticmethod
    def _finalize_entry(store_ref: "weakref.ref[DeviceRowStore]", uid: int) -> None:
        store = store_ref()
        if store is not None:
            store._buffers.pop(uid, None)
            store._finalized.discard(uid)

    def get(self, table: RelationalTable) -> jax.Array:
        ent = self._buffers.get(table.uid)
        if ent is not None and ent[0] == table.version:
            return ent[1]
        host = table.words()
        arr = jnp.asarray(host)
        if table.uid not in self._finalized:
            # dead tables must not pin device memory: evict with their owner.
            # The finalizer must hold the store weakly — a strong reference
            # (e.g. the bound `self._buffers.pop`) would let any long-lived
            # table pin a dead engine's whole buffer set.  One finalizer per
            # uid: clear()/drop() + re-upload must not accumulate more.
            weakref.finalize(table, self._finalize_entry, weakref.ref(self), table.uid)
            self._finalized.add(table.uid)
        self._buffers[table.uid] = (table.version, arr)
        if self.stats is not None:
            self.stats.uploads += 1
            self.stats.bytes_uploaded += host.size * host.itemsize
        return arr

    def contains(self, table: RelationalTable) -> bool:
        ent = self._buffers.get(table.uid)
        return ent is not None and ent[0] == table.version

    def drop(self, table: RelationalTable) -> None:
        self._buffers.pop(table.uid, None)

    def clear(self) -> None:
        self._buffers.clear()

    @property
    def occupancy_bytes(self) -> int:
        return sum(a.size * a.dtype.itemsize for _, a in self._buffers.values())


class RelationalMemoryEngine:
    """Host-side RME: registers ephemeral views and materializes them on access.

    ``revision`` selects the datapath (paper §5.2): ``"bsl"``, ``"pck"``,
    ``"mlp"`` (Pallas kernels, validated in interpret mode on CPU), or
    ``"xla"`` (fused gather — the path that lowers for CPU/dry-run targets).
    """

    def __init__(
        self,
        revision: str = "mlp",
        block_rows: int = K.DEFAULT_BLOCK_ROWS,
        cache_bytes: int = 2 << 20,
        interpret: bool = True,
        vmem_bytes: int = 2 << 20,  # paper: 2 MB data SPM
    ):
        if revision not in K.REVISIONS:
            raise ValueError(f"unknown revision {revision!r}; want one of {K.REVISIONS}")
        self.revision = revision
        self.block_rows = block_rows
        self.interpret = interpret
        self.vmem_bytes = vmem_bytes
        self.cache = ReorgCache(cache_bytes)
        self.stats = EngineStats()
        self.rowstore = DeviceRowStore(self.stats)

    # ---------------------------------------------------------------- config
    def register(
        self,
        table: RelationalTable,
        columns: Sequence[str],
        snapshot_ts: int | None = None,
        frame: int = 0,
    ) -> EphemeralView:
        """Configuration-port write: define a column-group view over ``table``.

        Nothing is materialized here (ephemeral variables "are never
        instantiated in the main memory"); the returned view triggers the
        engine on first access.
        """
        geom = TableGeometry.from_schema(
            table.schema, columns, row_count=table.row_count, frame=frame
        )
        return EphemeralView(self, table, tuple(columns), geom, snapshot_ts)

    def reset(self) -> None:
        """The configuration port's software reset SW (Table 1).

        Clears every derived-data cache the reset must invalidate: the reorg
        cache (epoch bump, O(1)) *and* the module-global q5 build-index cache
        — that one is keyed by table version, not engine epoch, so without an
        explicit clear its sorted indexes and ``JOIN_BUILD_STATS`` leak across
        benchmark repetitions.  (The cache is process-global, like the paper's
        single RME: resetting any engine resets it.)  The device row store is
        *not* dropped — it mirrors the row store itself, not derived state.
        """
        self.cache.reset()
        from .planner import clear_join_build_cache  # deferred: planner imports us

        clear_join_build_cache()

    # --------------------------------------------------------------- engine
    def view_key(self, table: RelationalTable, geom: TableGeometry) -> tuple:
        """The reorg-cache key for a view — the single definition every
        consumer (materialization, planner costing, serving-layer hot/cold
        classification) must agree on."""
        return (table.uid, geom.cache_key(), self.revision)

    def device_words(self, table: RelationalTable) -> jax.Array:
        """The table's device-resident word buffer (uploaded at most once per version)."""
        return self.rowstore.get(table)

    def materialize(self, view: EphemeralView) -> jax.Array:
        """Assemble the packed column group for ``view`` (cold) or serve it hot."""
        table, geom = view.table, view.geometry
        key = self.view_key(table, geom)
        hot = self.cache.get(key, table.version)
        if hot is not None:
            self.stats.hot_hits += 1
            return hot
        self.stats.cold_misses += 1
        words = self.device_words(table)
        packed = K.project_any(
            words, geom, revision=self.revision, block_rows=self.block_rows,
            interpret=self.interpret,
        )
        moved = bytes_moved(geom)
        self.stats.rows_projected += geom.row_count
        self.stats.bytes_from_dram += moved["rme"]
        self.stats.bytes_to_cpu += moved["columnar"]
        self.cache.put(key, table.version, packed)
        return packed

    def execute_many(self, ops: Sequence[ScanOp]) -> list:
        """Serve a heterogeneous op batch with one shared scan per table.

        Any mix of :class:`~repro.core.requests.ProjectOp` /
        ``FilterOp`` / ``AggregateOp`` / ``GroupByOp`` is coalesced per table:
        each table's cold work is lowered to kernel scan requests
        (de-duplicated — equal requests share one output slot) and served by a
        **single** pass of the heterogeneous one-pass kernel
        (``rme_scan_multi``), its bus-beat bytes charged once via the union
        geometry over every request's enabled words.  A lone request keeps
        today's single-op kernel (``project``/``filter_project``/
        ``aggregate``/``groupby_sum`` — the bsl/pck revisions stay exercised
        and nothing retraces).  Hot projections are served from the
        reorganization cache, and every cold projection lands there, warming
        the SPM for all batch members.  When the fused pass's modeled VMEM
        working set exceeds the engine's SPM budget, the row-tile height is
        halved (down to ``MIN_FUSED_BLOCK_ROWS``) before launching; the chosen
        tile is exposed as ``EngineStats.last_block_rows``.  Results are
        returned in input order, each matching its op's single-op contract.
        """
        results: list = [None] * len(ops)
        pending: dict[int, list[tuple[int, KR.ScanRequest]]] = {}
        tables: dict[int, RelationalTable] = {}
        for i, op in enumerate(ops):
            if isinstance(op, ProjectOp):
                key = self.view_key(op.table, op.view.geometry)
                hot = self.cache.get(key, op.table.version)
                if hot is not None:
                    self.stats.hot_hits += 1
                    results[i] = hot
                    continue
            pending.setdefault(op.table.uid, []).append((i, op.lower()))
            tables[op.table.uid] = op.table
        for tid, entries in pending.items():
            table = tables[tid]
            uniq = dict.fromkeys(req for _, req in entries)
            reqs = tuple(uniq)
            words = self.device_words(table)
            self.stats.cold_misses += len(entries)
            if len(reqs) == 1:
                # nothing to fuse: stay on the single-op datapath (keeps the
                # bsl/pck revision kernels) and don't count a shared scan
                outs = [self._execute_solo(words, table, reqs[0])]
            else:
                block_rows = self._fused_block_rows(reqs, words.shape[1])
                outs = K.scan_multi(
                    words, reqs, revision=self.revision,
                    block_rows=block_rows, interpret=self.interpret,
                )
                self.stats.shared_scans += 1
                self.stats.rows_projected += table.row_count
                self.stats.bytes_from_dram += self.scan_bytes(table, reqs)
            by_req = dict(zip(reqs, outs))
            for req, out in by_req.items():
                if isinstance(req, KR.ProjectRequest):
                    geom = req.geom
                    self.stats.bytes_to_cpu += geom.row_count * geom.out_bytes_per_row
                    self.cache.put(self.view_key(table, geom), table.version, out)
            for i, req in entries:
                results[i] = by_req[req]
        return results

    def materialize_many(self, views: Sequence[EphemeralView]) -> list[jax.Array]:
        """Materialize a batch of views with one shared scan per table.

        Thin wrapper over :meth:`execute_many`: each view becomes a
        :class:`~repro.core.requests.ProjectOp`, so a multi-view batch rides
        the heterogeneous one-pass scan (bus-beat bytes charged once via the
        union geometry) and every result lands in the reorganization cache.
        Results are returned in input order.
        """
        return self.execute_many([ProjectOp(v) for v in views])

    # -------------------------------------------- fused one-pass internals
    def _execute_solo(self, words: jax.Array, table: RelationalTable,
                      req: "KR.ScanRequest"):
        """One request, today's single-op kernel, engine-side accounting."""
        if isinstance(req, KR.ProjectRequest):
            out = K.project_any(
                words, req.geom, revision=self.revision,
                block_rows=self.block_rows, interpret=self.interpret,
            )
            self.stats.rows_projected += req.geom.row_count
            self.stats.bytes_from_dram += bytes_moved(req.geom)["rme"]
            return out
        self.stats.rows_projected += table.row_count
        self.stats.bytes_from_dram += self.scan_bytes(table, (req,))
        if isinstance(req, KR.FilterRequest):
            return K.filter_project(
                words, req.geom, pred_word=req.pred_word,
                pred_dtype=req.pred_dtype, pred_op=req.pred_op,
                pred_k=req.pred_k, ts=req.ts, ts_word=req.ts_word,
                block_rows=self.block_rows, interpret=self.interpret,
            )
        if isinstance(req, KR.AggregateRequest):
            return K.aggregate(
                words, agg_word=req.agg_word, agg_dtype=req.agg_dtype,
                pred_word=req.pred_word, pred_dtype=req.pred_dtype,
                pred_op=req.pred_op, pred_k=req.pred_k, ts=req.ts,
                ts_word=req.ts_word, block_rows=self.block_rows,
                interpret=self.interpret,
            )
        return K.groupby_sum(
            words, group_word=req.group_word, agg_word=req.agg_word,
            num_groups=req.num_groups, agg_dtype=req.agg_dtype,
            pred_word=req.pred_word, pred_dtype=req.pred_dtype,
            pred_op=req.pred_op, pred_k=req.pred_k, ts=req.ts,
            ts_word=req.ts_word, block_rows=self.block_rows,
            interpret=self.interpret,
        )

    def scan_bytes(self, table: RelationalTable,
                   reqs: Sequence["KR.ScanRequest"]) -> int:
        """Bus-beat bytes of one pass serving ``reqs``: Eq. (3) bursts over
        the union of every request's enabled words.  The row stride is the
        schema's — unless a fused MVCC snapshot enables the hidden timestamp
        words, in which case the storage stride (what the stream walks) is
        the honest model."""
        max_end = max(o + w for r in reqs for o, w in K.request_intervals(r))
        row_bytes = table.schema.row_bytes
        if max_end > row_bytes:
            row_bytes = table.row_words * WORD
        union = K.union_geometry(reqs, row_bytes=row_bytes,
                                 row_count=table.row_count)
        return bytes_moved(union)["rme"]

    def _fused_block_rows(self, reqs: Sequence["KR.ScanRequest"],
                          row_words: int) -> int:
        """SPM budget guard: halve the row tile until the fused pass's modeled
        VMEM working set fits ``vmem_bytes`` (never below the floor)."""
        block_rows = self.block_rows
        while (block_rows // 2 >= MIN_FUSED_BLOCK_ROWS
               and K.scan_vmem_footprint_bytes(reqs, row_words, block_rows)
               > self.vmem_bytes):
            block_rows //= 2
        self.stats.last_block_rows = block_rows
        return block_rows

    def aggregate_async(
        self,
        table: RelationalTable,
        agg_col: str,
        pred_col: str | None = None,
        pred_op: str = "none",
        pred_k=0,
        snapshot_ts: int | None = None,
    ) -> jax.Array:
        """Non-blocking fused aggregate: returns the device ``[sum, count]`` pair.

        Nothing syncs with the host here — the caller decides when (whether)
        to pull the scalars down, so batched query loops can enqueue many
        aggregates before blocking once.  The row store is read from the
        device-resident buffer: repeated aggregates over an unchanged table
        perform zero host→device transfers after the first call.  No
        ``bytes_to_cpu`` are charged here — nothing crosses to the host until
        a caller syncs (the blocking :meth:`aggregate` charges its 8 bytes).
        This is sugar for a one-op :meth:`execute_many` batch, so it shares
        the same accounting (including the bus-beat charge for the enabled
        aggregate/predicate words).
        """
        op = AggregateOp(table, agg_col, pred_col=pred_col, pred_op=pred_op,
                         pred_k=pred_k, snapshot_ts=snapshot_ts)
        return self.execute_many([op])[0]

    def aggregate(
        self,
        table: RelationalTable,
        agg_col: str,
        pred_col: str | None = None,
        pred_op: str = "none",
        pred_k=0,
        snapshot_ts: int | None = None,
    ) -> tuple[float, float]:
        """Fused near-memory ``SELECT SUM(agg), COUNT(*) WHERE pred`` (Q0/Q3).

        Only a 2-float scalar leaves the engine; the MVCC snapshot test is
        fused when a snapshot time is given.  This is the blocking wrapper
        around :meth:`aggregate_async` — the ``float()`` calls are the only
        host sync.
        """
        out = self.aggregate_async(
            table, agg_col, pred_col=pred_col, pred_op=pred_op, pred_k=pred_k,
            snapshot_ts=snapshot_ts,
        )
        self.stats.bytes_to_cpu += 8  # the [sum, count] pair crosses on sync
        return float(out[0]), float(out[1])

    def vmem_budget_bytes(self, geom: TableGeometry) -> int:
        """The 'area report' analogue: VMEM working set of one engine step."""
        return vmem_footprint_bytes(geom, self.block_rows, self.revision)
