"""The Relational Memory Engine (RME) — host-side orchestration.

This module is the software incarnation of the paper's Fig. 5 datapath:

* ``register`` plays the **Configuration Port**: it writes the table geometry
  (row size R, row count N, enabled columns Q with widths/offsets, frame F)
  and returns an :class:`~repro.core.ephemeral.EphemeralView` handle.
* The **Reorganization Buffer** (data SPM + metadata SPM) becomes
  :class:`ReorgCache`: reorganized column groups keyed by geometry, validated
  by an *epoch*.  The paper invalidates the whole SPM in one cycle by bumping
  the RME epoch; we do exactly that — ``reset()`` is O(1), it never walks or
  frees entries eagerly.
* **Hot vs cold** accesses (paper Fig. 6) map to cache hit vs kernel launch.
  The engine counts both, plus exact bytes pulled from the row store, so the
  benchmarks report the same cache-efficiency story as the paper's PMU plots.

The engine's compute path is revision-selectable (``bsl``/``pck``/``mlp``
Pallas kernels, or the ``xla`` fused-gather path used when lowering for
non-TPU targets), mirroring the paper's §5.2 hardware revisions.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as K
from repro.kernels.rme_project import vmem_footprint_bytes

from .descriptor import bytes_moved
from .ephemeral import EphemeralView
from .schema import TableGeometry
from .table import RelationalTable


@dataclasses.dataclass
class EngineStats:
    """Counters surfaced to the benchmarks (the 'PMU' of the software RME)."""

    hot_hits: int = 0
    cold_misses: int = 0
    rows_projected: int = 0
    bytes_from_dram: int = 0  # bus-beat-accurate bytes the engine pulled
    bytes_to_cpu: int = 0  # packed bytes shipped up the hierarchy

    def reset(self) -> None:
        self.hot_hits = 0
        self.cold_misses = 0
        self.rows_projected = 0
        self.bytes_from_dram = 0
        self.bytes_to_cpu = 0


class ReorgCache:
    """Epoch-validated cache of reorganized views (the two SPMs of Fig. 5).

    An entry is valid iff its stored epoch equals the cache's current epoch —
    the paper's single-cycle invalidation. Entries also carry the source table
    version, so any OLTP mutation (append/update/delete) invalidates affected
    views without touching unrelated tables.
    """

    def __init__(self, capacity_bytes: int = 2 << 20):  # paper: 2 MB data SPM
        self.capacity_bytes = capacity_bytes
        self.epoch = 0
        self._entries: dict[tuple, tuple[int, int, jax.Array]] = {}
        self._bytes = 0

    def reset(self) -> None:
        """Single-cycle SPM invalidation: bump the epoch; entries expire lazily."""
        self.epoch += 1

    def get(self, key: tuple, version: int) -> jax.Array | None:
        hit = self._entries.get(key)
        if hit is None:
            return None
        epoch, ver, arr = hit
        if epoch != self.epoch or ver != version:
            del self._entries[key]
            self._bytes -= arr.size * arr.dtype.itemsize
            return None
        return arr

    def put(self, key: tuple, version: int, arr: jax.Array) -> None:
        nbytes = arr.size * arr.dtype.itemsize
        if nbytes > self.capacity_bytes:
            return  # larger than the SPM: streamed, never cached (paper §6 scaling)
        # evict stale-epoch entries first, then FIFO until it fits
        for k in [k for k, (e, _, _) in self._entries.items() if e != self.epoch]:
            _, _, a = self._entries.pop(k)
            self._bytes -= a.size * a.dtype.itemsize
        while self._bytes + nbytes > self.capacity_bytes and self._entries:
            _, (_, _, a) = self._entries.popitem()
            self._bytes -= a.size * a.dtype.itemsize
        self._entries[key] = (self.epoch, version, arr)
        self._bytes += nbytes

    @property
    def occupancy_bytes(self) -> int:
        return self._bytes


class RelationalMemoryEngine:
    """Host-side RME: registers ephemeral views and materializes them on access.

    ``revision`` selects the datapath (paper §5.2): ``"bsl"``, ``"pck"``,
    ``"mlp"`` (Pallas kernels, validated in interpret mode on CPU), or
    ``"xla"`` (fused gather — the path that lowers for CPU/dry-run targets).
    """

    def __init__(
        self,
        revision: str = "mlp",
        block_rows: int = K.DEFAULT_BLOCK_ROWS,
        cache_bytes: int = 2 << 20,
        interpret: bool = True,
    ):
        if revision not in K.REVISIONS:
            raise ValueError(f"unknown revision {revision!r}; want one of {K.REVISIONS}")
        self.revision = revision
        self.block_rows = block_rows
        self.interpret = interpret
        self.cache = ReorgCache(cache_bytes)
        self.stats = EngineStats()

    # ---------------------------------------------------------------- config
    def register(
        self,
        table: RelationalTable,
        columns: Sequence[str],
        snapshot_ts: int | None = None,
        frame: int = 0,
    ) -> EphemeralView:
        """Configuration-port write: define a column-group view over ``table``.

        Nothing is materialized here (ephemeral variables "are never
        instantiated in the main memory"); the returned view triggers the
        engine on first access.
        """
        geom = TableGeometry.from_schema(
            table.schema, columns, row_count=table.row_count, frame=frame
        )
        return EphemeralView(self, table, tuple(columns), geom, snapshot_ts)

    def reset(self) -> None:
        """The configuration port's software reset SW (Table 1)."""
        self.cache.reset()

    # --------------------------------------------------------------- engine
    def _key(self, table: RelationalTable, geom: TableGeometry) -> tuple:
        return (id(table), geom.cache_key(), self.revision)

    def materialize(self, view: EphemeralView) -> jax.Array:
        """Assemble the packed column group for ``view`` (cold) or serve it hot."""
        table, geom = view.table, view.geometry
        key = self._key(table, geom)
        hot = self.cache.get(key, table.version)
        if hot is not None:
            self.stats.hot_hits += 1
            return hot
        self.stats.cold_misses += 1
        words = jnp.asarray(table.words())
        packed = K.project_any(
            words, geom, revision=self.revision, block_rows=self.block_rows,
            interpret=self.interpret,
        )
        moved = bytes_moved(geom)
        self.stats.rows_projected += geom.row_count
        self.stats.bytes_from_dram += moved["rme"]
        self.stats.bytes_to_cpu += moved["columnar"]
        self.cache.put(key, table.version, packed)
        return packed

    def aggregate(
        self,
        table: RelationalTable,
        agg_col: str,
        pred_col: str | None = None,
        pred_op: str = "none",
        pred_k=0,
        snapshot_ts: int | None = None,
    ) -> tuple[float, float]:
        """Fused near-memory ``SELECT SUM(agg), COUNT(*) WHERE pred`` (Q0/Q3).

        Only a 2-float scalar leaves the engine; the MVCC snapshot test is
        fused when a snapshot time is given.
        """
        schema = table.schema
        agg_word = schema.word_offset(agg_col)
        agg_dtype = schema.column(agg_col).dtype
        if pred_col is None:
            pred_word, pred_dtype = agg_word, agg_dtype
        else:
            pred_word = schema.word_offset(pred_col)
            pred_dtype = schema.column(pred_col).dtype
        ts_word = schema.row_words if snapshot_ts is not None else -1
        ts = table.now() if snapshot_ts is None else snapshot_ts
        out = K.aggregate(
            jnp.asarray(table.words()), agg_word=agg_word, agg_dtype=agg_dtype,
            pred_word=pred_word, pred_dtype=pred_dtype, pred_op=pred_op,
            pred_k=pred_k, ts=ts, ts_word=ts_word,
            block_rows=self.block_rows, interpret=self.interpret,
        )
        self.stats.cold_misses += 1
        self.stats.rows_projected += table.row_count
        self.stats.bytes_to_cpu += 8
        return float(out[0]), float(out[1])

    def vmem_budget_bytes(self, geom: TableGeometry) -> int:
        """The 'area report' analogue: VMEM working set of one engine step."""
        return vmem_footprint_bytes(geom, self.block_rows, self.revision)
