"""The Relational Memory Engine (RME) — host-side orchestration.

This module is the software incarnation of the paper's Fig. 5 datapath:

* ``register`` plays the **Configuration Port**: it writes the table geometry
  (row size R, row count N, enabled columns Q with widths/offsets, frame F)
  and returns an :class:`~repro.core.ephemeral.EphemeralView` handle.
* The **Reorganization Buffer** (data SPM + metadata SPM) becomes
  :class:`ReorgCache`: reorganized column groups keyed by geometry, validated
  by an *epoch*.  The paper invalidates the whole SPM in one cycle by bumping
  the RME epoch; we do exactly that — ``reset()`` is O(1), it never walks or
  frees entries eagerly.
* **Hot vs cold** accesses (paper Fig. 6) map to cache hit vs kernel launch.
  The engine counts both, plus exact bytes pulled from the row store, so the
  benchmarks report the same cache-efficiency story as the paper's PMU plots.

The engine's compute path is revision-selectable (``bsl``/``pck``/``mlp``
Pallas kernels, or the ``xla`` fused-gather path used when lowering for
non-TPU targets), mirroring the paper's §5.2 hardware revisions.

The write path: delta-chunked residency
---------------------------------------
In the paper, the row store lives next to the RME — it is never copied to get
scanned, and OLTP writes land in it directly.  The software analogue is
:class:`DeviceRowStore`, and since our 'DRAM' is host numpy, writes create a
host/device synchronization problem the store solves at **delta**
granularity:

* A table's device copy is a **base chunk plus appended tail chunks**
  (consecutive row ranges whose concatenation is the row store).  The first
  access uploads everything once; after that, an *append* of N rows ships
  exactly those N rows' words as a new tail chunk, and a *delete*/*update*
  ships exactly the patched hidden ``__ts_end`` words (replayed from the
  table's patch log) — never the whole table.  ``EngineStats`` splits the
  accounting: ``bytes_uploaded``/``uploads`` count every host→device
  transfer, ``bytes_uploaded_delta``/``delta_uploads`` the delta subset, so
  benchmarks can prove O(delta) transfer under sustained writes.
* The :class:`ReorgCache` is **delta-aware** for projections: a packed
  column group never contains the hidden timestamp words, so a cached view
  stays byte-valid for the physical rows it covers no matter how many
  deletes/updates patch timestamps.  A hot view whose table only grew is
  served by projecting just the appended tail and concatenating with the
  cached block (incremental view maintenance, counted in
  ``EngineStats.delta_hits``) instead of being invalidated.

Scan-sharing batch execution
----------------------------
Cold materializations and fused aggregates read the device-resident chunks —
repeated analytics over an unchanged table perform zero host→device
transfers.  On top sits :meth:`RelationalMemoryEngine.execute_many` (driven
by :class:`repro.core.executor.BatchExecutor` and the serving layer): pending
scan ops of **any** kind — projections, predicated filters, fused aggregates,
group-by partials, join probes (:mod:`repro.core.requests`) — are coalesced
per table,
lowered to kernel scan requests (equal requests de-duplicate into one output
slot), and served by the heterogeneous one-pass kernel in
``repro.kernels.rme_scan_multi``: one Fetch-Unit stream **per chunk** per
table per batch, every request's output emitted from those passes and
combined across chunks (blocked outputs concatenate, aggregate/group-by
partials add — see ``scan_multi_chunked``).  Bus-beat bytes are attributed
exactly once per chunk via the *union* geometry over all requests' enabled
words (:func:`repro.kernels.rme_scan_multi.union_geometry`), every projection
lands in the :class:`ReorgCache` so subsequent accesses are hot, and a batch
whose modeled VMEM working set exceeds the 2 MB SPM budget auto-halves its
row-tile height before launching (``EngineStats.last_block_rows`` records the
choice).  A lone request keeps its single-op kernel — solo queries never pay
the fused formulation.  :meth:`materialize_many` is the projection-only thin
wrapper, and ``aggregate_async`` — the non-blocking sibling of ``aggregate``
— is a one-op batch through the same path.
"""

from __future__ import annotations

import dataclasses
import weakref
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import common
from repro.kernels import ops as K
from repro.kernels import rme_scan_multi as KR
from repro.kernels.rme_project import vmem_footprint_bytes

from . import faults
from .descriptor import bytes_moved
from .ephemeral import EphemeralView
from .requests import (AggregateOp, JoinOp, JoinResult, ProjectOp, ScanOp,
                       finalize_scan_result)
from .schema import WORD, TableGeometry
from .table import RelationalTable

# the fused-pass tile guard never shrinks below this (grid overhead dominates)
MIN_FUSED_BLOCK_ROWS = 32

# streamed projections never slice finer than this: below it the per-chunk
# launch overhead dwarfs the chunk itself and the bus-beat rounding per slice
# starts to distort the Eq.(3) accounting
MIN_STREAM_CHUNK_ROWS = 32

# tail chunks are coalesced (device-side, no host transfer) beyond this count
# so per-chunk pass overhead stays bounded under sustained appends
MAX_TAIL_CHUNKS = 8


@dataclasses.dataclass
class EngineStats:
    """Counters surfaced to the benchmarks (the 'PMU' of the software RME).

    Charging rules (the single source of truth the benchmarks rely on):

    * ``bytes_from_dram`` — bus-beat-exact Eq.(3) bytes a scan pulled from
      the row store (union geometry for shared passes, charged once per
      chunk per pass).
    * ``bytes_to_cpu`` — packed bytes shipped up the hierarchy (per view;
      scalar syncs charge their 8 bytes at the blocking call).
    * ``bytes_uploaded`` / ``uploads`` — every host→device row-store
      transfer (full uploads *and* deltas; one event per sync).
    * ``bytes_uploaded_delta`` / ``delta_uploads`` — the delta subset:
      appended tail rows and patched ``__ts_end`` words only.  An append of
      N rows to a resident T-row table charges O(N) here, never O(T).
    * ``delta_hits`` — reorg-cache entries served by an incremental
      tail-chunk projection (also counted in ``cold_misses``: a scan, albeit
      a small one, did run).
    * ``bytes_collective`` / ``collective_ops`` — modeled interconnect
      traffic of the sharded backend: cross-shard reduction combines
      (aggregate ``[sum, count]`` pairs, group-by ``(G, 2)`` partials) and
      join build-partition broadcasts.  Always O(result/build) bytes, never
      O(rows) — blocked outputs gather through ``bytes_to_cpu`` like any
      packed view.  Zero on the single-device backend.
    * ``bytes_saved_compression`` — bytes the §4 codecs kept *off* the bus:
      for every charged pass whose union geometry touches encoded columns,
      the plain-width Eq.(3) cost minus the narrow cost actually booked to
      ``bytes_from_dram`` (``charge_scan`` is the single charge point).
    * ``decodes`` / ``decode_cache_hits`` — client-visible decode events on
      packed results (``EphemeralView.column`` → :meth:`RelationalMemoryEngine.
      decode_column``): real dictionary/FOR decodes vs per-table-version
      cache hits.  The fused pass itself never decodes — these counters stay
      0 until someone *reads* an encoded packed output.
    * ``retries`` / ``failovers`` / ``bytes_failover`` — the reliability
      layer's recovery work (``docs/reliability.md``): transient-fault
      retries of a shard pass or collective combine, shard passes
      re-executed on the root device after retries were exhausted (or the
      shard was quarantined), and the row bytes those failover passes
      re-scanned.  All zero in a fault-free run — the ≤5% overhead gate in
      ``fig_fault_recovery`` relies on that.
    """

    hot_hits: int = 0
    cold_misses: int = 0
    shared_scans: int = 0  # batched multi-view passes over a row store
    subsumed_requests: int = 0  # requests served by slicing a covering scan
    rows_projected: int = 0
    bytes_from_dram: int = 0  # bus-beat-accurate bytes the engine pulled
    bytes_to_cpu: int = 0  # packed bytes shipped up the hierarchy
    bytes_uploaded: int = 0  # host→device row-store transfer bytes (all)
    uploads: int = 0  # host→device row-store transfer count (all)
    bytes_uploaded_delta: int = 0  # of bytes_uploaded: delta-only transfers
    delta_uploads: int = 0  # of uploads: delta-only transfer events
    delta_hits: int = 0  # cache entries served by tail-chunk delta scans
    last_block_rows: int = 0  # row-tile height the fused-pass VMEM guard chose
    join_builds: int = 0  # hash-partition builds (one per build-table version)
    bytes_join_build: int = 0  # of bytes_uploaded: partition-array uploads
    bytes_collective: int = 0  # interconnect bytes (sharded reductions/broadcasts)
    collective_ops: int = 0  # cross-shard combine/broadcast events
    retries: int = 0  # transient-fault retries (shard passes, combines)
    failovers: int = 0  # shard passes re-executed on the root device
    bytes_failover: int = 0  # row bytes re-scanned by failover passes
    bytes_saved_compression: int = 0  # plain-minus-narrow bytes codecs kept off the bus
    decodes: int = 0  # client-read decodes of encoded packed results
    decode_cache_hits: int = 0  # decode results served from the per-version cache

    def reset(self) -> None:
        self.hot_hits = 0
        self.cold_misses = 0
        self.shared_scans = 0
        self.subsumed_requests = 0
        self.rows_projected = 0
        self.bytes_from_dram = 0
        self.bytes_to_cpu = 0
        self.bytes_uploaded = 0
        self.uploads = 0
        self.bytes_uploaded_delta = 0
        self.delta_uploads = 0
        self.delta_hits = 0
        self.last_block_rows = 0
        self.join_builds = 0
        self.bytes_join_build = 0
        self.bytes_collective = 0
        self.collective_ops = 0
        self.retries = 0
        self.failovers = 0
        self.bytes_failover = 0
        self.bytes_saved_compression = 0
        self.decodes = 0
        self.decode_cache_hits = 0


@dataclasses.dataclass
class PassHandle:
    """One enqueued op batch: the named half of the launch/finalize split.

    ``execute_many`` itself never syncs with the host — every result it
    returns is a device value (or a lazy cache hit) — but callers that want
    to *overlap* work need that contract spelled out as an object they can
    hold while doing something else.  :meth:`RelationalMemoryEngine.
    execute_many_async` returns one of these; the pipelined QueryServer
    stashes it for tick N while tick N+1 drains, compiles, and launches.

    ``results`` is aligned with the submitted ops (same order, same per-op
    contracts as ``execute_many``).  ``block_until_ready()`` is the only
    blocking member — an explicit rendezvous for callers that want the
    device drained without pulling any result to the host.
    """

    results: list

    def block_until_ready(self) -> "PassHandle":
        for r in self.results:
            if isinstance(r, JoinResult):
                jax.block_until_ready((r.s_proj, r.r_proj, r.matched))
            elif r is not None:
                jax.block_until_ready(r)
        return self


class ReorgCache:
    """Epoch-validated cache of reorganized views (the two SPMs of Fig. 5).

    An entry is valid iff its stored epoch equals the cache's current epoch —
    the paper's single-cycle invalidation.  Entries also carry a caller-chosen
    version token; the engine stores each packed projection under the **row
    coverage** it was built from (``table.row_count`` at build time).  Packed
    projections never include the hidden MVCC timestamp words, so an entry
    stays byte-valid for the rows it covers across any number of
    deletes/updates — only appends extend a table past an entry's coverage,
    and then the engine *delta-serves* it (tail projection + concatenate, see
    :meth:`RelationalMemoryEngine.materialize`) instead of discarding it.
    """

    def __init__(self, capacity_bytes: int = 2 << 20):  # paper: 2 MB data SPM
        self.capacity_bytes = capacity_bytes
        self.epoch = 0
        self._entries: dict[tuple, tuple[int, object, jax.Array]] = {}
        self._bytes = 0

    def reset(self) -> None:
        """Single-cycle SPM invalidation: bump the epoch; entries expire lazily."""
        self.epoch += 1

    def peek(self, key: tuple, version) -> jax.Array | None:
        """Exact-version probe without side effects.

        The planner costs queries with this; there is deliberately no
        delete-on-mismatch accessor — under coverage tokens a version
        mismatch usually means *delta-servable*, not garbage, so destroying
        mismatched entries would silently turn incremental tail serves back
        into full cold scans.  Entries are reclaimed by ``put`` (overwrite /
        stale-epoch sweep / FIFO eviction) instead.
        """
        hit = self._entries.get(key)
        if hit is None:
            return None
        epoch, ver, arr = hit
        if epoch != self.epoch or ver != version:
            return None
        return arr

    def lookup(self, key: tuple) -> tuple[object, jax.Array] | None:
        """Epoch-valid entry *regardless of version*: ``(version, arr)``.

        This is the delta-serving probe: the engine compares the stored row
        coverage against the table's current watermark to decide between a
        full hot hit, an incremental tail serve, or a cold rebuild.  Like
        ``peek``, it never mutates cache state.
        """
        hit = self._entries.get(key)
        if hit is None:
            return None
        epoch, ver, arr = hit
        if epoch != self.epoch:
            return None
        return ver, arr

    def put(self, key: tuple, version, arr: jax.Array) -> None:
        nbytes = arr.size * arr.dtype.itemsize
        if nbytes > self.capacity_bytes:
            return  # larger than the SPM: streamed, never cached (paper §6 scaling)
        old = self._entries.pop(key, None)
        if old is not None:
            self._bytes -= old[2].size * old[2].dtype.itemsize
        # evict stale-epoch entries first, then FIFO until it fits
        for k in [k for k, (e, _, _) in self._entries.items() if e != self.epoch]:
            _, _, a = self._entries.pop(k)
            self._bytes -= a.size * a.dtype.itemsize
        while self._bytes + nbytes > self.capacity_bytes and self._entries:
            oldest = next(iter(self._entries))  # FIFO: evict the oldest insert
            _, _, a = self._entries.pop(oldest)
            self._bytes -= a.size * a.dtype.itemsize
        self._entries[key] = (self.epoch, version, arr)
        self._bytes += nbytes

    @property
    def occupancy_bytes(self) -> int:
        return self._bytes


@dataclasses.dataclass
class _StoreEntry:
    """One table's device residency: base + tail chunks and sync positions."""

    chunks: list[jax.Array]  # consecutive row ranges; concat == rows [0, rows)
    rows: int  # append watermark this copy has synced to
    patch_seq: int  # table.mutation_version this copy has replayed to


class DeviceRowStore:
    """Delta-chunked device-resident row-store buffers, keyed by ``table.uid``.

    The paper's row store sits beside the RME in DRAM; nothing ever copies it
    to scan it.  Our 'DRAM' is host numpy, so the first access to a table must
    ship its word buffer to the device — but only the first.  After that the
    copy is kept in sync *incrementally*:

    * appended rows upload as a new **tail chunk** (O(new rows) bytes),
    * deleted/updated rows replay the table's patch log, rewriting only the
      hidden ``__ts_end`` word of each touched row inside the resident
      chunks (O(touched rows) words),
    * nothing else ever re-crosses the host→device boundary.

    ``get`` coalesces the chunk list into one array (a device-side concat —
    no host transfer, so it charges nothing) for single-buffer consumers;
    ``chunks`` hands the list to the chunk-iterating fused scan.  With
    ``delta=False`` the store reverts to whole-table re-upload on any change
    — the pre-delta behavior, kept as the measurable baseline for
    ``benchmarks/fig_htap_ingest.py``.

    One buffer set is kept per table identity (``uid``, never recycled —
    unlike ``id()``), a weakref finalizer drops it when its table is garbage
    collected, and every transfer is charged to the engine's PMU
    (``bytes_uploaded``/``uploads`` always; ``bytes_uploaded_delta``/
    ``delta_uploads`` additionally for delta syncs).
    """

    def __init__(self, stats: EngineStats | None = None, delta: bool = True):
        self.stats = stats
        self.delta = delta
        self._buffers: dict[int, _StoreEntry] = {}
        self._finalized: set[int] = set()  # uids with a registered finalizer

    @staticmethod
    def _finalize_entry(store_ref: "weakref.ref[DeviceRowStore]", uid: int) -> None:
        store = store_ref()
        if store is not None:
            store._buffers.pop(uid, None)
            store._finalized.discard(uid)

    # ----------------------------------------------------------------- sync
    def _charge(self, nbytes: int, is_delta: bool) -> None:
        if self.stats is None or nbytes == 0:
            return
        self.stats.uploads += 1
        self.stats.bytes_uploaded += nbytes
        if is_delta:
            self.stats.delta_uploads += 1
            self.stats.bytes_uploaded_delta += nbytes

    def _full_upload(self, table: RelationalTable) -> _StoreEntry:
        faults.maybe_fault("upload", table=table.uid, delta=False)
        host = table.words()
        ent = _StoreEntry([jnp.asarray(host)], table.row_count,
                          table.mutation_version)
        if table.uid not in self._finalized:
            # dead tables must not pin device memory: evict with their owner.
            # The finalizer must hold the store weakly — a strong reference
            # (e.g. the bound `self._buffers.pop`) would let any long-lived
            # table pin a dead engine's whole buffer set.  One finalizer per
            # uid: clear()/drop() + re-upload must not accumulate more.
            weakref.finalize(table, self._finalize_entry, weakref.ref(self), table.uid)
            self._finalized.add(table.uid)
        self._buffers[table.uid] = ent
        self._charge(host.size * host.itemsize, is_delta=False)
        return ent

    def _apply_patches(self, ent: _StoreEntry, table: RelationalTable,
                       patches: list[np.ndarray]) -> int:
        """Rewrite patched ``__ts_end`` words inside the resident chunks.

        Only rows below the entry's pre-sync watermark need patching — rows
        at or above it arrive in the freshly uploaded tail chunk with their
        current timestamps already in place.  Returns the bytes shipped.
        """
        idx = np.concatenate([p[p < ent.rows] for p in patches]) if patches else \
            np.empty(0, dtype=np.int64)
        if idx.size == 0:
            return 0
        vals = np.asarray(table.ts_end_at(idx))
        ts_word = table.ts_end_word
        start = 0
        for c, chunk in enumerate(ent.chunks):
            end = start + chunk.shape[0]
            sel = (idx >= start) & (idx < end)
            if sel.any():
                ent.chunks[c] = chunk.at[
                    jnp.asarray(idx[sel] - start), ts_word
                ].set(jnp.asarray(vals[sel]))
            start = end
        return idx.size * WORD  # one rewritten timestamp word per row

    def _sync(self, table: RelationalTable) -> _StoreEntry:
        """Bring the table's device copy current, shipping only the delta."""
        ent = self._buffers.get(table.uid)
        if ent is not None and not self.delta and (
            ent.rows != table.row_count
            or ent.patch_seq != table.mutation_version
        ):
            ent = None  # baseline mode: any change → whole-table re-upload
        if ent is None:
            return self._full_upload(table)
        patches = (table.patches_since(ent.patch_seq)
                   if ent.patch_seq != table.mutation_version else [])
        if patches is None:  # lagged past the trimmed patch log: full re-sync
            return self._full_upload(table)
        if patches or table.row_count > ent.rows:
            # before any entry mutation: a fault here leaves the resident
            # copy at its pre-sync state, so a bare retry re-syncs cleanly
            faults.maybe_fault("upload", table=table.uid, delta=True)
        moved = self._apply_patches(ent, table, patches)
        ent.patch_seq = table.mutation_version
        if table.row_count > ent.rows:
            tail = table.tail_words(ent.rows)
            ent.chunks.append(jnp.asarray(tail))
            ent.rows = table.row_count
            moved += tail.size * tail.itemsize
        self._charge(moved, is_delta=True)
        if len(ent.chunks) > MAX_TAIL_CHUNKS:
            # device-side compaction: no host transfer, nothing charged
            ent.chunks = [jnp.concatenate(ent.chunks, axis=0)]
        return ent

    # ------------------------------------------------------------ accessors
    def get(self, table: RelationalTable) -> jax.Array:
        """The table's row store as **one** device array (synced first).

        Multi-chunk entries are coalesced device-side and kept coalesced —
        single-buffer consumers (solo kernels, host fallbacks, validity
        masks) see exactly the pre-chunking contract.
        """
        ent = self._sync(table)
        if len(ent.chunks) > 1:
            ent.chunks = [jnp.concatenate(ent.chunks, axis=0)]
        return ent.chunks[0]

    def chunks(self, table: RelationalTable) -> tuple[jax.Array, ...]:
        """The table's resident chunk list (synced first), for per-chunk scans."""
        return tuple(self._sync(table).chunks)

    def tail(self, table: RelationalTable, start_row: int) -> jax.Array:
        """Device rows ``[start_row, row_count)`` — the delta-scan operand for
        incrementally maintained views.  Assembled by slicing the resident
        chunks (device-side; the sync itself shipped only the delta)."""
        ent = self._sync(table)
        parts, start = [], 0
        for chunk in ent.chunks:
            end = start + chunk.shape[0]
            if end > start_row:
                parts.append(chunk[max(start_row - start, 0) :])
            start = end
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)

    def contains(self, table: RelationalTable) -> bool:
        """True iff the resident copy is fully current (no pending delta)."""
        ent = self._buffers.get(table.uid)
        return (ent is not None and ent.rows == table.row_count
                and ent.patch_seq == table.mutation_version)

    def drop(self, table: RelationalTable) -> None:
        self._buffers.pop(table.uid, None)

    def clear(self) -> None:
        self._buffers.clear()

    @property
    def occupancy_bytes(self) -> int:
        return sum(
            c.size * c.dtype.itemsize
            for ent in self._buffers.values() for c in ent.chunks
        )


# -------------------------------------------------- request subsumption
def _geom_words(geom) -> tuple[int, ...]:
    """The absolute row-word indices a geometry enables, packed order."""
    words: list[int] = []
    for off, width in zip(geom.abs_offsets, geom.col_widths):
        words.extend(range(off // WORD, (off + width) // WORD))
    return tuple(words)


def _request_width(req: "KR.ScanRequest") -> int:
    """Covering-candidate ordering key: widest projections become the
    representatives, so subset requests fold into them."""
    if isinstance(req, (KR.ProjectRequest, KR.FilterRequest)):
        return len(_geom_words(req.geom))
    return -1  # aggregate/group-by requests never cover packed outputs


def _request_covers(a: "KR.ScanRequest", b: "KR.ScanRequest") -> bool:
    """Does serving ``a`` let the engine derive ``b``'s output exactly?

    The subsumption rule of the tick batcher: ``a``'s enabled words must be
    a superset of ``b``'s (projection ⊇) and ``a``'s predicate must be
    weaker-or-equal (predicate ⊆ in selected rows), so every row ``b``
    keeps is intact in ``a``'s packed output.  Derivation slices ``b``'s
    words out of ``a``'s packed block and, for filters, re-evaluates ``b``'s
    predicate on the raw packed words (code space — decode-free).
    Aggregate/group-by outputs are scalars/partials and take no part.
    """
    if not isinstance(a, (KR.ProjectRequest, KR.FilterRequest)):
        return False
    if not isinstance(b, (KR.ProjectRequest, KR.FilterRequest)):
        return False
    aw = set(_geom_words(a.geom))
    if isinstance(b, KR.ProjectRequest):
        # a filter's packed output zeroes failing rows — never a pure project
        return isinstance(a, KR.ProjectRequest) and aw >= set(_geom_words(b.geom))
    need = set(_geom_words(b.geom))
    if b.pred_op != "none":
        need.add(b.pred_word)
    if isinstance(a, KR.ProjectRequest):
        # visibility lives in ts words the packed block does not carry
        return b.ts_word < 0 and aw >= need
    if (a.ts_word, a.ts) != (b.ts_word, b.ts):
        return False
    weaker = a.pred_op == "none" or (
        a.pred_word == b.pred_word
        and a.pred_dtype == b.pred_dtype
        and a.pred_op == b.pred_op
        and (a.pred_k <= b.pred_k if a.pred_op == "gt" else a.pred_k >= b.pred_k)
    )
    return weaker and aw >= need


def _cover_requests(
    reqs: tuple["KR.ScanRequest", ...],
) -> tuple[tuple["KR.ScanRequest", ...], dict]:
    """Greedy covering: (representatives in input order, covered→rep map)."""
    cover: dict = {}
    reps: list = []
    for req in sorted(reqs, key=_request_width, reverse=True):
        rep = next((r for r in reps if _request_covers(r, req)), None)
        if rep is not None:
            cover[req] = rep
        else:
            reps.append(req)
    return tuple(r for r in reqs if r not in cover), cover


class RelationalMemoryEngine:
    """Host-side RME: registers ephemeral views and materializes them on access.

    ``revision`` selects the datapath (paper §5.2): ``"bsl"``, ``"pck"``,
    ``"mlp"`` (Pallas kernels, validated in interpret mode on CPU), or
    ``"xla"`` (fused gather — the path that lowers for CPU/dry-run targets).
    ``delta_uploads=False`` disables the whole write-path delta machinery:
    any table change re-ships the full device buffer on next access, and a
    grown table turns cached views cold instead of delta-serving them — the
    measurable pre-delta baseline the HTAP ingest benchmark compares against.
    """

    def __init__(
        self,
        revision: str = "mlp",
        block_rows: int = K.DEFAULT_BLOCK_ROWS,
        cache_bytes: int = 2 << 20,
        interpret: bool = True,
        vmem_bytes: int = 2 << 20,  # paper: 2 MB data SPM
        delta_uploads: bool = True,
        breaker_threshold: int = 3,
        breaker_cooldown: int = 4,
        subsume: bool = True,
    ):
        if revision not in K.REVISIONS:
            raise ValueError(f"unknown revision {revision!r}; want one of {K.REVISIONS}")
        self.revision = revision
        self.block_rows = block_rows
        self.interpret = interpret
        self.vmem_bytes = vmem_bytes
        self.delta = delta_uploads
        # subsumption-aware sharing: a batch member whose projection ⊆ and
        # predicate ⊇ another's is served by slicing/masking the covering
        # request's output instead of its own slot in the fused pass
        self.subsume = subsume
        self.cache = ReorgCache(cache_bytes)
        self.stats = EngineStats()
        self.rowstore = DeviceRowStore(self.stats, delta=delta_uploads)
        # decode-on-finalize cache: decoded client reads of encoded packed
        # outputs, keyed per table version/storage epoch (FIFO-capped)
        self._decode_cache: dict[tuple, object] = {}
        # lowering circuit breaker: flips a repeatedly-failing (table,
        # request-shape) route to the XLA fallback (docs/reliability.md)
        self.breaker = faults.CircuitBreaker(
            threshold=breaker_threshold, cooldown=breaker_cooldown
        )

    @property
    def backend(self) -> str:
        """Execution-backend identity: ``"single"`` here, ``"sharded"`` on
        :class:`repro.core.distributed.ShardedEngine`.  The planner's
        ``compile_plan(..., backend=...)`` validates against this — routing
        itself is dynamic dispatch (the sharded engine overrides the scan
        and join serving hooks), so a compiled plan runs on whichever
        backend its engine is."""
        return "single"

    # ---------------------------------------------------------------- config
    def register(
        self,
        table: RelationalTable,
        columns: Sequence[str],
        snapshot_ts: int | None = None,
        frame: int = 0,
    ) -> EphemeralView:
        """Configuration-port write: define a column-group view over ``table``.

        Nothing is materialized here (ephemeral variables "are never
        instantiated in the main memory"); the returned view triggers the
        engine on first access.  ``snapshot_ts`` pins the view's MVCC
        visibility: decoded accesses (``view.column``) and fused ops built
        from the view see exactly the rows live at that time, no matter what
        writes land afterwards — the packed block itself always covers every
        physical row (visibility is a mask, not a rewrite).
        """
        geom = TableGeometry.from_schema(
            table.schema, columns, row_count=table.row_count, frame=frame
        )
        return EphemeralView(self, table, tuple(columns), geom, snapshot_ts)

    def reset(self) -> None:
        """The configuration port's software reset SW (Table 1).

        Clears every derived-data cache the reset must invalidate: the reorg
        cache (epoch bump, O(1)) *and* the module-global q5 build-index cache
        — that one is keyed by table version, not engine epoch, so without an
        explicit clear its sorted indexes and ``JOIN_BUILD_STATS`` leak across
        benchmark repetitions.  (The cache is process-global, like the paper's
        single RME: resetting any engine resets it.)  The device row store is
        *not* dropped — it mirrors the row store itself, not derived state.
        """
        self.cache.reset()
        from .planner import clear_join_build_cache  # deferred: planner imports us

        clear_join_build_cache()

    # --------------------------------------------------------------- engine
    def view_key(self, table: RelationalTable, geom: TableGeometry) -> tuple:
        """The reorg-cache key for a view — the single definition every
        consumer (materialization, planner costing, serving-layer hot/cold
        classification) must agree on.  Keyed by the column *layout* only
        (row count excluded): a view over a grown table shares its slot with
        the pre-growth entry, which is what makes delta serving possible —
        the entry's stored version records the rows it covers.  The table's
        ``storage_epoch`` is folded in: a codec re-fit rewrites stored code
        words in place, so every pre-refit packed block is garbage."""
        return (table.uid, geom.layout_key(), self.revision,
                getattr(table, "storage_epoch", 0))

    def peek_project(self, table: RelationalTable,
                     geom: TableGeometry) -> jax.Array | None:
        """Side-effect-free full-hot probe for planner/server costing: the
        cached packed block iff it covers every current row."""
        return self.cache.peek(self.view_key(table, geom), table.row_count)

    def projection_is_cached(self, table: RelationalTable,
                             geom: TableGeometry) -> bool:
        """Side-effect-free: will :meth:`_project_from_cache` serve this view
        without a full scan — either a full hot hit or (in delta mode) a
        tail-only delta serve?  The serving layer uses this to keep its
        shared-scan/bytes-saved accounting aligned with what ``execute_many``
        will actually do."""
        ent = self.cache.lookup(self.view_key(table, geom))
        if ent is None:
            return False
        rows_cached = ent[0]
        if rows_cached == table.row_count:
            return True
        return (self.delta and isinstance(rows_cached, int)
                and 0 < rows_cached < table.row_count)

    def device_words(self, table: RelationalTable) -> jax.Array:
        """The table's device-resident word buffer as one array.

        The underlying sync ships only the write delta (appended rows,
        patched timestamp words) since the last access; multi-chunk entries
        are coalesced device-side.
        """
        return self.rowstore.get(table)

    def device_chunks(self, table: RelationalTable) -> tuple[jax.Array, ...]:
        """The table's resident base+tail chunk list (synced, O(delta))."""
        return self.rowstore.chunks(table)

    def valid_mask(self, table: RelationalTable, ts: int) -> jax.Array:
        """MVCC row visibility at snapshot ``ts``, from the device-resident
        hidden timestamp words: ``ts_begin <= ts < ts_end``.  The single
        host-side spelling of the visibility rule — ephemeral views and the
        planner's fallback routes both use it; the fused kernels evaluate
        the same test in-scan.  The underlying sync ships only the write
        delta, so this is O(patched rows) fresh after any number of writes.
        """
        words = self.device_words(table)
        begin = words[:, table.ts_begin_word]
        end = words[:, table.ts_end_word]
        return (begin <= ts) & (ts < end)

    def _project_from_cache(
        self, table: RelationalTable, geom: TableGeometry
    ) -> jax.Array | None:
        """Serve a projection from the reorg cache: full hot hit, or an
        incremental tail scan over the appended rows merged with the cached
        block (delta serve).  Returns ``None`` when a cold rebuild is needed.

        Correctness note: packed projections contain only user-column words,
        so deletes/updates (which rewrite hidden ``__ts_end`` words) never
        stale an entry — visibility is applied downstream by whoever masks
        (``EphemeralView.column``, fused snapshot tests).  Coverage is the
        only axis: an entry built at watermark ``w`` is byte-exact for rows
        ``[0, w)`` forever.
        """
        ent = self.cache.lookup(self.view_key(table, geom))
        if ent is None:
            return None
        rows_cached, cached = ent
        if rows_cached == table.row_count:
            self.stats.hot_hits += 1
            return cached
        if not self.delta:  # pre-delta compatibility mode: growth = cold
            return None
        if not isinstance(rows_cached, int) or not 0 < rows_cached < table.row_count:
            return None
        # incremental view maintenance: project only the appended tail
        n_tail = table.row_count - rows_cached
        tail = self.rowstore.tail(table, rows_cached)
        tail_geom = dataclasses.replace(geom, row_count=n_tail)
        packed_tail = K.project_any(
            tail, tail_geom, revision=self.revision,
            block_rows=self.block_rows, interpret=self.interpret,
        )
        packed = jnp.concatenate([cached, packed_tail], axis=0)
        self.stats.delta_hits += 1
        self.stats.cold_misses += 1  # a (tail-sized) scan did run
        moved = bytes_moved(tail_geom)
        self.stats.rows_projected += n_tail
        self.stats.bytes_from_dram += moved["rme"]
        self.stats.bytes_to_cpu += moved["columnar"]
        self.cache.put(self.view_key(table, geom), table.row_count, packed)
        return packed

    def materialize(self, view: EphemeralView) -> jax.Array:
        """Assemble the packed column group for ``view``: hot out of the
        reorganization cache, incrementally from a cached block plus a
        tail-chunk delta scan when the table only grew, or cold through the
        projection kernel."""
        table, geom = view.table, view.geometry
        served = self._project_from_cache(table, geom)
        if served is not None:
            return served
        self.stats.cold_misses += 1
        words = self.device_words(table)
        packed = K.project_any(
            words, geom, revision=self.revision, block_rows=self.block_rows,
            interpret=self.interpret,
        )
        moved = bytes_moved(geom)
        self.stats.rows_projected += geom.row_count
        self.stats.bytes_from_dram += moved["rme"]
        self.stats.bytes_to_cpu += moved["columnar"]
        self.cache.put(self.view_key(table, geom), table.row_count, packed)
        return packed

    def stream_project(self, view: EphemeralView,
                       chunk_rows: int | None = None):
        """Generator: the view's packed projection, one chunk at a time.

        The streaming sibling of :meth:`materialize` — instead of one packed
        block (and one blocking transfer for the consumer), the projection is
        emitted incrementally per **resident chunk** of the delta-chunked
        device row store, so a consumer (the QueryServer's streaming tickets)
        can forward each piece as soon as its scan lands.  ``chunk_rows``
        optionally re-slices resident chunks into at-most-that-many-row
        pieces (never below ``MIN_STREAM_CHUNK_ROWS``): a never-appended
        table is a single base chunk, and a bounded slice is what gives a
        multi-megabyte output its incremental delivery.

        Charging is per emitted chunk, with the same rules as a cold
        materialization of that many rows: ``rows_projected``, Eq.(3)
        ``bytes_from_dram`` over the sliced geometry, and packed
        ``bytes_to_cpu`` — each charged when its chunk is yielded, so an
        abandoned stream charges only what it actually moved.  A view the
        reorg cache can serve (hot hit or delta serve) arrives as one free
        chunk; a cold stream's concatenation lands in the cache after the
        last chunk, exactly like :meth:`materialize`.  The sharded backend
        streams unchanged: :meth:`device_chunks` there returns the per-shard
        parts in global row order.

        The *call* snapshots the resident chunk list eagerly (and triggers
        any needed upload); only the per-chunk scans are lazy.  This is what
        makes streams safe under pipelined serving — writes applied after
        the call (e.g. by the next tick's ``begin_tick``) cannot leak into
        a stream that was launched against the previous tick's state.
        """
        table, geom = view.table, view.geometry
        served = self._project_from_cache(table, geom)
        if served is not None:
            return iter((served,))
        self.stats.cold_misses += 1
        if chunk_rows is not None:
            chunk_rows = max(int(chunk_rows), MIN_STREAM_CHUNK_ROWS)
        chunks = tuple(self.device_chunks(table))
        return self._stream_chunks(table, geom, chunks, chunk_rows,
                                   table.row_count)

    def _stream_chunks(self, table: RelationalTable, geom, chunks,
                       chunk_rows: int | None, row_count: int):
        """The lazy half of :meth:`stream_project`: scan + charge + yield
        per chunk, then cache the concatenation under the snapshotted
        ``row_count`` (not the table's current one — the table may have
        grown while the stream drained)."""
        parts = []
        for chunk in chunks:
            start = 0
            while start < chunk.shape[0]:
                faults.maybe_fault("stream_chunk", table=table.uid,
                                   index=len(parts))
                stop = (chunk.shape[0] if chunk_rows is None
                        else min(start + chunk_rows, chunk.shape[0]))
                piece = chunk[start:stop]
                start = stop
                cg = dataclasses.replace(geom, row_count=piece.shape[0])
                packed = K.project_any(
                    piece, cg, revision=self.revision,
                    block_rows=self.block_rows, interpret=self.interpret,
                )
                moved = bytes_moved(cg)
                self.stats.rows_projected += cg.row_count
                self.stats.bytes_from_dram += moved["rme"]
                self.stats.bytes_to_cpu += moved["columnar"]
                parts.append(packed)
                yield packed
        if parts:
            full = parts[0] if len(parts) == 1 else jnp.concatenate(parts, 0)
            self.cache.put(self.view_key(table, geom), row_count, full)

    def execute_many(self, ops: Sequence[ScanOp]) -> list:
        """Serve a heterogeneous op batch with one shared scan per table.

        Any mix of :class:`~repro.core.requests.ProjectOp` /
        ``FilterOp`` / ``AggregateOp`` / ``GroupByOp`` is coalesced per table:
        each table's cold work is lowered to kernel scan requests
        (de-duplicated — equal requests share one output slot) and served by
        the heterogeneous one-pass kernel (``rme_scan_multi``) streamed over
        the table's **resident chunk list** — blocked outputs concatenate
        across chunks, aggregate/group-by partials add — with bus-beat bytes
        charged once per chunk via the union geometry over every request's
        enabled words.  A lone request keeps today's single-op kernel
        (``project``/``filter_project``/``aggregate``/``groupby_sum`` — the
        bsl/pck revisions stay exercised and nothing retraces).  Hot
        projections are served from the reorganization cache (including
        delta serves over appended tails), and every cold projection lands
        there, warming the SPM for all batch members.  When the fused pass's
        modeled VMEM working set exceeds the engine's SPM budget, the
        row-tile height is halved (down to ``MIN_FUSED_BLOCK_ROWS``) before
        launching; the chosen tile is exposed as
        ``EngineStats.last_block_rows``.  Results are returned in input
        order, each matching its op's single-op contract.
        """
        results: list = [None] * len(ops)
        pending: dict[int, list[tuple[int, KR.ScanRequest]]] = {}
        tables: dict[int, RelationalTable] = {}
        for i, op in enumerate(ops):
            if isinstance(op, ProjectOp):
                served = self._project_from_cache(op.table, op.view.geometry)
                if served is not None:
                    results[i] = served
                    continue
            pending.setdefault(op.table.uid, []).append((i, op.lower()))
            tables[op.table.uid] = op.table
        for tid, entries in pending.items():
            table = tables[tid]
            uniq = dict.fromkeys(req for _, req in entries)
            reqs = tuple(uniq)
            self.stats.cold_misses += len(entries)
            if (len(entries) == 1 and isinstance(ops[entries[0][0]], JoinOp)
                    and ops[entries[0][0]].pred_op == "none"):
                # a join alone on its table skips the packed materialization:
                # the probe kernel streams the row-store chunks directly, and
                # nothing crosses toward the CPU but the join result (a
                # probe-side predicate needs the filtered packed route below)
                results[entries[0][0]] = self._join_direct(ops[entries[0][0]])
                continue
            cover: dict = {}
            if self.subsume and len(reqs) > 1:
                # subsumption-aware sharing: a request whose words ⊆ and
                # predicate ⊇ a covering request's is served by deriving
                # from the covering output, not by its own fused slot
                reqs, cover = _cover_requests(reqs)
            outs = self._serve_scan(table, reqs, shared=bool(cover))
            by_req = dict(zip(reqs, outs))
            for req, rep in cover.items():
                by_req[req] = self._derive_covered(rep, req, by_req[rep])
            self.stats.subsumed_requests += len(cover)
            # a packed block consumed only by join probes stays on device —
            # bytes_to_cpu is charged only when a non-join consumer ships it
            cpu_reqs = {req for i, req in entries
                        if not isinstance(ops[i], JoinOp)}
            for req, out in by_req.items():
                if isinstance(req, KR.ProjectRequest):
                    geom = req.geom
                    if req in cpu_reqs:
                        self.stats.bytes_to_cpu += (
                            geom.row_count * geom.out_bytes_per_row
                        )
                    self.cache.put(
                        self.view_key(table, geom), table.row_count, out
                    )
            for i, req in entries:
                out = by_req[req]
                results[i] = (self._finish_join(ops[i], out)
                              if isinstance(ops[i], JoinOp)
                              else finalize_scan_result(ops[i], out))
        return results

    def execute_many_async(self, ops: Sequence[ScanOp]) -> PassHandle:
        """:meth:`execute_many` wrapped in a :class:`PassHandle`.

        Identical serving and accounting — one heterogeneous shared pass per
        table, results in op order — but the return type states the async
        contract explicitly: nothing has synced with the host, and the
        caller may hold the handle across arbitrary host work (the pipelined
        serving tick compiles and launches tick N+1 while tick N's handle is
        outstanding).  Works unchanged on the sharded backend, whose
        per-shard passes also enqueue without host syncs.
        """
        return PassHandle(self.execute_many(ops))

    def materialize_many(self, views: Sequence[EphemeralView]) -> list[jax.Array]:
        """Materialize a batch of views with one shared scan per table.

        Thin wrapper over :meth:`execute_many`: each view becomes a
        :class:`~repro.core.requests.ProjectOp`, so a multi-view batch rides
        the heterogeneous one-pass scan (bus-beat bytes charged once via the
        union geometry) and every result lands in the reorganization cache.
        Results are returned in input order.
        """
        return self.execute_many([ProjectOp(v) for v in views])

    # -------------------------------------------- fused one-pass internals
    def _serve_scan(self, table: RelationalTable,
                    reqs: tuple["KR.ScanRequest", ...],
                    shared: bool = False) -> list:
        """Serve one table's de-duplicated request tuple — the backend hook.

        Single-device: a lone request stays on its single-op kernel (keeps
        the bsl/pck revision kernels exercised, doesn't count a shared
        scan); two or more fuse into one heterogeneous pass streamed over
        the resident chunk list.  ``shared=True`` forces the fused path for
        a lone request too — how a subsumption-collapsed batch keeps the
        union-geometry charging and ``shared_scans`` accounting of the
        multi-consumer pass it replaces.  The sharded backend overrides this
        with one fused pass per shard plus reduction-only cross-shard
        combines — requests are chunk-agnostic (word offsets,
        row-position-local), so the same lowered tuple serves both backends
        unchanged.
        """
        faults.maybe_fault("scan_launch", table=table.uid)
        if len(reqs) == 1 and not shared:
            words = self.device_words(table)
            return [self._execute_solo(words, table, reqs[0])]
        chunks = self.device_chunks(table)
        block_rows = self._fused_block_rows(reqs, table.row_words)
        route = (table.uid, tuple(KR._strip_dynamic(r) for r in reqs))
        per_chunk = [self._scan_chunk(chunk, reqs, block_rows, route)
                     for chunk in chunks]
        outs = (per_chunk[0] if len(per_chunk) == 1 else [
            KR.combine_chunk_outputs(req, [o[r] for o in per_chunk])
            for r, req in enumerate(reqs)
        ])
        self.stats.shared_scans += 1
        self.stats.rows_projected += table.row_count
        for chunk in chunks:
            self.charge_scan(table, reqs, row_count=chunk.shape[0])
        return outs

    def _scan_chunk(self, chunk: jax.Array,
                    reqs: tuple["KR.ScanRequest", ...], block_rows: int,
                    route) -> list:
        """One chunk's fused pass behind the lowering circuit breaker.

        A ``closed`` route attempts the Pallas pass; a failure (a real
        lowering error or an injected ``lowering`` fault) records against
        the route and this chunk is served by the fused-gather XLA fallback
        — same results, per the xla-revision equality suite.  An ``open``
        route skips the attempt entirely for the cooldown.  Injected faults
        belonging to *other* sites propagate untouched: the breaker guards
        kernel dispatch, not the pass as a whole.
        """
        if self.revision == "xla":
            return KR.scan_multi_xla(chunk, tuple(reqs))
        if not self.breaker.allow(route):
            return KR.scan_multi_xla(chunk, tuple(reqs))
        try:
            faults.maybe_fault("lowering", op="scan")
            outs = KR.scan_multi(
                chunk, reqs, revision=self.revision,
                block_rows=block_rows, interpret=self.interpret,
            )
        except Exception as err:
            if isinstance(err, faults.FaultError) and err.site != "lowering":
                raise
            self.breaker.record_failure(route)
            return KR.scan_multi_xla(chunk, tuple(reqs))
        self.breaker.record_success(route)
        return outs

    def _execute_solo(self, words: jax.Array, table: RelationalTable,
                      req: "KR.ScanRequest"):
        """One request: accounting here, kernel dispatch behind the breaker
        in :meth:`_solo_kernel` (failures fall back to ``scan_multi_xla``,
        which honors every single-op contract)."""
        if isinstance(req, KR.ProjectRequest):
            self.stats.rows_projected += req.geom.row_count
            self.stats.bytes_from_dram += bytes_moved(req.geom)["rme"]
        else:
            self.stats.rows_projected += table.row_count
            self.charge_scan(table, (req,))
        if words.shape[0] == 0:
            # the single-op Pallas kernels need at least one row block; an
            # empty resident store short-circuits to the XLA reference pass
            return KR.scan_multi_xla(words, (req,))[0]
        if self.revision == "xla":
            return self._solo_kernel(words, req)
        route = (table.uid, (KR._strip_dynamic(req),))
        if not self.breaker.allow(route):
            return KR.scan_multi_xla(words, (req,))[0]
        try:
            faults.maybe_fault("lowering", op="scan")
            out = self._solo_kernel(words, req)
        except Exception as err:
            if isinstance(err, faults.FaultError) and err.site != "lowering":
                raise
            self.breaker.record_failure(route)
            return KR.scan_multi_xla(words, (req,))[0]
        self.breaker.record_success(route)
        return out

    def _solo_kernel(self, words: jax.Array, req: "KR.ScanRequest"):
        """Single-op kernel dispatch (bsl/pck revisions stay exercised)."""
        if isinstance(req, KR.ProjectRequest):
            return K.project_any(
                words, req.geom, revision=self.revision,
                block_rows=self.block_rows, interpret=self.interpret,
            )
        if isinstance(req, KR.FilterRequest):
            return K.filter_project(
                words, req.geom, pred_word=req.pred_word,
                pred_dtype=req.pred_dtype, pred_op=req.pred_op,
                pred_k=req.pred_k, ts=req.ts, ts_word=req.ts_word,
                block_rows=self.block_rows, interpret=self.interpret,
            )
        if isinstance(req, KR.AggregateRequest):
            return K.aggregate(
                words, agg_word=req.agg_word, agg_dtype=req.agg_dtype,
                pred_word=req.pred_word, pred_dtype=req.pred_dtype,
                pred_op=req.pred_op, pred_k=req.pred_k, ts=req.ts,
                ts_word=req.ts_word, block_rows=self.block_rows,
                interpret=self.interpret,
            )
        return K.groupby_sum(
            words, group_word=req.group_word, agg_word=req.agg_word,
            num_groups=req.num_groups, agg_dtype=req.agg_dtype,
            pred_word=req.pred_word, pred_dtype=req.pred_dtype,
            pred_op=req.pred_op, pred_k=req.pred_k, ts=req.ts,
            ts_word=req.ts_word, block_rows=self.block_rows,
            interpret=self.interpret,
        )

    def _derive_covered(self, covering: "KR.ScanRequest",
                        covered: "KR.ScanRequest", out):
        """Finalize a subsumed request from its covering request's output.

        Pure word-slicing on device: the covering packed block holds every
        word ``covered`` enables, so its output is a static column gather —
        and a covered filter re-evaluates its (already code-space) predicate
        on the raw packed words, exactly what the fused kernel would have
        computed.  No row-store pass, no decode.
        """
        geom = covering.geom
        word_out: dict[int, int] = {}
        for off, width in zip(geom.abs_offsets, geom.col_widths):
            for j in range(width // WORD):
                word_out[off // WORD + j] = len(word_out)
        packed, mask = (out if isinstance(covering, KR.FilterRequest)
                        else (out, None))
        idx = jnp.asarray(
            [word_out[w] for w in _geom_words(covered.geom)], jnp.int32
        )
        sliced = packed[:, idx]
        if isinstance(covered, KR.ProjectRequest):
            return sliced
        if covered.pred_op != "none":
            vals = common.decode(packed[:, word_out[covered.pred_word]],
                                 covered.pred_dtype)
            k = jnp.asarray(
                covered.pred_k,
                jnp.float32 if covered.pred_dtype == "float32" else jnp.int32,
            )
            m = vals > k if covered.pred_op == "gt" else vals < k
        else:
            m = jnp.ones(sliced.shape[0], bool)
        if mask is not None:
            m = m & mask
        return jnp.where(m[:, None], sliced, 0), m

    # ---------------------------------------------- device-resident join
    def _build_join_partitions(self, table: RelationalTable, key: str,
                               payload: str):
        """Hash-partition the build side's {key, payload, ts} columns into
        device buckets and insert them into the module-global join build
        cache (one build per build-table version — the next probe hits).

        The PMU charges the partition-array upload **once** here:
        ``bytes_uploaded``/``uploads`` (it is a host→device transfer) plus
        the dedicated ``join_builds``/``bytes_join_build`` split the
        benchmarks report.  Warm probes charge nothing — the buckets are
        device-resident state, exactly like the row store itself.
        """
        from .planner import DEVICE_JOIN_PATH, _insert_build_index

        faults.maybe_fault("join_build", table=table.uid)
        words = table.words()
        parts = K.build_partitions(
            words[:, table.schema.word_offset(key)],
            words[:, table.schema.word_offset(payload)],
            words[:, table.ts_begin_word],
            words[:, table.ts_end_word],
        )
        self.stats.join_builds += 1
        self.stats.bytes_join_build += parts.nbytes
        self.stats.uploads += 1
        self.stats.bytes_uploaded += parts.nbytes
        _insert_build_index(parts, table, key, payload, DEVICE_JOIN_PATH)
        return parts

    def _op_partitions(self, op: JoinOp):
        """The op's build partitions: the compile-time cache hit, or a fresh
        build-and-insert (the sorted-index closure pattern of the host
        route — two identical joins compiled before either runs both miss
        and both insert; the same-key overwrite keeps occupancy exact)."""
        if op.partitions is not None:
            return op.partitions
        return self._build_join_partitions(op.right_table, op.key,
                                           op.right_proj)

    def _probe_join(self, words: jax.Array, partitions, key_word: int,
                    val_word: int, ts_word: int, ts: int, build_ts: bool,
                    route=None):
        """One probe pass with the per-query lowering-failure fallback: the
        Pallas grid pass when the revision supports it, else — or on any
        lowering error — the fused-gather XLA probe (same results).  The
        probe honors the same SPM budget as the fused scan: the row tile is
        halved until the modeled working set (row tile + resident bucket
        arrays) fits ``vmem_bytes``.  ``route`` threads the caller's
        circuit-breaker key so repeated lowering failures flip the route
        ``open`` and skip the doomed attempt during the cooldown."""
        if self.revision == "xla":
            return K.hash_join_xla(words, partitions, key_word, val_word,
                                   ts_word=ts_word, ts=ts, build_ts=build_ts)
        block_rows = self.block_rows
        while (block_rows // 2 >= MIN_FUSED_BLOCK_ROWS
               and K.probe_vmem_footprint_bytes(
                   partitions, words.shape[1], block_rows) > self.vmem_bytes):
            block_rows //= 2
        self.stats.last_block_rows = block_rows
        if route is not None and not self.breaker.allow(route):
            return K.hash_join_xla(words, partitions, key_word, val_word,
                                   ts_word=ts_word, ts=ts, build_ts=build_ts)
        try:
            faults.maybe_fault("lowering", op="join")
            out = K.hash_join(words, partitions, key_word, val_word,
                              ts_word=ts_word, ts=ts, build_ts=build_ts,
                              revision=self.revision,
                              block_rows=block_rows,
                              interpret=self.interpret)
        except Exception as err:
            if isinstance(err, faults.FaultError) and err.site != "lowering":
                raise
            # mirror the PR 3 hardening: one query's lowering failure falls
            # back to the XLA probe instead of poisoning the batch
            if route is not None:
                self.breaker.record_failure(route)
            return K.hash_join_xla(words, partitions, key_word, val_word,
                                   ts_word=ts_word, ts=ts, build_ts=build_ts)
        if route is not None:
            self.breaker.record_success(route)
        return out

    def _join_direct(self, op: JoinOp) -> JoinResult:
        """Solo join: stream the probe kernel over the device row-store
        chunks (no packed materialization).  Bus beats are charged per chunk
        via the union geometry of the probe-side request — the same request
        the op would contribute to a shared pass."""
        table = op.table
        parts = self._op_partitions(op)
        chunks = self.device_chunks(table)
        key_word = table.schema.word_offset(op.key)
        val_word = table.schema.word_offset(op.left_proj)
        snap = op.snapshot_ts is not None
        ts_word = table.ts_begin_word if snap else -1
        outs = [
            self._probe_join(chunk, parts, key_word, val_word, ts_word,
                             op.snapshot_ts or 0, snap,
                             route=(table.uid, "join"))
            for chunk in chunks
        ]
        acc_req = op.lower()  # its intervals are exactly the probe footprint
        self.stats.rows_projected += table.row_count
        for chunk in chunks:
            self.charge_scan(table, (acc_req,), row_count=chunk.shape[0])
        return JoinResult.concat([JoinResult(*o) for o in outs])

    def _finish_join(self, op: JoinOp, out) -> JoinResult:
        """Probe a shared-scan output: the op's probe-side scan rode the
        fused pass (packed block, or ``(packed, mask)`` under a snapshot —
        the mask being the probe rows' MVCC visibility); the bucket probe
        runs on that packed block, so the join costs the tick no extra
        row-store pass."""
        parts = self._op_partitions(op)
        packed, mask = out if isinstance(out, tuple) else (out, None)
        key_word, _ = op.view.column_words(op.key)
        val_word, _ = op.view.column_words(op.left_proj)
        s, r, m = self._probe_join(
            packed, parts, key_word, val_word, ts_word=-1,
            ts=op.snapshot_ts or 0, build_ts=op.snapshot_ts is not None,
            route=(op.table.uid, "join"),
        )
        if mask is not None:  # packed blocks carry no ts words: mask outside
            s = jnp.where(mask, s, 0)
            r = jnp.where(mask, r, 0)
            m = m & mask
        return JoinResult(s_proj=s, r_proj=r, matched=m)

    def scan_bytes(self, table: RelationalTable,
                   reqs: Sequence["KR.ScanRequest"],
                   row_count: int | None = None) -> int:
        """Bus-beat bytes of one pass serving ``reqs``: Eq. (3) bursts over
        the union of every request's enabled words.  ``row_count`` prices a
        pass over one chunk (default: the whole table).  The row stride is
        the schema's — unless a fused MVCC snapshot enables the hidden
        timestamp words, in which case the storage stride (what the stream
        walks) is the honest model.

        When the union touches encoded columns (paper §4), the pass is
        priced at the codecs' *narrow* word budget instead — each encoded
        word contributes ``codec.code_bytes`` per row rather than its full
        4-byte slot — capped by the plain Eq.(3) cost.  Pure: callers that
        estimate (serving-layer scan-sharing stats) and callers that charge
        (:meth:`charge_scan`) see the same number.
        """
        narrow, _ = self._scan_bytes_pair(table, reqs, row_count)
        return narrow

    def charge_scan(self, table: RelationalTable,
                    reqs: Sequence["KR.ScanRequest"],
                    row_count: int | None = None) -> int:
        """Book one pass's bus-beat bytes — the single charge point.

        ``bytes_from_dram`` takes the (possibly codec-narrowed) cost;
        ``bytes_saved_compression`` takes the plain-minus-narrow remainder,
        so ``bytes_from_dram + bytes_saved_compression`` is always the
        uncompressed Eq.(3) cost of the same passes."""
        narrow, plain = self._scan_bytes_pair(table, reqs, row_count)
        self.stats.bytes_from_dram += narrow
        self.stats.bytes_saved_compression += plain - narrow
        return narrow

    def _scan_bytes_pair(self, table: RelationalTable,
                         reqs: Sequence["KR.ScanRequest"],
                         row_count: int | None = None) -> tuple[int, int]:
        """(narrow, plain) Eq.(3) bytes of one pass; equal when no enabled
        word is codec-backed."""
        max_end = max(o + w for r in reqs for o, w in K.request_intervals(r))
        row_bytes = table.schema.row_bytes
        if max_end > row_bytes:
            row_bytes = table.row_words * WORD
        rows = table.row_count if row_count is None else row_count
        union = K.union_geometry(reqs, row_bytes=row_bytes, row_count=rows)
        plain = bytes_moved(union)["rme"]
        codecs = getattr(table, "codecs", None)
        if not codecs:
            return plain, plain
        enabled: set[int] = set()
        for r in reqs:
            for o, w in K.request_intervals(r):
                enabled.update(range(o // WORD, -(-(o + w) // WORD)))
        by_word = {table.schema.word_offset(n): c for n, c in codecs.items()}
        if not any(w in enabled for w in by_word):
            return plain, plain
        per_row = sum(
            by_word[w].code_bytes if w in by_word else WORD for w in enabled
        )
        return min(plain, rows * per_row), plain

    # FIFO cap on cached decoded client reads — decoded string columns can be
    # large, and one live (table-version, result) pair per view is the norm
    DECODE_CACHE_MAX = 64

    def decode_column(self, table: RelationalTable, name: str, codes,
                      token: tuple = ()):
        """Decode-on-finalize: map a packed result's raw code words for
        column ``name`` back to values, cached per table version.

        This is the *only* place the engine decodes — the fused pass
        operates on raw codes end to end.  ``token`` distinguishes reads of
        the same column under different result shapes (e.g. a snapshot
        view's visible-row slice).  The cache key folds in ``version`` and
        ``storage_epoch`` so any append/update/refit invalidates naturally.
        """
        codec = table.codecs[name]
        key = (table.uid, name, table.version,
               getattr(table, "storage_epoch", 0), token)
        if key in self._decode_cache:
            self.stats.decode_cache_hits += 1
            return self._decode_cache[key]
        self.stats.decodes += 1
        out = codec.decode(codes)
        while len(self._decode_cache) >= self.DECODE_CACHE_MAX:
            self._decode_cache.pop(next(iter(self._decode_cache)))
        self._decode_cache[key] = out
        return out

    def _fused_block_rows(self, reqs: Sequence["KR.ScanRequest"],
                          row_words: int) -> int:
        """SPM budget guard: halve the row tile until the fused pass's modeled
        VMEM working set fits ``vmem_bytes`` (never below the floor)."""
        block_rows = self.block_rows
        while (block_rows // 2 >= MIN_FUSED_BLOCK_ROWS
               and K.scan_vmem_footprint_bytes(reqs, row_words, block_rows)
               > self.vmem_bytes):
            block_rows //= 2
        self.stats.last_block_rows = block_rows
        return block_rows

    def aggregate_async(
        self,
        table: RelationalTable,
        agg_col: str,
        pred_col: str | None = None,
        pred_op: str = "none",
        pred_k=0,
        snapshot_ts: int | None = None,
    ) -> jax.Array:
        """Non-blocking fused aggregate: returns the device ``[sum, count]`` pair.

        Nothing syncs with the host here — the caller decides when (whether)
        to pull the scalars down, so batched query loops can enqueue many
        aggregates before blocking once.  The row store is read from the
        device-resident buffer: repeated aggregates over an unchanged table
        perform zero host→device transfers after the first call, and a
        mutated table ships only its write delta.  ``snapshot_ts`` fuses the
        MVCC visibility test in-scan: rows outside the snapshot contribute
        nothing, so concurrent writers never perturb a pinned reader.  No
        ``bytes_to_cpu`` are charged here — nothing crosses to the host until
        a caller syncs (the blocking :meth:`aggregate` charges its 8 bytes).
        This is sugar for a one-op :meth:`execute_many` batch, so it shares
        the same accounting (including the bus-beat charge for the enabled
        aggregate/predicate words).
        """
        op = AggregateOp(table, agg_col, pred_col=pred_col, pred_op=pred_op,
                         pred_k=pred_k, snapshot_ts=snapshot_ts)
        return self.execute_many([op])[0]

    def aggregate(
        self,
        table: RelationalTable,
        agg_col: str,
        pred_col: str | None = None,
        pred_op: str = "none",
        pred_k=0,
        snapshot_ts: int | None = None,
    ) -> tuple[float, float]:
        """Fused near-memory ``SELECT SUM(agg), COUNT(*) WHERE pred`` (Q0/Q3).

        Only a 2-float scalar leaves the engine; the MVCC snapshot test is
        fused when a snapshot time is given.  This is the blocking wrapper
        around :meth:`aggregate_async` — the ``float()`` calls are the only
        host sync.
        """
        out = self.aggregate_async(
            table, agg_col, pred_col=pred_col, pred_op=pred_op, pred_k=pred_k,
            snapshot_ts=snapshot_ts,
        )
        self.stats.bytes_to_cpu += 8  # the [sum, count] pair crosses on sync
        return float(out[0]), float(out[1])

    def vmem_budget_bytes(self, geom: TableGeometry) -> int:
        """The 'area report' analogue: VMEM working set of one engine step."""
        return vmem_footprint_bytes(geom, self.block_rows, self.revision)
