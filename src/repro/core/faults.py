"""Deterministic fault injection + the lowering circuit breaker.

A real deployment of Relational Memory sits *between* the CPU and memory:
the accelerator path can fail — a lowering error on a new target, a device
dropping mid-pass, an interconnect hiccup during a cross-shard combine —
and the serving stack has to recover without losing writes, hanging
clients, or silently returning wrong answers.  None of those failures
occur naturally in a CPU interpret-mode test run, so this module makes
them *schedulable*: a :class:`FaultPlan` scripts exactly which named
**injection site** raises what, on which hit, and the hot paths consult
:func:`maybe_fault` at every site.  Every failure path in the engine,
the sharded backend, and the serving loop is thereby reproducible in
tests and CI — not just theorized.

Injection sites (each named call is threaded through the corresponding
hot path):

==================== =====================================================
``upload``           host→device row-store transfer (full or delta sync)
``scan_launch``      a tick's fused scan entering the backend scan hook
``shard_pass``       one shard's fused pass (``ShardedEngine``)
``collective_combine`` the cross-shard combine of reduced partials
``join_build``       build-side hash partitioning for the device join
``stream_chunk``     one chunk of a streamed projection
``lowering``         Pallas kernel dispatch (scan or join probe)
==================== =====================================================

Faults are **typed**: a :class:`TransientFault` models a failure that a
bounded retry can outlast (the plan stops firing after ``times`` hits);
a :class:`PermanentFault` models a failure that will never succeed on
retry (device loss, an unlowerable kernel).  The recovery layers key off
the type — transients are retried, permanents skip straight to failover
or a typed client error.

Plans are scriptable (``inject(site, at=N)`` fires on the Nth hit) and
seeded (``inject_random(site, p=...)`` draws from the plan's own
``random.Random(seed)``), so a chaos run is reproducible bit-for-bit.
Install a plan globally with :func:`install`/:func:`clear` or the
:func:`fault_plan` context manager; with no plan installed,
:func:`maybe_fault` is a single ``None`` check — the fault-free hot path
stays unmeasurably close to uninstrumented (gated ≤5% by
``benchmarks/fig_fault_recovery.py``).

:class:`CircuitBreaker` lives here too: the engine wraps every Pallas
kernel dispatch with it, counting lowering failures per (table,
request-shape) route and flipping a repeatedly-failing route to the XLA
fallback (``scan_multi_xla`` / ``hash_join_xla``) for a cooldown, with
half-open probes to recover — the classic pattern, counter-based so it
is deterministic under test.  See ``docs/reliability.md``.
"""

from __future__ import annotations

import contextlib
import dataclasses
import random
from typing import Iterator

SITES = (
    "upload",
    "scan_launch",
    "shard_pass",
    "collective_combine",
    "join_build",
    "stream_chunk",
    "lowering",
)


class FaultError(RuntimeError):
    """Base of every injected fault; carries its site and hit index."""

    kind = "fault"

    def __init__(self, site: str, hit: int):
        super().__init__(f"injected {self.kind} fault at site {site!r} "
                         f"(hit {hit})")
        self.site = site
        self.hit = hit


class TransientFault(FaultError):
    """A failure a bounded retry can outlast (spurious device error)."""

    kind = "transient"


class PermanentFault(FaultError):
    """A failure that never succeeds on retry (device loss, unlowerable
    kernel) — recovery means failover or a typed client error, not
    persistence."""

    kind = "permanent"


_KINDS = {"transient": TransientFault, "permanent": PermanentFault}


@dataclasses.dataclass
class FaultSpec:
    """One scripted fault: fire ``times`` consecutive hits starting at the
    ``at``-th matching hit of ``site`` (1-based).  ``times=None`` fires on
    every hit from ``at`` on — a deterministically failing route.
    ``match`` restricts which hits count: a hit matches iff every key the
    spec names equals the context the site passed (e.g. ``shard=1``).
    ``p`` (random mode) fires each matching hit with probability ``p``
    from the plan's seeded RNG instead of by position."""

    site: str
    at: int = 1
    times: int | None = 1
    kind: str = "transient"
    match: dict = dataclasses.field(default_factory=dict)
    p: float | None = None
    hits: int = 0
    fired: int = 0

    def _matches(self, ctx: dict) -> bool:
        return all(ctx.get(k) == v for k, v in self.match.items())


class FaultPlan:
    """A seeded, scriptable registry of faults to inject.

    Build one, script it (chainable), install it::

        plan = FaultPlan().inject("shard_pass", at=1, shard=1)
        with fault_plan(plan):
            server.drain()
        assert plan.fired("shard_pass") == 1

    The plan is pure bookkeeping — it never touches engine state — so the
    same plan object can be inspected after the run to assert exactly
    which faults fired.
    """

    def __init__(self, seed: int = 0):
        self.seed = seed
        self.specs: list[FaultSpec] = []
        self._rng = random.Random(seed)

    # ------------------------------------------------------------ scripting
    def inject(self, site: str, at: int = 1, kind: str = "transient",
               times: int | None = 1, **match) -> "FaultPlan":
        """Script a fault: raise ``kind`` on hits ``[at, at + times)`` of
        ``site`` (restricted to hits whose context matches ``match``)."""
        if site not in SITES:
            raise ValueError(f"unknown fault site {site!r}; want one of {SITES}")
        if kind not in _KINDS:
            raise ValueError(f"unknown fault kind {kind!r}; "
                             "want 'transient' or 'permanent'")
        if at < 1:
            raise ValueError(f"at must be >= 1, got {at}")
        self.specs.append(FaultSpec(site, at=at, times=times, kind=kind,
                                    match=dict(match)))
        return self

    def inject_random(self, site: str, p: float, kind: str = "transient",
                      **match) -> "FaultPlan":
        """Script a seeded random fault: each matching hit of ``site`` fires
        with probability ``p`` (drawn from the plan's own RNG, so a fixed
        seed reproduces the exact same fault schedule)."""
        if site not in SITES:
            raise ValueError(f"unknown fault site {site!r}; want one of {SITES}")
        if not 0.0 <= p <= 1.0:
            raise ValueError(f"p must be in [0, 1], got {p}")
        self.specs.append(FaultSpec(site, kind=kind, match=dict(match), p=p))
        return self

    # -------------------------------------------------------------- firing
    def hit(self, site: str, **ctx) -> None:
        """Record one hit of ``site``; raises the first spec due to fire."""
        due: FaultSpec | None = None
        for spec in self.specs:
            if spec.site != site or not spec._matches(ctx):
                continue
            spec.hits += 1
            if due is not None:
                continue  # one fault per hit; later specs still count hits
            if spec.p is not None:
                if self._rng.random() < spec.p:
                    due = spec
            elif spec.hits >= spec.at and (
                spec.times is None or spec.hits < spec.at + spec.times
            ):
                due = spec
        if due is not None:
            due.fired += 1
            raise _KINDS[due.kind](site, due.hits)

    # ----------------------------------------------------------- reporting
    def fired(self, site: str | None = None) -> int:
        """Total faults raised (optionally for one site)."""
        return sum(s.fired for s in self.specs
                   if site is None or s.site == site)

    def hits_at(self, site: str) -> int:
        """Times the site was reached (max over specs watching it; 0 when
        nothing watches it)."""
        return max((s.hits for s in self.specs if s.site == site), default=0)


# ------------------------------------------------------- global installation
_ACTIVE: FaultPlan | None = None


def install(plan: FaultPlan) -> FaultPlan:
    """Make ``plan`` the process-wide active plan (returns it)."""
    global _ACTIVE
    _ACTIVE = plan
    return plan


def clear() -> None:
    """Deactivate fault injection (the production state)."""
    global _ACTIVE
    _ACTIVE = None


def active_plan() -> FaultPlan | None:
    return _ACTIVE


@contextlib.contextmanager
def fault_plan(plan: FaultPlan | None = None) -> Iterator[FaultPlan]:
    """Scope a plan's installation: ``with fault_plan(plan): ...`` — always
    cleared on exit, so a failing chaos test never leaks faults into the
    next one."""
    global _ACTIVE
    plan = plan if plan is not None else FaultPlan()
    prev = _ACTIVE
    install(plan)
    try:
        yield plan
    finally:
        _ACTIVE = prev


def maybe_fault(site: str, **ctx) -> None:
    """The hot-path hook: a no-op unless a plan is installed.

    Sites pass identifying context (``shard=``, ``table=``, ...) so plans
    can target, e.g., shard 1's second pass specifically.  Keep this call
    cheap — it sits on every upload, scan, and stream chunk."""
    if _ACTIVE is not None:
        _ACTIVE.hit(site, **ctx)


# ========================================================== circuit breaker
@dataclasses.dataclass
class _Route:
    """Breaker state for one (table, request-shape) route."""

    state: str = "closed"  # "closed" | "open" | "half_open"
    streak: int = 0  # consecutive failures while closed
    cooldown_left: int = 0  # fallback serves remaining while open


class CircuitBreaker:
    """Counter-based circuit breaker over kernel-lowering routes.

    ``closed`` routes attempt the Pallas kernel; ``threshold`` consecutive
    failures **trip** the route ``open``, and the next ``cooldown`` serves
    go straight to the XLA fallback without attempting (no repeated
    lowering cost, no repeated exception).  After the cooldown the route is
    ``half_open``: one probe attempt is allowed — success closes it,
    failure re-trips a fresh cooldown.  Everything is counted in *serves*,
    not wall time, so tests and CI are deterministic.
    """

    def __init__(self, threshold: int = 3, cooldown: int = 4):
        if threshold < 1 or cooldown < 1:
            raise ValueError("threshold and cooldown must be >= 1")
        self.threshold = threshold
        self.cooldown = cooldown
        self._routes: dict = {}
        self.trips = 0  # closed/half_open -> open transitions
        self.fallbacks = 0  # serves routed to the fallback while open
        self.probes = 0  # half-open probe attempts

    def _route(self, key) -> _Route:
        route = self._routes.get(key)
        if route is None:
            route = self._routes[key] = _Route()
        return route

    def allow(self, key) -> bool:
        """Should this serve attempt the Pallas kernel?  ``False`` routes it
        to the fallback (and burns one cooldown serve)."""
        route = self._route(key)
        if route.state == "open":
            route.cooldown_left -= 1
            if route.cooldown_left <= 0:
                route.state = "half_open"
            self.fallbacks += 1
            return False
        if route.state == "half_open":
            self.probes += 1
        return True

    def record_failure(self, key) -> None:
        route = self._route(key)
        if route.state == "half_open":
            route.state = "open"
            route.cooldown_left = self.cooldown
            self.trips += 1
            return
        route.streak += 1
        if route.streak >= self.threshold:
            route.state = "open"
            route.cooldown_left = self.cooldown
            route.streak = 0
            self.trips += 1

    def record_success(self, key) -> None:
        route = self._route(key)
        route.streak = 0
        if route.state == "half_open":
            route.state = "closed"  # the probe succeeded: recovered

    # ----------------------------------------------------------- reporting
    def state(self, key) -> str:
        route = self._routes.get(key)
        return route.state if route is not None else "closed"

    @property
    def open_routes(self) -> int:
        return sum(1 for r in self._routes.values() if r.state != "closed")

    def snapshot(self) -> dict:
        """Flat counters for the serving layer's ``snapshot()`` export."""
        return {
            "breaker_trips": self.trips,
            "breaker_fallbacks": self.fallbacks,
            "breaker_probes": self.probes,
            "breaker_open": self.open_routes,
        }
