"""The roofline analyzer itself: trip-count weighting, wire model, dtypes.

The §Roofline numbers are only as good as this parser — verify it against
compiled programs with known FLOP/collective structure.
"""


import jax
import jax.numpy as jnp

from repro.roofline.analysis import (
    _dot_flops,
    _group_size,
    _wire_bytes,
    compiled_hlo_text,
    hlo_stats,
    roofline_terms,
)


def compile_fn(f, *shapes):
    return jax.jit(f).lower(*shapes).compile()


def test_scan_trip_count_weighting_exact():
    """A scanned matmul must count trip_count × one-matmul FLOPs, exactly."""
    for n in (1, 3, 10, 37):
        def f(x, n=n):
            def body(c, _):
                return c @ c, None
            y, _ = jax.lax.scan(body, x, None, length=n)
            return y

        c = compile_fn(f, jax.ShapeDtypeStruct((128, 128), jnp.float32))
        stats = hlo_stats(compiled_hlo_text(c))
        assert stats["flops"] == 2 * 128**3 * n, n
        assert stats["trip_weighted"]


def test_nested_scan_multiplies():
    def f(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ ci, None
            y, _ = jax.lax.scan(inner, c, None, length=4)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    c = compile_fn(f, jax.ShapeDtypeStruct((64, 64), jnp.float32))
    stats = hlo_stats(compiled_hlo_text(c))
    assert stats["flops"] == 2 * 64**3 * 12  # 3 × 4 inner matmuls


def test_unscanned_matmul_baseline():
    c = compile_fn(lambda a, b: a @ b,
                   jax.ShapeDtypeStruct((64, 256), jnp.float32),
                   jax.ShapeDtypeStruct((256, 32), jnp.float32))
    stats = hlo_stats(compiled_hlo_text(c))
    assert stats["flops"] == 2 * 64 * 256 * 32


def test_dot_flops_parser_units():
    line = ("%dot.1 = f32[256,32]{1,0} dot(f32[256,512]{1,0} %a, "
            "f32[512,32]{1,0} %b), lhs_contracting_dims={1}, "
            "rhs_contracting_dims={0}")
    assert _dot_flops(line) == 2 * 256 * 32 * 512
    batched = ("%dot.2 = f32[8,64,32]{2,1,0} dot(f32[8,64,128]{2,1,0} %a, "
               "f32[8,128,32]{2,1,0} %b), lhs_batch_dims={0}, "
               "lhs_contracting_dims={2}, rhs_batch_dims={0}, "
               "rhs_contracting_dims={1}")
    assert _dot_flops(batched) == 2 * (8 * 64 * 32) * 128


def test_wire_model_units():
    ag = ("%ag = bf16[64,512]{1,0} all-gather(bf16[64,32]{1,0} %x), "
          "replica_groups=[4,16]<=[64], dimensions={1}")
    assert _group_size(ag) == 16
    assert _wire_bytes("all-gather", ag) == 64 * 512 * 2 * 15 // 16
    ar = ("%ar = f32[1024]{0} all-reduce(f32[1024]{0} %x), "
          "replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add")
    assert _group_size(ar) == 4
    assert _wire_bytes("all-reduce", ar) == 2 * 4096 * 3 // 4
    cp = ("%cp = bf16[256]{0} collective-permute(bf16[256]{0} %x), "
          "source_target_pairs={{0,1},{1,0}}")
    assert _wire_bytes("collective-permute", cp) == 512


def test_collectives_detected_in_compiled_program():
    import os
    import subprocess
    import sys
    import textwrap

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(repo, "src")
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from repro import compat
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.roofline.analysis import compiled_hlo_text, hlo_stats

        mesh = make_mesh((8,), ("data",))
        def f(x):
            return jax.lax.psum(x * 2, "data")
        c = jax.jit(compat.shard_map(f, mesh=mesh, in_specs=P("data"),
                                  out_specs=P())).lower(
            jax.ShapeDtypeStruct((1024,), jnp.float32)).compile()
        stats = hlo_stats(compiled_hlo_text(c))
        coll = stats["collectives"]
        assert coll["all-reduce"] > 0, coll
        # per-chip shard is 128 floats = 512 B; ring all-reduce 2*(7/8)*512
        assert coll["all-reduce"] == 2 * 512 * 7 // 8, coll
        print("OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=240)
    assert out.returncode == 0, out.stderr[-2000:]


def test_roofline_terms_math():
    t = roofline_terms(197e12, 819e9, 50e9)
    assert abs(t["compute"] - 1.0) < 1e-9
    assert abs(t["memory"] - 1.0) < 1e-9
    assert abs(t["collective"] - 1.0) < 1e-9


def test_dus_scan_bytes_not_whole_buffer():
    """Scan ys-stacking must bill the slice, not the stacked buffer."""
    def f(x):
        def body(c, _):
            c = c + 1.0
            return c, c
        _, ys = jax.lax.scan(body, x, None, length=100)
        return ys

    c = compile_fn(f, jax.ShapeDtypeStruct((1024, 1024), jnp.float32))
    stats = hlo_stats(compiled_hlo_text(c))
    buffer_bytes = 100 * 1024 * 1024 * 4
    # honest per-iteration traffic: carry read+write (8 MB), carry copy
    # (4 MB), add read+slice write (8 MB) ≈ 20 MB × 100 = 5× the stacked
    # buffer, plus its one-time zero-init (1×); some XLA versions emit one
    # more per-iteration carry copy (~8×).  Billing the whole buffer per
    # iteration (the naive parse) would be ~100×.
    assert stats["hbm_bytes"] < 10 * buffer_bytes, (
        stats["hbm_bytes"] / buffer_bytes
    )
    assert stats["hbm_bytes"] > 2 * buffer_bytes  # sanity floor
