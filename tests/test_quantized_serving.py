"""int8 serving-weight quantization: fidelity + structure."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.models.layers import quantize_for_serving, quantize_weight, cast


def test_quantize_weight_roundtrip_error():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(0, 0.05, (256, 128)), jnp.float32)
    q = quantize_weight(w)
    assert q["q"].dtype == jnp.int8
    deq = np.asarray(cast(q, jnp.float32))
    err = np.abs(deq - np.asarray(w))
    col_scale = np.abs(np.asarray(w)).max(axis=0)
    assert (err <= col_scale / 127.0 + 1e-7).all()  # absmax grid bound


@pytest.mark.parametrize("arch", ["qwen3-8b", "recurrentgemma-9b", "mamba2-1.3b"])
def test_quantized_decode_close_to_bf16(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qparams = quantize_for_serving(params)
    # structure: big 2D weights quantized, embeddings/norms not
    leaves = jax.tree_util.tree_flatten_with_path(qparams)[0]
    n_q = sum(1 for kp, _ in leaves if any(getattr(p, "key", None) == "q" for p in kp))
    assert n_q > 0
    rng = np.random.default_rng(1)
    B, S = 2, 32
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    max_len = S + 8
    l_ref, c_ref = jax.jit(lambda p, b: model.prefill(p, b, max_len))(
        params, {"tokens": toks})
    l_q, c_q = jax.jit(lambda p, b: model.prefill(p, b, max_len))(
        qparams, {"tokens": toks})
    # int8 grid error accumulates over layers; require close logits and
    # strong top-1 agreement
    ref = np.asarray(l_ref, np.float32)
    qd = np.asarray(l_q, np.float32)
    denom = np.abs(ref).max() + 1e-9
    assert np.abs(ref - qd).max() / denom < 0.25
    agree = (ref.argmax(-1) == qd.argmax(-1)).mean()
    assert agree >= 0.5, agree
    # decode step runs with the quantized tree
    tok = jnp.argmax(l_q, -1)[:, None].astype(jnp.int32)
    l2, _ = jax.jit(model.decode_step)(qparams, c_q, tok, jnp.asarray(S, jnp.int32))
    assert np.isfinite(np.asarray(l2)).all()


def test_quantized_tree_is_smaller():
    cfg = get_smoke_config("qwen1.5-110b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qparams = quantize_for_serving(params)
    size = lambda t: sum(
        x.size * x.dtype.itemsize for x in jax.tree.leaves(t)
    )
    assert size(qparams) < 0.45 * size(params)  # ~int8 vs f32 on the matmuls
