"""RME engine behaviour: ephemeral views, hot/cold, epochs, MVCC, operators."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    RelationalMemoryEngine,
    RelationalTable,
    TS_INF,
    benchmark_schema,
    compression,
)
from repro.core import operators as ops


@pytest.fixture
def table():
    rng = np.random.default_rng(0)
    schema = benchmark_schema(64, 4)
    n = 500
    cols = {c.name: rng.integers(-100, 100, n).astype(np.int32)
            for c in schema.columns}
    return RelationalTable.from_columns(schema, cols)


def test_ephemeral_view_is_lazy_and_hot_after_first_access(table):
    eng = RelationalMemoryEngine(revision="mlp")
    view = eng.register(table, ("A1", "A5"))
    assert eng.stats.cold_misses == 0  # registration materializes nothing
    _ = view.packed()
    assert eng.stats.cold_misses == 1
    _ = view.packed()
    _ = view.column("A1")
    assert eng.stats.cold_misses == 1  # hot
    assert eng.stats.hot_hits >= 2


def test_oltp_mutation_invalidates_views(table):
    eng = RelationalMemoryEngine()
    view = eng.register(table, ("A1",))
    a1 = np.asarray(view.column("A1"))
    table.append({c: np.array([7], np.int32) for c in table.schema.names})
    view2 = eng.register(table, ("A1",))
    a1b = np.asarray(view2.column("A1"))
    assert len(a1b) == len(a1) + 1
    assert a1b[-1] == 7
    assert eng.stats.cold_misses == 2  # second access was cold (version bump)


def test_engine_reset_is_epoch_bump(table):
    eng = RelationalMemoryEngine()
    v = eng.register(table, ("A2",))
    _ = v.packed()
    epoch0 = eng.cache.epoch
    eng.reset()  # single-cycle invalidation
    assert eng.cache.epoch == epoch0 + 1
    _ = eng.register(table, ("A2",)).packed()
    assert eng.stats.cold_misses == 2


def test_reorg_cache_capacity_eviction(table):
    # tiny SPM: second view evicts the first
    eng = RelationalMemoryEngine(cache_bytes=500 * 8 + 64)
    v1 = eng.register(table, ("A1",))
    v2 = eng.register(table, ("A2", "A3", "A4"))
    _ = v1.packed()
    _ = v2.packed()  # too big to cache alongside v1
    _ = v1.packed()
    assert eng.stats.cold_misses >= 2


def test_mvcc_update_creates_new_version(table):
    eng = RelationalMemoryEngine()
    n0 = int(table.snapshot_mask().sum())
    ts_before = table.now()
    rows = np.arange(5)
    table.update(rows, {"A1": np.full(5, 999, np.int32)})
    # live view sees updated values, same live count
    assert int(table.snapshot_mask().sum()) == n0
    live = eng.register(table, ("A1",))
    a1 = np.asarray(live.column("A1"))
    assert (a1 == 999).sum() == 5
    # snapshot before the update still sees the old values
    old = eng.register(table, ("A1",), snapshot_ts=ts_before)
    a1_old = np.asarray(old.column("A1"))
    assert (a1_old == 999).sum() == 0
    assert len(a1_old) == n0


def test_all_queries_cross_path_equality(table):
    eng = RelationalMemoryEngine()
    all_cols = list(table.schema.names)
    cs = ops.make_colstore(table, all_cols)
    q0 = {p: ops.q0_sum(eng, table, "A1", path=p, colstore=cs) for p in ops.PATHS}
    assert len({round(v, 2) for v in q0.values()}) == 1
    q3 = {p: ops.q3_select_aggregate(eng, table, "A2", "A4", 5, path=p, colstore=cs)
          for p in ops.PATHS}
    assert len({round(v, 2) for v in q3.values()}) == 1
    q4 = {p: np.asarray(ops.q4_groupby_avg(eng, table, "A1", "A3", "A2", 5, 16,
                                           path=p, colstore=cs))
          for p in ops.PATHS}
    np.testing.assert_allclose(q4["rme"], q4["row"], rtol=1e-5)
    np.testing.assert_allclose(q4["rme"], q4["col"], rtol=1e-5)


def test_join_cross_path(table):
    rng = np.random.default_rng(9)
    schema = table.schema
    n_r = 128
    r_cols = {c.name: rng.integers(-50, 50, n_r).astype(np.int32)
              for c in schema.columns}
    r_cols["A2"] = np.arange(n_r, dtype=np.int32)  # primary key
    rt = RelationalTable.from_columns(schema, r_cols)
    eng = RelationalMemoryEngine()
    rcs = ops.make_colstore(rt, ["A2", "A3"])
    scs = ops.make_colstore(table, ["A1", "A2"])
    res = {p: ops.q5_hash_join(eng, table, rt, path=p, s_colstore=scs,
                               r_colstore=rcs) for p in ops.PATHS}
    for p in ("row", "col"):
        np.testing.assert_array_equal(
            np.asarray(res["rme"].matched), np.asarray(res[p].matched)
        )
        np.testing.assert_array_equal(
            np.asarray(res["rme"].r_proj), np.asarray(res[p].r_proj)
        )


def test_engine_data_movement_accounting(table):
    eng = RelationalMemoryEngine()
    _ = eng.register(table, ("A1",)).packed()
    row_wise = table.row_count * 64  # full rows through the hierarchy
    assert eng.stats.bytes_to_cpu == table.row_count * 4
    assert eng.stats.bytes_from_dram < row_wise


# --------------------------------------------------------------- codecs
def test_dict_codec_roundtrip():
    rng = np.random.default_rng(3)
    vals = rng.integers(-1000, 1000, 400).astype(np.int64)
    codec = compression.DictCodec.fit(vals)
    codes = codec.encode(vals)
    np.testing.assert_array_equal(np.asarray(codec.decode(jnp.asarray(codes))), vals)
    assert codes.dtype == np.int32


def test_delta_codec_roundtrip():
    rng = np.random.default_rng(4)
    for frame in (16, 128, 1024):
        vals = rng.integers(0, 1 << 30, 300).astype(np.int64)
        codec = compression.DeltaCodec.fit(vals, frame)
        out = np.asarray(codec.decode(jnp.asarray(codec.encode(vals))))
        np.testing.assert_array_equal(out, vals)
