"""Crash-recovery property suite for the write-ahead log (satellite 3).

The durability contract (docs/reliability.md): a server crash may tear the
WAL at *any* record boundary, or corrupt a partially-flushed tail record.
Recovery must rebuild, from the surviving prefix, a table byte-identical —
words, ``row_count``, MVCC clock — to the live table as it stood after
exactly that many writes, and the recovered table must serve queries
identically on both the single-device and the sharded backend.
"""

import numpy as np
import pytest

from repro.core import (
    RelationalMemoryEngine, RelationalTable, WriteAheadLog, plan,
)
from repro.core.distributed import ShardedEngine
from repro.core.requests import AggregateOp, GroupByOp
from repro.core.schema import Column, TableSchema
from repro.serve.query_server import QueryServer

SCHEMA = TableSchema((Column("a", "int32"), Column("b", "int32"),
                      Column("g", "int32")))


def _cols(rng, n):
    return {"a": rng.integers(-100, 100, n).astype(np.int32),
            "b": rng.integers(0, 1000, n).astype(np.int32),
            "g": rng.integers(0, 8, n).astype(np.int32)}


def _state(t):
    return (t._words[: t.row_count].copy(), t.row_count, t._clock)


def logged_history(seed=0):
    """Run a write workload through a WAL-attached server; return the WAL
    plus the live table's state after the checkpoint and after each write."""
    rng = np.random.default_rng(seed)
    t = RelationalTable.from_columns(SCHEMA, _cols(rng, 40))
    wal = WriteAheadLog()
    srv = QueryServer(RelationalMemoryEngine(revision="xla"), wal=wal)

    states = [_state(t)]  # the checkpoint: pre-first-write
    def step(submit):
        submit()
        srv.drain()
        states.append(_state(t))

    step(lambda: srv.submit_insert(t, _cols(rng, 8)))
    step(lambda: srv.submit_update(t, np.array([1, 5, 41], np.int64),
                                   {"b": np.array([7, 8, 9], np.int32)}))
    step(lambda: srv.submit_delete(t, np.array([0, 44], np.int64)))
    step(lambda: srv.submit_insert(t, _cols(rng, 3)))
    step(lambda: srv.submit_update(t, np.array([2], np.int64),
                                   {"a": np.array([-1], np.int32)}))
    step(lambda: srv.submit_delete(t, np.array([3], np.int64)))
    assert wal.record_count == len(states)  # checkpoint + one per write
    return wal, t, states


def assert_recovers_to(recovered, state):
    words, row_count, clock = state
    assert recovered is not None
    assert recovered.row_count == row_count
    assert recovered._clock == clock
    np.testing.assert_array_equal(recovered._words[:row_count], words)


class TestCrashRecovery:
    def test_truncation_at_every_record_boundary(self):
        wal, t, states = logged_history()
        bounds = wal.boundaries()
        assert len(bounds) == len(states) + 1  # offset 0 .. end of last rec
        for k, cut in enumerate(bounds):
            survivor = wal.truncated(cut)
            recovered = RelationalTable.recover(survivor, t.uid)
            if k == 0:  # checkpoint itself lost: nothing recoverable
                assert recovered is None
            else:
                assert_recovers_to(recovered, states[k - 1])

    def test_truncation_inside_a_record_drops_the_torn_tail(self):
        wal, t, states = logged_history()
        bounds = wal.boundaries()
        for k in range(1, len(bounds)):
            cut = bounds[k] - 3  # mid-record: frame k-1 intact, k torn
            recovered = RelationalTable.recover(wal.truncated(cut), t.uid)
            if k == 1:
                assert recovered is None
            else:
                assert_recovers_to(recovered, states[k - 2])

    def test_corrupted_tail_checksum_recovers_prefix(self):
        wal, t, states = logged_history()
        recovered = RelationalTable.recover(wal.corrupted_tail(), t.uid)
        assert_recovers_to(recovered, states[-2])

    def test_full_log_replays_to_live_table(self):
        wal, t, states = logged_history()
        recovered = RelationalTable.recover(wal, t.uid)
        assert_recovers_to(recovered, states[-1])
        # MVCC snapshots replay too: every historical timestamp reads the
        # same visible rows on the recovered table
        for ts in range(t._clock + 1):
            np.testing.assert_array_equal(recovered.snapshot_mask(ts),
                                          t.snapshot_mask(ts))

    def test_recover_ignores_other_tables_records(self):
        rng = np.random.default_rng(3)
        t1 = RelationalTable.from_columns(SCHEMA, _cols(rng, 10))
        t2 = RelationalTable.from_columns(SCHEMA, _cols(rng, 12))
        wal = WriteAheadLog()
        srv = QueryServer(RelationalMemoryEngine(revision="xla"), wal=wal)
        srv.submit_insert(t1, _cols(rng, 2))
        srv.submit_insert(t2, _cols(rng, 5))
        srv.drain()
        r1 = RelationalTable.recover(wal, t1.uid)
        r2 = RelationalTable.recover(wal, t2.uid)
        assert r1.row_count == 12 and r2.row_count == 17
        np.testing.assert_array_equal(r1.words(), t1.words())
        np.testing.assert_array_equal(r2.words(), t2.words())

    def test_file_backed_log_survives_reopen(self, tmp_path):
        path = tmp_path / "server.wal"
        rng = np.random.default_rng(4)
        t = RelationalTable.from_columns(SCHEMA, _cols(rng, 20))
        wal = WriteAheadLog(path)
        srv = QueryServer(RelationalMemoryEngine(revision="xla"), wal=wal)
        srv.submit_insert(t, _cols(rng, 6))
        srv.submit_delete(t, np.array([2], np.int64))
        srv.drain()
        wal.close()
        reopened = WriteAheadLog.open(path)
        assert reopened.record_count == wal.record_count
        recovered = RelationalTable.recover(reopened, t.uid)
        assert_recovers_to(recovered, _state(t))


@pytest.mark.parametrize("backend", ["single", "sharded"])
class TestRecoveredTableServes:
    """A recovered table is a first-class table: both backends serve it
    byte-identically to the live table they never lost."""

    def make_engine(self, backend):
        if backend == "sharded":
            return ShardedEngine(num_shards=2, revision="xla")
        return RelationalMemoryEngine(revision="xla")

    def test_full_recovery_serves_identically(self, backend):
        wal, t, states = logged_history()
        recovered = RelationalTable.recover(wal, t.uid)
        live = self.make_engine(backend).execute_many(
            [AggregateOp(t, "b"), GroupByOp(t, "g", "b", num_groups=8)])
        redo = self.make_engine(backend).execute_many(
            [AggregateOp(recovered, "b"),
             GroupByOp(recovered, "g", "b", num_groups=8)])
        for a, b in zip(live, redo):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_every_truncation_prefix_serves_identically(self, backend):
        wal, t, states = logged_history()
        bounds = wal.boundaries()
        for k in range(1, len(bounds)):
            recovered = RelationalTable.recover(wal.truncated(bounds[k]),
                                                t.uid)
            words, row_count, clock = states[k - 1]
            reference = RelationalTable(SCHEMA, capacity=max(row_count, 16))
            reference._words[:row_count] = words
            reference.row_count, reference._clock = row_count, clock
            live = self.make_engine(backend).execute_many(
                [AggregateOp(reference, "b")])
            redo = self.make_engine(backend).execute_many(
                [AggregateOp(recovered, "b")])
            np.testing.assert_array_equal(np.asarray(live[0]),
                                          np.asarray(redo[0]))

    def test_recovered_table_accepts_new_writes(self, backend):
        wal, t, states = logged_history()
        recovered = RelationalTable.recover(wal.corrupted_tail(), t.uid)
        srv = QueryServer(self.make_engine(backend))
        rng = np.random.default_rng(9)
        srv.submit_insert(recovered, _cols(rng, 4))
        tk = srv.submit(plan(recovered).aggregate("b"))
        srv.drain()
        assert float(np.asarray(tk.result())) == float(
            np.sum(np.asarray(recovered.read_column("b"), np.float64)))
