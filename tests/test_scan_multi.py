"""Heterogeneous one-pass scan: kernel, engine, executor, and server layers.

Cross-path equality: every request kind served by the fused pass must match
its single-op kernel and the ``ref.py`` oracle — across all revisions,
under padded (non-tile-multiple) row counts, and with the MVCC snapshot test
fused.  Plus the engine-level contracts: request de-duplication, union-
geometry byte accounting, the VMEM budget guard, and the serving-layer
guarantee that a mixed-kind same-table tick performs exactly one shared scan.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    AggregateOp,
    BatchExecutor,
    FilterOp,
    GroupByOp,
    ProjectOp,
    RelationalMemoryEngine,
    RelationalTable,
    TableGeometry,
    benchmark_schema,
    execute_batch,
    plan,
)
from repro.kernels import ref as R
from repro.kernels.ops import (
    REVISIONS,
    AggregateRequest,
    FilterRequest,
    GroupByRequest,
    ProjectRequest,
    aggregate,
    filter_project,
    groupby_sum,
    project_any,
    request_intervals,
    scan_multi,
    scan_vmem_footprint_bytes,
    union_geometry,
)
from repro.serve import QueryServer


def make_table(n=500, row_bytes=64, seed=0):
    rng = np.random.default_rng(seed)
    schema = benchmark_schema(row_bytes, 4)
    cols = {c.name: rng.integers(-100, 100, n).astype(np.int32)
            for c in schema.columns}
    return schema, RelationalTable.from_columns(schema, cols)


def mixed_requests(schema, n):
    g_proj = TableGeometry.from_schema(schema, ["A1", "A2", "A3", "A4"], n)
    g_filt = TableGeometry.from_schema(schema, ["A1", "A3"], n)
    return (
        ProjectRequest(g_proj),
        FilterRequest(g_filt, pred_word=4, pred_op="gt", pred_k=10),
        AggregateRequest(agg_word=1, pred_word=3, pred_op="lt", pred_k=5),
        GroupByRequest(group_word=1, agg_word=0, num_groups=8),
    )


# ------------------------------------------------------------ kernel layer
@pytest.mark.parametrize("revision", REVISIONS)
@pytest.mark.parametrize("n", [64, 777])  # tile-multiple and padded tails
def test_scan_multi_matches_solo_kernels_and_oracle(revision, n):
    schema, t = make_table(n)
    words = jnp.asarray(t.words())
    reqs = mixed_requests(schema, n)
    outs = scan_multi(words, reqs, revision=revision, block_rows=256)

    np.testing.assert_array_equal(
        np.asarray(outs[0]), np.asarray(R.project_ref(words, reqs[0].geom))
    )
    ref_pk, ref_m = R.filter_project_ref(
        words, reqs[1].geom, 4, "int32", "gt", 10
    )
    np.testing.assert_array_equal(np.asarray(outs[1][0]), np.asarray(ref_pk))
    np.testing.assert_array_equal(np.asarray(outs[1][1]), np.asarray(ref_m))
    ref_sum = R.aggregate_ref(words, 1, "int32", 3, "int32", "lt", 5)
    np.testing.assert_allclose(float(outs[2][0]), float(ref_sum), rtol=1e-5)
    ref_s, ref_c = R.groupby_sum_ref(words, 1, 0, "int32", 8)
    np.testing.assert_allclose(np.asarray(outs[3][0]), np.asarray(ref_s), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(outs[3][1]), np.asarray(ref_c), rtol=1e-5)

    # ... and the solo kernels agree with the same fused outputs
    solo_pk, solo_m = filter_project(words, reqs[1].geom, pred_word=4,
                                     pred_op="gt", pred_k=10)
    np.testing.assert_array_equal(np.asarray(outs[1][0]), np.asarray(solo_pk))
    np.testing.assert_array_equal(np.asarray(outs[1][1]), np.asarray(solo_m))
    solo_agg = aggregate(words, agg_word=1, pred_word=3, pred_op="lt", pred_k=5)
    np.testing.assert_allclose(np.asarray(outs[2]), np.asarray(solo_agg), rtol=1e-6)
    solo_s, solo_c = groupby_sum(words, group_word=1, agg_word=0, num_groups=8)
    np.testing.assert_allclose(np.asarray(outs[3][0]), np.asarray(solo_s), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(outs[3][1]), np.asarray(solo_c), rtol=1e-6)
    np.testing.assert_array_equal(
        np.asarray(outs[0]), np.asarray(project_any(words, reqs[0].geom,
                                                    revision=revision))
    )


@pytest.mark.parametrize("revision", ["mlp", "xla"])
def test_scan_multi_fused_mvcc_snapshot(revision):
    """Deleted rows disappear from snapshot-enabled requests of the fused
    pass — and padded tail rows never contribute."""
    schema, t = make_table(n=333, row_bytes=32)
    ts0 = t.now()
    t.delete(np.arange(0, 333, 2))  # kill even rows after ts0
    words = jnp.asarray(t.words())
    ts_word = schema.row_words
    g = TableGeometry.from_schema(schema, ["A1", "A2"], t.row_count)
    reqs = (
        AggregateRequest(agg_word=0, ts_word=ts_word, ts=ts0),
        AggregateRequest(agg_word=0, ts_word=ts_word, ts=t.now()),
        FilterRequest(g, pred_word=1, pred_op="gt", pred_k=-1000,
                      ts_word=ts_word, ts=t.now()),
        GroupByRequest(group_word=1, agg_word=0, num_groups=4,
                       ts_word=ts_word, ts=t.now()),
    )
    outs = scan_multi(words, reqs, revision=revision, block_rows=64)
    assert int(outs[0][1]) == 333  # the old snapshot still sees every row
    assert int(outs[1][1]) == 333 // 2  # only the 166 odd rows live now
    valid = np.asarray(R.mvcc_mask_ref(words, ts_word, t.now()))
    ref_pk, ref_m = R.filter_project_ref(
        words, g, 1, "int32", "gt", -1000, valid=jnp.asarray(valid)
    )
    np.testing.assert_array_equal(np.asarray(outs[2][0]), np.asarray(ref_pk))
    np.testing.assert_array_equal(np.asarray(outs[2][1]), np.asarray(ref_m))
    ref_s, ref_c = R.groupby_sum_ref(words, 1, 0, "int32", 4,
                                     valid=jnp.asarray(valid))
    np.testing.assert_allclose(np.asarray(outs[3][0]), np.asarray(ref_s), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(outs[3][1]), np.asarray(ref_c), rtol=1e-5)


def test_request_intervals_and_union_geometry():
    schema, _ = make_table(n=10)
    g = TableGeometry.from_schema(schema, ["A1", "A2"], 10)
    # an unpredicated aggregate enables only its aggregate word
    assert request_intervals(AggregateRequest(agg_word=5)) == [(20, 4)]
    # the predicate word and both MVCC timestamp words ride along when enabled
    spans = request_intervals(
        AggregateRequest(agg_word=5, pred_word=2, pred_op="gt", ts_word=16)
    )
    assert (20, 4) in spans and (8, 4) in spans and (64, 8) in spans
    # adjacent/overlapping intervals collapse into one burst chain
    u = union_geometry(
        (ProjectRequest(g), AggregateRequest(agg_word=2)), row_bytes=64,
        row_count=10,
    )
    assert u.col_widths == (12,) and u.abs_offsets == (0,)
    with pytest.raises(ValueError):
        union_geometry((), row_bytes=64, row_count=10)


def test_scan_multi_rejects_empty_and_narrow_storage():
    schema, t = make_table(n=8)
    words = jnp.asarray(t.words())
    with pytest.raises(ValueError):
        scan_multi(words, ())
    wide = TableGeometry.from_schema(benchmark_schema(128, 4), ["A32"], 8)
    with pytest.raises(ValueError):
        scan_multi(words[:, :4], (ProjectRequest(wide),))


# ------------------------------------------------------------ engine layer
@pytest.mark.parametrize("revision", REVISIONS)
def test_execute_many_mixed_matches_solo_paths(revision):
    schema, t = make_table(n=400)
    eng = RelationalMemoryEngine(revision=revision)
    ex = BatchExecutor(eng)
    v = ex.add_columns(t, ("A1", "A2", "A3", "A4"))
    ex.add_filter(t, ("A1", "A3"), "A5", "gt", 10)
    ex.add_aggregate(t, "A2", "A4", "lt", 5)
    ex.add_groupby(t, "A2", "A1", 8)
    assert len(ex) == 4
    outs = ex.submit()
    assert len(ex) == 0 and ex.submit() == []
    assert eng.stats.shared_scans == 1  # four ops, one pass
    assert eng.stats.uploads == 1

    solo = RelationalMemoryEngine(revision=revision)
    np.testing.assert_array_equal(
        np.asarray(outs[0]), np.asarray(solo.register(t, v.columns).packed())
    )
    words = solo.device_words(t)
    geom_f = TableGeometry.from_schema(schema, ["A1", "A3"], t.row_count)
    solo_pk, solo_m = filter_project(words, geom_f, pred_word=4,
                                     pred_op="gt", pred_k=10)
    np.testing.assert_array_equal(np.asarray(outs[1][0]), np.asarray(solo_pk))
    np.testing.assert_array_equal(np.asarray(outs[1][1]), np.asarray(solo_m))
    s, c = solo.aggregate(t, "A2", "A4", "lt", 5)
    assert (float(outs[2][0]), float(outs[2][1])) == (s, c)
    solo_s, solo_c = groupby_sum(words, group_word=1, agg_word=0, num_groups=8)
    np.testing.assert_allclose(np.asarray(outs[3][0]), np.asarray(solo_s), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(outs[3][1]), np.asarray(solo_c), rtol=1e-6)


def test_execute_many_dedupes_equal_requests_and_serves_hot():
    _, t = make_table(n=300)
    eng = RelationalMemoryEngine()
    warm = eng.register(t, ("A2", "A4"))
    _ = warm.packed()  # pre-warm one projection
    ops = [
        ProjectOp(eng.register(t, ("A2", "A4"))),  # hot
        AggregateOp(t, "A1"),
        AggregateOp(t, "A1"),  # identical: must share one output slot
        AggregateOp(t, "A1", "A3", "gt", 0),  # different predicate: its own
        GroupByOp(t, "A2", "A1", 8),
    ]
    hot_before = eng.stats.hot_hits
    outs = execute_batch(eng, ops)
    assert eng.stats.hot_hits == hot_before + 1
    assert eng.stats.shared_scans == 1  # 3 distinct cold requests, one pass
    assert eng.stats.cold_misses == 1 + 4  # warm-up + the four cold ops
    np.testing.assert_array_equal(np.asarray(outs[1]), np.asarray(outs[2]))
    assert float(outs[1][1]) == t.row_count
    assert float(outs[3][1]) < t.row_count  # the predicated twin differs
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(warm.packed()))


def test_fused_pass_charges_union_bytes_once():
    """The mixed pass charges the union geometry's bus beats — strictly fewer
    than the same ops executed one at a time on an identical engine."""
    _, t = make_table(n=1000)
    mk = lambda: [  # noqa: E731 — tiny op-batch factory
        ProjectOp(eng.register(t, ("A1", "A2"))),
        AggregateOp(t, "A2", "A4", "lt", 5),
        GroupByOp(t, "A3", "A1", 8),
    ]
    eng = RelationalMemoryEngine()
    batch_ops = mk()
    eng.execute_many(batch_ops)
    fused_bytes = eng.stats.bytes_from_dram
    assert fused_bytes == eng.scan_bytes(t, tuple(o.lower() for o in batch_ops))

    eng = RelationalMemoryEngine()
    for op in mk():
        eng.execute_many([op])
    assert eng.stats.shared_scans == 0  # solo ops keep the single-op kernels
    assert fused_bytes < eng.stats.bytes_from_dram


def test_vmem_budget_guard_halves_block_rows():
    schema, t = make_table(n=2000)
    reqs = tuple(
        ProjectRequest(TableGeometry.from_schema(schema, [f"A{i + 1}"], 2000))
        for i in range(8)
    )
    # the modeled footprint shrinks linearly with the tile height; the row
    # tile is the *storage* stride (hidden MVCC words ride in the stream)
    big = scan_vmem_footprint_bytes(reqs, t.row_words, 256)
    assert scan_vmem_footprint_bytes(reqs, t.row_words, 128) == big // 2

    tight = RelationalMemoryEngine(vmem_bytes=big // 4)
    ops = [ProjectOp(tight.register(t, [f"A{i + 1}"])) for i in range(8)]
    outs = tight.execute_many(ops)
    assert tight.stats.last_block_rows == 64  # halved 256 -> 128 -> 64
    solo = RelationalMemoryEngine()
    for i, out in enumerate(outs):  # tile choice never changes results
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(solo.register(t, [f"A{i + 1}"]).packed())
        )

    floor = RelationalMemoryEngine(vmem_bytes=1)  # absurd budget: floor holds
    floor.execute_many([ProjectOp(floor.register(t, [f"A{i + 1}"]))
                        for i in range(8)])
    assert floor.stats.last_block_rows == 32

    roomy = RelationalMemoryEngine()  # 2 MB default: no shrink needed here
    roomy.execute_many([ProjectOp(roomy.register(t, [f"A{i + 1}"]))
                        for i in range(2)])
    assert roomy.stats.last_block_rows == roomy.block_rows


def test_executor_snapshot_ops_respect_mvcc():
    """Snapshot-carrying filter/aggregate ops fused into one pass see only
    the rows live at their snapshot time."""
    _, t = make_table(n=200, row_bytes=32)
    ts0 = t.now()
    keep = np.asarray(t.read_column("A1")[1::2], dtype=np.float64)
    t.delete(np.arange(0, 200, 2))
    eng = RelationalMemoryEngine()
    ex = BatchExecutor(eng)
    ex.add_aggregate(t, "A1", snapshot_ts=ts0)
    ex.add_aggregate(t, "A1", snapshot_ts=t.now())
    ex.add_filter(t, ("A1", "A2"), "A2", "gt", -1000, snapshot_ts=t.now())
    before, after, (packed, mask) = ex.submit()
    assert eng.stats.shared_scans == 1
    assert int(before[1]) == 200
    assert int(after[1]) == 100
    np.testing.assert_allclose(float(after[0]), keep.sum(), rtol=1e-6)
    assert int(np.asarray(mask).sum()) == 100  # dead rows fail validity
    assert not np.asarray(packed)[::2].any()  # ...and are zeroed in the block


def test_executor_rejects_foreign_filter_views():
    _, t = make_table(n=50)
    eng1, eng2 = RelationalMemoryEngine(), RelationalMemoryEngine()
    ex = BatchExecutor(eng1)
    with pytest.raises(ValueError):
        ex.add_op(FilterOp(eng2.register(t, ("A1",)), "A2"))


# ------------------------------------------------------------ server layer
def test_mixed_kind_tick_is_one_shared_scan():
    """The acceptance check: a mixed-kind same-table tick performs exactly
    one shared scan, and every result matches its solo execution."""
    _, t = make_table(n=400)
    eng = RelationalMemoryEngine()
    server = QueryServer(eng)
    t_proj = server.submit(plan(t).project("A1", "A3"))
    t_filt = server.submit(plan(t).filter("A5", "gt", 10).project("A1", "A2"))
    t_agg = server.submit(plan(t).filter("A4", "lt", 5).sum("A2"))
    t_gb = server.submit(plan(t).groupby("A2", "A1", "avg", 16))
    server.run_tick()
    assert eng.stats.shared_scans == 1  # one pass answered all four kinds
    assert eng.stats.uploads == 1
    assert t_proj.route == "rme"
    assert t_filt.route == "fused-filter"
    assert t_agg.route == "fused-aggregate"
    assert t_gb.route == "fused-groupby"
    assert server.stats.table_groups == 1
    assert server.stats.shared_scan_ratio == 1.0
    assert server.stats.bytes_saved > 0

    solo = RelationalMemoryEngine()
    np.testing.assert_array_equal(
        np.asarray(t_proj.result(timeout=5)),
        np.asarray(solo.register(t, ("A1", "A3")).packed()),
    )
    geom = TableGeometry.from_schema(t.schema, ["A1", "A2"], t.row_count)
    ref_pk, ref_m = filter_project(solo.device_words(t), geom, pred_word=4,
                                   pred_op="gt", pred_k=10)
    got_pk, got_m = t_filt.result(timeout=5)
    np.testing.assert_array_equal(np.asarray(got_pk), np.asarray(ref_pk))
    np.testing.assert_array_equal(np.asarray(got_m), np.asarray(ref_m))
    s, _ = solo.aggregate(t, "A2", "A4", "lt", 5)
    assert t_agg.result(timeout=5) == s
    ref_s, ref_c = groupby_sum(solo.device_words(t), group_word=1, agg_word=0,
                               num_groups=16)
    np.testing.assert_allclose(
        np.asarray(t_gb.result(timeout=5)),
        np.asarray(ref_s) / np.maximum(np.asarray(ref_c), 1.0), rtol=1e-6,
    )


def test_bad_query_does_not_poison_the_tick():
    """One client's unservable query (int64 aggregate: fused kernels decode
    4-byte words only) fails its own ticket — the other clients' results
    still arrive.  Compile-time dtype validation catches the known case, and
    the per-query fallback guards the shared step against anything else."""
    from repro.core import paper_schema

    rng = np.random.default_rng(5)
    schema = paper_schema()
    n = 128
    cols = {}
    for c in schema.columns:
        if c.dtype == "char":
            cols[c.name] = (rng.integers(0, 256, (n, c.width)).astype(np.uint8)
                            .view(np.dtype((np.bytes_, c.width))).reshape(-1))
        elif c.dtype == "int64":
            cols[c.name] = np.arange(n, dtype=np.int64)
        else:
            cols[c.name] = rng.integers(-50, 50, n).astype(np.int32)
    t = RelationalTable.from_columns(schema, cols)
    eng = RelationalMemoryEngine()
    server = QueryServer(eng)
    good = server.submit(plan(t).project("num_fld1"))
    bad = server.submit(plan(t).sum("key"))  # int64: inexpressible fused
    server.run_tick()
    with pytest.raises(ValueError, match="4-byte numeric"):
        bad.result(timeout=5)
    np.testing.assert_array_equal(
        np.asarray(good.result(timeout=5))[:, 0],
        np.asarray(t.read_column("num_fld1")),
    )
    assert server.stats.served == 1 and server.stats.failed == 1


def test_shared_step_fallback_isolates_the_offender():
    """If the shared pass itself dies mid-tick, healthy queries are re-run
    individually instead of inheriting the batch's error."""
    _, t = make_table(n=100)
    eng = RelationalMemoryEngine()
    server = QueryServer(eng)
    real = eng.execute_many
    calls = {"n": 0}

    def flaky(ops):
        calls["n"] += 1
        if calls["n"] == 1 and len(ops) > 1:  # only the coalesced launch dies
            raise RuntimeError("fused pass failed to lower")
        return real(ops)

    eng.execute_many = flaky
    tk1 = server.submit(plan(t).project("A1", "A2"))
    tk2 = server.submit(plan(t).filter("A4", "lt", 5).sum("A2"))
    server.run_tick()
    solo = RelationalMemoryEngine()
    np.testing.assert_array_equal(
        np.asarray(tk1.result(timeout=5)),
        np.asarray(solo.register(t, ("A1", "A2")).packed()),
    )
    s, _ = solo.aggregate(t, "A2", "A4", "lt", 5)
    assert tk2.result(timeout=5) == s
    assert server.stats.served == 2 and server.stats.failed == 0


def test_mixed_kinds_two_tables_two_scans():
    _, t1 = make_table(n=300, seed=1)
    _, t2 = make_table(n=200, seed=2)
    eng = RelationalMemoryEngine()
    server = QueryServer(eng)
    for t in (t1, t2):
        server.submit(plan(t).project("A1", "A2"))
        server.submit(plan(t).filter("A4", "lt", 5).sum("A2"))
    server.run_tick()
    assert eng.stats.shared_scans == 2  # one fused pass per table
    assert server.stats.table_groups == 2
    assert server.stats.shared_scan_ratio == 1.0
