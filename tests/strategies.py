"""Deterministic case generators for the compressed-execution harness.

``hypothesis`` is a CI-only extra (requirements-dev.txt), but the tier-1
suite must run the differential property harness everywhere — so cases are
plain seeded-numpy generators: every (table, plan) case is a pure function
of its integer seed, reproducible by seed alone.

A *case* is an encoded table, a byte-aligned plain twin, and the logical
column values:

* ``K``  — int32, dict-encoded; hostile distributions (value skew, INT32
  extremes, duplicate-heavy, all-distinct) keyed off the seed.
* ``F``  — int32, FOR-encoded; values in a small offset range so every
  float32 partial sum is integer-exact and the ``base * count +
  sum(deltas)`` identity is bit-equal to the plain sum.
* ``S``  — str, dictionary-coded by construction.  The plain twin stores
  the same column as its raw int32 dictionary codes, which keeps the twin
  byte-aligned word-for-word (bytes comparisons are apples-to-apples) and
  makes plain group-bys over it match the encoded remap exactly whenever
  ``num_groups`` covers the dictionary.
* ``V``/``P`` — plain int32 payload/predicate columns in [-50, 50).

Empty tables (n=0) are generated too — dictionary fits on nothing must
still serve every plan shape.
"""

import numpy as np

from repro.core.compression import DictCodec
from repro.core.schema import Column, TableSchema
from repro.core.table import RelationalTable

I32 = np.iinfo(np.int32)

STRING_POOL = np.array(
    ["amber", "basil", "cedar", "ember", "fig", "grove", "holly", "iris"],
    dtype=np.str_,
)

KEY_STYLES = ("skew", "extremes", "dupes", "distinct")

ROW_COUNTS = (0, 1, 7, 64, 257, 600)

ENC_SCHEMA = TableSchema((
    Column("K", "int32", codec="dict"),
    Column("F", "int32", codec="for"),
    Column("S", "str"),
    Column("V", "int32"),
    Column("P", "int32"),
))

PLAIN_SCHEMA = TableSchema((
    Column("K", "int32"),
    Column("F", "int32"),
    Column("S", "int32"),  # the raw dictionary codes, same word slot
    Column("V", "int32"),
    Column("P", "int32"),
))


def key_column(rng: np.random.Generator, style: str, n: int) -> np.ndarray:
    """One hostile dict-key distribution."""
    if n == 0:
        return np.zeros(0, np.int32)
    if style == "skew":
        pool = np.array([-7, 0, 3, 1 << 20], np.int64)
        p = np.array([0.85, 0.05, 0.05, 0.05])
        return rng.choice(pool, n, p=p).astype(np.int32)
    if style == "extremes":
        pool = np.array(
            [I32.min, I32.min + 1, -1, 0, I32.max - 1, I32.max], np.int64
        )
        return rng.choice(pool, n).astype(np.int32)
    if style == "dupes":
        return rng.integers(-3, 3, n).astype(np.int32)
    # all-distinct, including negatives
    return rng.permutation(np.arange(n, dtype=np.int32) - n // 2)


def logical_columns(seed: int) -> dict[str, np.ndarray]:
    """The logical column values of case ``seed`` (style follows the seed)."""
    rng = np.random.default_rng(seed)
    n = ROW_COUNTS[seed % len(ROW_COUNTS)]
    style = KEY_STYLES[(seed // len(ROW_COUNTS)) % len(KEY_STYLES)]
    base = int(rng.integers(-60, 60))
    return {
        "K": key_column(rng, style, n),
        "F": (base + rng.integers(0, 100, n)).astype(np.int32),
        "S": (rng.choice(STRING_POOL, n) if n
              else np.zeros(0, STRING_POOL.dtype)),
        "V": rng.integers(-50, 50, n).astype(np.int32),
        "P": rng.integers(-50, 50, n).astype(np.int32),
    }


def str_codes(strs: np.ndarray) -> np.ndarray:
    """The dictionary codes the encoded table stores for ``strs`` — what the
    plain twin's int32 ``S`` column carries."""
    if strs.size == 0:
        return np.zeros(0, np.int32)
    return DictCodec.fit(strs).encode(strs)


def case_tables(seed: int):
    """(encoded table, plain twin, logical values) for case ``seed``."""
    logical = logical_columns(seed)
    enc = RelationalTable.from_columns(ENC_SCHEMA, logical)
    plain_cols = dict(logical, S=str_codes(logical["S"]))
    plain = RelationalTable.from_columns(PLAIN_SCHEMA, plain_cols)
    return enc, plain, logical


def build_tables(seed: int, n_build: int = 41):
    """A build-side pair for join cases: unique keys drawn to overlap the
    probe table's ``K`` domain, both sides sharing one table-level
    dictionary (the encoded-join contract)."""
    rng = np.random.default_rng(seed + 10_000)
    logical = logical_columns(seed)
    probe_keys = logical["K"]
    pool = np.unique(np.concatenate([
        probe_keys.astype(np.int64),
        rng.integers(-100, 100, n_build).astype(np.int64),
    ])).astype(np.int32)
    build_keys = rng.permutation(pool)[: min(n_build, pool.size)]
    if build_keys.size == 0:
        build_keys = np.array([0], np.int32)
    build_vals = rng.integers(-50, 50, build_keys.size).astype(np.int32)

    shared = DictCodec.fit(
        np.concatenate([probe_keys, build_keys]).astype(np.int32)
    )
    enc_probe = RelationalTable.from_columns(
        ENC_SCHEMA, logical, codecs={"K": shared}
    )
    build_schema = TableSchema((Column("K", "int32"), Column("B", "int32")))
    enc_build = RelationalTable.from_columns(
        build_schema, {"K": build_keys, "B": build_vals},
        codecs={"K": shared},
    )
    plain_probe = RelationalTable.from_columns(
        PLAIN_SCHEMA, dict(logical, S=str_codes(logical["S"]))
    )
    plain_build = RelationalTable.from_columns(
        build_schema, {"K": build_keys, "B": build_vals}
    )
    return (enc_probe, enc_build), (plain_probe, plain_build), (
        logical, {"K": build_keys, "B": build_vals}
    )


def pred_constant(rng: np.random.Generator, values: np.ndarray) -> int:
    """A predicate constant: usually inside the value range, sometimes a
    never-pass / all-pass extreme (exercises the translated collapses)."""
    roll = rng.integers(0, 8)
    if roll == 0:
        return int(I32.min)
    if roll == 1:
        return int(I32.max)
    if values.size == 0:
        return int(rng.integers(-50, 50))
    return int(rng.choice(values.astype(np.int64)))


PLAN_KINDS = ("project", "filter", "aggregate", "groupby", "groupby_str")


def plan_params(seed: int, kind: str) -> dict:
    """Parameters of the ``kind`` plan for case ``seed`` — predicate column,
    op, constant, group domain, snapshot choice — all seed-derived."""
    rng = np.random.default_rng(seed * 7 + PLAN_KINDS.index(kind))
    logical = logical_columns(seed)
    p: dict = {"snapshot": bool(rng.integers(0, 2))}
    if kind == "project":
        p["cols"] = ("K", "F", "S", "V")
    elif kind == "filter":
        p["cols"] = ("K", "V")
        p["pred_col"] = str(rng.choice(["K", "P"]))
        p["pred_op"] = str(rng.choice(["gt", "lt"]))
        p["pred_k"] = pred_constant(rng, logical[p["pred_col"]])
    elif kind == "aggregate":
        p["agg_col"] = str(rng.choice(["F", "V"]))
        p["pred_col"] = str(rng.choice(["K", "P"]))
        p["pred_op"] = str(rng.choice(["gt", "lt"]))
        p["pred_k"] = pred_constant(rng, logical[p["pred_col"]])
    elif kind == "groupby":
        p["group_col"] = "K"
        p["agg_col"] = str(rng.choice(["F", "V"]))
        p["num_groups"] = int(rng.choice([8, 16]))
    elif kind == "groupby_str":
        p["group_col"] = "S"
        p["agg_col"] = str(rng.choice(["F", "V"]))
        # must cover the string dictionary (checked at lowering)
        p["num_groups"] = len(STRING_POOL) + int(rng.integers(0, 3))
    return p
