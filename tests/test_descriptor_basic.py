"""Deterministic descriptor tests split out of test_descriptor.py.

test_descriptor.py is hypothesis-based end to end (its geometry strategy is a
``@st.composite``), so it importorskips when hypothesis is absent; these
deterministic checks of the Requestor math keep running in that tier-1
environment.
"""

import numpy as np

from repro.core import TableGeometry, benchmark_schema, descriptors, fetch_model
from repro.core.descriptor import descriptor_arrays
from repro.core.schema import WORD


def test_vectorized_matches_scalar():
    schema = benchmark_schema(64, 4)
    geom = TableGeometry.from_schema(schema, ["A1", "A7", "A13"], 100)
    arrs = descriptor_arrays(geom)
    descs = descriptors(geom)
    for d in descs:
        assert arrs["r_addr"][d.i, d.j] == d.r_addr
        assert arrs["r_burst"][d.i, d.j] == d.r_burst
        assert arrs["w_addr"][d.i, d.j] == d.w_addr
        assert arrs["e_start"][d.i, d.j] == d.e_start
        assert arrs["e_end"][d.i, d.j] == d.e_end


def test_offset_insensitivity():
    """Fig. 6's second message: burst count is offset-independent except when
    the column straddles a bus line (the paper's spikes at offsets 13-15,
    29-31, 45-47 — at word granularity: an 8B column at offset ≡ 12 mod 16)."""
    n = 64
    beats = {}
    for off_words in range(0, 14):
        geom = TableGeometry(
            row_bytes=64, row_count=n, col_widths=(8,),
            col_rel_offsets=(off_words * WORD,),
        )
        rng = np.random.default_rng(0)
        mem = rng.integers(0, 256, geom.row_bytes * n, dtype=np.uint8)
        _, b = fetch_model(mem, geom, bus_width=16)
        beats[off_words * WORD] = b
    base = beats[0]
    for off, b in beats.items():
        if off % 16 == 12:  # 8B column starting 4B before a bus boundary
            assert b == 2 * base, (off, b, base)  # the paper's spike
        else:
            assert b == base, (off, b, base)
