"""Logical plan IR + plan compiler: shapes, routing, cross-path equality."""

import numpy as np
import pytest

from repro.core import (
    Aggregate,
    Filter,
    GroupBy,
    Join,
    PlanError,
    Project,
    RelationalMemoryEngine,
    RelationalTable,
    Scan,
    benchmark_schema,
    compile_plan,
    decompose,
    plan,
)
from repro.core import operators as ops
from repro.core.plan import describe

PATHS = ("rme", "row", "col")


@pytest.fixture
def table():
    rng = np.random.default_rng(0)
    schema = benchmark_schema(64, 4)
    n = 600
    return RelationalTable.from_columns(
        schema,
        {c.name: rng.integers(-100, 100, n).astype(np.int32)
         for c in schema.columns},
    )


@pytest.fixture
def build_table(table):
    rng = np.random.default_rng(9)
    n_r = 128
    r_cols = {c.name: rng.integers(-50, 50, n_r).astype(np.int32)
              for c in table.schema.columns}
    r_cols["A2"] = np.arange(n_r, dtype=np.int32)  # primary key
    return RelationalTable.from_columns(table.schema, r_cols)


# ------------------------------------------------------------------- IR
def test_builder_constructs_expected_trees(table):
    node = plan(table).filter("A3", "gt", 5).project("A1", "A4").build()
    assert isinstance(node, Project) and node.columns == ("A1", "A4")
    assert isinstance(node.child, Filter) and node.child.op == "gt"
    assert isinstance(node.child.child, Scan)
    assert node.child.child.table is table

    agg = plan(table).filter("A4", "lt", 0).sum("A2").build()
    assert isinstance(agg, Aggregate) and agg.op == "sum"
    gb = plan(table).groupby("A2", "A1", "avg", 32).build()
    assert isinstance(gb, GroupBy) and gb.num_groups == 32
    j = plan(table).join(table, key="A2", left_proj="A1", right_proj="A3").build()
    assert isinstance(j, Join) and isinstance(j.right, Scan)
    assert "Scan" in describe(node)


def test_decompose_flattens_and_validates(table):
    shape = decompose(plan(table).filter("A3", "lt", 7).sum("A1"))
    assert shape.kind == "aggregate"
    assert shape.pred.col == "A3" and shape.pred.k == 7
    assert shape.columns == ("A1", "A3")  # physical order, dedup
    # project/filter commute
    s1 = decompose(plan(table).filter("A3", "gt", 0).project("A1"))
    s2 = decompose(plan(table).project("A1").filter("A3", "gt", 0))
    assert s1.kind == s2.kind == "project"
    assert s1.pred == s2.pred and s1.columns == s2.columns
    # bare scan projects every column
    assert decompose(plan(table)).columns == table.schema.names


def test_invalid_plans_raise(table):
    with pytest.raises(PlanError):
        plan(table).filter("A1", "eq", 0)  # unsupported predicate op
    with pytest.raises(PlanError):
        plan(table).aggregate("A1", "median")
    with pytest.raises(PlanError):
        decompose(plan(table).filter("A1", "gt", 0).filter("A2", "lt", 0)
                  .project("A3"))  # two fused predicates
    with pytest.raises(KeyError):
        decompose(plan(table).project("nope"))
    with pytest.raises(PlanError):
        # join sides must be plain scans (modulo probe-side Filters)
        decompose(Join(plan(table).project("A1").build(), Scan(table),
                       "A2", "A1", "A3"))


def test_decompose_is_order_insensitive(table):
    """Regression for the reordered spellings the rewrite passes produce."""
    # Filter above Project above Filter — identical predicates collapse
    s = decompose(plan(table).filter("A3", "gt", 2).project("A1")
                  .filter("A3", "gt", 2))
    assert s.kind == "project" and s.columns == ("A1",)
    assert s.pred.col == "A3" and s.pred.k == 2
    # nested Projects: the outermost defines the output group
    s = decompose(plan(table).project("A1", "A4", "A7").project("A1", "A4"))
    assert s.columns == ("A1", "A4")
    # Project under Aggregate widens the scanned group (pruning's target)
    s = decompose(plan(table).project("A1", "A4").sum("A1"))
    assert s.kind == "aggregate" and s.columns == ("A1", "A4")
    # ...and under GroupBy
    s = decompose(plan(table).project("A5").groupby("A2", "A1"))
    assert s.kind == "groupby" and s.columns == ("A1", "A2", "A5")
    # two *distinct* predicates still exceed the fused kernels
    with pytest.raises(PlanError):
        decompose(plan(table).filter("A3", "gt", 2).project("A1")
                  .filter("A3", "gt", 3))
    # Filter above a Join becomes the probe-side predicate; Filter below
    # the Join's probe side is the same shape
    above = decompose(Filter(
        plan(table).join(table, key="A2", left_proj="A1",
                         right_proj="A3").build(), "A4", "gt", 1))
    below = decompose(plan(table).filter("A4", "gt", 1)
                      .join(table, key="A2", left_proj="A1", right_proj="A3"))
    assert above.kind == below.kind == "join"
    assert above.pred == below.pred and above.pred.col == "A4"
    assert above.columns == below.columns
    # a left-deep two-join chain flattens innermost-first
    chain = decompose(
        plan(table).join(table, key="A2", left_proj="A1", right_proj="A3")
        .join(table, key="A4", left_proj="A5", right_proj="A6"))
    assert chain.kind == "join" and len(chain.joins) == 2
    assert chain.joins[0].key == "A2" and chain.joins[1].key == "A4"
    assert chain.join is chain.joins[0]
    assert chain.columns == ("A1", "A2", "A4", "A5")


# ------------------------------------------------------- compiler routing
def test_compiler_routes_by_shape(table):
    eng = RelationalMemoryEngine()
    assert compile_plan(eng, plan(table).sum("A1")).route == "fused-aggregate"
    assert compile_plan(
        eng, plan(table).filter("A3", "lt", 0).groupby("A2", "A1")
    ).route == "fused-groupby"
    assert compile_plan(
        eng, plan(table).filter("A3", "gt", 0).project("A1")
    ).route == "fused-filter"
    assert compile_plan(eng, plan(table).project("A1", "A5")).route == "rme"
    # beyond the configuration port's Q cap: host fallback over full rows
    wide = compile_plan(eng, plan(table).project(*table.schema.names))
    assert wide.route == "row-fallback" and wide.views == ()
    # a warmed view is served from the reorganization cache
    _ = eng.register(table, ("A1", "A5")).packed()
    assert compile_plan(eng, plan(table).project("A1", "A5")).route == "hot"
    # baseline paths compile to host routes
    assert compile_plan(eng, plan(table).sum("A1"), path="row").route == "host-row"


def test_compiled_query_run_matches_operator_surface(table):
    eng = RelationalMemoryEngine()
    got = compile_plan(eng, plan(table).filter("A4", "lt", 3).sum("A2")).run()
    assert got == ops.q3_select_aggregate(eng, table, "A2", "A4", 3)
    avg = compile_plan(eng, plan(table).avg("A1")).run()
    s = table.read_column("A1").astype(np.float64).sum()
    np.testing.assert_allclose(avg, s / table.row_count, rtol=1e-5)
    cnt = compile_plan(eng, plan(table).filter("A3", "gt", 0).count("A3")).run()
    assert cnt == float((table.read_column("A3") > 0).sum())


# -------------------------------------------------- cross-path equality
def test_q0_cross_path_via_plan(table):
    eng = RelationalMemoryEngine()
    cs = ops.make_colstore(table, list(table.schema.names))
    q = plan(table).sum("A1")
    got = {p: compile_plan(eng, q, path=p, colstore=cs).run() for p in PATHS}
    assert len({round(v, 2) for v in got.values()}) == 1


def test_q1_cross_path_via_plan(table):
    eng = RelationalMemoryEngine()
    cols = ("A1", "A3", "A7")
    cs = ops.make_colstore(table, cols)
    q = plan(table).project(*cols)
    got = {p: np.asarray(compile_plan(eng, q, path=p, colstore=cs).run())
           for p in PATHS}
    np.testing.assert_array_equal(got["rme"], got["row"])
    np.testing.assert_array_equal(got["rme"], got["col"])


def test_q2_cross_path_via_plan(table):
    eng = RelationalMemoryEngine()
    cs = ops.make_colstore(table, list(table.schema.names))
    q = plan(table).filter("A3", "gt", 10).project("A1")
    for p in ("row", "col"):
        packed, mask = compile_plan(eng, q, path=p, colstore=cs).run()
        ref_packed, ref_mask = compile_plan(eng, q).run()
        np.testing.assert_array_equal(np.asarray(packed), np.asarray(ref_packed))
        np.testing.assert_array_equal(np.asarray(mask), np.asarray(ref_mask))


def test_q3_cross_path_via_plan(table):
    eng = RelationalMemoryEngine()
    cs = ops.make_colstore(table, list(table.schema.names))
    q = plan(table).filter("A4", "lt", 5).sum("A2")
    got = {p: compile_plan(eng, q, path=p, colstore=cs).run() for p in PATHS}
    assert len({round(v, 2) for v in got.values()}) == 1


def test_q4_cross_path_via_plan(table):
    eng = RelationalMemoryEngine()
    cs = ops.make_colstore(table, list(table.schema.names))
    q = plan(table).filter("A3", "lt", 5).groupby("A2", "A1", "avg", 16)
    got = {p: np.asarray(compile_plan(eng, q, path=p, colstore=cs).run())
           for p in PATHS}
    np.testing.assert_allclose(got["rme"], got["row"], rtol=1e-5)
    np.testing.assert_allclose(got["rme"], got["col"], rtol=1e-5)


def test_q5_cross_path_via_plan(table, build_table):
    eng = RelationalMemoryEngine()
    scs = ops.make_colstore(table, ["A1", "A2"])
    rcs = ops.make_colstore(build_table, ["A2", "A3"])
    q = plan(table).join(build_table, key="A2", left_proj="A1", right_proj="A3")
    got = {p: compile_plan(eng, q, path=p, colstore=scs,
                           right_colstore=rcs).run() for p in PATHS}
    for p in ("row", "col"):
        np.testing.assert_array_equal(np.asarray(got["rme"].matched),
                                      np.asarray(got[p].matched))
        np.testing.assert_array_equal(np.asarray(got["rme"].r_proj),
                                      np.asarray(got[p].r_proj))


def test_groupby_without_filter_cross_path(table):
    eng = RelationalMemoryEngine()
    cs = ops.make_colstore(table, list(table.schema.names))
    q = plan(table).groupby("A2", "A1", "sum", 8)
    got = {p: np.asarray(compile_plan(eng, q, path=p, colstore=cs).run())
           for p in PATHS}
    np.testing.assert_allclose(got["rme"], got["row"], rtol=1e-5)
    np.testing.assert_allclose(got["rme"], got["col"], rtol=1e-5)


def test_row_fallback_uses_resident_store_and_charges_bytes(table):
    """The beyond-Q-cap fallback must stream the device-resident row store
    (no per-call host re-upload) and charge the PMU a full-row pass."""
    eng = RelationalMemoryEngine()
    q = plan(table).project(*table.schema.names)
    first = np.asarray(compile_plan(eng, q).run())
    assert eng.stats.uploads == 1
    dram_after_first = eng.stats.bytes_from_dram
    assert dram_after_first == table.row_count * table.schema.row_bytes
    second = np.asarray(compile_plan(eng, q).run())
    assert eng.stats.uploads == 1  # resident buffer reused, not re-shipped
    assert eng.stats.bytes_from_dram == 2 * dram_after_first
    np.testing.assert_array_equal(first, second)


def test_filtered_wide_projection_falls_back_not_crashes(table):
    """A filtered plan whose output group exceeds the Q cap (e.g. a bare
    Filter over all 16 columns) must route to the full-row fallback with the
    same (packed, mask) contract — not raise from TableGeometry."""
    eng = RelationalMemoryEngine()
    q = plan(table).filter("A3", "gt", 10)  # no Project: all 16 columns
    pq = compile_plan(eng, q)
    assert pq.route == "row-fallback"
    packed, mask = pq.run()
    a3 = table.read_column("A3")
    np.testing.assert_array_equal(np.asarray(mask), a3 > 10)
    np.testing.assert_array_equal(
        np.asarray(packed)[:, 0], np.where(a3 > 10, table.read_column("A1"), 0)
    )
    # host baselines agree
    cs = ops.make_colstore(table, list(table.schema.names))
    for p in ("row", "col"):
        hp, hm = compile_plan(eng, q, path=p, colstore=cs).run()
        np.testing.assert_array_equal(np.asarray(hp), np.asarray(packed))
        np.testing.assert_array_equal(np.asarray(hm), np.asarray(mask))


def test_duplicate_build_index_insert_keeps_occupancy_exact(table, build_table):
    """Two identical joins compiled in one tick both insert at launch; the
    same-key overwrite must not double-count occupancy bytes."""
    from repro.core import planner

    eng = RelationalMemoryEngine()
    ops.clear_join_build_cache()
    q = plan(table).join(build_table, key="A2", left_proj="A1", right_proj="A3")
    pq1 = compile_plan(eng, q)
    pq2 = compile_plan(eng, q)  # both compiled before either launches: both miss
    r1, r2 = pq1.run(), pq2.run()
    np.testing.assert_array_equal(np.asarray(r1.matched), np.asarray(r2.matched))
    entries = [v for k, v in planner._BUILD_INDEX_CACHE.items()
               if k[0] == build_table.uid]
    assert len(entries) == 1
    expect = sum(a.size * a.dtype.itemsize for a in entries[0])
    assert planner._build_index_bytes == expect  # no drift from the overwrite


# ------------------------------------------------------- reset regression
def test_engine_reset_clears_join_build_cache(table, build_table):
    """reset() must clear the module-global q5 build-index cache — stale
    JOIN_BUILD_STATS and sorted indexes used to leak across repetitions."""
    eng = RelationalMemoryEngine()
    ops.clear_join_build_cache()
    _ = ops.q5_hash_join(eng, table, build_table)
    assert ops.JOIN_BUILD_STATS == {"hits": 0, "misses": 1}
    assert any(k[0] == build_table.uid for k in ops._BUILD_INDEX_CACHE)
    eng.reset()
    assert ops.JOIN_BUILD_STATS == {"hits": 0, "misses": 0}
    assert not ops._BUILD_INDEX_CACHE  # no stale sorted indexes survive reset
    _ = ops.q5_hash_join(eng, table, build_table)
    assert ops.JOIN_BUILD_STATS == {"hits": 0, "misses": 1}  # cold again


def test_engine_reset_clears_device_partition_cache(table, build_table):
    """Same stale-bytes leak class for the device hash route: reset() (and
    clear_join_build_cache()) must also drop the cached hash-partition
    arrays, or a benchmark repetition would warm-probe a previous rep's
    device buckets."""
    from repro.core.planner import DEVICE_JOIN_PATH

    eng = RelationalMemoryEngine()
    ops.clear_join_build_cache()
    pq = compile_plan(
        eng, plan(table).join(build_table, key="A2", left_proj="A1",
                              right_proj="A3"))
    assert pq.route == "device-hash-join"
    _ = pq.run()
    assert eng.stats.join_builds == 1
    assert [k for k in ops._BUILD_INDEX_CACHE if k[-1] == DEVICE_JOIN_PATH]
    eng.reset()
    assert not ops._BUILD_INDEX_CACHE  # partitions dropped with the indexes
    assert ops.JOIN_BUILD_STATS == {"hits": 0, "misses": 0}
    _ = compile_plan(
        eng, plan(table).join(build_table, key="A2", left_proj="A1",
                              right_proj="A3")).run()
    assert eng.stats.join_builds == 2  # cold again: a fresh build ran
