"""Scan-sharing batch executor + device row store + cache accounting fixes."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    BatchExecutor,
    RelationalMemoryEngine,
    RelationalTable,
    ReorgCache,
    TableGeometry,
    benchmark_schema,
    bytes_moved,
    materialize_batch,
    merge_geometries,
)
from repro.core import operators as ops
from repro.core.planner import plan_batch, plan_query
from repro.kernels.ops import REVISIONS

GROUPS = (("A1",), ("A1", "A2", "A3", "A4"), ("A2", "A4"))


@pytest.fixture
def table():
    rng = np.random.default_rng(0)
    schema = benchmark_schema(64, 4)
    n = 500
    return RelationalTable.from_columns(
        schema,
        {c.name: rng.integers(-100, 100, n).astype(np.int32)
         for c in schema.columns},
    )


# ------------------------------------------------- project_multi (kernel)
@pytest.mark.parametrize("revision", REVISIONS)
def test_project_multi_kernel_matches_oracle(table, revision):
    """Direct kernel-level check: the engine now routes batches through the
    heterogeneous scan (rme_scan_multi), so the multi-view projection kernel
    needs its own equality sweep to stay honest."""
    import jax.numpy as jnp

    from repro.kernels import ref as R
    from repro.kernels.ops import project_multi

    words = jnp.asarray(table.words())
    geoms = tuple(
        TableGeometry.from_schema(table.schema, list(g), table.row_count)
        for g in GROUPS
    )
    outs = project_multi(words, geoms, revision=revision, block_rows=128)
    for geom, got in zip(geoms, outs):
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(R.project_ref(words, geom))
        )


# ------------------------------------------------------- materialize_many
@pytest.mark.parametrize("revision", REVISIONS)
def test_materialize_many_matches_per_view(table, revision):
    batch_eng = RelationalMemoryEngine(revision=revision)
    solo_eng = RelationalMemoryEngine(revision=revision)
    views = [batch_eng.register(table, g) for g in GROUPS]
    batched = batch_eng.materialize_many(views)
    for view, got in zip(views, batched):
        solo = solo_eng.register(table, view.columns).packed()
        np.testing.assert_array_equal(np.asarray(got), np.asarray(solo))


def test_materialize_many_serves_hot_and_dedupes(table):
    eng = RelationalMemoryEngine()
    warm = eng.register(table, ("A2", "A4"))
    _ = warm.packed()  # pre-warm one member of the batch
    views = [eng.register(table, g) for g in GROUPS] + [
        eng.register(table, ("A1",))  # duplicate geometry of GROUPS[0]
    ]
    hot_before = eng.stats.hot_hits
    scans_before = eng.stats.shared_scans
    outs = eng.materialize_many(views)
    assert eng.stats.hot_hits == hot_before + 1  # ("A2","A4") served hot
    assert eng.stats.shared_scans == scans_before + 1  # one pass for the rest
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(outs[3]))
    # the batch warmed the cache: re-access is hot
    _ = views[1].packed()
    assert eng.stats.cold_misses == 1 + len(GROUPS)  # warm-up + 3 batch misses


def test_batch_counts_scan_bytes_once(table):
    eng = RelationalMemoryEngine()
    views = [eng.register(table, g) for g in GROUPS]
    geoms = [v.geometry for v in views]
    eng.materialize_many(views)
    union_bytes = bytes_moved(merge_geometries(geoms))["rme"]
    per_view_bytes = sum(bytes_moved(g)["rme"] for g in geoms)
    assert eng.stats.bytes_from_dram == union_bytes
    assert union_bytes < per_view_bytes  # overlapping views share the stream
    # packed bytes to the CPU are still per view
    assert eng.stats.bytes_to_cpu == sum(bytes_moved(g)["columnar"] for g in geoms)


def test_batch_executor_coalesces_across_tables(table):
    rng = np.random.default_rng(1)
    other = RelationalTable.from_columns(
        table.schema,
        {c.name: rng.integers(-5, 5, 64).astype(np.int32)
         for c in table.schema.columns},
    )
    eng = RelationalMemoryEngine()
    ex = BatchExecutor(eng)
    v1 = ex.add_columns(table, ("A1", "A3"))
    v2 = ex.add_columns(other, ("A2",))
    v3 = ex.add(eng.register(table, ("A5",)))
    assert len(ex) == 3
    outs = ex.submit()
    assert len(ex) == 0 and ex.submit() == []
    # table got a genuine 2-view shared scan; other's singleton group stays a
    # plain per-view materialization and must not count as sharing
    assert eng.stats.shared_scans == 1
    solo = RelationalMemoryEngine()
    for view, got in zip((v1, v2, v3), outs):
        expect = solo.register(view.table, view.columns).packed()
        np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))
    # materialize_batch is the one-shot spelling of the same path
    again = materialize_batch(eng, [v1, v2, v3])
    for got, ref in zip(again, outs):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_executor_rejects_foreign_views(table):
    eng1, eng2 = RelationalMemoryEngine(), RelationalMemoryEngine()
    ex = BatchExecutor(eng1)
    with pytest.raises(ValueError):
        ex.add(eng2.register(table, ("A1",)))


# --------------------------------------------------------- device row store
def test_device_rowstore_uploads_once_then_serves_resident(table):
    eng = RelationalMemoryEngine()
    _ = eng.register(table, ("A1",)).packed()
    assert eng.stats.uploads == 1
    assert eng.stats.bytes_uploaded == table.row_count * table.row_bytes
    # more cold views, aggregates, and batches: same resident buffer
    _ = eng.register(table, ("A2", "A3")).packed()
    _ = eng.aggregate(table, "A1")
    eng.materialize_many([eng.register(table, ("A5", "A7"))])
    assert eng.stats.uploads == 1


def test_repeated_aggregate_zero_reupload(table):
    eng = RelationalMemoryEngine()
    s1, c1 = eng.aggregate(table, "A1")
    uploads_after_first = eng.stats.uploads
    s2, _ = eng.aggregate(table, "A1")
    s3, _ = eng.aggregate(table, "A2", "A4", "lt", 10)
    assert uploads_after_first == 1
    assert eng.stats.uploads == 1  # zero host→device transfers after the first
    assert s1 == s2
    expect = table.read_column("A1").astype(np.float64).sum()
    np.testing.assert_allclose(s1, expect, rtol=1e-6)
    assert c1 == table.row_count


def test_device_rowstore_invalidates_on_mutation(table):
    eng = RelationalMemoryEngine()
    _ = eng.aggregate(table, "A1")
    assert eng.rowstore.contains(table)
    table.append({c: np.array([3], np.int32) for c in table.schema.names})
    assert not eng.rowstore.contains(table)  # stale version
    s, n = eng.aggregate(table, "A1")
    assert eng.stats.uploads == 2  # exactly one re-upload for the new version
    assert n == table.row_count
    expect = table.read_column("A1").astype(np.float64).sum()
    np.testing.assert_allclose(s, expect, rtol=1e-6)


def test_caches_survive_table_id_recycling():
    """uid (not id()) keys: a fresh table at a dead table's address is never
    served the dead table's device buffer, and dead buffers are dropped."""
    import gc

    schema = benchmark_schema(64, 4)
    eng = RelationalMemoryEngine()
    for fill in (1, 2, 3):
        t = RelationalTable.from_columns(
            schema, {c.name: np.full(8, fill, np.int32) for c in schema.columns}
        )
        s, _ = eng.aggregate(t, "A1")
        assert s == 8 * fill
        del t
        gc.collect()
    assert eng.stats.uploads == 3  # three distinct tables, three uploads
    # the weakref finalizers released every dead table's device buffer
    assert eng.rowstore.occupancy_bytes == 0


def test_aggregate_async_returns_device_pair(table):
    eng = RelationalMemoryEngine()
    out = eng.aggregate_async(table, "A1", "A3", "gt", 0)
    assert out.shape == (2,)
    s, c = eng.aggregate(table, "A1", "A3", "gt", 0)
    assert float(out[0]) == s and float(out[1]) == c


# ----------------------------------------------------------- cache fixes
def _arr(words: int) -> jnp.ndarray:
    return jnp.zeros((words,), dtype=jnp.int32)


def test_reorg_cache_overwrite_does_not_leak_bytes():
    cache = ReorgCache(capacity_bytes=1 << 20)
    for _ in range(10):
        cache.put(("k",), 0, _arr(100))
    assert cache.occupancy_bytes == 400  # one live entry, not ten


def test_reorg_cache_evicts_fifo():
    cache = ReorgCache(capacity_bytes=3 * 400)
    cache.put(("a",), 0, _arr(100))
    cache.put(("b",), 0, _arr(100))
    cache.put(("c",), 0, _arr(100))
    cache.put(("d",), 0, _arr(100))  # must evict the oldest ("a"), not "c"
    assert cache.peek(("a",), 0) is None
    assert cache.peek(("b",), 0) is not None
    assert cache.peek(("c",), 0) is not None
    assert cache.peek(("d",), 0) is not None


def test_reorg_cache_peek_has_no_side_effects():
    cache = ReorgCache(capacity_bytes=1 << 20)
    cache.put(("k",), 0, _arr(100))
    assert cache.peek(("k",), 1) is None  # stale version
    assert cache.occupancy_bytes == 400  # ...but the entry is untouched
    assert cache.peek(("k",), 0) is not None


def test_planning_does_not_mutate_cache(table):
    eng = RelationalMemoryEngine()
    _ = eng.register(table, ("A1", "A5")).packed()
    occupancy = eng.cache.occupancy_bytes
    table.append({c: np.array([1], np.int32) for c in table.schema.names})
    plan = plan_query(eng, table, ["A1", "A5"])  # stale entry probed, kept
    assert plan.path == "rme"
    assert eng.cache.occupancy_bytes == occupancy


# ---------------------------------------------------------------- planner
def test_plan_batch_credits_shared_scan(table):
    eng = RelationalMemoryEngine()
    bp = plan_batch(eng, table, GROUPS)
    assert bp.shared
    assert bp.shared_bytes < bp.independent_bytes
    assert bp.est_bytes == bp.shared_bytes
    geoms = [TableGeometry.from_schema(table.schema, list(g), table.row_count)
             for g in GROUPS]
    assert bp.shared_bytes == bytes_moved(merge_geometries(geoms))["rme"]


def test_plan_batch_single_view_is_independent(table):
    eng = RelationalMemoryEngine()
    bp = plan_batch(eng, table, [("A1", "A5")])
    assert not bp.shared
    assert bp.shared_bytes == bp.independent_bytes == bp.per_view[0].est_bytes


# ------------------------------------------------------- merge_geometries
def test_merge_geometries_unions_intervals():
    schema = benchmark_schema(64, 4)
    g1 = TableGeometry.from_schema(schema, ["A1", "A2"], 10)
    g2 = TableGeometry.from_schema(schema, ["A2", "A3", "A8"], 10)
    u = merge_geometries([g1, g2])
    # A1..A3 are adjacent/overlapping -> one 12-byte interval; A8 stands alone
    assert u.col_widths == (12, 4)
    assert u.abs_offsets == (0, 28)
    assert u.row_count == 10
    with pytest.raises(ValueError):
        merge_geometries([])


def test_merge_geometries_lifts_column_cap():
    schema = benchmark_schema(128, 4)  # 32 columns
    geoms = [TableGeometry.from_schema(schema, [f"A{2 * i + 1}"], 5)
             for i in range(11)]  # 11 disjoint single-column views
    extra = TableGeometry.from_schema(schema, ["A26"], 5)
    u = merge_geometries(geoms + [extra])
    assert u.q == 12  # beyond the per-view Q cap: fine for accounting


# ------------------------------------------------- bytes_moved closed form
def test_bytes_moved_periodic_closed_form_matches_oracle():
    from repro.core import descriptor_arrays

    for row_bytes, cols, n in [
        (64, ["A1", "A5"], 777),
        (64, ["A2"], 1),
        (36, ["A3", "A7", "A9"], 500),  # row size not a bus-width multiple
        (20, ["A1", "A4"], 333),
    ]:
        schema = benchmark_schema(row_bytes, 4)
        geom = TableGeometry.from_schema(schema, cols, n)
        for bus in (8, 16, 32, 64):
            oracle = int(descriptor_arrays(geom, bus)["r_burst"].sum()) * bus
            assert bytes_moved(geom, bus)["rme"] == oracle, (row_bytes, cols, bus)


# ------------------------------------------------------------- q5 cache
def test_join_build_index_cache(table):
    rng = np.random.default_rng(9)
    n_r = 64
    r_cols = {c.name: rng.integers(-50, 50, n_r).astype(np.int32)
              for c in table.schema.columns}
    r_cols["A2"] = np.arange(n_r, dtype=np.int32)
    rt = RelationalTable.from_columns(table.schema, r_cols)
    eng = RelationalMemoryEngine()
    ops.clear_join_build_cache()
    first = ops.q5_hash_join(eng, table, rt)
    assert ops.JOIN_BUILD_STATS == {"hits": 0, "misses": 1}
    second = ops.q5_hash_join(eng, table, rt)
    assert ops.JOIN_BUILD_STATS == {"hits": 1, "misses": 1}
    np.testing.assert_array_equal(np.asarray(first.matched),
                                  np.asarray(second.matched))
    np.testing.assert_array_equal(np.asarray(first.r_proj),
                                  np.asarray(second.r_proj))
    # build-side mutation invalidates the sorted index (version key changes),
    # and the dead version's entry is dropped rather than accumulating
    rt.update(np.array([0]), {"A3": np.array([999], np.int32)})
    _ = ops.q5_hash_join(eng, table, rt)
    assert ops.JOIN_BUILD_STATS["misses"] == 2
    assert len([k for k in ops._BUILD_INDEX_CACHE if k[0] == rt.uid]) == 1
