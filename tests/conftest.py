"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see 1 device by design;
multi-device tests spawn subprocesses with their own flag."""

import numpy as np
import pytest



@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _seed_numpy():
    np.random.seed(0)
