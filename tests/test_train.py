"""Training substrate: optimizer, trainer fault tolerance, data pipeline."""

import dataclasses
import tempfile

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.ckpt import restore_checkpoint, save_checkpoint
from repro.configs import get_smoke_config
from repro.data import RecordStore, TrainPipeline, synthetic_corpus
from repro.models import build_model
from repro.train import AdamWConfig, adamw_init, adamw_update, make_train_step
from repro.train.optimizer import global_norm, schedule
from repro.train.step import init_train_state
from repro.train.trainer import Trainer, TrainerConfig


def test_adamw_descends_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, decay_steps=1000,
                      weight_decay=0.0, clip_norm=100.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}  # d/dw ||w||^2
        params, state, m = adamw_update(params, grads, state, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_grad_clipping_bounds_update():
    cfg = AdamWConfig(lr=1.0, warmup_steps=0, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params)
    grads = {"w": jnp.full(4, 1e6)}
    _, state, m = adamw_update(params, grads, state, cfg)
    assert float(m["grad_norm"]) > 1e5  # raw norm reported
    # effective grad after clip has norm 1 -> mu bounded
    assert float(global_norm(state["mu"])) <= 0.11


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, decay_steps=100, min_lr_ratio=0.1)
    lrs = [float(schedule(cfg, jnp.asarray(s))) for s in range(0, 120, 5)]
    assert lrs[0] < lrs[1] <= 1e-3  # warmup
    assert abs(lrs[2] - 1e-3) < 1e-9  # peak at end of warmup
    assert lrs[-1] >= 1e-4 - 1e-12  # floor


def test_grad_accum_matches_full_batch():
    """Microbatched gradients equal the full-batch gradient (direct compare —
    comparing post-Adam params would amplify FP summation-order noise through
    the ~sign() update at step 1)."""
    cfg = get_smoke_config("qwen3-8b")
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    model = build_model(cfg)
    rng = np.random.default_rng(0)
    B, S = 8, 64
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    params = model.init(jax.random.PRNGKey(0))
    grad_fn = jax.jit(jax.grad(lambda p, b: model.loss(p, b)[0]))
    g_full = grad_fn(params, batch)
    ga = 4
    acc = jax.tree.map(lambda p: jnp.zeros_like(p), params)
    for i in range(ga):
        mb = {k: v[i * (B // ga):(i + 1) * (B // ga)] for k, v in batch.items()}
        acc = jax.tree.map(jnp.add, acc, grad_fn(params, mb))
    g_micro = jax.tree.map(lambda g: g / ga, acc)
    gn_full = float(jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree.leaves(g_full))))
    for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_micro)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-5 * gn_full
        )


def test_record_store_projectivity_and_training():
    """The HTAP pipeline: row-major ingest, ephemeral projection, training."""
    cfg = get_smoke_config("qwen3-8b")
    S = 64
    store = RecordStore(seq_len=S)
    tok, lab = synthetic_corpus(64, S, cfg.vocab, seed=1)
    store.ingest(tok, lab)
    # eval projection (tokens only) moves ~half the training projection bytes
    eng = store.engine
    eng.stats.reset()
    _ = store.project(("tokens",)).packed()
    eval_bytes = eng.stats.bytes_to_cpu
    eng.stats.reset()
    _ = store.project(("tokens", "labels")).packed()
    train_bytes = eng.stats.bytes_to_cpu
    assert abs(train_bytes - 2 * eval_bytes) <= eval_bytes * 0.01

    pipe = TrainPipeline(store, batch_size=8, seed=0)
    it = pipe.batches()
    b0 = next(it)
    assert b0["tokens"].shape == (8, S)
    # determinism: a fresh iterator seeked to step 1 reproduces batch 2
    b1 = next(it)
    it2 = pipe.batches(start_step=1)
    b1b = next(it2)
    np.testing.assert_array_equal(b1["tokens"], b1b["tokens"])


def test_pipeline_snapshot_isolated_from_ingest():
    store = RecordStore(seq_len=16)
    tok, lab = synthetic_corpus(32, 16, 100, seed=2)
    store.ingest(tok, lab)
    pipe = TrainPipeline(store, batch_size=4, seed=0)
    it = pipe.batches()
    first = next(it)
    # concurrent OLTP ingest must not change the epoch's batch stream
    store.ingest(*synthetic_corpus(32, 16, 100, seed=3))
    second_iter = pipe.batches()  # snapshot taken then; different rows OK
    _ = next(second_iter)
    again = pipe.batches(start_step=0)
    # but the original iterator's snapshot stays fixed for its epoch
    np.testing.assert_array_equal(first["tokens"], next(again)["tokens"])


def test_ckpt_roundtrip_and_structure_check(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    save_checkpoint(str(tmp_path), 7, tree)
    step, restored = restore_checkpoint(str(tmp_path), tree)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(10))
    bad = {"a": jnp.arange(10, dtype=jnp.float32)}  # missing leaf
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), bad)


def test_trainer_end_to_end_with_restart(tmp_path):
    cfg = get_smoke_config("qwen3-8b")
    model = build_model(cfg)
    step_fn = jax.jit(make_train_step(model, AdamWConfig(lr=3e-3, warmup_steps=5)))
    S = 64
    store = RecordStore(seq_len=S)
    store.ingest(*synthetic_corpus(128, S, cfg.vocab, seed=1))
    pipe = TrainPipeline(store, batch_size=8, seed=0)
    to_jnp = lambda b: {k: jnp.asarray(v) for k, v in b.items()}

    tcfg = TrainerConfig(total_steps=12, ckpt_dir=str(tmp_path), ckpt_every=5,
                         log_every=4)
    tr = Trainer(step_fn, init_train_state(model, jax.random.PRNGKey(0)),
                 (to_jnp(b) for b in pipe.batches()), tcfg)
    hist = tr.run()
    assert tr.step == 12
    assert all(np.isfinite(h["loss"]) for h in hist)

    # elastic restart: fresh state, restore, continue to 16
    tr2 = Trainer(step_fn, init_train_state(model, jax.random.PRNGKey(99)),
                  (to_jnp(b) for b in pipe.batches(start_step=12)),
                  dataclasses.replace(tcfg, total_steps=16))
    assert tr2.try_restore()
    assert tr2.step == 12
    tr2.run()
    assert tr2.step == 16


def test_straggler_watchdog_flags_slow_steps():
    calls = {"n": 0}

    def slow_step(state, batch):
        import time

        calls["n"] += 1
        if calls["n"] == 20:
            time.sleep(0.25)
        return state, {"loss": jnp.zeros(())}

    flagged = []
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(
            slow_step, {"x": jnp.zeros(())},
            iter([{"t": jnp.zeros(())}] * 30),
            TrainerConfig(total_steps=30, ckpt_dir=d, ckpt_every=1000,
                          straggler_factor=3.0),
            on_straggler=lambda s, dt, med: flagged.append(s),
        )
        tr.run()
    assert 20 in flagged
