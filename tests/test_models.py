"""Per-architecture smoke + decode-vs-forward consistency tests.

Each assigned architecture instantiates its REDUCED config, runs one forward
+ train step on CPU (shapes + finiteness), and proves the serving path: a
prefill at S tokens followed by greedy decode steps must reproduce the
full-sequence forward's logits (KV ring buffers, SSD states, RG-LRU states,
cross-attention caches — all exercised).
"""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config, get_smoke_config
from repro.models import build_model


def make_batch(cfg, rng, B, S, with_labels=True):
    batch = {}
    if cfg.is_encdec:
        batch["enc_embeds"] = jnp.asarray(
            rng.normal(0, 0.5, (B, max(S // cfg.enc_subsample, 1), cfg.d_model)),
            jnp.float32,
        )
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    elif cfg.embed_inputs:
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    else:
        batch["embeds"] = jnp.asarray(
            rng.normal(0, 0.5, (B, S, cfg.d_model)), jnp.float32
        )
        if cfg.mrope:
            p1 = np.broadcast_to(np.arange(S), (B, S))
            batch["positions"] = jnp.asarray(
                np.broadcast_to(p1[:, None, :], (B, 3, S)).astype(np.int32)
            )
    if with_labels:
        batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_and_grad(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S = 2, 128
    batch = make_batch(cfg, rng, B, S)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    g = jax.jit(jax.grad(lambda p, b: model.loss(p, b)[0]))(params, batch)
    gn = sum(float(jnp.sum(x.astype(jnp.float32) ** 2)) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    repl = {"compute_dtype": "float32"}  # tight comparison
    if cfg.n_experts:  # drop-free capacity so both paths route identically
        repl["capacity_factor"] = float(cfg.n_experts / cfg.top_k)
    cfg = dataclasses.replace(cfg, **repl)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(3)
    B, S, extra = 2, 64, 4
    max_len = S + 16
    batch_full = make_batch(cfg, rng, B, S + extra, with_labels=False)
    if cfg.is_encdec:
        batch_full["enc_embeds"] = batch_full["enc_embeds"][
            :, : max(S // cfg.enc_subsample, 1)
        ]

    def cut(b, n):
        out = dict(b)
        if "tokens" in out:
            out["tokens"] = out["tokens"][:, :n]
        if "embeds" in out:
            out["embeds"] = out["embeds"][:, :n]
        if "positions" in out:
            out["positions"] = out["positions"][:, :, :n]
        if "labels" in out:
            del out["labels"]
        return out

    batch_pre = cut(batch_full, S)
    ref_logits, _ = jax.jit(lambda p, b: model.prefill(p, b, max_len))(
        params, batch_full
    )
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, max_len))(
        params, batch_pre
    )
    step = jax.jit(model.decode_step)
    for t in range(S, S + extra):
        if cfg.is_encdec or cfg.embed_inputs:
            tok = batch_full["tokens"][:, t][:, None]
        else:
            tok = batch_full["embeds"][:, t][:, None, :]
        logits, cache = step(params, cache, tok, jnp.asarray(t, jnp.int32))
    err = float(jnp.max(jnp.abs(logits - ref_logits)))
    scale = float(jnp.max(jnp.abs(ref_logits))) + 1e-9
    assert err / scale < 2e-3, (arch, err, scale)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_full_config_is_well_formed(arch):
    """Full configs: exact assigned geometry, pattern covers n_layers."""
    cfg = get_config(arch)
    assert cfg.n_units * len(cfg.block_pattern) + len(cfg.tail_pattern) == cfg.n_layers
    assert cfg.padded_vocab >= cfg.vocab
    assert cfg.padded_vocab % cfg.vocab_pad_to == 0
    n = cfg.param_count()
    assert n > 1e9 or arch == "seamless-m4t-medium"  # seamless is ~0.6B
    assert cfg.active_param_count() <= n


def test_assigned_geometry_matches_assignment_table():
    rows = {
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "mamba2-1.3b": (48, 2048, None, None, 0, 50280),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
    }
    for arch, (L, d, h, kv, ff, vocab) in rows.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L, arch
        assert cfg.d_model == d, arch
        if h is not None:
            assert cfg.n_heads == h, arch
            assert cfg.n_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab == vocab, arch


def test_moe_dispatch_conservation():
    """With drop-free capacity, MoE output equals the dense-dispatch oracle."""
    from repro.models import layers as L

    spec = L.MoESpec(d_model=32, d_ff=16, n_experts=4, top_k=2,
                     capacity_factor=2.0)  # cap >= k*T/E guarantees no drops
    params = L.init_moe(jax.random.PRNGKey(0), spec)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (2, 8, 32)), jnp.float32)
    out = L.moe_block(params, spec, x)
    # dense oracle: route every token through its top-k experts explicitly
    xt = np.asarray(x).reshape(16, 32)
    logits = xt @ np.asarray(params["router"])
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    idx = np.argsort(-probs, axis=-1)[:, :2]
    expect = np.zeros_like(xt)
    for t in range(16):
        g = probs[t, idx[t]]
        g = g / g.sum()
        for j, e in enumerate(idx[t]):
            wg, wu, wd = (np.asarray(params["expert_gate"][e]),
                          np.asarray(params["expert_up"][e]),
                          np.asarray(params["expert_down"][e]))
            h = (xt[t] @ wg)
            h = h / (1 + np.exp(-h)) * (xt[t] @ wu)
            expect[t] += g[j] * (h @ wd)
    np.testing.assert_allclose(
        np.asarray(out).reshape(16, 32), expect, rtol=2e-2, atol=2e-2
    )


def test_ssd_matches_sequential_recurrence():
    """Chunked SSD == step-by-step h=a*h+B⊗x recurrence."""
    from repro.models import layers as L

    spec = L.SSDSpec(d_model=32, d_state=8, head_dim=8, expand=2, chunk=16)
    params = L.init_ssd(jax.random.PRNGKey(2), spec)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(0, 0.5, (2, 48, 32)), jnp.float32)
    out_chunked = L.ssd_block(params, spec, x)
    # sequential oracle via ssd_decode
    state = L.init_ssd_state(spec, 2)
    state = {"conv": state["conv"].astype(jnp.float32), "ssm": state["ssm"]}
    outs = []
    for t in range(48):
        o, state = L.ssd_decode(params, spec, x[:, t : t + 1], state)
        outs.append(o)
    out_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(out_chunked), np.asarray(out_seq), rtol=2e-3, atol=2e-3
    )


def test_rglru_matches_sequential_recurrence():
    from repro.models import layers as L

    spec = L.RGLRUSpec(d_model=32, lru_width=32)
    params = L.init_rglru(jax.random.PRNGKey(3), spec)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(0, 0.5, (2, 40, 32)), jnp.float32)
    out_scan = L.rglru_block(params, spec, x)
    state = L.init_rglru_state(spec, 2)
    state = {"conv": state["conv"].astype(jnp.float32), "h": state["h"]}
    outs = []
    for t in range(40):
        o, state = L.rglru_decode(params, spec, x[:, t : t + 1], state)
        outs.append(o)
    out_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(out_scan), np.asarray(out_seq), rtol=2e-3, atol=2e-3
    )


def test_blockwise_attention_matches_dense():
    from repro.models import layers as L

    spec = L.AttnSpec(d_model=64, n_heads=4, n_kv_heads=2, head_dim=16)
    rng = np.random.default_rng(5)
    B, S = 2, 96
    q = jnp.asarray(rng.normal(0, 1, (B, S, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, 2, 16)), jnp.float32)
    for window in (None, 17):
        sp = dataclasses.replace(spec, window=window)
        out = L.blockwise_attention(q, k, v, sp, chunk=32)
        # dense oracle
        kk = jnp.repeat(k, 2, axis=2)
        vv = jnp.repeat(v, 2, axis=2)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q * sp.scale, kk)
        pos = np.arange(S)
        dist = pos[:, None] - pos[None, :]
        mask = (dist >= 0) & (dist < (window or S))
        logits = jnp.where(mask[None, None], logits, -1e30)
        ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(logits, -1), vv)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)
