"""QueryServer: concurrent admission, per-tick shared scans, stats, errors."""

import threading

import numpy as np
import pytest

from repro.core import RelationalMemoryEngine, RelationalTable, benchmark_schema, plan
from repro.serve import QueryServer

GROUPS = (("A1",), ("A1", "A2", "A3", "A4"), ("A1", "A3"), ("A2", "A4"))


@pytest.fixture
def table():
    rng = np.random.default_rng(0)
    schema = benchmark_schema(64, 4)
    n = 400
    return RelationalTable.from_columns(
        schema,
        {c.name: rng.integers(-100, 100, n).astype(np.int32)
         for c in schema.columns},
    )


def test_concurrent_same_table_queries_share_one_scan(table):
    """N clients, same table, one tick: exactly one shared scan, one upload."""
    eng = RelationalMemoryEngine()
    server = QueryServer(eng)
    tickets = {}
    barrier = threading.Barrier(len(GROUPS))

    def client(i, cols):
        barrier.wait()  # all clients submit concurrently
        tickets[i] = server.submit(plan(table).project(*cols), client=f"c{i}")

    threads = [threading.Thread(target=client, args=(i, g))
               for i, g in enumerate(GROUPS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert server.queue_depth == len(GROUPS)

    served = server.run_tick()
    assert served == len(GROUPS)
    assert eng.stats.shared_scans == 1  # one pass served every client
    assert eng.stats.uploads == 1  # the row store crossed host->device once
    assert server.stats.shared_scan_ratio == 1.0
    assert server.stats.bytes_saved > 0

    solo = RelationalMemoryEngine()
    for i, cols in enumerate(GROUPS):
        expect = solo.register(table, cols).packed()
        np.testing.assert_array_equal(
            np.asarray(tickets[i].result(timeout=5)), np.asarray(expect)
        )


def test_mixed_kinds_one_tick(table):
    """Aggregates, group-bys, and projections coexist in one coalesced tick."""
    eng = RelationalMemoryEngine()
    server = QueryServer(eng)
    t_agg = server.submit(plan(table).filter("A4", "lt", 5).sum("A2"))
    t_proj = server.submit(plan(table).project("A1", "A3"))
    t_gb = server.submit(plan(table).groupby("A2", "A1", "avg", 16))
    server.run_tick()
    assert t_agg.route == "fused-aggregate"
    assert t_proj.route == "rme"
    assert t_gb.route == "fused-groupby"
    s, _ = eng.aggregate(table, "A2", "A4", "lt", 5)
    assert t_agg.result(timeout=5) == s
    assert t_gb.result(timeout=5).shape == (16,)


def test_two_tables_two_shared_scans(table):
    rng = np.random.default_rng(1)
    other = RelationalTable.from_columns(
        table.schema,
        {c.name: rng.integers(-5, 5, 64).astype(np.int32)
         for c in table.schema.columns},
    )
    eng = RelationalMemoryEngine()
    server = QueryServer(eng)
    for tab in (table, other):
        for cols in (("A1", "A2"), ("A2", "A5")):
            server.submit(plan(tab).project(*cols))
    server.run_tick()
    assert eng.stats.shared_scans == 2  # one coalesced pass per table
    assert eng.stats.uploads == 2
    assert server.stats.table_groups == 2
    assert server.stats.shared_scan_ratio == 1.0


def test_second_tick_is_hot(table):
    eng = RelationalMemoryEngine()
    server = QueryServer(eng)
    for cols in GROUPS:
        server.submit(plan(table).project(*cols))
    server.run_tick()
    scans = eng.stats.shared_scans
    for cols in GROUPS:
        server.submit(plan(table).project(*cols))
    server.run_tick()
    assert eng.stats.shared_scans == scans  # reorg cache absorbed the repeat
    assert eng.stats.hot_hits >= len(GROUPS)
    assert server.stats.table_groups == 1  # the hot tick opened no cold group


def test_max_batch_bounds_a_tick(table):
    server = QueryServer(RelationalMemoryEngine(), max_batch=3)
    tks = [server.submit(plan(table).project("A1")) for _ in range(7)]
    assert server.run_tick() == 3
    assert server.queue_depth == 4
    assert server.drain() == 4
    for tk in tks:
        assert tk.done()


def test_errors_resolve_their_ticket_only(table):
    server = QueryServer(RelationalMemoryEngine())
    bad = server.submit(plan(table).project("A1").filter("missing", "gt", 0))
    good = server.submit(plan(table).sum("A1"))
    server.run_tick()
    with pytest.raises(KeyError):
        bad.result(timeout=5)
    assert isinstance(good.result(timeout=5), float)
    assert server.stats.failed == 1 and server.stats.served == 1


def test_shared_step_failure_resolves_every_ticket(table):
    """If the coalesced materialize_many itself raises, every ticket in the
    batch must resolve with the error — a hung result() (and a silently dead
    background loop) is the failure mode being guarded."""
    eng = RelationalMemoryEngine()
    server = QueryServer(eng)

    def boom(ops):
        raise RuntimeError("union geometry failed to lower")

    eng.execute_many = boom
    tks = [server.submit(plan(table).project(*g)) for g in GROUPS]
    assert server.run_tick() == len(GROUPS)
    for tk in tks:
        assert tk.done()
        with pytest.raises(RuntimeError, match="union geometry"):
            tk.result(timeout=1)
    assert server.stats.failed == len(GROUPS) and server.stats.served == 0


def test_background_serving_thread(table):
    eng = RelationalMemoryEngine()
    with QueryServer(eng) as server:
        tickets = [
            server.submit(plan(table).project(*GROUPS[i % len(GROUPS)]),
                          client=f"c{i % 2}")
            for i in range(8)
        ]
        results = [tk.result(timeout=30) for tk in tickets]
    assert all(r is not None for r in results)
    lat = server.client_latencies()
    assert set(lat) == {"c0", "c1"}
    assert all(v["count"] == 4 for v in lat.values())
    snap = server.snapshot()
    assert snap["served"] == 8 and snap["queue_depth"] == 0
    assert snap["max_latency_s"] >= snap["mean_latency_s"] > 0


def test_served_join_shares_scans(table):
    rng = np.random.default_rng(9)
    n_r = 64
    r_cols = {c.name: rng.integers(-50, 50, n_r).astype(np.int32)
              for c in table.schema.columns}
    r_cols["A2"] = np.arange(n_r, dtype=np.int32)
    rt = RelationalTable.from_columns(table.schema, r_cols)
    eng = RelationalMemoryEngine()
    server = QueryServer(eng)
    from repro.core import operators as ops

    ops.clear_join_build_cache()
    tk = server.submit(
        plan(table).join(rt, key="A2", left_proj="A1", right_proj="A3")
    )
    server.run_tick()
    res = tk.result(timeout=5)
    ref = ops.q5_hash_join(RelationalMemoryEngine(), table, rt)
    np.testing.assert_array_equal(np.asarray(res.matched),
                                  np.asarray(ref.matched))
    np.testing.assert_array_equal(np.asarray(res.r_proj),
                                  np.asarray(ref.r_proj))


# ---------------------------------------------------------------------------
# Pipelined serving: lanes, deadlines, backpressure, streaming, reservoirs
# ---------------------------------------------------------------------------

def _cols(seed, n, schema):
    rng = np.random.default_rng(seed)
    return {c.name: rng.integers(-100, 100, n).astype(np.int32)
            for c in schema.columns}


def test_express_completes_while_bulk_in_flight(table):
    """begin_tick serves express tickets to completion while the bulk lane's
    (same fused) pass is still awaiting finish_tick."""
    eng = RelationalMemoryEngine()
    server = QueryServer(eng)
    t_bulk = server.submit(plan(table).project("A1", "A2", "A3"))
    t_exp = server.submit(plan(table).sum("A1"))
    assert t_exp.lane == "express" and t_bulk.lane == "bulk"

    tick = server.begin_tick()
    assert t_exp.done() and not t_bulk.done()
    assert isinstance(t_exp.result(timeout=1), float)

    assert server.finish_tick(tick) == 2
    assert t_bulk.done()
    # lanes share one fused pass — the one-pass-per-tick invariant holds
    assert eng.stats.shared_scans == 1
    snap = server.snapshot()
    assert snap["express_served"] == 1 and snap["bulk_served"] == 1
    assert snap["express_p99_ms"] > 0 and snap["bulk_p99_ms"] > 0


def test_deadline_missed_fails_typed_not_hung(table):
    """An expired ticket resolves promptly with DeadlineExceeded (a
    TimeoutError) — and healthy co-tick tickets are unaffected."""
    from repro.serve import DeadlineExceeded

    server = QueryServer(RelationalMemoryEngine())
    doomed = server.submit(plan(table).project("A1"), deadline_s=0.0)
    fine = server.submit(plan(table).project("A2"))
    server.run_tick()
    assert doomed.done()
    with pytest.raises(DeadlineExceeded):
        doomed.result(timeout=1)
    assert isinstance(DeadlineExceeded("x"), TimeoutError)
    assert fine.result(timeout=5) is not None
    snap = server.snapshot()
    assert snap["deadline_misses"] == 1
    assert snap["bulk_deadline_misses"] == 1
    assert server.stats.failed == 1 and server.stats.served == 1


def _mixed_workload(server, t, other):
    return [
        server.submit(plan(t).project("A1", "A3")),
        server.submit(plan(t).filter("A5", "gt", 10).project("A1", "A2")),
        server.submit(plan(t).sum("A2")),
        server.submit(plan(t).groupby("A2", "A1", "avg", 16)),
        server.submit(plan(other).project("A2", "A4")),
        server.submit(plan(other).filter("A4", "lt", 5).sum("A1")),
    ]


@pytest.mark.parametrize("backend", ["single", "sharded"])
def test_overlapped_ticks_match_serial(table, backend):
    """Pipelined (double-buffered) drain is byte-identical to serial ticks,
    on both backends."""
    def mk_engine():
        if backend == "sharded":
            from repro.core.distributed import ShardedEngine
            return ShardedEngine(num_shards=3, revision="xla")
        return RelationalMemoryEngine()

    def run(pipeline):
        t = RelationalTable.from_columns(
            table.schema, _cols(3, 300, table.schema))
        other = RelationalTable.from_columns(
            table.schema, _cols(4, 200, table.schema))
        # max_batch=2 forces several ticks, so the pipelined drain overlaps
        server = QueryServer(mk_engine(), max_batch=2, pipeline=pipeline)
        tickets = _mixed_workload(server, t, other)
        assert server.drain() == len(tickets)
        return [tk.result(timeout=30) for tk in tickets], server

    serial, _ = run(pipeline=False)
    piped, server = run(pipeline=True)
    assert server.stats.ticks_overlapped > 0  # it really double-buffered
    for i, (a, b) in enumerate(zip(serial, piped)):
        fa = a if isinstance(a, tuple) else (a,)
        fb = b if isinstance(b, tuple) else (b,)
        for x, y in zip(fa, fb):
            assert np.array_equal(np.asarray(x), np.asarray(y)), f"query {i}"


@pytest.mark.parametrize("backend", ["single", "sharded"])
def test_streamed_chunks_concat_to_blocking_result(table, backend):
    """A streamed projection's chunks concatenate to exactly the blocking
    result, and arrive as more than one piece."""
    if backend == "sharded":
        from repro.core.distributed import ShardedEngine
        engine = ShardedEngine(num_shards=3, revision="xla")
    else:
        engine = RelationalMemoryEngine()
    t = RelationalTable.from_columns(table.schema, _cols(5, 400, table.schema))
    server = QueryServer(engine)

    blocking = server.submit(plan(t).project("A1", "A4"))
    server.drain()
    expect = np.asarray(blocking.result(timeout=30))

    # fresh server+engine so the stream runs cold, not from the warm cache
    if backend == "sharded":
        engine = ShardedEngine(num_shards=3, revision="xla")
    else:
        engine = RelationalMemoryEngine()
    t2 = RelationalTable.from_columns(table.schema, _cols(5, 400, table.schema))
    server = QueryServer(engine)
    tk = server.submit(plan(t2).project("A1", "A4"), stream=True,
                       stream_chunk_rows=64)
    from repro.serve import StreamingTicket
    assert isinstance(tk, StreamingTicket)
    server.drain()
    chunks = list(tk.chunks(timeout=5))
    assert len(chunks) > 1
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(c) for c in chunks]), expect)
    np.testing.assert_array_equal(np.asarray(tk.result(timeout=5)), expect)
    snap = server.snapshot()
    assert snap["streams"] == 1
    assert snap["stream_chunks"] == len(chunks)


def test_stream_yields_chunks_before_resolution(table):
    """chunks() observes early chunks while the pass is still in flight:
    after begin_tick the stream is launched but unresolved."""
    server = QueryServer(RelationalMemoryEngine())
    tk = server.submit(plan(table).project("A1"), stream=True,
                       stream_chunk_rows=64)
    tick = server.begin_tick()
    assert not tk.done()  # launched, not finalized
    server.finish_tick(tick)
    assert tk.done()
    assert len(list(tk.chunks(timeout=1))) > 1


def test_stream_of_written_table_fails_honestly(table):
    """A streamed read of a table this server has written compiles with the
    tick snapshot, which the stream contract cannot carry — the ticket must
    fail with PlanError, never return unversioned rows."""
    from repro.core.plan import PlanError

    t = RelationalTable.from_columns(table.schema, _cols(6, 100, table.schema))
    server = QueryServer(RelationalMemoryEngine())
    server.submit_delete(t, np.array([0, 1]))
    tk = server.submit(plan(t).project("A1"), stream=True)
    server.drain()
    with pytest.raises(PlanError):
        tk.result(timeout=5)


def test_backpressure_shed_at_bound(table):
    from repro.serve import ServerOverloaded

    server = QueryServer(RelationalMemoryEngine(), max_queue=4)
    tks = [server.submit(plan(table).project("A1")) for _ in range(4)]
    with pytest.raises(ServerOverloaded):
        server.submit(plan(table).project("A2"))
    assert server.stats.shed == 1
    server.drain()
    for tk in tks:
        assert tk.result(timeout=5) is not None


def test_backpressure_degrade_then_hard_shed(table):
    from repro.serve import ServerOverloaded

    server = QueryServer(RelationalMemoryEngine(), max_queue=2,
                         overload="degrade")
    server.submit(plan(table).sum("A1"))
    server.submit(plan(table).sum("A2"))
    # at the bound: demoted to bulk, deadline stripped, not refused
    demoted = server.submit(plan(table).sum("A3"), deadline_s=10.0)
    assert demoted.lane == "bulk" and demoted.deadline_s is None
    assert server.stats.degraded == 1
    server.submit(plan(table).sum("A4"))  # depth 4 == 2 * bound
    with pytest.raises(ServerOverloaded):  # hard shed keeps memory bounded
        server.submit(plan(table).sum("A5"))
    # writes are never degraded — refused outright at the bound
    with pytest.raises(ServerOverloaded):
        server.submit_insert(table, _cols(7, 4, table.schema))
    assert server.stats.shed == 2
    server.drain()


def test_lanes_off_restores_single_fifo(table):
    server = QueryServer(RelationalMemoryEngine(), lanes=False)
    tk = server.submit(plan(table).sum("A1"))
    assert tk.lane == "bulk"
    tick = server.begin_tick()
    assert not tk.done()  # no express fast path
    server.finish_tick(tick)
    assert isinstance(tk.result(timeout=5), float)


def test_latency_reservoir_exact_small_n():
    from repro.serve import LatencyReservoir

    r = LatencyReservoir(cap=512)
    values = list(range(1, 101))
    rng = np.random.default_rng(8)
    rng.shuffle(values)
    for v in values:
        r.add(float(v))
    assert r.count == 100
    assert r.sum == sum(range(1, 101))
    assert r.max == 100.0
    # nearest-rank percentiles are exact below the cap
    assert r.percentile(50) == 50.0
    assert r.percentile(95) == 95.0
    assert r.percentile(99) == 99.0
    assert r.percentile(100) == 100.0


def test_latency_reservoir_bounded_memory():
    from repro.serve import LatencyReservoir

    r = LatencyReservoir(cap=64)
    n = 100_000
    for i in range(n):
        r.add(float(i % 1000))
    assert r.count == n  # exact totals survive the sampling
    assert r.sum == sum(float(i % 1000) for i in range(n))
    assert r.max == 999.0
    assert len(r._samples) == 64  # memory stays at the cap
    assert 0.0 <= r.percentile(50) <= 999.0


def test_snapshot_back_compat_keys(table):
    """Historical snapshot/stat consumers keep working after the reservoir
    rework: mean/max read through the reservoir-backed properties."""
    server = QueryServer(RelationalMemoryEngine())
    server.submit(plan(table).project("A1"))
    server.drain()
    snap = server.snapshot()
    assert snap["max_latency_s"] >= snap["mean_latency_s"] > 0
    assert server.stats.latency_sum_s > 0
    assert server.stats.latency_max_s >= server.stats.latency_sum_s / 1
