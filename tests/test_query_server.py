"""QueryServer: concurrent admission, per-tick shared scans, stats, errors."""

import threading

import numpy as np
import pytest

from repro.core import RelationalMemoryEngine, RelationalTable, benchmark_schema, plan
from repro.serve import QueryServer

GROUPS = (("A1",), ("A1", "A2", "A3", "A4"), ("A1", "A3"), ("A2", "A4"))


@pytest.fixture
def table():
    rng = np.random.default_rng(0)
    schema = benchmark_schema(64, 4)
    n = 400
    return RelationalTable.from_columns(
        schema,
        {c.name: rng.integers(-100, 100, n).astype(np.int32)
         for c in schema.columns},
    )


def test_concurrent_same_table_queries_share_one_scan(table):
    """N clients, same table, one tick: exactly one shared scan, one upload."""
    eng = RelationalMemoryEngine()
    server = QueryServer(eng)
    tickets = {}
    barrier = threading.Barrier(len(GROUPS))

    def client(i, cols):
        barrier.wait()  # all clients submit concurrently
        tickets[i] = server.submit(plan(table).project(*cols), client=f"c{i}")

    threads = [threading.Thread(target=client, args=(i, g))
               for i, g in enumerate(GROUPS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert server.queue_depth == len(GROUPS)

    served = server.run_tick()
    assert served == len(GROUPS)
    assert eng.stats.shared_scans == 1  # one pass served every client
    assert eng.stats.uploads == 1  # the row store crossed host->device once
    assert server.stats.shared_scan_ratio == 1.0
    assert server.stats.bytes_saved > 0

    solo = RelationalMemoryEngine()
    for i, cols in enumerate(GROUPS):
        expect = solo.register(table, cols).packed()
        np.testing.assert_array_equal(
            np.asarray(tickets[i].result(timeout=5)), np.asarray(expect)
        )


def test_mixed_kinds_one_tick(table):
    """Aggregates, group-bys, and projections coexist in one coalesced tick."""
    eng = RelationalMemoryEngine()
    server = QueryServer(eng)
    t_agg = server.submit(plan(table).filter("A4", "lt", 5).sum("A2"))
    t_proj = server.submit(plan(table).project("A1", "A3"))
    t_gb = server.submit(plan(table).groupby("A2", "A1", "avg", 16))
    server.run_tick()
    assert t_agg.route == "fused-aggregate"
    assert t_proj.route == "rme"
    assert t_gb.route == "fused-groupby"
    s, _ = eng.aggregate(table, "A2", "A4", "lt", 5)
    assert t_agg.result(timeout=5) == s
    assert t_gb.result(timeout=5).shape == (16,)


def test_two_tables_two_shared_scans(table):
    rng = np.random.default_rng(1)
    other = RelationalTable.from_columns(
        table.schema,
        {c.name: rng.integers(-5, 5, 64).astype(np.int32)
         for c in table.schema.columns},
    )
    eng = RelationalMemoryEngine()
    server = QueryServer(eng)
    for tab in (table, other):
        for cols in (("A1", "A2"), ("A2", "A5")):
            server.submit(plan(tab).project(*cols))
    server.run_tick()
    assert eng.stats.shared_scans == 2  # one coalesced pass per table
    assert eng.stats.uploads == 2
    assert server.stats.table_groups == 2
    assert server.stats.shared_scan_ratio == 1.0


def test_second_tick_is_hot(table):
    eng = RelationalMemoryEngine()
    server = QueryServer(eng)
    for cols in GROUPS:
        server.submit(plan(table).project(*cols))
    server.run_tick()
    scans = eng.stats.shared_scans
    for cols in GROUPS:
        server.submit(plan(table).project(*cols))
    server.run_tick()
    assert eng.stats.shared_scans == scans  # reorg cache absorbed the repeat
    assert eng.stats.hot_hits >= len(GROUPS)
    assert server.stats.table_groups == 1  # the hot tick opened no cold group


def test_max_batch_bounds_a_tick(table):
    server = QueryServer(RelationalMemoryEngine(), max_batch=3)
    tks = [server.submit(plan(table).project("A1")) for _ in range(7)]
    assert server.run_tick() == 3
    assert server.queue_depth == 4
    assert server.drain() == 4
    for tk in tks:
        assert tk.done()


def test_errors_resolve_their_ticket_only(table):
    server = QueryServer(RelationalMemoryEngine())
    bad = server.submit(plan(table).project("A1").filter("missing", "gt", 0))
    good = server.submit(plan(table).sum("A1"))
    server.run_tick()
    with pytest.raises(KeyError):
        bad.result(timeout=5)
    assert isinstance(good.result(timeout=5), float)
    assert server.stats.failed == 1 and server.stats.served == 1


def test_shared_step_failure_resolves_every_ticket(table):
    """If the coalesced materialize_many itself raises, every ticket in the
    batch must resolve with the error — a hung result() (and a silently dead
    background loop) is the failure mode being guarded."""
    eng = RelationalMemoryEngine()
    server = QueryServer(eng)

    def boom(ops):
        raise RuntimeError("union geometry failed to lower")

    eng.execute_many = boom
    tks = [server.submit(plan(table).project(*g)) for g in GROUPS]
    assert server.run_tick() == len(GROUPS)
    for tk in tks:
        assert tk.done()
        with pytest.raises(RuntimeError, match="union geometry"):
            tk.result(timeout=1)
    assert server.stats.failed == len(GROUPS) and server.stats.served == 0


def test_background_serving_thread(table):
    eng = RelationalMemoryEngine()
    with QueryServer(eng) as server:
        tickets = [
            server.submit(plan(table).project(*GROUPS[i % len(GROUPS)]),
                          client=f"c{i % 2}")
            for i in range(8)
        ]
        results = [tk.result(timeout=30) for tk in tickets]
    assert all(r is not None for r in results)
    lat = server.client_latencies()
    assert set(lat) == {"c0", "c1"}
    assert all(v["count"] == 4 for v in lat.values())
    snap = server.snapshot()
    assert snap["served"] == 8 and snap["queue_depth"] == 0
    assert snap["max_latency_s"] >= snap["mean_latency_s"] > 0


def test_served_join_shares_scans(table):
    rng = np.random.default_rng(9)
    n_r = 64
    r_cols = {c.name: rng.integers(-50, 50, n_r).astype(np.int32)
              for c in table.schema.columns}
    r_cols["A2"] = np.arange(n_r, dtype=np.int32)
    rt = RelationalTable.from_columns(table.schema, r_cols)
    eng = RelationalMemoryEngine()
    server = QueryServer(eng)
    from repro.core import operators as ops

    ops.clear_join_build_cache()
    tk = server.submit(
        plan(table).join(rt, key="A2", left_proj="A1", right_proj="A3")
    )
    server.run_tick()
    res = tk.result(timeout=5)
    ref = ops.q5_hash_join(RelationalMemoryEngine(), table, rt)
    np.testing.assert_array_equal(np.asarray(res.matched),
                                  np.asarray(ref.matched))
    np.testing.assert_array_equal(np.asarray(res.r_proj),
                                  np.asarray(ref.r_proj))
