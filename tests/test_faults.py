"""Chaos suite: every named fault site recovers byte-identical or fails typed.

The acceptance bar of the reliability layer (docs/reliability.md): under a
scripted :class:`~repro.core.faults.FaultPlan`, each injection site either
(a) recovers to a result byte-identical to the fault-free run — transient
retries, shard failover, circuit-breaker fallback — or (b) resolves with a
*typed* error (permanent faults, poison quarantine).  Never a hang, never a
silently wrong answer.
"""

import numpy as np
import pytest

from repro.core import (
    CircuitBreaker,
    FaultPlan,
    PermanentFault,
    RelationalMemoryEngine,
    RelationalTable,
    TransientFault,
    fault_plan,
    faults,
    plan,
)
from repro.core.distributed import ShardedEngine
from repro.core.requests import AggregateOp, GroupByOp
from repro.core.schema import Column, TableSchema
from repro.serve.query_server import PoisonedPlanError, QueryServer

SCHEMA = TableSchema((Column("a", "int32"), Column("b", "int32"),
                      Column("g", "int32")))


def make_table(n=200, seed=0):
    rng = np.random.default_rng(seed)
    return RelationalTable.from_columns(SCHEMA, {
        "a": rng.integers(-100, 100, n).astype(np.int32),
        "b": rng.integers(0, 1000, n).astype(np.int32),
        "g": rng.integers(0, 8, n).astype(np.int32),
    })


def as_np(result):
    parts = result if isinstance(result, tuple) else (result,)
    return [np.asarray(p) for p in parts]


def assert_same(a, b):
    for x, y in zip(as_np(a), as_np(b)):
        np.testing.assert_array_equal(x, y)


# --------------------------------------------------------------- FaultPlan
class TestFaultPlan:
    def test_fires_on_nth_hit_for_times_hits(self):
        p = FaultPlan().inject("upload", at=2, times=2)
        outcomes = []
        for _ in range(5):
            try:
                p.hit("upload")
                outcomes.append("ok")
            except TransientFault:
                outcomes.append("fault")
        assert outcomes == ["ok", "fault", "fault", "ok", "ok"]
        assert p.fired("upload") == 2

    def test_match_context_restricts_hits(self):
        p = FaultPlan().inject("shard_pass", shard=1)
        p.hit("shard_pass", shard=0)  # does not match, does not count
        with pytest.raises(TransientFault):
            p.hit("shard_pass", shard=1)
        assert p.hits_at("shard_pass") == 1

    def test_permanent_kind_and_typed_attributes(self):
        p = FaultPlan().inject("lowering", kind="permanent")
        with pytest.raises(PermanentFault) as exc:
            p.hit("lowering")
        assert exc.value.site == "lowering"
        assert exc.value.hit == 1
        assert isinstance(exc.value, faults.FaultError)
        assert not isinstance(exc.value, TransientFault)

    def test_times_none_fires_forever(self):
        p = FaultPlan().inject("upload", times=None)
        for _ in range(4):
            with pytest.raises(TransientFault):
                p.hit("upload")

    def test_seeded_random_schedule_is_reproducible(self):
        def schedule(seed):
            p = FaultPlan(seed=seed).inject_random("upload", p=0.5)
            out = []
            for _ in range(32):
                try:
                    p.hit("upload")
                    out.append(0)
                except TransientFault:
                    out.append(1)
            return out

        assert schedule(7) == schedule(7)
        assert schedule(7) != schedule(8)
        assert sum(schedule(7)) > 0

    def test_unknown_site_and_kind_rejected(self):
        with pytest.raises(ValueError):
            FaultPlan().inject("nonsense")
        with pytest.raises(ValueError):
            FaultPlan().inject("upload", kind="flaky")

    def test_context_manager_restores_previous_plan(self):
        assert faults.active_plan() is None
        outer = FaultPlan()
        with fault_plan(outer):
            with fault_plan(FaultPlan()) as inner:
                assert faults.active_plan() is inner
            assert faults.active_plan() is outer
        assert faults.active_plan() is None

    def test_maybe_fault_is_noop_without_plan(self):
        faults.maybe_fault("upload")  # must not raise


# ---------------------------------------------------------- CircuitBreaker
class TestCircuitBreaker:
    def test_trips_after_threshold_then_cooldown_then_half_open(self):
        br = CircuitBreaker(threshold=2, cooldown=2)
        key = ("t", "r")
        assert br.allow(key)
        br.record_failure(key)
        assert br.allow(key)
        br.record_failure(key)  # second consecutive failure: trips
        assert br.state(key) == "open"
        assert br.trips == 1
        assert not br.allow(key)  # cooldown serve 1 -> fallback
        assert not br.allow(key)  # cooldown serve 2 -> half_open next
        assert br.state(key) == "half_open"
        assert br.allow(key)  # the probe
        assert br.probes == 1
        br.record_success(key)
        assert br.state(key) == "closed"
        assert br.fallbacks == 2

    def test_failed_probe_reopens(self):
        br = CircuitBreaker(threshold=1, cooldown=1)
        br.record_failure("k")
        assert not br.allow("k")
        assert br.allow("k")  # half-open probe
        br.record_failure("k")  # probe failed: re-trip
        assert br.state("k") == "open"
        assert br.trips == 2

    def test_success_resets_streak(self):
        br = CircuitBreaker(threshold=2, cooldown=1)
        br.record_failure("k")
        br.record_success("k")
        br.record_failure("k")
        assert br.state("k") == "closed"  # never two consecutive


# ----------------------------------------------- engine sites (single dev)
class TestEngineSites:
    def test_upload_fault_recovers_via_server_retry(self):
        ref_t = make_table()
        srv0 = QueryServer(RelationalMemoryEngine(revision="xla"))
        tk = srv0.submit(plan(ref_t).project("a", "b"))
        srv0.drain()
        ref = tk.result()

        t = make_table()
        srv = QueryServer(RelationalMemoryEngine(revision="xla"))
        with fault_plan(FaultPlan().inject("upload")) as p:
            tk = srv.submit(plan(t).project("a", "b"))
            srv.drain()
        assert_same(tk.result(), ref)
        assert p.fired("upload") == 1
        assert srv.snapshot()["retries"] >= 0  # recovered without poisoning
        assert srv.snapshot()["served"] == 1

    def test_delta_upload_fault_leaves_store_consistent(self):
        t = make_table()
        srv = QueryServer(RelationalMemoryEngine(revision="xla"))
        tk = srv.submit(plan(t).aggregate("b"))
        srv.drain()
        tk.result()  # table resident
        new = {"a": np.array([1], np.int32), "b": np.array([50], np.int32),
               "g": np.array([0], np.int32)}
        with fault_plan(FaultPlan().inject("upload", delta=True)):
            srv.submit_insert(t, new)
            rd = srv.submit(plan(t).aggregate("b"))
            srv.drain()
        total = float(np.asarray(rd.result()))
        expect = float(np.sum(np.asarray(t.read_column("b"), dtype=np.float64)))
        assert total == expect  # retry re-synced the delta exactly once

    def test_scan_launch_permanent_fault_fails_typed_no_retry(self):
        t = make_table()
        srv = QueryServer(RelationalMemoryEngine(revision="xla"))
        with fault_plan(FaultPlan().inject(
                "scan_launch", kind="permanent", times=None)):
            tk = srv.submit(plan(t).aggregate("b"))
            srv.drain()
        with pytest.raises(PermanentFault):
            tk.result()
        assert srv.snapshot()["retries"] == 0  # permanents skip the retry loop

    def test_join_build_fault_recovers(self):
        left, right = make_table(150, seed=1), make_table(40, seed=2)
        q = (plan(left).join(right, key="a", left_proj="b", right_proj="b")
             .build())
        srv0 = QueryServer(RelationalMemoryEngine(revision="xla"))
        tk = srv0.submit(q)
        srv0.drain()
        ref = tk.result()

        from repro.core import operators as ops

        ops.clear_join_build_cache()  # module-global: drop the ref's build
        srv = QueryServer(RelationalMemoryEngine(revision="xla"))
        with fault_plan(FaultPlan().inject("join_build")) as p:
            tk = srv.submit(q)
            srv.drain()
        out = tk.result()
        assert p.fired("join_build") == 1
        np.testing.assert_array_equal(np.asarray(out.s_proj),
                                      np.asarray(ref.s_proj))
        np.testing.assert_array_equal(np.asarray(out.matched),
                                      np.asarray(ref.matched))

    def test_stream_chunk_fault_before_first_chunk_retries_clean(self):
        t = make_table(300)
        srv0 = QueryServer(RelationalMemoryEngine(revision="xla"))
        tk = srv0.submit(plan(t).project("a", "b"))
        srv0.drain()
        ref = tk.result()

        srv = QueryServer(RelationalMemoryEngine(revision="xla"))
        with fault_plan(FaultPlan().inject("stream_chunk", at=1)) as p:
            tk = srv.submit(plan(t).project("a", "b"), stream=True,
                            stream_chunk_rows=64)
            srv.drain()
        out = tk.result()
        assert p.fired("stream_chunk") == 1
        assert srv.snapshot()["retries"] == 1
        assert_same(out, ref)  # restarted stream is byte-identical

    def test_stream_fault_mid_stream_fails_typed_prefix_intact(self):
        t = make_table(300)
        srv = QueryServer(RelationalMemoryEngine(revision="xla"))
        # chunk index 1: the second chunk faults after the first was pushed
        with fault_plan(FaultPlan().inject("stream_chunk", index=1,
                                           times=None)):
            tk = srv.submit(plan(t).project("a", "b"), stream=True,
                            stream_chunk_rows=64)
            srv.drain()
        with pytest.raises(TransientFault):
            tk.result()
        assert len(tk._chunks) == 1  # the yielded prefix stands
        assert srv.snapshot()["poisoned"] == 0  # positional, not poisoned


# ------------------------------------------------- lowering circuit breaker
class TestLoweringBreaker:
    def test_lowering_fault_falls_back_byte_identical(self):
        t = make_table()
        ops = [AggregateOp(t, "b"), GroupByOp(t, "g", "b", num_groups=8)]
        ref_eng = RelationalMemoryEngine(revision="xla")
        ref = ref_eng.execute_many(list(ops))

        eng = RelationalMemoryEngine(revision="mlp", breaker_threshold=2,
                                     breaker_cooldown=2)
        with fault_plan(FaultPlan().inject("lowering", times=None, op="scan")):
            outs = [eng.execute_many(list(ops)) for _ in range(5)]
        for out in outs:
            assert_same(out[0], ref[0])
            assert_same(out[1], ref[1])
        snap = eng.breaker.snapshot()
        assert snap["breaker_trips"] >= 1
        assert snap["breaker_fallbacks"] >= 1
        assert snap["breaker_open"] == 1

    def test_half_open_probe_recovers_route(self):
        t = make_table()
        ops = [AggregateOp(t, "b"), GroupByOp(t, "g", "b", num_groups=8)]
        eng = RelationalMemoryEngine(revision="mlp", breaker_threshold=1,
                                     breaker_cooldown=1)
        with fault_plan(FaultPlan().inject("lowering", op="scan")):
            eng.execute_many(list(ops))  # fault -> trip open
        route = next(iter(eng.breaker._routes))
        assert eng.breaker.state(route) == "open"
        eng.execute_many(list(ops))  # cooldown serve (fallback)
        eng.execute_many(list(ops))  # half-open probe succeeds
        assert eng.breaker.state(route) == "closed"
        assert eng.breaker.probes == 1

    def test_other_site_faults_pass_through_breaker(self):
        t = make_table()
        eng = RelationalMemoryEngine(revision="mlp")
        ops = [AggregateOp(t, "b"), GroupByOp(t, "g", "b", num_groups=8)]
        eng.execute_many(list(ops))  # warm: table resident
        with fault_plan(FaultPlan().inject("scan_launch", times=None)):
            with pytest.raises(TransientFault):
                eng.execute_many(list(ops))
        assert eng.breaker.open_routes == 0  # not misattributed to lowering


# -------------------------------------------------- sharded shard failover
class TestShardFailover:
    def exec_ops(self, eng, t):
        return eng.execute_many([AggregateOp(t, "b"),
                                 GroupByOp(t, "g", "b", num_groups=8)])

    def reference(self):
        t = make_table()
        return self.exec_ops(RelationalMemoryEngine(revision="xla"), t)

    def test_transient_shard_fault_retries_byte_identical(self):
        ref = self.reference()
        eng = ShardedEngine(num_shards=2, revision="xla")
        t = make_table()
        with fault_plan(FaultPlan().inject("shard_pass", shard=1)) as p:
            out = self.exec_ops(eng, t)
        assert p.fired("shard_pass") == 1
        assert eng.stats.retries == 1
        assert eng.stats.failovers == 0
        for o, r in zip(out, ref):
            assert_same(o, r)

    def test_permanent_shard_fault_fails_over_byte_identical(self):
        ref = self.reference()
        eng = ShardedEngine(num_shards=2, revision="xla")
        t = make_table()
        with fault_plan(FaultPlan().inject(
                "shard_pass", kind="permanent", times=None, shard=0)):
            out = self.exec_ops(eng, t)
        assert eng.stats.failovers == 1
        assert eng.stats.bytes_failover > 0
        for o, r in zip(out, ref):
            assert_same(o, r)

    def test_retry_exhaustion_fails_over(self):
        ref = self.reference()
        eng = ShardedEngine(num_shards=2, revision="xla", shard_retries=1)
        t = make_table()
        with fault_plan(FaultPlan().inject("shard_pass", times=None,
                                           shard=1)):
            out = self.exec_ops(eng, t)
        assert eng.stats.retries == 1
        assert eng.stats.failovers == 1
        for o, r in zip(out, ref):
            assert_same(o, r)

    def test_quarantine_and_probe_recovery(self):
        ref = self.reference()
        eng = ShardedEngine(num_shards=2, revision="xla", shard_retries=0,
                            quarantine_after=2, quarantine_probe_every=2)
        t = make_table()
        with fault_plan(FaultPlan().inject("shard_pass", times=None,
                                           shard=0)):
            self.exec_ops(eng, t)
            self.exec_ops(eng, t)  # second failure -> quarantined
        assert eng.shard_health() == ["quarantined", "healthy"]
        # quarantined: pass 1 skips (straight to failover, no attempt),
        # pass 2 probes the now-healthy shard and restores it
        out = self.exec_ops(eng, t)
        assert eng.shard_health()[0] == "quarantined"
        out = self.exec_ops(eng, t)
        assert eng.shard_health() == ["healthy", "healthy"]
        for o, r in zip(out, ref):
            assert_same(o, r)

    def test_collective_combine_transient_retries(self):
        ref = self.reference()
        eng = ShardedEngine(num_shards=2, revision="xla")
        t = make_table()
        with fault_plan(FaultPlan().inject("collective_combine")):
            out = self.exec_ops(eng, t)
        assert eng.stats.retries == 1
        for o, r in zip(out, ref):
            assert_same(o, r)

    def test_collective_combine_permanent_propagates_typed(self):
        eng = ShardedEngine(num_shards=2, revision="xla")
        t = make_table()
        with fault_plan(FaultPlan().inject(
                "collective_combine", kind="permanent", times=None)):
            with pytest.raises(PermanentFault):
                self.exec_ops(eng, t)

    def test_sharded_server_recovers_through_failover(self):
        ref_srv = QueryServer(RelationalMemoryEngine(revision="xla"))
        t0 = make_table()
        tk = ref_srv.submit(plan(t0).aggregate("b"))
        ref_srv.drain()
        ref = tk.result()

        srv = QueryServer(ShardedEngine(num_shards=2, revision="xla"))
        t = make_table()
        with fault_plan(FaultPlan().inject(
                "shard_pass", kind="permanent", times=None, shard=1)):
            tk = srv.submit(plan(t).aggregate("b"))
            srv.drain()
        assert_same(tk.result(), ref)
        snap = srv.snapshot()
        assert snap["engine_failovers"] >= 1
        assert snap["engine_bytes_failover"] > 0


# ------------------------------------------------ server-level degradation
class TestServerDegradation:
    def test_transient_fault_retried_and_tick_mates_unaffected(self):
        t = make_table()
        srv = QueryServer(RelationalMemoryEngine(revision="xla"))
        with fault_plan(FaultPlan().inject("scan_launch", at=1, times=2)):
            a = srv.submit(plan(t).aggregate("b"))
            b = srv.submit(plan(t).project("a"))
            srv.drain()
        a.result()
        b.result()
        snap = srv.snapshot()
        assert snap["served"] == 2
        assert snap["failed"] == 0
        assert snap["retries"] >= 1

    def test_poison_quarantine_resolves_typed_and_blocks_resubmits(self):
        t = make_table()
        srv = QueryServer(RelationalMemoryEngine(revision="xla"),
                          max_retries=2, poison_cooldown_ticks=2)
        q_bad = plan(t).aggregate("b").build()
        with fault_plan(FaultPlan().inject("scan_launch", times=None,
                                           table=t.uid)):
            bad = srv.submit(q_bad)
            srv.drain()
            with pytest.raises(TransientFault):
                bad.result()
            assert srv.snapshot()["poisoned"] == 1
            assert srv.snapshot()["poison_quarantined"] == 1
            again = srv.submit(q_bad)
            srv.drain()
            with pytest.raises(PoisonedPlanError):
                again.result()
        # retries were bounded: initial attempt burns no retry, then
        # max_retries individual re-runs for the first ticket only
        assert srv.snapshot()["retries"] == 2

    def test_quarantine_expires_after_cooldown(self):
        t = make_table()
        srv = QueryServer(RelationalMemoryEngine(revision="xla"),
                          max_retries=1, poison_cooldown_ticks=1)
        q = plan(t).aggregate("b").build()
        with fault_plan(FaultPlan().inject("scan_launch", times=None,
                                           table=t.uid)):
            bad = srv.submit(q)
            srv.drain()
            with pytest.raises(TransientFault):
                bad.result()
        srv.submit(plan(t).aggregate("a"))
        srv.drain()  # one tick: the cooldown lapses
        ok = srv.submit(q)
        srv.drain()
        expect = float(np.sum(np.asarray(t.read_column("b"),
                                         dtype=np.float64)))
        assert float(np.asarray(ok.result())) == expect

    def test_poison_does_not_starve_other_plans(self):
        t = make_table()
        srv = QueryServer(RelationalMemoryEngine(revision="xla"),
                          max_retries=1)
        q_bad = plan(t).aggregate("b").build()
        q_good = plan(t).project("a").build()
        with fault_plan(FaultPlan().inject("scan_launch", times=None,
                                           table=t.uid)):
            bad = srv.submit(q_bad)
            srv.drain()
            with pytest.raises(TransientFault):
                bad.result()
        good = srv.submit(q_good)
        srv.drain()
        assert np.asarray(good.result()).shape[0] == t.row_count

    def test_per_lane_shed_counts_and_depths_in_message(self):
        t = make_table()
        srv = QueryServer(RelationalMemoryEngine(revision="xla"),
                          max_queue=1, overload="degrade")
        srv.submit(plan(t).project("a"))  # fills the queue (bulk)
        srv.submit(plan(t).project("b"))  # degraded to bulk
        with pytest.raises(Exception) as exc:  # hard shed at 2x the bound
            srv.submit(plan(t).project("g"))
        msg = str(exc.value)
        assert "shed lane: bulk" in msg
        assert "express=0" in msg and "bulk=2" in msg
        assert srv.stats.lanes["bulk"].shed == 1
        assert srv.stats.lanes["express"].shed == 0
        srv.drain()

    def test_expired_inflight_ticket_dropped_before_transfer(self):
        t = make_table(2000)
        srv = QueryServer(RelationalMemoryEngine(revision="xla"),
                          pipeline=True)
        tk = srv.submit(plan(t).project("a", "b"), deadline_s=0.0)
        import time as _time

        tick = srv.begin_tick()
        _time.sleep(0.01)  # the deadline lapses while the pass is in flight
        srv.finish_tick(tick)
        with pytest.raises(TimeoutError):
            tk.result()
        snap = srv.snapshot()
        assert snap["deadline_misses"] == 1
        assert snap["bulk_deadline_misses"] == 1
