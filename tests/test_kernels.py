"""Per-kernel shape/dtype/geometry sweeps vs the pure-jnp oracles.

Every Pallas kernel runs in interpret mode (the kernel body executes on CPU)
and must match ``ref.py`` bit-exactly for projection and to float tolerance
for aggregation.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import RelationalTable, TableGeometry, benchmark_schema
from repro.core.schema import Column, TableSchema
from repro.kernels import ref as R
from repro.kernels.ops import (
    REVISIONS, aggregate, filter_project, groupby_sum, project_any,
)


def make_table(row_bytes, col_bytes, n, seed=0):
    rng = np.random.default_rng(seed)
    schema = benchmark_schema(row_bytes, col_bytes)
    cols = {
        c.name: rng.integers(-1000, 1000, n).astype(np.int32)
        for c in schema.columns
    }
    return schema, RelationalTable.from_columns(schema, cols)


GEOMS = [
    # (row_bytes, col_bytes, n_rows, projected columns)
    (64, 4, 100, ["A1"]),
    (64, 4, 1000, ["A1", "A7", "A13"]),
    (64, 4, 555, ["A2", "A3", "A4"]),  # contiguous group
    (128, 4, 257, ["A1", "A16", "A32"]),
    (32, 4, 64, ["A8"]),
    (256, 4, 100, [f"A{i}" for i in (1, 9, 17, 25, 33, 41, 49, 57, 64)]),
]


@pytest.mark.parametrize("row_bytes,col_bytes,n,cols", GEOMS)
@pytest.mark.parametrize("revision", REVISIONS)
def test_project_all_revisions_match_oracle(row_bytes, col_bytes, n, cols, revision):
    schema, t = make_table(row_bytes, col_bytes, n)
    geom = TableGeometry.from_schema(schema, cols, n)
    words = jnp.asarray(t.words())
    out = project_any(words, geom, revision=revision, block_rows=128)
    ref = R.project_ref(words[:, : schema.row_words], geom)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("block_rows", [8, 64, 256, 1024])
def test_project_block_row_sweep(block_rows):
    schema, t = make_table(64, 4, 777)
    geom = TableGeometry.from_schema(schema, ["A1", "A5", "A9"], 777)
    words = jnp.asarray(t.words())
    out = project_any(words, geom, revision="mlp", block_rows=block_rows)
    ref = R.project_ref(words[:, : schema.row_words], geom)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_project_wide_char_columns():
    """Multi-word (char) columns pack correctly."""
    schema = TableSchema.of(
        Column("key", "int64"),
        Column("text", "char", 16),
        Column("num", "int32"),
        Column("pad", "char", 36),
    )
    rng = np.random.default_rng(1)
    n = 100
    t = RelationalTable.from_columns(schema, {
        "key": rng.integers(0, 1 << 40, n),
        "text": [bytes(rng.integers(65, 90, 16).tolist()) for _ in range(n)],
        "num": rng.integers(-5, 5, n).astype(np.int32),
        "pad": [b"x" * 36] * n,
    })
    geom = TableGeometry.from_schema(schema, ["text", "num"], n)
    words = jnp.asarray(t.words())
    for rev in REVISIONS:
        out = project_any(words, geom, revision=rev)
        ref = R.project_ref(words[:, : schema.row_words], geom)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref), err_msg=rev)


@pytest.mark.parametrize("pred_op,k", [("gt", 0), ("lt", -500), ("none", 0)])
@pytest.mark.parametrize("agg_dtype", ["int32", "float32"])
def test_aggregate_sweep(pred_op, k, agg_dtype):
    rng = np.random.default_rng(2)
    n = 999
    schema = TableSchema.of(
        Column("a", agg_dtype), Column("b", "int32"), Column("c", "int32"),
    )
    vals = (
        rng.normal(0, 10, n).astype(np.float32)
        if agg_dtype == "float32" else rng.integers(-100, 100, n).astype(np.int32)
    )
    t = RelationalTable.from_columns(schema, {
        "a": vals,
        "b": rng.integers(-1000, 1000, n).astype(np.int32),
        "c": np.zeros(n, np.int32),
    })
    words = jnp.asarray(t.words())
    out = aggregate(words, agg_word=0, agg_dtype=agg_dtype, pred_word=1,
                    pred_op=pred_op, pred_k=k, block_rows=128)
    ref = R.aggregate_ref(words, 0, agg_dtype, 1, "int32", pred_op, k)
    np.testing.assert_allclose(float(out[0]), float(ref), rtol=1e-5)


def test_aggregate_mvcc_snapshot_fused():
    """The fused snapshot test only aggregates rows live at the given ts."""
    schema = benchmark_schema(32, 4)
    rng = np.random.default_rng(3)
    n = 200
    cols = {c.name: rng.integers(0, 100, n).astype(np.int32) for c in schema.columns}
    t = RelationalTable.from_columns(schema, cols)
    ts0 = t.now()
    t.delete(np.arange(0, n, 2))  # kill even rows at ts0+1
    words = jnp.asarray(t.words())
    ts_word = schema.row_words
    # snapshot BEFORE the delete sees everything
    before = aggregate(words, agg_word=0, ts=ts0, ts_word=ts_word, block_rows=64)
    assert int(before[1]) == n
    # snapshot now sees only odd rows
    after = aggregate(words, agg_word=0, ts=t.now(), ts_word=ts_word, block_rows=64)
    assert int(after[1]) == n // 2
    np.testing.assert_allclose(
        float(after[0]), float(cols["A1"][1::2].sum()), rtol=1e-6
    )


@pytest.mark.parametrize("num_groups", [4, 16, 128])
def test_groupby_sweep(num_groups):
    schema, t = make_table(64, 4, 1234, seed=4)
    words = jnp.asarray(t.words())
    s, c = groupby_sum(words, group_word=1, agg_word=0, num_groups=num_groups,
                       block_rows=128)
    sr, cr = R.groupby_sum_ref(words, 1, 0, "int32", num_groups)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(c), np.asarray(cr), rtol=1e-5)


def test_filter_project_matches_oracle():
    schema, t = make_table(64, 4, 321, seed=5)
    geom = TableGeometry.from_schema(schema, ["A1", "A9"], 321)
    words = jnp.asarray(t.words())
    packed, mask = filter_project(words, geom, pred_word=2, pred_op="gt",
                                  pred_k=0, block_rows=64)
    pr, mr = R.filter_project_ref(words[:, : schema.row_words], geom, 2,
                                  "int32", "gt", 0)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(pr))
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(mr))


def test_revision_equivalence_under_odd_sizes():
    """All hardware revisions agree for row counts far from block multiples."""
    for n in (1, 7, 127, 129, 500):
        schema, t = make_table(64, 4, n, seed=n)
        geom = TableGeometry.from_schema(schema, ["A3", "A11"], n)
        words = jnp.asarray(t.words())
        outs = [
            np.asarray(project_any(words, geom, revision=r, block_rows=64))
            for r in REVISIONS
        ]
        for o in outs[1:]:
            np.testing.assert_array_equal(outs[0], o)
