"""Multi-device tests (subprocess with forced host device count).

The dry-run env var is process-local by design (tests/benches see 1 device),
so every multi-device scenario runs in a child interpreter with its own
``--xla_force_host_platform_device_count``.
"""

import os
import subprocess
import sys
import textwrap


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_child(code: str, devices: int = 8, timeout: int = 480) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env, capture_output=True, text=True, timeout=timeout,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_distributed_relational_operators():
    run_child("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import RelationalTable, benchmark_schema, TableGeometry
        from repro.core import distributed as D
        from repro.launch.mesh import make_mesh

        rng = np.random.default_rng(2)
        schema = benchmark_schema(64, 4)
        n = 1003  # deliberately not divisible by 8: padding must be masked
        cols = {f"A{i+1}": rng.integers(-100, 100, n).astype(np.int32) for i in range(16)}
        t = RelationalTable.from_columns(schema, cols)
        mesh = make_mesh((8,), ("data",))
        words = D.pad_rows_to(t.words(), 8)
        geom = TableGeometry.from_schema(schema, ["A1", "A5"], row_count=n)

        out = np.asarray(D.dist_project(words, geom, mesh, valid_rows=n))
        ref = np.stack([cols["A1"], cols["A5"]], 1)
        np.testing.assert_array_equal(out[:n], ref)
        assert (out[n:] == 0).all(), "padding rows leaked into the packed output"

        agg = D.dist_aggregate(words, mesh, agg_word=0, pred_word=2,
                               pred_op="gt", pred_k=10, valid_rows=n)
        expect = cols["A1"][(cols["A3"] > 10)].sum()
        np.testing.assert_allclose(float(agg[0]), float(expect), rtol=1e-6)

        s, c = D.dist_groupby(words, mesh, group_word=1, agg_word=0,
                              num_groups=16, valid_rows=n)
        g = cols["A2"] % 16
        sr = np.zeros(16); np.add.at(sr, g, cols["A1"].astype(np.float64))
        np.testing.assert_allclose(np.asarray(s), sr, rtol=1e-5)
        print("OK")
    """)


def test_dist_join_padding_regression():
    """Padded rows carry key word 0; a legitimate key-0 build row must match
    real probes and never the padding (the pre-fix false-positive)."""
    run_child("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import RelationalTable, benchmark_schema, TableGeometry
        from repro.core import distributed as D
        from repro.kernels.ref import hash_join_ref
        from repro.launch.mesh import make_mesh

        rng = np.random.default_rng(5)
        schema = benchmark_schema(64, 4)
        n_s, n_r = 1001, 117  # both non-divisible by 8
        s_cols = {f"A{i+1}": rng.integers(-20, 20, n_s).astype(np.int32)
                  for i in range(16)}
        r_cols = {f"A{i+1}": rng.integers(-20, 20, n_r).astype(np.int32)
                  for i in range(16)}
        r_cols["A2"] = np.arange(n_r, dtype=np.int32) - 3  # unique keys incl. 0
        s_t = RelationalTable.from_columns(schema, s_cols)
        r_t = RelationalTable.from_columns(schema, r_cols)
        mesh = make_mesh((8,), ("data",))
        s_geom = TableGeometry.from_schema(schema, ["A1", "A2"], row_count=n_s)
        r_geom = TableGeometry.from_schema(schema, ["A2", "A3"], row_count=n_r)

        s_val, r_val, matched = D.dist_join(
            D.pad_rows_to(s_t.words(), 8), D.pad_rows_to(r_t.words(), 8),
            mesh, s_geom, r_geom, s_key_word=1, s_val_word=0,
            r_key_word=0, r_val_word=1, s_valid_rows=n_s, r_valid_rows=n_r,
        )
        s_val, r_val, matched = (np.asarray(s_val), np.asarray(r_val),
                                 np.asarray(matched))
        ref_s, ref_r, ref_m = hash_join_ref(
            jnp.asarray(s_cols["A2"]), jnp.asarray(s_cols["A1"]),
            jnp.asarray(r_cols["A2"]), jnp.asarray(r_cols["A3"]),
        )
        np.testing.assert_array_equal(matched[:n_s], np.asarray(ref_m))
        np.testing.assert_array_equal(r_val[:n_s], np.asarray(ref_r))
        np.testing.assert_array_equal(s_val[:n_s], np.asarray(ref_s))
        # key 0 exists on the build side, so some real probe matches it...
        assert matched[:n_s][s_cols["A2"] == 0].all()
        # ...but padded probe rows (also key 0) never match anything
        assert not matched[n_s:].any(), "padding probed the build side"
        print("OK")
    """)


def test_gpipe_pipeline_matches_sequential():
    run_child("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.distributed.pipeline import pipeline_apply
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((4, 2), ("pod", "data"))
        n_stages, n_micro, d = 4, 8, 16
        rng = np.random.default_rng(0)
        ws = jnp.asarray(rng.normal(0, 0.3, (n_stages, d, d)), jnp.float32)
        stage_fn = lambda w, x: jax.nn.relu(x @ w)
        pp = pipeline_apply(stage_fn, mesh, n_microbatches=n_micro, axis="pod")
        x = jnp.asarray(rng.normal(0, 1, (n_micro * 4, d)), jnp.float32)
        y = pp(ws, x)
        ref = x
        for i in range(n_stages):
            ref = jax.nn.relu(ref @ ws[i])
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5, atol=1e-5)
        print("OK")
    """)


def test_compressed_collectives():
    run_child("""
        import numpy as np, jax, jax.numpy as jnp
        from repro import compat
        from jax.sharding import PartitionSpec as P
        from repro.distributed.collectives import tree_psum_compressed
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        g = {"a": jnp.asarray(rng.normal(0, 1, (8, 32)), jnp.float32)}
        res = jax.tree.map(jnp.zeros_like, g)
        def red(mode):
            f = lambda gl, rl: tree_psum_compressed(gl, rl, "data", mode=mode)
            return compat.shard_map(f, mesh=mesh, in_specs=(P("data"), P("data")),
                                 out_specs=(P("data"), P("data")))
        exact, _ = red("none")(g, res)
        bf, _ = red("bf16")(g, res)
        i8, r8 = red("int8_ef")(g, res)
        assert float(jnp.max(jnp.abs(exact["a"] - bf["a"]))) < 0.05
        assert float(jnp.max(jnp.abs(exact["a"] - i8["a"]))) < 0.5
        assert float(jnp.linalg.norm(r8["a"])) > 0  # error feedback captured
        print("OK")
    """)


def test_sharded_train_step_runs_and_matches_single_device():
    """Real (not dry) sharded train step on 8 devices == 1-device result."""
    run_child("""
        import numpy as np, jax, jax.numpy as jnp, dataclasses
        from repro import compat
        from repro.configs import get_smoke_config
        from repro.distributed.partitioning import axis_rules, rules_for_mesh
        from repro.launch import specs as S
        from repro.launch.mesh import make_mesh
        from repro.models import build_model
        from repro.train import AdamWConfig, make_train_step
        from repro.train.step import init_train_state

        cfg = get_smoke_config("qwen3-8b")
        cfg = dataclasses.replace(cfg, compute_dtype="float32")
        model = build_model(cfg)
        rng = np.random.default_rng(0)
        B, S_ = 8, 64
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S_)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S_)), jnp.int32),
        }
        opt = AdamWConfig(lr=1e-3, warmup_steps=0)

        # single-device reference
        state = init_train_state(model, jax.random.PRNGKey(0))
        ref_state, ref_m = jax.jit(make_train_step(model, opt))(
            jax.tree.map(jnp.copy, state), batch)

        mesh = make_mesh((4, 2), ("data", "model"))
        rules = rules_for_mesh(mesh)
        with axis_rules(rules, dict(zip(mesh.axis_names, mesh.devices.shape))), \\
             compat.set_mesh(mesh):
            state_sh = S.train_state_shardings(
                mesh, jax.eval_shape(lambda: state))
            batch_sh = S.batch_shardings(mesh, batch)
            state_d = jax.device_put(state, state_sh)
            batch_d = jax.device_put(batch, batch_sh)
            step = jax.jit(make_train_step(model, opt),
                           in_shardings=(state_sh, batch_sh),
                           out_shardings=(state_sh, None))
            new_state, m = step(state_d, batch_d)
        np.testing.assert_allclose(float(m["loss"]), float(ref_m["loss"]),
                                   rtol=1e-4)
        for a, b in zip(jax.tree.leaves(new_state["params"]),
                        jax.tree.leaves(ref_state["params"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=3e-3, atol=3e-4)
        print("OK")
    """, devices=8)


def test_sp_decode_matches_single_device():
    """Sequence-parallel decode (shard_map path) == unsharded decode."""
    run_child("""
        import numpy as np, jax, jax.numpy as jnp, dataclasses
        from repro import compat
        from repro.configs import get_smoke_config
        from repro.distributed.partitioning import axis_rules, rules_for_mesh
        from repro.launch import specs as S
        from repro.launch.mesh import make_mesh
        from repro.models import build_model

        cfg = get_smoke_config("qwen1.5-110b")
        cfg = dataclasses.replace(cfg, compute_dtype="float32")
        model = build_model(cfg)
        rng = np.random.default_rng(0)
        B, S_, max_len = 4, 32, 64
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S_)), jnp.int32)
        params = model.init(jax.random.PRNGKey(1))

        # unsharded reference
        logits0, cache0 = jax.jit(lambda p, b: model.prefill(p, b, max_len))(
            params, {"tokens": toks})
        step0 = jax.jit(model.decode_step)
        l_ref, _ = step0(params, cache0, jnp.argmax(logits0, -1)[:, None].astype(jnp.int32),
                         jnp.asarray(S_, jnp.int32))

        mesh = make_mesh((2, 4), ("data", "model"))
        rules = rules_for_mesh(mesh)
        with axis_rules(rules, dict(zip(mesh.axis_names, mesh.devices.shape))), \\
             compat.set_mesh(mesh):
            logits1, cache1 = jax.jit(lambda p, b: model.prefill(p, b, max_len))(
                params, {"tokens": toks})
            l_sp, _ = jax.jit(model.decode_step)(
                params, cache1, jnp.argmax(logits1, -1)[:, None].astype(jnp.int32),
                jnp.asarray(S_, jnp.int32))
        np.testing.assert_allclose(np.asarray(l_sp), np.asarray(l_ref),
                                   rtol=2e-3, atol=2e-3)
        print("OK")
    """, devices=8)


def test_elastic_restore_across_mesh_shapes(tmp_path):
    """Checkpoint on a (4,2) mesh, restore+step on (2,4) — elastic restart."""
    ckpt = str(tmp_path / "elastic")
    save_code = f"""
        import numpy as np, jax, jax.numpy as jnp
        from repro import compat
        from repro.configs import get_smoke_config
        from repro.distributed.partitioning import axis_rules, rules_for_mesh
        from repro.launch import specs as S
        from repro.launch.mesh import make_mesh
        from repro.models import build_model
        from repro.train.step import init_train_state
        from repro.ckpt import save_checkpoint

        cfg = get_smoke_config("qwen3-8b")
        model = build_model(cfg)
        mesh = make_mesh((4, 2), ("data", "model"))
        rules = rules_for_mesh(mesh)
        with axis_rules(rules, dict(zip(mesh.axis_names, mesh.devices.shape))), \\
             compat.set_mesh(mesh):
            state = init_train_state(model, jax.random.PRNGKey(0))
            sh = S.train_state_shardings(mesh, jax.eval_shape(lambda: state))
            state = jax.device_put(state, sh)
            save_checkpoint({ckpt!r}, 3, state)
        print("SAVED")
    """
    restore_code = f"""
        import numpy as np, jax, jax.numpy as jnp
        from repro import compat
        from repro.configs import get_smoke_config
        from repro.distributed.partitioning import axis_rules, rules_for_mesh
        from repro.launch import specs as S
        from repro.launch.mesh import make_mesh
        from repro.models import build_model
        from repro.train import AdamWConfig, make_train_step
        from repro.train.step import init_train_state
        from repro.ckpt import restore_checkpoint

        cfg = get_smoke_config("qwen3-8b")
        model = build_model(cfg)
        mesh = make_mesh((2, 4), ("data", "model"))  # DIFFERENT topology
        rules = rules_for_mesh(mesh)
        with axis_rules(rules, dict(zip(mesh.axis_names, mesh.devices.shape))), \\
             compat.set_mesh(mesh):
            like = jax.eval_shape(
                lambda: init_train_state(model, jax.random.PRNGKey(0)))
            sh = S.train_state_shardings(mesh, like)
            step, state = restore_checkpoint({ckpt!r}, like, shardings=sh)
            assert step == 3, step
            rng = np.random.default_rng(0)
            batch = {{
                "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 64)), jnp.int32),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 64)), jnp.int32),
            }}
            fn = jax.jit(make_train_step(model, AdamWConfig()),
                         in_shardings=(sh, None), out_shardings=(sh, None))
            state, m = fn(state, batch)
            assert np.isfinite(float(m["loss"]))
        print("RESTORED+STEPPED on", mesh.devices.shape)
    """
    assert "SAVED" in run_child(save_code, devices=8)
    assert "RESTORED" in run_child(restore_code, devices=8)


def test_dryrun_cell_on_tiny_mesh():
    """The dry-run driver machinery on an 8-device (2,2,2) multi-pod mesh."""
    run_child("""
        import jax, jax.numpy as jnp
        from repro import compat
        from repro.configs import get_smoke_config
        from repro.configs.base import ShapeSpec
        from repro.distributed.partitioning import axis_rules, rules_for_mesh
        from repro.launch import specs as S
        from repro.launch.mesh import make_mesh
        from repro.models import build_model
        from repro.train import AdamWConfig, make_train_step
        from repro.roofline.analysis import analyze_compiled

        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        rules = rules_for_mesh(mesh)
        cfg = get_smoke_config("gemma3-27b")
        sh = ShapeSpec("t", 128, 8, "train")
        model = build_model(cfg)
        with axis_rules(rules, dict(zip(mesh.axis_names, mesh.devices.shape))), \\
             compat.set_mesh(mesh):
            st = S.train_state_shapes(model, cfg)
            lowered = jax.jit(
                make_train_step(model, AdamWConfig(), grad_accum=2),
                in_shardings=(S.train_state_shardings(mesh, st),
                              S.batch_shardings(mesh, S.train_batch_shapes(cfg, sh))),
                out_shardings=(S.train_state_shardings(mesh, st), None),
            ).lower(st, S.train_batch_shapes(cfg, sh))
            compiled = lowered.compile()
        res = analyze_compiled(compiled, arch="gemma3-smoke", shape="t",
                               mesh_name="2x2x2", n_devices=8, model_flops=1e9)
        t = res.terms()
        assert all(v > 0 for v in t.values()), t
        assert res.collective["total"] > 0
        print("OK", t)
    """, devices=8)


# ===================================================== sharded backend (logical)
# The sharded engine's code path is device-count-independent: ``num_shards``
# without a mesh runs every shard on the current device, so the equality
# suite runs in-process (1 device) and the mesh placement runs in a child.

def _sharded_case(seed=7, n=1003, n_extra=37):
    import numpy as np
    from repro.core import benchmark_schema

    rng = np.random.default_rng(seed)
    schema = benchmark_schema(64, 4)
    # bounded int values: every partial sum is exactly representable in
    # float32, so re-associated sharded reductions are bit-equal
    cols = {c.name: rng.integers(-50, 50, n).astype(np.int32)
            for c in schema.columns}
    extra = {c.name: rng.integers(-50, 50, n_extra).astype(np.int32)
             for c in schema.columns}
    return schema, cols, extra


def _mk_ops(engine, t, r_t, snapshot_ts=None):
    from repro.core.requests import (
        AggregateOp, FilterOp, GroupByOp, JoinOp, ProjectOp,
    )

    return [
        ProjectOp(engine.register(t, ("A1", "A2"))),
        FilterOp(engine.register(t, ("A1", "A3")), "A3", "gt", 5,
                 snapshot_ts=snapshot_ts),
        AggregateOp(t, "A1", pred_col="A2", pred_op="lt", pred_k=0,
                    snapshot_ts=snapshot_ts),
        GroupByOp(t, "A2", "A1", 16, snapshot_ts=snapshot_ts),
        JoinOp(engine.register(t, ("A1", "A4")), "A1", "A4", r_t, "A3",
               snapshot_ts=snapshot_ts),
    ]


def _flatten(result):
    import numpy as np
    from repro.core.requests import JoinResult

    if isinstance(result, JoinResult):
        return [np.asarray(result.s_proj), np.asarray(result.r_proj),
                np.asarray(result.matched)]
    if isinstance(result, tuple):
        return [np.asarray(x) for x in result]
    return [np.asarray(result)]


def _assert_results_equal(a, b, label):
    import numpy as np

    for i, (x, y) in enumerate(zip(a, b)):
        for xa, ya in zip(_flatten(x), _flatten(y)):
            np.testing.assert_array_equal(xa, ya, err_msg=f"{label} op {i}")


def test_sharded_engine_matches_single_device():
    """Byte-identical results for every op kind, with and without a
    snapshot, across shard counts and revisions, on a non-divisible table."""
    import numpy as np
    from repro.core import RelationalMemoryEngine, RelationalTable
    from repro.core.distributed import ShardedEngine

    schema, cols, extra = _sharded_case()
    rng_r = np.random.default_rng(11)
    r_cols = {c.name: rng_r.integers(-50, 50, 130).astype(np.int32)
              for c in schema.columns}
    r_cols["A1"] = np.arange(130, dtype=np.int32) - 7  # unique keys incl. 0

    def run(engine, snapshot):
        t = RelationalTable.from_columns(
            schema, {k: v.copy() for k, v in cols.items()})
        r_t = RelationalTable.from_columns(
            schema, {k: v.copy() for k, v in r_cols.items()})
        ts = t.now() if snapshot else None
        return engine.execute_many(_mk_ops(engine, t, r_t, snapshot_ts=ts))

    for revision in ("xla", "mlp"):
        for snapshot in (False, True):
            ref = run(RelationalMemoryEngine(revision=revision), snapshot)
            for shards in (3, 4):
                got = run(ShardedEngine(num_shards=shards, revision=revision),
                          snapshot)
                _assert_results_equal(
                    ref, got, f"{revision} snap={snapshot} shards={shards}")


def test_sharded_mixed_tick_one_fused_pass_per_shard(monkeypatch):
    """A mixed-kind tick launches exactly one fused scan_multi per shard."""
    from repro.core import RelationalTable
    from repro.core.distributed import ShardedEngine
    from repro.core.plan import plan
    from repro.kernels import rme_scan_multi as KR
    from repro.serve.query_server import QueryServer

    schema, cols, _ = _sharded_case()
    t = RelationalTable.from_columns(schema, cols)
    engine = ShardedEngine(num_shards=4, revision="xla")
    server = QueryServer(engine, snapshot_reads=False)

    calls = []
    orig = KR.scan_multi

    def spy(words, requests, **kw):
        calls.append((words.shape[0], len(tuple(requests))))
        return orig(words, requests, **kw)

    monkeypatch.setattr(KR, "scan_multi", spy)
    for q in (plan(t).project("A1", "A2"),
              plan(t).aggregate("A1", "sum"),
              plan(t).groupby("A2", "A1", "sum", num_groups=8)):
        server.submit(q)
    server.run_tick()
    assert len(calls) == 4, calls  # one fused pass per shard, nothing else
    assert all(n_req == 3 for _, n_req in calls), calls
    assert sum(rows for rows, _ in calls) == t.row_count
    assert engine.stats.shared_scans == 1
    snap = server.snapshot()
    assert snap["engine_collective_ops"] == 2  # aggregate + group-by combines
    assert snap["engine_bytes_collective"] == 3 * (8 + 8 * 2 * 4)


def test_sharded_append_lands_only_in_owning_shard():
    """An append uploads O(new rows) bytes to exactly one shard's chunks."""
    from repro.core import RelationalTable
    from repro.core.distributed import ShardedEngine
    from repro.core.requests import AggregateOp

    schema, cols, extra = _sharded_case()
    t = RelationalTable.from_columns(schema, cols)
    engine = ShardedEngine(num_shards=4, revision="xla")
    engine.execute_many([AggregateOp(t, "A1")])  # full upload
    before = [[c.segments for c in chunks]
              for chunks in engine.rowstore.shard_parts(t)]

    n0 = t.row_count
    t.append(extra)
    delta0 = engine.stats.bytes_uploaded_delta
    engine.execute_many([AggregateOp(t, "A1")])  # syncs the delta
    n_extra = len(next(iter(extra.values())))
    assert (engine.stats.bytes_uploaded_delta - delta0
            == n_extra * t.row_words * 4)
    after = [[c.segments for c in chunks]
             for chunks in engine.rowstore.shard_parts(t)]
    changed = [s for s in range(4) if after[s] != before[s]]
    assert len(changed) == 1, changed  # exactly one owning shard grew
    new_segs = [seg for segs in after[changed[0]] for seg in segs
                if segs not in before[changed[0]]]
    assert (n0, n_extra) in new_segs


def test_sharded_mvcc_snapshot_reads_under_concurrent_writes():
    """A pinned read is byte-identical across backends while writes land."""
    import numpy as np
    from repro.core import RelationalMemoryEngine, RelationalTable
    from repro.core.distributed import ShardedEngine
    from repro.core.requests import AggregateOp, FilterOp, GroupByOp

    schema, cols, extra = _sharded_case(seed=13)

    def run(engine):
        t = RelationalTable.from_columns(
            schema, {k: v.copy() for k, v in cols.items()})
        engine.execute_many([AggregateOp(t, "A1")])  # resident before writes
        ts = t.now()
        t.append({k: v.copy() for k, v in extra.items()})
        t.delete(np.arange(20))
        t.update(np.arange(30, 40),
                 {"A1": np.full(10, 7, np.int32)})
        pinned = engine.execute_many([
            AggregateOp(t, "A1", snapshot_ts=ts),
            GroupByOp(t, "A2", "A1", 8, snapshot_ts=ts),
            FilterOp(engine.register(t, ("A1", "A2")), "A2", "gt", 0,
                     snapshot_ts=ts),
        ])
        live = engine.execute_many([AggregateOp(t, "A1", snapshot_ts=t.now())])
        return pinned + live

    ref = run(RelationalMemoryEngine(revision="xla"))
    got = run(ShardedEngine(num_shards=4, revision="xla"))
    _assert_results_equal(ref, got, "mvcc-under-writes")


def test_sharded_reset_drops_broadcast_cache():
    import numpy as np
    from repro.core import RelationalTable
    from repro.core.distributed import ShardedEngine
    from repro.core.requests import JoinOp

    schema, cols, _ = _sharded_case()
    rng = np.random.default_rng(17)
    r_cols = {c.name: rng.integers(-50, 50, 64).astype(np.int32)
              for c in schema.columns}
    r_cols["A1"] = np.arange(64, dtype=np.int32)
    t = RelationalTable.from_columns(schema, cols)
    r_t = RelationalTable.from_columns(schema, r_cols)
    engine = ShardedEngine(num_shards=4, revision="xla")
    engine.execute_many(
        [JoinOp(engine.register(t, ("A1", "A4")), "A1", "A4", r_t, "A3")])
    assert engine._bcast_parts  # broadcast replicas cached
    ops0 = engine.stats.collective_ops
    engine.reset()
    assert not engine._bcast_parts
    # the next probe re-broadcasts (fresh build after reset)
    engine.execute_many(
        [JoinOp(engine.register(t, ("A1", "A4")), "A1", "A4", r_t, "A3")])
    assert engine.stats.collective_ops > ops0


def test_sharded_collective_bytes_scale_with_results_not_rows():
    """Interconnect bytes are a function of result size only: growing the
    table 4x leaves aggregate/group-by collective traffic unchanged."""
    import numpy as np
    from repro.core import RelationalTable, benchmark_schema
    from repro.core.distributed import ShardedEngine
    from repro.core.requests import AggregateOp, GroupByOp

    schema = benchmark_schema(64, 4)
    rng = np.random.default_rng(19)

    def collective_bytes(n):
        cols = {c.name: rng.integers(-50, 50, n).astype(np.int32)
                for c in schema.columns}
        t = RelationalTable.from_columns(schema, cols)
        engine = ShardedEngine(num_shards=4, revision="xla")
        engine.execute_many([AggregateOp(t, "A1"),
                             GroupByOp(t, "A2", "A1", 16)])
        assert engine.stats.bytes_from_dram > 0
        return engine.stats.bytes_collective, engine.stats.bytes_from_dram

    coll_small, dram_small = collective_bytes(500)
    coll_large, dram_large = collective_bytes(2000)
    assert dram_large > 3 * dram_small  # the scan itself does scale
    assert coll_large == coll_small  # the interconnect does not


def test_group_ids_agree_across_paths():
    """Hostile keys (negative, near-overflow) group identically on the
    fused kernel, the sharded engine, the oracle, and dist_groupby."""
    import numpy as np
    import jax.numpy as jnp
    from repro.core import RelationalMemoryEngine, RelationalTable, benchmark_schema
    from repro.core.distributed import ShardedEngine
    from repro.core.requests import GroupByOp
    from repro.kernels.common import group_ids
    from repro.kernels.ref import groupby_sum_ref

    schema = benchmark_schema(64, 4)
    n, G = 512, 16
    rng = np.random.default_rng(23)
    hostile = np.concatenate([
        rng.integers(-(2**31), 2**31 - 1, n - 8).astype(np.int32),
        np.asarray([0, -1, -16, 2**31 - 1, -(2**31), 17, -17, 5], np.int32),
    ])
    cols = {c.name: rng.integers(-10, 10, n).astype(np.int32)
            for c in schema.columns}
    cols["A2"] = hostile
    t1 = RelationalTable.from_columns(schema, {k: v.copy() for k, v in cols.items()})
    t2 = RelationalTable.from_columns(schema, {k: v.copy() for k, v in cols.items()})

    # the shared lowering is a floored modulo: always in [0, G)
    g = np.asarray(group_ids(jnp.asarray(hostile), G))
    assert ((g >= 0) & (g < G)).all()
    np.testing.assert_array_equal(g, np.mod(hostile.astype(np.int64), G))

    fused = RelationalMemoryEngine(revision="xla").execute_many(
        [GroupByOp(t1, "A2", "A1", G)])[0]
    sharded = ShardedEngine(num_shards=4, revision="xla").execute_many(
        [GroupByOp(t2, "A2", "A1", G)])[0]
    oracle = groupby_sum_ref(jnp.asarray(t1.words()), 1, 0, "int32", G)
    from repro.core import distributed as D
    from repro.launch.mesh import make_mesh

    dist = D.dist_groupby(jnp.asarray(t1.words()), make_mesh((1,), ("data",)),
                          group_word=1, agg_word=0, num_groups=G, valid_rows=n)
    for a, b in ((fused, sharded), (fused, oracle), (fused, dist)):
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
        np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


def test_sharded_engine_on_mesh_matches_single_device():
    """The same backend on a real 8-device mesh: per-device placement plus
    byte-identical results through the QueryServer."""
    run_child("""
        import numpy as np, jax
        assert len(jax.devices()) == 8
        from repro.core import RelationalMemoryEngine, RelationalTable, benchmark_schema
        from repro.core.distributed import ShardedEngine
        from repro.core.plan import plan
        from repro.launch.mesh import make_mesh
        from repro.serve.query_server import QueryServer

        rng = np.random.default_rng(29)
        schema = benchmark_schema(64, 4)
        n = 1003
        cols = {c.name: rng.integers(-50, 50, n).astype(np.int32)
                for c in schema.columns}
        extra = {c.name: rng.integers(-50, 50, 21).astype(np.int32)
                 for c in schema.columns}

        def serve(server):
            t = RelationalTable.from_columns(
                schema, {k: v.copy() for k, v in cols.items()})
            tickets = [
                server.submit(plan(t).project("A1", "A2")),
                server.submit(plan(t).filter("A3", "gt", 3).aggregate("A1", "sum")),
                server.submit(plan(t).groupby("A2", "A1", "sum", num_groups=8)),
                server.submit_insert(t, extra),
                server.submit(plan(t).aggregate("A1", "count")),
            ]
            server.run_tick()
            return [tk.result(timeout=30) for tk in tickets], t

        mesh = make_mesh((8,), ("data",))
        ref_server = QueryServer(RelationalMemoryEngine(revision="xla"))
        sh_engine = ShardedEngine(mesh=mesh, revision="xla")
        sh_server = QueryServer(sh_engine)
        ref, _ = serve(ref_server)
        got, t = serve(sh_server)
        for i, (a, b) in enumerate(zip(ref, got)):
            fa = a if isinstance(a, tuple) else (a,)
            fb = b if isinstance(b, tuple) else (b,)
            for x, y in zip(fa, fb):
                assert np.array_equal(np.asarray(x), np.asarray(y)), f"query {i}"
        # every shard's buffers live on that shard's own device
        for s, chunks in enumerate(sh_engine.rowstore.shard_parts(t)):
            for c in chunks:
                assert {d.id for d in c.words.devices()} == {s}
        snap = sh_server.snapshot()
        assert snap["engine_bytes_collective"] > 0
        assert snap["engine_collective_ops"] > 0
        print("OK")
    """)


def test_sharded_encoded_columns_match_single_device():
    """Compressed execution on the sharded backend: predicate translation is
    shard-local, per-code group-by partials combine across shards before the
    dictionary remap, shared-dictionary join keys survive the build-side
    broadcast — all byte-identical to the single-device engine."""
    import strategies
    from repro.core import RelationalMemoryEngine
    from repro.core.distributed import ShardedEngine
    from repro.core.requests import AggregateOp, FilterOp, GroupByOp, JoinOp

    def run(engine, seed):
        (probe, build), _, _ = strategies.build_tables(seed)
        ops = [
            FilterOp(engine.register(probe, ("K", "V")), "K", "gt", 0),
            AggregateOp(probe, "F", pred_col="K", pred_op="lt", pred_k=3),
            GroupByOp(probe, "K", "V", 16),
            GroupByOp(probe, "S", "V", len(strategies.STRING_POOL)),
            JoinOp(engine.register(probe, ("V", "K")), "V", "K",
                   build, "B"),
        ]
        return engine.execute_many(ops), engine

    for revision, seed in (("xla", 4), ("xla", 9), ("mlp", 9)):
        ref_res, _ = run(RelationalMemoryEngine(revision=revision), seed)
        for shards in (3, 4):
            got, eng = run(
                ShardedEngine(num_shards=shards, revision=revision), seed)
            _assert_results_equal(
                ref_res, got, f"{revision} shards={shards} seed={seed}")
            # the narrow word budget is charged per shard-local chunk too
            assert eng.stats.bytes_saved_compression > 0
