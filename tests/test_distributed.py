"""Multi-device tests (subprocess with forced host device count).

The dry-run env var is process-local by design (tests/benches see 1 device),
so every multi-device scenario runs in a child interpreter with its own
``--xla_force_host_platform_device_count``.
"""

import os
import subprocess
import sys
import textwrap


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_child(code: str, devices: int = 8, timeout: int = 480) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env, capture_output=True, text=True, timeout=timeout,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_distributed_relational_operators():
    run_child("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import RelationalTable, benchmark_schema, TableGeometry
        from repro.core import distributed as D
        from repro.launch.mesh import make_mesh

        rng = np.random.default_rng(2)
        schema = benchmark_schema(64, 4)
        n = 1000
        cols = {f"A{i+1}": rng.integers(-100, 100, n).astype(np.int32) for i in range(16)}
        t = RelationalTable.from_columns(schema, cols)
        mesh = make_mesh((8,), ("data",))
        words = D.pad_rows_to(t.words(), 8)
        geom = TableGeometry.from_schema(schema, ["A1", "A5"], row_count=n)

        out = D.dist_project(words, geom, mesh)
        ref = np.stack([cols["A1"], cols["A5"]], 1)
        np.testing.assert_array_equal(np.asarray(out)[:n], ref)

        agg = D.dist_aggregate(words, mesh, agg_word=0, pred_word=2,
                               pred_op="gt", pred_k=10, valid_rows=n)
        expect = cols["A1"][(cols["A3"] > 10)].sum()
        np.testing.assert_allclose(float(agg[0]), float(expect), rtol=1e-6)

        s, c = D.dist_groupby(words, mesh, group_word=1, agg_word=0,
                              num_groups=16, valid_rows=n)
        g = cols["A2"] % 16
        sr = np.zeros(16); np.add.at(sr, g, cols["A1"].astype(np.float64))
        np.testing.assert_allclose(np.asarray(s), sr, rtol=1e-5)
        print("OK")
    """)


def test_gpipe_pipeline_matches_sequential():
    run_child("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.distributed.pipeline import pipeline_apply
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((4, 2), ("pod", "data"))
        n_stages, n_micro, d = 4, 8, 16
        rng = np.random.default_rng(0)
        ws = jnp.asarray(rng.normal(0, 0.3, (n_stages, d, d)), jnp.float32)
        stage_fn = lambda w, x: jax.nn.relu(x @ w)
        pp = pipeline_apply(stage_fn, mesh, n_microbatches=n_micro, axis="pod")
        x = jnp.asarray(rng.normal(0, 1, (n_micro * 4, d)), jnp.float32)
        y = pp(ws, x)
        ref = x
        for i in range(n_stages):
            ref = jax.nn.relu(ref @ ws[i])
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5, atol=1e-5)
        print("OK")
    """)


def test_compressed_collectives():
    run_child("""
        import numpy as np, jax, jax.numpy as jnp
        from repro import compat
        from jax.sharding import PartitionSpec as P
        from repro.distributed.collectives import tree_psum_compressed
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        g = {"a": jnp.asarray(rng.normal(0, 1, (8, 32)), jnp.float32)}
        res = jax.tree.map(jnp.zeros_like, g)
        def red(mode):
            f = lambda gl, rl: tree_psum_compressed(gl, rl, "data", mode=mode)
            return compat.shard_map(f, mesh=mesh, in_specs=(P("data"), P("data")),
                                 out_specs=(P("data"), P("data")))
        exact, _ = red("none")(g, res)
        bf, _ = red("bf16")(g, res)
        i8, r8 = red("int8_ef")(g, res)
        assert float(jnp.max(jnp.abs(exact["a"] - bf["a"]))) < 0.05
        assert float(jnp.max(jnp.abs(exact["a"] - i8["a"]))) < 0.5
        assert float(jnp.linalg.norm(r8["a"])) > 0  # error feedback captured
        print("OK")
    """)


def test_sharded_train_step_runs_and_matches_single_device():
    """Real (not dry) sharded train step on 8 devices == 1-device result."""
    run_child("""
        import numpy as np, jax, jax.numpy as jnp, dataclasses
        from repro import compat
        from repro.configs import get_smoke_config
        from repro.distributed.partitioning import axis_rules, rules_for_mesh
        from repro.launch import specs as S
        from repro.launch.mesh import make_mesh
        from repro.models import build_model
        from repro.train import AdamWConfig, make_train_step
        from repro.train.step import init_train_state

        cfg = get_smoke_config("qwen3-8b")
        cfg = dataclasses.replace(cfg, compute_dtype="float32")
        model = build_model(cfg)
        rng = np.random.default_rng(0)
        B, S_ = 8, 64
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S_)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S_)), jnp.int32),
        }
        opt = AdamWConfig(lr=1e-3, warmup_steps=0)

        # single-device reference
        state = init_train_state(model, jax.random.PRNGKey(0))
        ref_state, ref_m = jax.jit(make_train_step(model, opt))(
            jax.tree.map(jnp.copy, state), batch)

        mesh = make_mesh((4, 2), ("data", "model"))
        rules = rules_for_mesh(mesh)
        with axis_rules(rules, dict(zip(mesh.axis_names, mesh.devices.shape))), \\
             compat.set_mesh(mesh):
            state_sh = S.train_state_shardings(
                mesh, jax.eval_shape(lambda: state))
            batch_sh = S.batch_shardings(mesh, batch)
            state_d = jax.device_put(state, state_sh)
            batch_d = jax.device_put(batch, batch_sh)
            step = jax.jit(make_train_step(model, opt),
                           in_shardings=(state_sh, batch_sh),
                           out_shardings=(state_sh, None))
            new_state, m = step(state_d, batch_d)
        np.testing.assert_allclose(float(m["loss"]), float(ref_m["loss"]),
                                   rtol=1e-4)
        for a, b in zip(jax.tree.leaves(new_state["params"]),
                        jax.tree.leaves(ref_state["params"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=3e-3, atol=3e-4)
        print("OK")
    """, devices=8)


def test_sp_decode_matches_single_device():
    """Sequence-parallel decode (shard_map path) == unsharded decode."""
    run_child("""
        import numpy as np, jax, jax.numpy as jnp, dataclasses
        from repro import compat
        from repro.configs import get_smoke_config
        from repro.distributed.partitioning import axis_rules, rules_for_mesh
        from repro.launch import specs as S
        from repro.launch.mesh import make_mesh
        from repro.models import build_model

        cfg = get_smoke_config("qwen1.5-110b")
        cfg = dataclasses.replace(cfg, compute_dtype="float32")
        model = build_model(cfg)
        rng = np.random.default_rng(0)
        B, S_, max_len = 4, 32, 64
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S_)), jnp.int32)
        params = model.init(jax.random.PRNGKey(1))

        # unsharded reference
        logits0, cache0 = jax.jit(lambda p, b: model.prefill(p, b, max_len))(
            params, {"tokens": toks})
        step0 = jax.jit(model.decode_step)
        l_ref, _ = step0(params, cache0, jnp.argmax(logits0, -1)[:, None].astype(jnp.int32),
                         jnp.asarray(S_, jnp.int32))

        mesh = make_mesh((2, 4), ("data", "model"))
        rules = rules_for_mesh(mesh)
        with axis_rules(rules, dict(zip(mesh.axis_names, mesh.devices.shape))), \\
             compat.set_mesh(mesh):
            logits1, cache1 = jax.jit(lambda p, b: model.prefill(p, b, max_len))(
                params, {"tokens": toks})
            l_sp, _ = jax.jit(model.decode_step)(
                params, cache1, jnp.argmax(logits1, -1)[:, None].astype(jnp.int32),
                jnp.asarray(S_, jnp.int32))
        np.testing.assert_allclose(np.asarray(l_sp), np.asarray(l_ref),
                                   rtol=2e-3, atol=2e-3)
        print("OK")
    """, devices=8)


def test_elastic_restore_across_mesh_shapes(tmp_path):
    """Checkpoint on a (4,2) mesh, restore+step on (2,4) — elastic restart."""
    ckpt = str(tmp_path / "elastic")
    save_code = f"""
        import numpy as np, jax, jax.numpy as jnp
        from repro import compat
        from repro.configs import get_smoke_config
        from repro.distributed.partitioning import axis_rules, rules_for_mesh
        from repro.launch import specs as S
        from repro.launch.mesh import make_mesh
        from repro.models import build_model
        from repro.train.step import init_train_state
        from repro.ckpt import save_checkpoint

        cfg = get_smoke_config("qwen3-8b")
        model = build_model(cfg)
        mesh = make_mesh((4, 2), ("data", "model"))
        rules = rules_for_mesh(mesh)
        with axis_rules(rules, dict(zip(mesh.axis_names, mesh.devices.shape))), \\
             compat.set_mesh(mesh):
            state = init_train_state(model, jax.random.PRNGKey(0))
            sh = S.train_state_shardings(mesh, jax.eval_shape(lambda: state))
            state = jax.device_put(state, sh)
            save_checkpoint({ckpt!r}, 3, state)
        print("SAVED")
    """
    restore_code = f"""
        import numpy as np, jax, jax.numpy as jnp
        from repro import compat
        from repro.configs import get_smoke_config
        from repro.distributed.partitioning import axis_rules, rules_for_mesh
        from repro.launch import specs as S
        from repro.launch.mesh import make_mesh
        from repro.models import build_model
        from repro.train import AdamWConfig, make_train_step
        from repro.train.step import init_train_state
        from repro.ckpt import restore_checkpoint

        cfg = get_smoke_config("qwen3-8b")
        model = build_model(cfg)
        mesh = make_mesh((2, 4), ("data", "model"))  # DIFFERENT topology
        rules = rules_for_mesh(mesh)
        with axis_rules(rules, dict(zip(mesh.axis_names, mesh.devices.shape))), \\
             compat.set_mesh(mesh):
            like = jax.eval_shape(
                lambda: init_train_state(model, jax.random.PRNGKey(0)))
            sh = S.train_state_shardings(mesh, like)
            step, state = restore_checkpoint({ckpt!r}, like, shardings=sh)
            assert step == 3, step
            rng = np.random.default_rng(0)
            batch = {{
                "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 64)), jnp.int32),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 64)), jnp.int32),
            }}
            fn = jax.jit(make_train_step(model, AdamWConfig()),
                         in_shardings=(sh, None), out_shardings=(sh, None))
            state, m = fn(state, batch)
            assert np.isfinite(float(m["loss"]))
        print("RESTORED+STEPPED on", mesh.devices.shape)
    """
    assert "SAVED" in run_child(save_code, devices=8)
    assert "RESTORED" in run_child(restore_code, devices=8)


def test_dryrun_cell_on_tiny_mesh():
    """The dry-run driver machinery on an 8-device (2,2,2) multi-pod mesh."""
    run_child("""
        import jax, jax.numpy as jnp
        from repro import compat
        from repro.configs import get_smoke_config
        from repro.configs.base import ShapeSpec
        from repro.distributed.partitioning import axis_rules, rules_for_mesh
        from repro.launch import specs as S
        from repro.launch.mesh import make_mesh
        from repro.models import build_model
        from repro.train import AdamWConfig, make_train_step
        from repro.roofline.analysis import analyze_compiled

        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        rules = rules_for_mesh(mesh)
        cfg = get_smoke_config("gemma3-27b")
        sh = ShapeSpec("t", 128, 8, "train")
        model = build_model(cfg)
        with axis_rules(rules, dict(zip(mesh.axis_names, mesh.devices.shape))), \\
             compat.set_mesh(mesh):
            st = S.train_state_shapes(model, cfg)
            lowered = jax.jit(
                make_train_step(model, AdamWConfig(), grad_accum=2),
                in_shardings=(S.train_state_shardings(mesh, st),
                              S.batch_shardings(mesh, S.train_batch_shapes(cfg, sh))),
                out_shardings=(S.train_state_shardings(mesh, st), None),
            ).lower(st, S.train_batch_shapes(cfg, sh))
            compiled = lowered.compile()
        res = analyze_compiled(compiled, arch="gemma3-smoke", shape="t",
                               mesh_name="2x2x2", n_devices=8, model_flops=1e9)
        t = res.terms()
        assert all(v > 0 for v in t.values()), t
        assert res.collective["total"] > 0
        print("OK", t)
    """, devices=8)
